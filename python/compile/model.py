"""Layer-2: the JAX model — a Llama-family transformer.

Everything a downstream artifact needs is defined here as pure
functions over *flat tuples of arrays* (no pytrees at the export
boundary, so the PJRT call ABI is a plain positional argument list the
rust runtime can drive; ``aot.py`` records the exact order in
``manifest.json``).

Architecture (matches the models the paper prunes, scaled to this
testbed — see DESIGN.md §2):

* RMSNorm (pre-norm), rotary position embeddings, causal MHA,
  SwiGLU MLP, untied embedding / LM head.
* Pruned-linear inventory per block: ``wq wk wv wo w_gate w_up w_down``
  — exactly the seven Llama linears SLaB and the baselines compress.
  ``tok_emb``, ``lm_head`` and norms are never pruned (paper §III-A4).

Param flat order (load-bearing — mirrored by rust ``model::params``):

    tok_emb,
    [per layer: attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down],
    final_norm, lm_head
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels import slab_kernels as K

PAD_ID = 0  # token id 0 is reserved for padding everywhere


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    dim: int
    n_layers: int
    n_heads: int
    ffn: int
    max_seq: int
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self):
        return self.dim // self.n_heads

    def param_names(self):
        names = ["tok_emb"]
        for i in range(self.n_layers):
            names += [
                f"l{i}.attn_norm",
                f"l{i}.wq",
                f"l{i}.wk",
                f"l{i}.wv",
                f"l{i}.wo",
                f"l{i}.mlp_norm",
                f"l{i}.w_gate",
                f"l{i}.w_up",
                f"l{i}.w_down",
            ]
        names += ["final_norm", "lm_head"]
        return names

    def param_shapes(self):
        d, f, v = self.dim, self.ffn, self.vocab
        shapes = [(v, d)]
        for _ in range(self.n_layers):
            shapes += [
                (d,),
                (d, d),
                (d, d),
                (d, d),
                (d, d),
                (d,),
                (f, d),
                (f, d),
                (d, f),
            ]
        shapes += [(d,), (v, d)]
        return shapes

    def pruned_linears(self):
        """(name, (dout, din)) for every linear the pipeline compresses,
        in param order."""
        out = []
        for name, shape in zip(self.param_names(), self.param_shapes()):
            base = name.split(".")[-1]
            if base in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"):
                out.append((name, shape))
        return out

    def n_params(self):
        return sum(int(np.prod(s)) for s in self.param_shapes())


import numpy as np  # noqa: E402  (np only used for static shape math)


# The three evaluation models (stand-ins for Llama-3.2 1B / Llama-2 7B /
# Llama-3 8B — same architecture family, testbed scale; DESIGN.md §2).
CONFIGS = {
    "small": ModelConfig("small", vocab=512, dim=64, n_layers=2, n_heads=4, ffn=176, max_seq=64),
    "base": ModelConfig("base", vocab=512, dim=128, n_layers=4, n_heads=4, ffn=344, max_seq=96),
    "large": ModelConfig("large", vocab=1024, dim=256, n_layers=6, n_heads=8, ffn=688, max_seq=96),
}


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key):
    """Scaled-normal init (GPT-2 style: residual projections down-scaled)."""
    params = []
    for name, shape in zip(cfg.param_names(), cfg.param_shapes()):
        key, sub = jax.random.split(key)
        base = name.split(".")[-1]
        if len(shape) == 1:
            params.append(jnp.ones(shape, jnp.float32))
        else:
            std = 0.02
            if base in ("wo", "w_down"):
                std = 0.02 / math.sqrt(2 * cfg.n_layers)
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _rmsnorm(x, gamma, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * gamma / jnp.sqrt(ms + eps)


def _rope_angles(cfg: ModelConfig, positions):
    """(T, head_dim/2) angles for the given integer positions."""
    half = cfg.head_dim // 2
    inv_freq = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[:, None] * inv_freq[None, :]


def _apply_rope(x, angles):
    """x: (B, T, H, Hd); angles: (T, Hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _split_layer(cfg, params, i):
    base = 1 + i * 9
    return params[base : base + 9]


def _attention(cfg, q, k, v, mask):
    """q,k,v: (B, T, H, Hd) / (B, S, H, Hd); mask: (T, S) additive."""
    scale = 1.0 / math.sqrt(cfg.head_dim)
    scores = jnp.einsum("bthd,bshd->bhts", q, k) * scale
    scores = scores + mask[None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, v)
    return out.reshape(out.shape[0], out.shape[1], cfg.dim)


def _block(cfg, layer_params, h, angles, mask):
    (attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down) = layer_params
    bsz, t, _ = h.shape
    x = _rmsnorm(h, attn_norm, cfg.norm_eps)
    q = (x @ wq.T).reshape(bsz, t, cfg.n_heads, cfg.head_dim)
    k = (x @ wk.T).reshape(bsz, t, cfg.n_heads, cfg.head_dim)
    v = (x @ wv.T).reshape(bsz, t, cfg.n_heads, cfg.head_dim)
    q = _apply_rope(q, angles)
    k = _apply_rope(k, angles)
    h = h + _attention(cfg, q, k, v, mask) @ wo.T
    x = _rmsnorm(h, mlp_norm, cfg.norm_eps)
    h = h + (jax.nn.silu(x @ w_gate.T) * (x @ w_up.T)) @ w_down.T
    return h


def forward(cfg: ModelConfig, params, tokens):
    """tokens (B, T) int32 → logits (B, T, vocab)."""
    bsz, t = tokens.shape
    tok_emb, final_norm, lm_head = params[0], params[-2], params[-1]
    h = jnp.take(tok_emb, tokens, axis=0)
    positions = jnp.arange(t)
    angles = _rope_angles(cfg, positions)
    mask = jnp.where(
        jnp.arange(t)[None, :] <= jnp.arange(t)[:, None], 0.0, -1e30
    ).astype(jnp.float32)
    for i in range(cfg.n_layers):
        h = _block(cfg, _split_layer(cfg, params, i), h, angles, mask)
    h = _rmsnorm(h, final_norm, cfg.norm_eps)
    return h @ lm_head.T


# ---------------------------------------------------------------------------
# Loss / eval
# ---------------------------------------------------------------------------


def loss_fn(cfg: ModelConfig, params, tokens):
    """tokens (B, T+1): causal LM loss, PAD targets masked. Scalar mean."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets != PAD_ID).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def eval_nll(cfg: ModelConfig, params, tokens):
    """tokens (B, T+1) → (nll_sum (B,), token_count (B,)).

    Rust accumulates these across batches for corpus perplexity
    ``exp(Σ nll / Σ count)``.
    """
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets != PAD_ID).astype(jnp.float32)
    return jnp.sum(nll * mask, axis=1), jnp.sum(mask, axis=1)


# ---------------------------------------------------------------------------
# AdamW train step
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    peak_lr: float = 3e-3
    warmup: int = 30
    total_steps: int = 600
    min_lr_frac: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip: float = 1.0


def _lr_schedule(hp: TrainHyper, step):
    warm = hp.peak_lr * (step + 1.0) / hp.warmup
    progress = jnp.clip((step - hp.warmup) / max(hp.total_steps - hp.warmup, 1), 0.0, 1.0)
    cos = hp.peak_lr * (hp.min_lr_frac + (1 - hp.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
    return jnp.where(step < hp.warmup, warm, cos)


def train_step(cfg: ModelConfig, hp: TrainHyper, params, m, v, step, tokens):
    """One AdamW step. All state positional; returns
    ``(loss, new_params..., new_m..., new_v...)`` flattened by aot.py."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(list(params))
    # Global-norm clip.
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
    scale = jnp.minimum(1.0, hp.clip / jnp.maximum(gnorm, 1e-9))
    grads = [g * scale for g in grads]
    lr = _lr_schedule(hp, step.astype(jnp.float32))
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - hp.beta1**t
    bc2 = 1.0 - hp.beta2**t
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = hp.beta1 * mi + (1 - hp.beta1) * g
        vi = hp.beta2 * vi + (1 - hp.beta2) * g * g
        update = (mi / bc1) / (jnp.sqrt(vi / bc2) + hp.eps)
        # Decoupled weight decay on matrices only (norms exempt).
        wd = hp.weight_decay if p.ndim > 1 else 0.0
        new_params.append(p - lr * (update + wd * p))
        new_m.append(mi)
        new_v.append(vi)
    return loss, new_params, new_m, new_v


# ---------------------------------------------------------------------------
# KV-cache serving path (prefill + decode)
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params, tokens):
    """tokens (B, T) → (last_logits (B, vocab), k_cache, v_cache).

    Caches are (L, B, max_seq, H, Hd), zero-padded beyond T. PAD
    positions are masked out of attention by key masking (PAD_ID keys
    still enter the cache but their scores are −inf for queries at
    other positions only via the causal mask — prompts are
    left-aligned so this matches standard serving).
    """
    bsz, t = tokens.shape
    tok_emb, final_norm, lm_head = params[0], params[-2], params[-1]
    h = jnp.take(tok_emb, tokens, axis=0)
    angles = _rope_angles(cfg, jnp.arange(t))
    causal = jnp.where(jnp.arange(t)[None, :] <= jnp.arange(t)[:, None], 0.0, -1e30)
    # PAD keys masked for all queries (prompt padding on the right).
    key_ok = (tokens != PAD_ID)[:, None, None, :]  # (B,1,1,T)
    k_cache = jnp.zeros((cfg.n_layers, bsz, cfg.max_seq, cfg.n_heads, cfg.head_dim), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    for i in range(cfg.n_layers):
        (attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down) = _split_layer(cfg, params, i)
        x = _rmsnorm(h, attn_norm, cfg.norm_eps)
        q = _apply_rope((x @ wq.T).reshape(bsz, t, cfg.n_heads, cfg.head_dim), angles)
        k = _apply_rope((x @ wk.T).reshape(bsz, t, cfg.n_heads, cfg.head_dim), angles)
        v = (x @ wv.T).reshape(bsz, t, cfg.n_heads, cfg.head_dim)
        k_cache = k_cache.at[i, :, :t].set(k)
        v_cache = v_cache.at[i, :, :t].set(v)
        scores = jnp.einsum("bthd,bshd->bhts", q, k) * scale
        scores = scores + causal[None, None, :, :]
        scores = jnp.where(key_ok, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(bsz, t, cfg.dim)
        h = h + att @ wo.T
        x = _rmsnorm(h, mlp_norm, cfg.norm_eps)
        h = h + (jax.nn.silu(x @ w_gate.T) * (x @ w_up.T)) @ w_down.T
    h = _rmsnorm(h, final_norm, cfg.norm_eps)
    return h[:, -1] @ lm_head.T, k_cache, v_cache


def decode_step(cfg: ModelConfig, params, k_cache, v_cache, token, pos):
    """One token for every sequence in the batch.

    token (B,) int32, pos scalar int32 (same position for the whole
    batch — the dynamic batcher aligns sequences; see rust
    ``coordinator::serve``). Returns (logits (B, vocab), k_cache,
    v_cache) with position ``pos`` written.
    """
    bsz = token.shape[0]
    tok_emb, final_norm, lm_head = params[0], params[-2], params[-1]
    h = jnp.take(tok_emb, token, axis=0)[:, None, :]  # (B, 1, D)
    angles = _rope_angles(cfg, pos[None])  # (1, Hd/2)
    valid = (jnp.arange(cfg.max_seq)[None, :] <= pos)[:, None, :]  # (1,1,S)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    for i in range(cfg.n_layers):
        (attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down) = _split_layer(cfg, params, i)
        x = _rmsnorm(h, attn_norm, cfg.norm_eps)
        q = _apply_rope((x @ wq.T).reshape(bsz, 1, cfg.n_heads, cfg.head_dim), angles)
        k = _apply_rope((x @ wk.T).reshape(bsz, 1, cfg.n_heads, cfg.head_dim), angles)
        v = (x @ wv.T).reshape(bsz, 1, cfg.n_heads, cfg.head_dim)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k[None, :, :], (i, 0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v[None, :, :], (i, 0, pos, 0, 0))
        ks, vs = k_cache[i], v_cache[i]  # (B, S, H, Hd)
        scores = jnp.einsum("bthd,bshd->bhts", q, ks) * scale  # (B,H,1,S)
        scores = jnp.where(valid[:, :, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("bhts,bshd->bthd", probs, vs).reshape(bsz, 1, cfg.dim)
        h = h + att @ wo.T
        x = _rmsnorm(h, mlp_norm, cfg.norm_eps)
        h = h + (jax.nn.silu(x @ w_gate.T) * (x @ w_up.T)) @ w_down.T
    h = _rmsnorm(h, final_norm, cfg.norm_eps)
    return h[:, 0] @ lm_head.T, k_cache, v_cache


# ---------------------------------------------------------------------------
# SLaB-compressed forward (calls the L1 Pallas kernel)
# ---------------------------------------------------------------------------


def slab_param_names(cfg: ModelConfig):
    """Flat arg order of the compressed forward: for every param, the
    dense array if unpruned, else the (ws, u, v, b) quadruple."""
    names = []
    for name, shape in zip(cfg.param_names(), cfg.param_shapes()):
        base = name.split(".")[-1]
        if base in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"):
            names += [f"{name}.ws", f"{name}.u", f"{name}.v", f"{name}.b"]
        else:
            names.append(name)
    return names


def slab_forward(cfg: ModelConfig, slab_params, tokens):
    """Compressed-model forward: every pruned linear runs through the
    Pallas :func:`compile.kernels.slab_kernels.slab_linear` kernel —
    this is the L1→L2 composition the AOT bundle proves end-to-end.

    ``slab_params`` follows :func:`slab_param_names` order.
    tokens (B, T) → logits (B, T, vocab).
    """
    it = iter(slab_params)

    def take_dense():
        return next(it)

    def take_linear():
        ws, u, v, b = next(it), next(it), next(it), next(it)

        def apply(x):
            flat = x.reshape(-1, x.shape[-1])
            y = K.slab_linear(flat, ws, u, v, b)
            return y.reshape(*x.shape[:-1], ws.shape[0])

        return apply

    tok_emb = take_dense()
    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            dict(
                attn_norm=take_dense(),
                wq=take_linear(),
                wk=take_linear(),
                wv=take_linear(),
                wo=take_linear(),
                mlp_norm=take_dense(),
                w_gate=take_linear(),
                w_up=take_linear(),
                w_down=take_linear(),
            )
        )
    final_norm = take_dense()
    lm_head = take_dense()

    bsz, t = tokens.shape
    h = jnp.take(tok_emb, tokens, axis=0)
    angles = _rope_angles(cfg, jnp.arange(t))
    mask = jnp.where(jnp.arange(t)[None, :] <= jnp.arange(t)[:, None], 0.0, -1e30)
    for lp in layers:
        x = _rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        q = lp["wq"](x).reshape(bsz, t, cfg.n_heads, cfg.head_dim)
        k = lp["wk"](x).reshape(bsz, t, cfg.n_heads, cfg.head_dim)
        v = lp["wv"](x).reshape(bsz, t, cfg.n_heads, cfg.head_dim)
        q = _apply_rope(q, angles)
        k = _apply_rope(k, angles)
        h = h + lp["wo"](_attention(cfg, q, k, v, mask))
        x = _rmsnorm(h, lp["mlp_norm"], cfg.norm_eps)
        h = h + lp["w_down"](jax.nn.silu(lp["w_gate"](x)) * lp["w_up"](x))
    h = _rmsnorm(h, final_norm, cfg.norm_eps)
    return h @ lm_head.T
