"""AOT exporter: lower every Layer-1/Layer-2 computation to HLO text.

Run once by ``make artifacts``; the rust binary is self-contained
afterwards. Python never runs on the request path.

Interchange is HLO **text** (not serialized HloModuleProto): jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md
and aot_recipe.md).

Outputs in ``--outdir``:

* ``train_step_{cfg}.hlo.txt``   (loss, params', m', v') ← (params, m, v, step, tokens[B,T+1])
* ``eval_nll_{cfg}.hlo.txt``     (nll_sum[B], count[B]) ← (params, tokens[B,T+1])
* ``prefill_{cfg}.hlo.txt``      (logits[B,V], kc, vc) ← (params, tokens[B,T])
* ``decode_step_{cfg}.hlo.txt``  (logits[B,V], kc', vc') ← (params, kc, vc, token[B], pos)
* ``slab_fwd_{cfg}.hlo.txt``     logits[B,T,V] ← (slab_params, tokens[B,T])   [Pallas L1]
* ``decompose_{dout}x{din}.hlo.txt``  (w_s, u, v, w_b) ← (w, sx, keep_frac, iters)  [Pallas L1]
* ``slab_linear_{dout}x{din}.hlo.txt`` y ← (x, ws, u, v, b)                  [Pallas L1]
* ``manifest.json``              the ABI contract consumed by rust runtime/
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import decompose as D
from . import model as M
from .kernels import slab_kernels as K

# Export-time constants (recorded in the manifest; rust must use the
# same values when building literals).
TRAIN_BATCH = 8
EVAL_BATCH = 8
SERVE_BATCH = 4
KERNEL_BENCH_BATCH = 32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape=()):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


class Exporter:
    def __init__(self, outdir):
        self.outdir = outdir
        self.artifacts = {}
        os.makedirs(outdir, exist_ok=True)

    def export(self, name, fn, example_args, inputs, outputs):
        """Lower ``fn`` at ``example_args`` and write ``{name}.hlo.txt``."""
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(self.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        self.artifacts[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": inputs,
            "outputs": outputs,
        }
        print(f"  wrote {name}.hlo.txt ({len(text) / 1024:.0f} KiB)")


def export_config(ex: Exporter, cfg: M.ModelConfig, hp: M.TrainHyper):
    names = cfg.param_names()
    shapes = cfg.param_shapes()
    P = len(names)
    t_train = cfg.max_seq

    # ---- train_step -----------------------------------------------------
    def train_flat(*args):
        params = list(args[:P])
        m = list(args[P : 2 * P])
        v = list(args[2 * P : 3 * P])
        step, tokens = args[3 * P], args[3 * P + 1]
        loss, np_, nm, nv = M.train_step(cfg, hp, params, m, v, step, tokens)
        return (loss, *np_, *nm, *nv)

    par = [f32(s) for s in shapes]
    ex.export(
        f"train_step_{cfg.name}",
        train_flat,
        par + par + par + [i32(), i32((TRAIN_BATCH, t_train + 1))],
        inputs=[{"name": n, **spec(s)} for n, s in zip(names, shapes)]
        + [{"name": f"m.{n}", **spec(s)} for n, s in zip(names, shapes)]
        + [{"name": f"v.{n}", **spec(s)} for n, s in zip(names, shapes)]
        + [
            {"name": "step", **spec((), "i32")},
            {"name": "tokens", **spec((TRAIN_BATCH, t_train + 1), "i32")},
        ],
        outputs=[{"name": "loss", **spec(())}]
        + [{"name": n, **spec(s)} for n, s in zip(names, shapes)]
        + [{"name": f"m.{n}", **spec(s)} for n, s in zip(names, shapes)]
        + [{"name": f"v.{n}", **spec(s)} for n, s in zip(names, shapes)],
    )

    # ---- eval_nll ---------------------------------------------------------
    def eval_flat(*args):
        params = list(args[:P])
        tokens = args[P]
        return M.eval_nll(cfg, params, tokens)

    ex.export(
        f"eval_nll_{cfg.name}",
        eval_flat,
        par + [i32((EVAL_BATCH, t_train + 1))],
        inputs=[{"name": n, **spec(s)} for n, s in zip(names, shapes)]
        + [{"name": "tokens", **spec((EVAL_BATCH, t_train + 1), "i32")}],
        outputs=[
            {"name": "nll_sum", **spec((EVAL_BATCH,))},
            {"name": "count", **spec((EVAL_BATCH,))},
        ],
    )

    # ---- prefill / decode --------------------------------------------------
    cache_shape = (cfg.n_layers, SERVE_BATCH, cfg.max_seq, cfg.n_heads, cfg.head_dim)
    prompt_len = cfg.max_seq // 2

    def prefill_flat(*args):
        params = list(args[:P])
        tokens = args[P]
        return M.prefill(cfg, params, tokens)

    ex.export(
        f"prefill_{cfg.name}",
        prefill_flat,
        par + [i32((SERVE_BATCH, prompt_len))],
        inputs=[{"name": n, **spec(s)} for n, s in zip(names, shapes)]
        + [{"name": "tokens", **spec((SERVE_BATCH, prompt_len), "i32")}],
        outputs=[
            {"name": "logits", **spec((SERVE_BATCH, cfg.vocab))},
            {"name": "k_cache", **spec(cache_shape)},
            {"name": "v_cache", **spec(cache_shape)},
        ],
    )

    def decode_flat(*args):
        params = list(args[:P])
        kc, vc, token, pos = args[P], args[P + 1], args[P + 2], args[P + 3]
        return M.decode_step(cfg, params, kc, vc, token, pos)

    ex.export(
        f"decode_step_{cfg.name}",
        decode_flat,
        par + [f32(cache_shape), f32(cache_shape), i32((SERVE_BATCH,)), i32()],
        inputs=[{"name": n, **spec(s)} for n, s in zip(names, shapes)]
        + [
            {"name": "k_cache", **spec(cache_shape)},
            {"name": "v_cache", **spec(cache_shape)},
            {"name": "token", **spec((SERVE_BATCH,), "i32")},
            {"name": "pos", **spec((), "i32")},
        ],
        outputs=[
            {"name": "logits", **spec((SERVE_BATCH, cfg.vocab))},
            {"name": "k_cache", **spec(cache_shape)},
            {"name": "v_cache", **spec(cache_shape)},
        ],
    )

    # ---- layer-wise pipeline: embed + block-capture + gram ------------------
    # The coordinator's one-shot pruning loop (paper §II-A.1) forwards
    # calibration batches block by block, capturing the inputs of every
    # pruned linear. Within a block, the four distinct activation
    # sources are: x_attn (feeds wq/wk/wv), att_out (feeds wo),
    # x_mlp (feeds w_gate/w_up), mlp_inner (feeds w_down).
    bsz_cal = EVAL_BATCH
    t_cal = cfg.max_seq

    def embed_flat(tok_emb, tokens):
        return (jnp.take(tok_emb, tokens, axis=0),)

    ex.export(
        f"embed_{cfg.name}",
        embed_flat,
        [f32((cfg.vocab, cfg.dim)), i32((bsz_cal, t_cal))],
        inputs=[
            {"name": "tok_emb", **spec((cfg.vocab, cfg.dim))},
            {"name": "tokens", **spec((bsz_cal, t_cal), "i32")},
        ],
        outputs=[{"name": "h", **spec((bsz_cal, t_cal, cfg.dim))}],
    )

    def block_capture_flat(*args):
        layer_params = list(args[:9])
        h = args[9]
        import math as _math

        (attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down) = layer_params
        bsz, t, _ = h.shape
        angles = M._rope_angles(cfg, jnp.arange(t))
        mask = jnp.where(jnp.arange(t)[None, :] <= jnp.arange(t)[:, None], 0.0, -1e30)
        x_attn = M._rmsnorm(h, attn_norm, cfg.norm_eps)
        q = M._apply_rope(
            (x_attn @ wq.T).reshape(bsz, t, cfg.n_heads, cfg.head_dim), angles
        )
        k = M._apply_rope(
            (x_attn @ wk.T).reshape(bsz, t, cfg.n_heads, cfg.head_dim), angles
        )
        v = (x_attn @ wv.T).reshape(bsz, t, cfg.n_heads, cfg.head_dim)
        att_out = M._attention(cfg, q, k, v, mask)
        h = h + att_out @ wo.T
        x_mlp = M._rmsnorm(h, mlp_norm, cfg.norm_eps)
        mlp_inner = jax.nn.silu(x_mlp @ w_gate.T) * (x_mlp @ w_up.T)
        h = h + mlp_inner @ w_down.T
        return h, x_attn, att_out, x_mlp, mlp_inner

    layer_shapes = [
        (cfg.dim,),
        (cfg.dim, cfg.dim),
        (cfg.dim, cfg.dim),
        (cfg.dim, cfg.dim),
        (cfg.dim, cfg.dim),
        (cfg.dim,),
        (cfg.ffn, cfg.dim),
        (cfg.ffn, cfg.dim),
        (cfg.dim, cfg.ffn),
    ]
    layer_names = [
        "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down",
    ]
    ex.export(
        f"block_capture_{cfg.name}",
        block_capture_flat,
        [f32(s) for s in layer_shapes] + [f32((bsz_cal, t_cal, cfg.dim))],
        inputs=[{"name": n, **spec(s)} for n, s in zip(layer_names, layer_shapes)]
        + [{"name": "h", **spec((bsz_cal, t_cal, cfg.dim))}],
        outputs=[
            {"name": "h_out", **spec((bsz_cal, t_cal, cfg.dim))},
            {"name": "x_attn", **spec((bsz_cal, t_cal, cfg.dim))},
            {"name": "att_out", **spec((bsz_cal, t_cal, cfg.dim))},
            {"name": "x_mlp", **spec((bsz_cal, t_cal, cfg.dim))},
            {"name": "mlp_inner", **spec((bsz_cal, t_cal, cfg.ffn))},
        ],
    )

    # ---- slab_fwd (compressed forward through the Pallas kernel) -----------
    slab_names = M.slab_param_names(cfg)
    slab_shapes = []
    for name, shape in zip(names, shapes):
        base = name.split(".")[-1]
        if base in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"):
            dout, din = shape
            slab_shapes += [(dout, din), (dout,), (din,), (dout, din)]
        else:
            slab_shapes.append(shape)

    def slab_flat(*args):
        sp = list(args[:-1])
        tokens = args[-1]
        return M.slab_forward(cfg, sp, tokens)

    ex.export(
        f"slab_fwd_{cfg.name}",
        slab_flat,
        [f32(s) for s in slab_shapes] + [i32((SERVE_BATCH, prompt_len))],
        inputs=[{"name": n, **spec(s)} for n, s in zip(slab_names, slab_shapes)]
        + [{"name": "tokens", **spec((SERVE_BATCH, prompt_len), "i32")}],
        outputs=[{"name": "logits", **spec((SERVE_BATCH, prompt_len, cfg.vocab))}],
    )


def export_gram_kernels(ex: Exporter, din_rows):
    """Per distinct (din, rows): streaming XᵀX accumulation for the
    SparseGPT Hessian (native rust gram is too slow at Din³ scale)."""
    for din, rows in sorted(din_rows):
        ex.export(
            f"gram_{rows}x{din}",
            lambda x: (x.T @ x,),
            [f32((rows, din))],
            inputs=[{"name": "x", **spec((rows, din))}],
            outputs=[{"name": "gram", **spec((din, din))}],
        )


def export_shape_kernels(ex: Exporter, shapes):
    """Per distinct pruned-linear shape: decompose + standalone kernel."""
    for dout, din in sorted(shapes):
        ex.export(
            f"decompose_{dout}x{din}",
            D.decompose_fn,
            [f32((dout, din)), f32((din,)), f32(()), i32(())],
            inputs=[
                {"name": "w", **spec((dout, din))},
                {"name": "sx", **spec((din,))},
                {"name": "keep_frac", **spec(())},
                {"name": "iters", **spec((), "i32")},
            ],
            outputs=[
                {"name": "w_s", **spec((dout, din))},
                {"name": "u", **spec((dout,))},
                {"name": "v", **spec((din,))},
                {"name": "w_b", **spec((dout, din))},
            ],
        )
        b = KERNEL_BENCH_BATCH
        ex.export(
            f"slab_linear_{dout}x{din}",
            lambda x, ws, u, v, bm: (K.slab_linear(x, ws, u, v, bm),),
            [f32((b, din)), f32((dout, din)), f32((dout,)), f32((din,)), f32((dout, din))],
            inputs=[
                {"name": "x", **spec((b, din))},
                {"name": "ws", **spec((dout, din))},
                {"name": "u", **spec((dout,))},
                {"name": "v", **spec((din,))},
                {"name": "b", **spec((dout, din))},
            ],
            outputs=[{"name": "y", **spec((b, dout))}],
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--configs",
        default="small,base,large",
        help="comma-separated model configs to export",
    )
    args = ap.parse_args()

    ex = Exporter(args.outdir)
    hp = M.TrainHyper()
    cfg_names = [c for c in args.configs.split(",") if c]
    shapes = set()
    grams = set()
    for cname in cfg_names:
        cfg = M.CONFIGS[cname]
        print(f"[aot] exporting config '{cname}' "
              f"({cfg.n_layers}L d={cfg.dim} ffn={cfg.ffn} vocab={cfg.vocab})")
        export_config(ex, cfg, hp)
        for _, shape in cfg.pruned_linears():
            shapes.add(shape)
        rows = EVAL_BATCH * cfg.max_seq
        grams.add((cfg.dim, rows))
        grams.add((cfg.ffn, rows))
    print(f"[aot] exporting {len(shapes)} shape kernels + {len(grams)} gram kernels")
    export_shape_kernels(ex, shapes)
    export_gram_kernels(ex, grams)

    manifest = {
        "format": "slab-aot-v1",
        "constants": {
            "train_batch": TRAIN_BATCH,
            "eval_batch": EVAL_BATCH,
            "serve_batch": SERVE_BATCH,
            "kernel_bench_batch": KERNEL_BENCH_BATCH,
            "pad_id": M.PAD_ID,
        },
        "train_hyper": {
            "peak_lr": hp.peak_lr,
            "warmup": hp.warmup,
            "total_steps": hp.total_steps,
            "min_lr_frac": hp.min_lr_frac,
            "beta1": hp.beta1,
            "beta2": hp.beta2,
            "eps": hp.eps,
            "weight_decay": hp.weight_decay,
            "clip": hp.clip,
        },
        "configs": {
            cname: {
                "vocab": M.CONFIGS[cname].vocab,
                "dim": M.CONFIGS[cname].dim,
                "n_layers": M.CONFIGS[cname].n_layers,
                "n_heads": M.CONFIGS[cname].n_heads,
                "ffn": M.CONFIGS[cname].ffn,
                "max_seq": M.CONFIGS[cname].max_seq,
                "prompt_len": M.CONFIGS[cname].max_seq // 2,
                "param_names": M.CONFIGS[cname].param_names(),
                "param_shapes": [list(s) for s in M.CONFIGS[cname].param_shapes()],
                "pruned": [
                    {"name": n, "shape": list(s)}
                    for n, s in M.CONFIGS[cname].pruned_linears()
                ],
                "slab_param_names": M.slab_param_names(M.CONFIGS[cname]),
            }
            for cname in cfg_names
        },
        "artifacts": ex.artifacts,
    }
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] manifest.json with {len(ex.artifacts)} artifacts")


if __name__ == "__main__":
    main()
