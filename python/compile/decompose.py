"""Layer-2: Algorithm 1 as a jitted JAX computation.

One AOT artifact per distinct pruned-linear shape serves *all*
compression ratios and iteration counts: ``keep_frac`` and ``iters``
are runtime scalars (the thresholding is rank-based so a traced `k`
works; the outer loop is a ``lax.while_loop`` on a traced bound).

The fused elementwise pass (sign / low-rank-binary residual / Wanda
score) is the L1 Pallas kernel
:func:`compile.kernels.slab_kernels.slab_residual_score`; the rank-1
power iteration and the per-row rank-based threshold are XLA ops
(sorts and reductions are VPU work, not MXU work — DESIGN.md §3).

Group geometry is traced at the paper default ``(1, Din)``. The
Table II group-shape sweep uses the bit-compatible rust-native path.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import slab_kernels as K

SVD_ITERS = 30  # static power-iteration count (matches ref.py default)


def _rank1_abs_power(y):
    """√σ-split rank-1 tSVD of |y| — ones-init power iteration,
    identical to ref.rank1_abs_svd_ref but with a static fori_loop."""
    a = jnp.abs(y)
    dout, din = a.shape

    def body(_, uv):
        u, v = uv
        u = a @ v
        u = u / jnp.maximum(jnp.linalg.norm(u), 1e-20)
        v = a.T @ u
        v = v / jnp.maximum(jnp.linalg.norm(v), 1e-20)
        return (u, v)

    u0 = jnp.ones((dout,), a.dtype)
    v0 = jnp.ones((din,), a.dtype) / jnp.sqrt(din)
    u, v = lax.fori_loop(0, SVD_ITERS, body, (u0, v0))
    sigma = u @ (a @ v)
    root = jnp.sqrt(jnp.maximum(sigma, 0.0))
    return u * root, v * root


def _row_topk_mask(scores, keep_frac):
    """Per-row keep mask with traced keep fraction.

    Rank-based: stable argsort-of-argsort gives each element its rank
    by (score desc, index asc); keep rank < ⌊keep_frac · Din⌋. Matches
    rust ``group_topk_mask`` tie-breaking.
    """
    din = scores.shape[1]
    k = jnp.floor(keep_frac * din).astype(jnp.int32)
    order = jnp.argsort(-scores, axis=1, stable=True)
    ranks = jnp.argsort(order, axis=1, stable=True)
    return (ranks < k).astype(scores.dtype)


def decompose_fn(w, sx, keep_frac, iters):
    """Algorithm 1. Returns (w_s, u, v, w_b).

    Args:
      w: (Dout, Din) f32 — the layer weight.
      sx: (Din,) f32 — calibration column norms ``||X_j||₂``.
      keep_frac: f32 scalar — Eq. 10 keep fraction (runtime input).
      iters: i32 scalar — alternating iterations `s` (runtime input).
    """
    dout, din = w.shape

    def body(state):
        t, w_s, _, _, _ = state
        y_bl = w - w_s
        u, v = _rank1_abs_power(y_bl)
        # Fused Pallas pass: sign, residual, score.
        w_b, y_s, scores = K.slab_residual_score(w, w_s, u, v, sx)
        mask = _row_topk_mask(scores, keep_frac)
        return (t + 1, y_s * mask, u, v, w_b)

    def cond(state):
        return state[0] < jnp.maximum(iters, 1)

    init = (
        jnp.int32(0),
        jnp.zeros_like(w),
        jnp.zeros((dout,), w.dtype),
        jnp.zeros((din,), w.dtype),
        jnp.ones_like(w),
    )
    _, w_s, u, v, w_b = lax.while_loop(cond, body, init)
    return w_s, u, v, w_b
