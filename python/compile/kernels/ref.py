"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal).

Each function here is the mathematically transparent reference; the
Pallas kernels in ``slab_kernels.py`` must match these to float32
tolerance under the pytest/hypothesis sweeps in ``python/tests/``.
"""

import jax.nn
import jax.numpy as jnp


def slab_linear_ref(x, ws, u, v, b):
    """Compressed SLaB forward: ``y = x·W_Sᵀ + u ⊙ ((x ⊙ v)·Bᵀ)``.

    Args:
      x:  (B, Din) activations.
      ws: (Dout, Din) sparse component (dense storage, zeros at pruned).
      u:  (Dout,) left rank-1 factor (√σ-split).
      v:  (Din,) right rank-1 factor.
      b:  (Dout, Din) ±1 sign matrix (float).

    Returns:
      (B, Dout)
    """
    sparse_term = x @ ws.T
    binary_term = (x * v[None, :]) @ b.T  # (B, Dout)
    return sparse_term + binary_term * u[None, :]


def slab_linear_dense_equiv(x, ws, u, v, b):
    """Same value via the dense reconstruction ``Ŵ = W_S + (u vᵀ) ⊙ B``.

    Identity check: ``slab_linear_ref == x @ Ŵᵀ``.
    """
    w_hat = ws + jnp.outer(u, v) * b
    return x @ w_hat.T


def wanda_scores_ref(y, sx):
    """``S_ij = |Y_ij| · ||X_j||₂`` with sx = per-column activation norms."""
    return jnp.abs(y) * sx[None, :]


def group_threshold_ref(scores, keep_frac):
    """Per-row top-⌊keep_frac·Din⌋ keep mask (comparison group (1, Din)).

    Ties broken toward lower column index, matching the rust
    ``group_topk_mask`` (stable ordering on (score desc, index asc)).
    """
    dout, din = scores.shape
    keep = int(keep_frac * din)
    if keep <= 0:
        return jnp.zeros_like(scores)
    if keep >= din:
        return jnp.ones_like(scores)
    # Rank with index tiebreak: sort by (-score, +index).
    order = jnp.argsort(-scores, axis=1, stable=True)
    ranks = jnp.argsort(order, axis=1, stable=True)
    return (ranks < keep).astype(scores.dtype)


def rank1_abs_svd_ref(y, n_iter=30):
    """√σ-split rank-1 truncated SVD of |y| via power iteration.

    Deterministic: starts from the all-ones vector (|y| is entrywise
    non-negative, so the Perron vector has non-negative overlap with
    ones and power iteration converges to it).

    Returns (u, v) with |y| ≈ outer(u, v).
    """
    a = jnp.abs(y)
    dout, din = a.shape
    v = jnp.ones((din,), a.dtype) / jnp.sqrt(din)
    u = jnp.ones((dout,), a.dtype)
    for _ in range(n_iter):
        u = a @ v
        un = jnp.linalg.norm(u)
        u = u / jnp.maximum(un, 1e-20)
        v = a.T @ u
        sigma = jnp.linalg.norm(v)
        v = v / jnp.maximum(sigma, 1e-20)
    sigma = u @ (a @ v)
    root = jnp.sqrt(jnp.maximum(sigma, 0.0))
    return u * root, v * root


def slab_decompose_step_ref(w, w_s, sx, keep_frac, svd_iters=30):
    """One iteration of Algorithm 1 (lines 5–8), the pure-jnp oracle.

    Returns (w_s', u, v, w_b).
    """
    y_bl = w - w_s
    w_b = jnp.where(y_bl >= 0, 1.0, -1.0).astype(w.dtype)
    u, v = rank1_abs_svd_ref(y_bl, svd_iters)
    lb = jnp.outer(u, v) * w_b
    y_s = w - lb
    scores = wanda_scores_ref(y_s, sx)
    mask = group_threshold_ref(scores, keep_frac)
    return y_s * mask, u, v, w_b


def slab_decompose_ref(w, sx, keep_frac, iters=20, svd_iters=30):
    """Full Algorithm 1 oracle."""
    w_s = jnp.zeros_like(w)
    u = v = w_b = None
    for _ in range(max(int(iters), 1)):
        w_s, u, v, w_b = slab_decompose_step_ref(w, w_s, sx, keep_frac, svd_iters)
    return w_s, u, v, w_b


def rmsnorm_ref(x, gamma, eps=1e-5):
    """RMSNorm over the last axis."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * gamma / jnp.sqrt(ms + eps)


def swiglu_ref(x, w_gate, w_up, w_down):
    """SwiGLU MLP: ``down( silu(x·gateᵀ) ⊙ (x·upᵀ) )``."""
    g = x @ w_gate.T
    return (jax.nn.silu(g) * (x @ w_up.T)) @ w_down.T
