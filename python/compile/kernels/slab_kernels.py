"""Layer-1 Pallas kernels — the SLaB compute hot-spots.

Two kernels:

* :func:`slab_linear` — the deployment forward
  ``y = x·W_Sᵀ + u ⊙ ((x ⊙ v)·Bᵀ)``. Tiled for the TPU memory
  hierarchy: the grid walks (batch-tile, dout-tile) MXU output tiles
  and streams Din in VMEM-sized chunks. The ±1 matrix `B` enters the
  MXU as a regular (bf16/f32) operand — the TPU win is *bandwidth*
  (1 bit/elem from HBM), which the BlockSpec schedule expresses by
  tiling 16× more `B` columns per step than fp16 weights would allow
  (see DESIGN.md §Hardware-Adaptation).

* :func:`slab_residual_score` — the fused elementwise pass of
  Algorithm 1 (lines 5 + 7): ``W_B = sign(W − W_S)``,
  ``Y_S = W − (u vᵀ) ⊙ W_B``, ``S = |Y_S| ⊙ S_X`` in one VMEM
  round-trip. The top-k thresholding (line 8) is XLA `sort` territory
  (VPU, not MXU) and stays in the L2 jax graph.

Both kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode *is* the
correctness path; TPU performance is estimated analytically
(EXPERIMENTS.md §Perf). Correctness is pinned against ``ref.py`` by
``python/tests/test_kernels.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tiles. Every model dim in configs.py is a
# multiple of these (or of the fallbacks chosen in _tile()).
BLOCK_B = 8
BLOCK_OUT = 128
BLOCK_IN = 128


def _tile(dim, pref):
    """Largest divisor of ``dim`` that is ≤ ``pref`` (tiles must divide)."""
    t = min(pref, dim)
    while dim % t != 0:
        t -= 1
    return t


# ---------------------------------------------------------------------------
# slab_linear
# ---------------------------------------------------------------------------


def _slab_linear_kernel(x_ref, ws_ref, u_ref, v_ref, b_ref, o_ref, *, n_in_tiles):
    """One (block_b, block_out) output tile.

    Refs (VMEM views picked by the BlockSpecs):
      x_ref:  (block_b, Din)       — full contraction stripe of x
      ws_ref: (block_out, Din)     — sparse-component stripe
      u_ref:  (block_out,)         — rank-1 left factor slice
      v_ref:  (Din,)               — rank-1 right factor
      b_ref:  (block_out, Din)     — ±1 stripe
      o_ref:  (block_b, block_out)
    """
    x = x_ref[...]
    v = v_ref[...]
    # Sparse term: x · W_Sᵀ  (MXU matmul; W_S is dense-stored here —
    # the CSR gather path is the rust-native variant).
    acc = jnp.dot(x, ws_ref[...].T, preferred_element_type=jnp.float32)
    # Rank-1-binary term: (x ⊙ v) · Bᵀ, then row-scale by u.
    xv = x * v[None, :]
    binary = jnp.dot(xv, b_ref[...].T, preferred_element_type=jnp.float32)
    acc = acc + binary * u_ref[...][None, :]
    o_ref[...] = acc.astype(o_ref.dtype)


def slab_linear(x, ws, u, v, b, *, block_b=BLOCK_B, block_out=BLOCK_OUT, interpret=True):
    """Compressed SLaB linear layer: ``(B, Din) → (B, Dout)``.

    Matches :func:`compile.kernels.ref.slab_linear_ref`.
    """
    bsz, din = x.shape
    dout, din2 = ws.shape
    assert din == din2, (din, din2)
    assert u.shape == (dout,) and v.shape == (din,)
    assert b.shape == (dout, din)

    bb = _tile(bsz, block_b)
    bo = _tile(dout, block_out)
    grid = (bsz // bb, dout // bo)

    return pl.pallas_call(
        functools.partial(_slab_linear_kernel, n_in_tiles=1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, din), lambda i, j: (i, 0)),
            pl.BlockSpec((bo, din), lambda i, j: (j, 0)),
            pl.BlockSpec((bo,), lambda i, j: (j,)),
            pl.BlockSpec((din,), lambda i, j: (0,)),
            pl.BlockSpec((bo, din), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, dout), x.dtype),
        interpret=interpret,
    )(x, ws, u, v, b)


# ---------------------------------------------------------------------------
# slab_residual_score (fused Algorithm-1 elementwise pass)
# ---------------------------------------------------------------------------


def _residual_score_kernel(w_ref, ws_ref, u_ref, v_ref, sx_ref, wb_ref, ys_ref, s_ref):
    """Fused: sign, low-rank-binary residual, Wanda score — one pass.

    Refs: (block_out, Din) stripes of w / w_s plus broadcast factors.
    """
    w = w_ref[...]
    y_bl = w - ws_ref[...]
    wb = jnp.where(y_bl >= 0, 1.0, -1.0).astype(w.dtype)
    lb = (u_ref[...][:, None] * v_ref[...][None, :]) * wb
    ys = w - lb
    wb_ref[...] = wb
    ys_ref[...] = ys
    s_ref[...] = jnp.abs(ys) * sx_ref[...][None, :]


def slab_residual_score(w, w_s, u, v, sx, *, block_out=BLOCK_OUT, interpret=True):
    """Fused lines 5+7 of Algorithm 1.

    Returns ``(w_b, y_s, scores)``; matches the composition of the
    ``ref.py`` oracles (sign / residual / wanda_scores).
    """
    dout, din = w.shape
    assert w_s.shape == (dout, din)
    assert u.shape == (dout,) and v.shape == (din,) and sx.shape == (din,)

    bo = _tile(dout, block_out)
    grid = (dout // bo,)

    return pl.pallas_call(
        _residual_score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bo, din), lambda i: (i, 0)),
            pl.BlockSpec((bo, din), lambda i: (i, 0)),
            pl.BlockSpec((bo,), lambda i: (i,)),
            pl.BlockSpec((din,), lambda i: (0,)),
            pl.BlockSpec((din,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bo, din), lambda i: (i, 0)),
            pl.BlockSpec((bo, din), lambda i: (i, 0)),
            pl.BlockSpec((bo, din), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dout, din), w.dtype),
            jax.ShapeDtypeStruct((dout, din), w.dtype),
            jax.ShapeDtypeStruct((dout, din), w.dtype),
        ],
        interpret=interpret,
    )(w, w_s, u, v, sx)


# ---------------------------------------------------------------------------
# VMEM / roofline estimator (used by DESIGN.md §9 and bench reporting)
# ---------------------------------------------------------------------------


def slab_linear_vmem_bytes(block_b, block_out, din, dtype_bytes=2, b_bits=1):
    """VMEM working-set estimate for one slab_linear grid step.

    x tile + ws stripe + b stripe (at its *deployed* width) + factors
    + output tile. Used to verify the schedule fits the ~16 MiB TPU
    VMEM budget and to compute the HBM-bytes ratio vs a dense layer.
    """
    x_tile = block_b * din * dtype_bytes
    ws_stripe = block_out * din * dtype_bytes  # dense-stored here
    b_stripe = block_out * din * b_bits // 8
    factors = (block_out + din) * dtype_bytes
    out_tile = block_b * block_out * 4  # f32 accumulator
    return x_tile + ws_stripe + b_stripe + factors + out_tile


def dense_linear_hbm_bytes(dout, din, dtype_bytes=2):
    """Per-forward HBM weight traffic of the dense layer."""
    return dout * din * dtype_bytes


def slab_linear_hbm_bytes(dout, din, keep_frac, rank=1, dtype_bytes=2, idx_bytes=2):
    """Per-forward HBM weight traffic of the SLaB layer (CSR + bits +
    factors)."""
    k = int(keep_frac * dout * din)
    csr = k * (dtype_bytes + idx_bytes)
    bits = dout * din // 8
    factors = rank * (dout + din) * dtype_bytes
    return csr + bits + factors
