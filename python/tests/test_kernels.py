"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/seeds; assert_allclose at float32 tolerance.
This is the core correctness signal for everything the AOT bundle
ships (DESIGN.md §7).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels import slab_kernels as K

DIMS = st.sampled_from([8, 16, 24, 64, 96, 128, 176])
BATCH = st.sampled_from([1, 2, 8, 17, 32])


def make_inputs(rng, bsz, dout, din, sparse_frac=0.5):
    x = rng.normal(size=(bsz, din)).astype(np.float32)
    ws = (rng.normal(size=(dout, din)) * (rng.random((dout, din)) > sparse_frac)).astype(
        np.float32
    )
    u = rng.random(dout).astype(np.float32)
    v = rng.random(din).astype(np.float32)
    b = np.where(rng.normal(size=(dout, din)) >= 0, 1.0, -1.0).astype(np.float32)
    return map(jnp.asarray, (x, ws, u, v, b))


class TestSlabLinear:
    @settings(max_examples=20, deadline=None)
    @given(bsz=BATCH, dout=DIMS, din=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, bsz, dout, din, seed):
        rng = np.random.default_rng(seed)
        x, ws, u, v, b = make_inputs(rng, bsz, dout, din)
        got = K.slab_linear(x, ws, u, v, b)
        want = ref.slab_linear_ref(x, ws, u, v, b)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_matches_dense_reconstruction(self):
        rng = np.random.default_rng(7)
        x, ws, u, v, b = make_inputs(rng, 4, 64, 96)
        got = K.slab_linear(x, ws, u, v, b)
        want = ref.slab_linear_dense_equiv(x, ws, u, v, b)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_zero_u_collapses_to_sparse(self):
        rng = np.random.default_rng(8)
        x, ws, _, v, b = make_inputs(rng, 4, 32, 48)
        u0 = jnp.zeros((32,), jnp.float32)
        got = K.slab_linear(x, ws, u0, v, b)
        assert_allclose(np.asarray(got), np.asarray(x @ ws.T), rtol=1e-5, atol=1e-5)

    def test_block_size_invariance(self):
        rng = np.random.default_rng(9)
        x, ws, u, v, b = make_inputs(rng, 16, 128, 128)
        y1 = K.slab_linear(x, ws, u, v, b, block_b=8, block_out=128)
        y2 = K.slab_linear(x, ws, u, v, b, block_b=16, block_out=32)
        # Different tilings reassociate the f32 accumulation; allow ulp-
        # level drift scaled by the accumulator magnitude.
        assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)

    def test_odd_shapes_fall_back_to_divisor_tiles(self):
        rng = np.random.default_rng(10)
        x, ws, u, v, b = make_inputs(rng, 3, 33, 7)
        got = K.slab_linear(x, ws, u, v, b)
        want = ref.slab_linear_ref(x, ws, u, v, b)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


class TestResidualScore:
    @settings(max_examples=20, deadline=None)
    @given(dout=DIMS, din=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_composition(self, dout, din, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(size=(dout, din)), jnp.float32)
        ws = jnp.asarray(
            rng.normal(size=(dout, din)) * (rng.random((dout, din)) > 0.5), jnp.float32
        )
        u = jnp.asarray(rng.random(dout), jnp.float32)
        v = jnp.asarray(rng.random(din), jnp.float32)
        sx = jnp.asarray(rng.random(din) + 0.1, jnp.float32)

        wb, ys, s = K.slab_residual_score(w, ws, u, v, sx)

        y_bl = w - ws
        wb_ref = jnp.where(y_bl >= 0, 1.0, -1.0)
        ys_ref = w - jnp.outer(u, v) * wb_ref
        s_ref = ref.wanda_scores_ref(ys_ref, sx)
        assert_allclose(np.asarray(wb), np.asarray(wb_ref), rtol=0, atol=0)
        assert_allclose(np.asarray(ys), np.asarray(ys_ref), rtol=1e-5, atol=1e-5)
        assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-5, atol=1e-5)

    def test_sign_of_zero_is_positive(self):
        w = jnp.zeros((8, 8), jnp.float32)
        ws = jnp.zeros_like(w)
        z = jnp.zeros((8,), jnp.float32)
        wb, _, _ = K.slab_residual_score(w, ws, z, z, z)
        assert np.all(np.asarray(wb) == 1.0)


class TestVmemEstimator:
    def test_slab_traffic_below_dense(self):
        dense = K.dense_linear_hbm_bytes(4096, 4096)
        slab = K.slab_linear_hbm_bytes(4096, 4096, keep_frac=0.4355)
        assert slab < dense
        # At 70% CR the ratio should exceed 2x (DESIGN.md §9).
        slab70 = K.slab_linear_hbm_bytes(4096, 4096, keep_frac=0.2355)
        assert dense / slab70 > 1.8

    def test_vmem_fits_tpu_budget(self):
        # One grid step of the default schedule must fit 16 MiB VMEM.
        assert K.slab_linear_vmem_bytes(8, 128, 4096) < 16 * 1024 * 1024
