"""L2 model checks: shapes, loss behaviour, train-step descent,
prefill/decode vs full-forward agreement, compressed-forward identity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M

CFG = M.ModelConfig("test", vocab=64, dim=32, n_layers=2, n_heads=4, ffn=48, max_seq=16)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def random_tokens(rng, bsz, t, vocab):
    # never PAD (=0) inside the sequence body for these tests
    return jnp.asarray(rng.integers(1, vocab, size=(bsz, t)), jnp.int32)


class TestForward:
    def test_shapes(self, params):
        rng = np.random.default_rng(0)
        tokens = random_tokens(rng, 3, 10, CFG.vocab)
        logits = M.forward(CFG, params, tokens)
        assert logits.shape == (3, 10, CFG.vocab)
        assert np.all(np.isfinite(np.asarray(logits)))

    def test_causality(self, params):
        # Changing a future token must not affect past logits.
        rng = np.random.default_rng(1)
        tokens = random_tokens(rng, 1, 12, CFG.vocab)
        l1 = M.forward(CFG, params, tokens)
        tokens2 = tokens.at[0, 8].set((tokens[0, 8] % (CFG.vocab - 1)) + 1)
        l2 = M.forward(CFG, params, tokens2)
        assert_allclose(np.asarray(l1[:, :8]), np.asarray(l2[:, :8]), rtol=1e-5, atol=1e-5)
        assert not np.allclose(np.asarray(l1[:, 8:]), np.asarray(l2[:, 8:]))

    def test_param_inventory(self):
        names = CFG.param_names()
        shapes = CFG.param_shapes()
        # tok_emb + 9/layer + final_norm + lm_head
        assert len(names) == len(shapes) == 3 + 9 * CFG.n_layers
        assert len(CFG.pruned_linears()) == 7 * CFG.n_layers
        # No embedding/head/norm in the pruned set (paper §III-A4).
        for n, _ in CFG.pruned_linears():
            assert "emb" not in n and "head" not in n and "norm" not in n


class TestLoss:
    def test_masks_padding(self, params):
        rng = np.random.default_rng(2)
        tokens = random_tokens(rng, 2, 9, CFG.vocab)
        # Padding the tail must not change the masked mean loss much
        # beyond removing those terms: compare explicit slice.
        padded = jnp.concatenate(
            [tokens, jnp.zeros((2, 4), jnp.int32)], axis=1
        )
        full = M.loss_fn(CFG, params, padded)
        assert np.isfinite(float(full))

    def test_uniform_init_loss_near_log_vocab(self, params):
        rng = np.random.default_rng(3)
        tokens = random_tokens(rng, 4, CFG.max_seq, CFG.vocab)
        loss = float(M.loss_fn(CFG, params, tokens))
        # Fresh init ≈ uniform predictions → loss ≈ ln(vocab).
        assert abs(loss - np.log(CFG.vocab)) < 0.5, loss


class TestTrainStep:
    def test_loss_descends(self, params):
        hp = M.TrainHyper(peak_lr=1e-2, warmup=2, total_steps=50)
        rng = np.random.default_rng(4)
        tokens = random_tokens(rng, 4, CFG.max_seq + 1, CFG.vocab)
        p = [jnp.array(x) for x in params]
        m = [jnp.zeros_like(x) for x in p]
        v = [jnp.zeros_like(x) for x in p]
        step_fn = jax.jit(
            lambda p, m, v, s, t: M.train_step(CFG, hp, p, m, v, s, t)
        )
        losses = []
        for s in range(30):
            loss, p, m, v = step_fn(p, m, v, jnp.int32(s), tokens)
            losses.append(float(loss))
        # Memorizing one batch: the loss must drop substantially.
        assert losses[-1] < losses[0] * 0.6, losses[::6]

    def test_state_shapes_preserved(self, params):
        hp = M.TrainHyper()
        rng = np.random.default_rng(5)
        tokens = random_tokens(rng, 2, CFG.max_seq + 1, CFG.vocab)
        m = [jnp.zeros_like(x) for x in params]
        v = [jnp.zeros_like(x) for x in params]
        loss, p2, m2, v2 = M.train_step(CFG, hp, params, m, v, jnp.int32(0), tokens)
        for a, b in zip(params, p2):
            assert a.shape == b.shape


class TestEvalNll:
    def test_accumulates_per_row(self, params):
        rng = np.random.default_rng(6)
        tokens = random_tokens(rng, 3, CFG.max_seq + 1, CFG.vocab)
        nll, cnt = M.eval_nll(CFG, params, tokens)
        assert nll.shape == (3,) and cnt.shape == (3,)
        assert np.all(np.asarray(cnt) == CFG.max_seq)
        # Cross-check one row against loss_fn on that row.
        row = tokens[:1]
        loss = float(M.loss_fn(CFG, params, row))
        assert abs(float(nll[0]) / float(cnt[0]) - loss) < 1e-4

    def test_padding_rows(self, params):
        rng = np.random.default_rng(7)
        tokens = random_tokens(rng, 2, CFG.max_seq + 1, CFG.vocab)
        tokens = tokens.at[1, 5:].set(M.PAD_ID)
        _, cnt = M.eval_nll(CFG, params, tokens)
        assert float(cnt[1]) == 4.0  # targets 1..4 (positions 5.. padded)


class TestServingPath:
    def test_prefill_matches_forward(self, params):
        rng = np.random.default_rng(8)
        t = 8
        tokens = random_tokens(rng, 2, t, CFG.vocab)
        logits_full = M.forward(CFG, params, tokens)[:, -1]
        logits_pre, kc, vc = M.prefill(CFG, params, tokens)
        assert_allclose(np.asarray(logits_pre), np.asarray(logits_full), rtol=1e-4, atol=1e-4)
        assert kc.shape == (CFG.n_layers, 2, CFG.max_seq, CFG.n_heads, CFG.head_dim)

    def test_decode_matches_forward(self, params):
        # prefill(t) + decode(t), decode(t+1) must equal full forward.
        rng = np.random.default_rng(9)
        t = 6
        full = random_tokens(rng, 2, t + 2, CFG.vocab)
        prompt = full[:, :t]
        _, kc, vc = M.prefill(CFG, params, prompt)
        l1, kc, vc = M.decode_step(CFG, params, kc, vc, full[:, t], jnp.int32(t))
        l2, _, _ = M.decode_step(CFG, params, kc, vc, full[:, t + 1], jnp.int32(t + 1))
        ref_logits = M.forward(CFG, params, full)
        assert_allclose(np.asarray(l1), np.asarray(ref_logits[:, t]), rtol=2e-3, atol=2e-3)
        assert_allclose(np.asarray(l2), np.asarray(ref_logits[:, t + 1]), rtol=2e-3, atol=2e-3)


class TestSlabForward:
    def test_identity_when_components_encode_dense(self, params):
        """Encode each pruned linear exactly as (ws=W, u=0, v=0, b=1):
        the compressed forward must equal the dense forward."""
        slab_params = []
        for name, p in zip(CFG.param_names(), params):
            base = name.split(".")[-1]
            if base in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"):
                dout, din = p.shape
                slab_params += [
                    p,
                    jnp.zeros((dout,), jnp.float32),
                    jnp.zeros((din,), jnp.float32),
                    jnp.ones((dout, din), jnp.float32),
                ]
            else:
                slab_params.append(p)
        rng = np.random.default_rng(10)
        tokens = random_tokens(rng, 2, 8, CFG.vocab)
        dense = M.forward(CFG, params, tokens)
        comp = M.slab_forward(CFG, slab_params, tokens)
        assert_allclose(np.asarray(comp), np.asarray(dense), rtol=1e-4, atol=1e-4)

    def test_slab_param_names_cover_all(self):
        names = M.slab_param_names(CFG)
        # tok_emb + final_norm + lm_head stay dense; per layer: 2 norms +
        # 7 linears × 4 components = 30 entries.
        assert len(names) == 3 + 30 * CFG.n_layers
