"""Algorithm-1 correctness: the jitted decompose graph vs the oracle,
plus the invariants the paper's analysis promises (Prop. 1/2, Fig. 3
premise, Eq. 10 sparsity accounting)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import decompose as D
from compile.kernels import ref


def setup(seed, dout=48, din=96):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(0.05 * rng.normal(size=(dout, din)), jnp.float32)
    sx = jnp.asarray(rng.random(din) + 0.5, jnp.float32)
    return w, sx


class TestDecomposeGraph:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_matches_oracle(self, seed):
        w, sx = setup(seed)
        keep = 0.4355
        ws, u, v, wb = D.decompose_fn(w, sx, jnp.float32(keep), jnp.int32(4))
        ws_r, u_r, v_r, wb_r = ref.slab_decompose_ref(w, sx, keep, iters=4, svd_iters=D.SVD_ITERS)
        assert_allclose(np.asarray(ws), np.asarray(ws_r), rtol=1e-4, atol=1e-5)
        assert_allclose(np.asarray(u), np.asarray(u_r), rtol=1e-3, atol=1e-4)
        assert_allclose(np.asarray(v), np.asarray(v_r), rtol=1e-3, atol=1e-4)
        assert_allclose(np.asarray(wb), np.asarray(wb_r), rtol=0, atol=0)

    def test_wb_is_pm1(self):
        w, sx = setup(1)
        _, _, _, wb = D.decompose_fn(w, sx, jnp.float32(0.4), jnp.int32(3))
        vals = np.unique(np.asarray(wb))
        assert set(vals.tolist()) <= {-1.0, 1.0}

    def test_wl_nonnegative_prop2(self):
        w, sx = setup(2)
        _, u, v, _ = D.decompose_fn(w, sx, jnp.float32(0.4), jnp.int32(5))
        wl = np.outer(np.asarray(u), np.asarray(v))
        assert wl.min() >= -1e-5

    def test_sparsity_exact_eq10(self):
        w, sx = setup(3)
        keep = 0.4355
        ws, _, _, _ = D.decompose_fn(w, sx, jnp.float32(keep), jnp.int32(3))
        per_row = int(keep * w.shape[1])
        nnz = np.count_nonzero(np.asarray(ws), axis=1)
        assert np.all(nnz == per_row)

    def test_error_decreases_with_iters(self):
        w, sx = setup(4)

        def err(iters):
            ws, u, v, wb = D.decompose_fn(w, sx, jnp.float32(0.4355), jnp.int32(iters))
            w_hat = np.asarray(ws) + np.outer(np.asarray(u), np.asarray(v)) * np.asarray(wb)
            return np.linalg.norm(np.asarray(w) - w_hat)

        e1, e10 = err(1), err(10)
        assert e10 <= e1 + 1e-6, (e1, e10)

    def test_beats_wanda_fig3_premise(self):
        # rank-1 SLaB error < rank-0 (Wanda) error at the same keep.
        w, sx = setup(5)
        keep = 0.4355
        ws, u, v, wb = D.decompose_fn(w, sx, jnp.float32(keep), jnp.int32(5))
        w_hat = np.asarray(ws) + np.outer(np.asarray(u), np.asarray(v)) * np.asarray(wb)
        e_slab = np.linalg.norm(np.asarray(w) - w_hat)
        scores = ref.wanda_scores_ref(w, sx)
        mask = ref.group_threshold_ref(scores, keep)
        e_wanda = np.linalg.norm(np.asarray(w) - np.asarray(w * mask))
        assert e_slab < e_wanda

    def test_dynamic_keep_frac(self):
        # One artifact serves all CRs: different traced keep fractions
        # through the same jitted function give correct sparsity.
        w, sx = setup(6)
        import jax

        f = jax.jit(D.decompose_fn)
        for keep in [0.2, 0.3355, 0.4355]:
            ws, _, _, _ = f(w, sx, jnp.float32(keep), jnp.int32(2))
            per_row = int(np.floor(keep * w.shape[1]))
            assert np.all(np.count_nonzero(np.asarray(ws), axis=1) == per_row), keep


class TestRefOracles:
    def test_rank1_svd_matches_numpy(self):
        rng = np.random.default_rng(11)
        a = np.abs(rng.normal(size=(32, 48))).astype(np.float32)
        u, v = ref.rank1_abs_svd_ref(jnp.asarray(a), n_iter=60)
        rec = np.outer(np.asarray(u), np.asarray(v))
        un, s, vt = np.linalg.svd(a)
        rec_np = s[0] * np.outer(un[:, 0], vt[0])
        # Same rank-1 approximation (sign-canonical: both non-negative).
        assert_allclose(rec, np.abs(rec_np), rtol=5e-3, atol=5e-3)

    def test_group_threshold_keeps_exact(self):
        rng = np.random.default_rng(12)
        s = jnp.asarray(rng.random((5, 40)), jnp.float32)
        mask = ref.group_threshold_ref(s, 0.25)
        assert np.all(np.asarray(mask).sum(axis=1) == 10)

    @settings(max_examples=15, deadline=None)
    @given(
        keep=st.floats(0.05, 0.95),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_group_threshold_property(self, keep, seed):
        rng = np.random.default_rng(seed)
        s = jnp.asarray(rng.random((7, 33)), jnp.float32)
        mask = np.asarray(ref.group_threshold_ref(s, keep))
        k = int(keep * 33)
        assert np.all(mask.sum(axis=1) == k)
        # Kept entries all score ≥ dropped entries per row.
        for i in range(7):
            row = np.asarray(s)[i]
            if 0 < k < 33:
                assert row[mask[i] == 1].min() >= row[mask[i] == 0].max() - 1e-6
