//! The SLaB decomposition — the paper's core contribution.
//!
//! * [`config`] — CR accounting (Eq. 9/10), hyperparameters.
//! * [`scores`] — activation-aware (Wanda) scoring.
//! * [`threshold`] — group-wise hard thresholding + N:M composition.
//! * [`decompose`] — Algorithm 1 (alternating optimization).
//! * [`refine`] — activation-weighted joint refinement of a
//!   decomposition (the opt-in quality stage; DESIGN.md §16).
//! * [`layer`] — packed CSR + rank-1 + bitplane deployment format.
//! * [`ablation`] — Table III component ablations.

pub mod ablation;
pub mod config;
pub mod decompose;
pub mod layer;
pub mod refine;
pub mod scores;
pub mod threshold;

pub use ablation::{ablate, AblationOut, Variant};
pub use config::{GroupShape, SlabConfig, Structure};
pub use decompose::{decompose, decompose_par, Decomposition};
pub use layer::SlabLayer;
pub use refine::{refine, refine_table, RefineConfig, RefineReport};
pub use scores::{wanda_scores, wanda_scores_par, weighted_frob_norm, ActStats};
pub use threshold::{group_topk_mask, semi_structured_mask};
