//! The SLaB decomposition — the paper's core contribution.
//!
//! * [`config`] — CR accounting (Eq. 9/10), hyperparameters.
//! * [`scores`] — activation-aware (Wanda) scoring.
//! * [`threshold`] — group-wise hard thresholding + N:M composition.
//! * [`decompose`] — Algorithm 1 (alternating optimization).
//! * [`layer`] — packed CSR + rank-1 + bitplane deployment format.
//! * [`ablation`] — Table III component ablations.

pub mod ablation;
pub mod config;
pub mod decompose;
pub mod layer;
pub mod scores;
pub mod threshold;

pub use ablation::{ablate, AblationOut, Variant};
pub use config::{GroupShape, SlabConfig, Structure};
pub use decompose::{decompose, decompose_par, Decomposition};
pub use layer::SlabLayer;
pub use scores::{wanda_scores, wanda_scores_par, ActStats};
pub use threshold::{group_topk_mask, semi_structured_mask};
