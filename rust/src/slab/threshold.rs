//! Group-wise hard thresholding (paper `HardThreshold` + §II-B2).
//!
//! Scores compete inside comparison groups of shape `(gr, gc)`; each
//! group keeps its top `⌊keep_frac · |group|⌋` scorers. The default
//! Wanda geometry `(1, Din)` keeps `⌊k/Dout⌋` per output row. Uses
//! `select_nth_unstable` (O(n) per group) rather than a full sort —
//! this is the pipeline's hottest native loop at decompose time.

use crate::sparse::NmPattern;
use crate::tensor::Mat;

/// Keep-mask (1.0 = keep) with exactly `⌊keep_frac·group_size⌋` ones
/// per full group. Ragged edge groups (when dims don't divide) keep
/// the floor of the same fraction of their actual size.
pub fn group_topk_mask(scores: &Mat, keep_frac: f64, gr: usize, gc: usize) -> Mat {
    assert!((0.0..=1.0).contains(&keep_frac), "keep_frac {keep_frac}");
    let (rows, cols) = scores.shape();
    let gr = gr.clamp(1, rows);
    let gc = gc.clamp(1, cols);
    let mut mask = Mat::zeros(rows, cols);
    // Scratch: (score, flat_offset_in_group) pairs.
    let mut buf: Vec<(f32, u32)> = Vec::with_capacity(gr * gc);
    let mut r0 = 0;
    while r0 < rows {
        let r1 = (r0 + gr).min(rows);
        let mut c0 = 0;
        while c0 < cols {
            let c1 = (c0 + gc).min(cols);
            let size = (r1 - r0) * (c1 - c0);
            let keep = ((keep_frac * size as f64).floor() as usize).min(size);
            if keep == size {
                for i in r0..r1 {
                    for j in c0..c1 {
                        mask.set(i, j, 1.0);
                    }
                }
            } else if keep > 0 {
                buf.clear();
                for i in r0..r1 {
                    let row = scores.row(i);
                    for j in c0..c1 {
                        let off = ((i - r0) * (c1 - c0) + (j - c0)) as u32;
                        buf.push((row[j], off));
                    }
                }
                // Partition so the top-`keep` land in the head.
                buf.select_nth_unstable_by(keep - 1, |a, b| {
                    b.0.partial_cmp(&a.0)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.1.cmp(&b.1))
                });
                for &(_, off) in buf[..keep].iter() {
                    let i = r0 + off as usize / (c1 - c0);
                    let j = c0 + off as usize % (c1 - c0);
                    mask.set(i, j, 1.0);
                }
            }
            c0 = c1;
        }
        r0 = r1;
    }
    mask
}

/// The paper's semi-structured composition (§II-B2): apply the N:M
/// pattern to the scores first, then group-wise top-k *within the
/// N:M survivors* to reach the (lower) target keep fraction.
pub fn semi_structured_mask(
    scores: &Mat,
    keep_frac: f64,
    pattern: NmPattern,
    gr: usize,
    gc: usize,
) -> Mat {
    let nm = pattern.mask_from_scores(scores);
    // Suppress scores outside the N:M mask so the group top-k can only
    // pick N:M survivors; NEG_INFINITY guarantees exclusion even for
    // all-negative score matrices (scores are ≥ 0 in practice).
    let gated = scores.zip(&nm, |s, m| if m != 0.0 { s } else { f32::NEG_INFINITY });
    let mask = group_topk_mask(&gated, keep_frac, gr, gc);
    // Defensive intersection (keeps the invariant even when keep_frac
    // exceeds the pattern density).
    mask.hadamard(&nm)
}

/// Scores of a layer sorted descending — the per-layer primitive of
/// the budget allocator's water-filling pass (`coordinator::budget`).
/// Deterministic for the non-negative finite scores Wanda produces.
pub fn sorted_scores_desc(scores: &Mat) -> Vec<f32> {
    let mut s = scores.data.clone();
    s.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
    s
}

/// Kept-energy curve of a descending-sorted score slice:
/// `curve[k] = Σ_{i<k} s_(i)²` (f64 accumulation), for `k = 0..=n`.
///
/// For *pruning-only* selection the squared activation-weighted
/// reconstruction error at keep budget `k` is exactly the dropped
/// score energy `curve[n] − curve[k]` — a Wanda score is
/// `|W_ij|·s_j`, so `Σ_dropped (W_ij·s_j)² = Σ_dropped score²`. The
/// budget allocator probes layer sensitivity and water-fills against
/// this curve; for the full sparse+low-rank+binary decomposition it
/// is a proxy (the low-rank part absorbs part of the drop), which is
/// why the pipeline re-measures the true weighted error per layer
/// after decomposing.
pub fn kept_energy_curve(sorted: &[f32]) -> Vec<f64> {
    let mut curve = Vec::with_capacity(sorted.len() + 1);
    let mut acc = 0.0f64;
    curve.push(0.0);
    for &s in sorted {
        acc += s as f64 * s as f64;
        curve.push(acc);
    }
    curve
}

/// Count of kept elements per full group that `group_topk_mask`
/// guarantees — exposed for tests and CR verification.
pub fn kept_per_group(keep_frac: f64, gr: usize, gc: usize) -> usize {
    (keep_frac * (gr * gc) as f64).floor() as usize
}

/// Naive full-sort variant of [`group_topk_mask`] (per-row groups
/// only). Kept as the ablation reference for the `select_nth_unstable`
/// optimization — `bench_decompose` measures both; EXPERIMENTS.md §Perf
/// records the delta. Results are identical (same tie-break order).
pub fn group_topk_mask_sort(scores: &Mat, keep_frac: f64) -> Mat {
    let (rows, cols) = scores.shape();
    let keep = ((keep_frac * cols as f64).floor() as usize).min(cols);
    let mut mask = Mat::zeros(rows, cols);
    let mut idx: Vec<usize> = Vec::with_capacity(cols);
    for i in 0..rows {
        let row = scores.row(i);
        idx.clear();
        idx.extend(0..cols);
        idx.sort_by(|&a, &b| {
            row[b]
                .partial_cmp(&row[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        for &j in idx.iter().take(keep) {
            mask.set(i, j, 1.0);
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{PATTERN_2_4, PATTERN_4_8};
    use crate::util::rng::Pcg64;

    #[test]
    fn keeps_exact_count_per_row_group() {
        let mut rng = Pcg64::seed_from_u64(80);
        let s = Mat::rand_uniform(8, 32, 0.0, 1.0, &mut rng);
        let mask = group_topk_mask(&s, 0.25, 1, 32);
        for i in 0..8 {
            let kept = mask.row(i).iter().filter(|&&v| v != 0.0).count();
            assert_eq!(kept, 8);
        }
    }

    #[test]
    fn keeps_highest_scorers() {
        let s = Mat::from_vec(1, 6, vec![0.9, 0.1, 0.8, 0.2, 0.7, 0.3]);
        let mask = group_topk_mask(&s, 0.5, 1, 6);
        assert_eq!(mask.data, vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn column_groups() {
        // Group (1, 4): two groups per row of 8 cols; keep 50% = 2 each.
        let s = Mat::from_vec(1, 8, vec![9.0, 8.0, 1.0, 2.0, 1.0, 2.0, 9.0, 8.0]);
        let mask = group_topk_mask(&s, 0.5, 1, 4);
        assert_eq!(mask.data, vec![1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn multirow_groups() {
        // Group (2, 2) on a 2x2 matrix: one group, keep 25% = 1 element.
        let s = Mat::from_vec(2, 2, vec![1.0, 5.0, 3.0, 2.0]);
        let mask = group_topk_mask(&s, 0.25, 2, 2);
        assert_eq!(mask.data, vec![0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn extremes() {
        let s = Mat::filled(4, 4, 1.0);
        assert_eq!(group_topk_mask(&s, 0.0, 1, 4).count_nonzero(), 0);
        assert_eq!(group_topk_mask(&s, 1.0, 1, 4).count_nonzero(), 16);
    }

    #[test]
    fn ties_are_broken_deterministically() {
        let s = Mat::filled(1, 8, 0.5);
        let m1 = group_topk_mask(&s, 0.5, 1, 8);
        let m2 = group_topk_mask(&s, 0.5, 1, 8);
        assert_eq!(m1, m2);
        assert_eq!(m1.count_nonzero(), 4);
    }

    #[test]
    fn energy_curve_matches_dropped_score_energy_of_topk() {
        // The allocator's exactness claim for pruning-only selection:
        // dropped score energy at keep k == squared weighted error of
        // keeping the top-k scorers.
        let mut rng = Pcg64::seed_from_u64(82);
        let s = Mat::rand_uniform(1, 16, 0.0, 1.0, &mut rng);
        let sorted = sorted_scores_desc(&s);
        assert!(sorted.windows(2).all(|w| w[0] >= w[1]), "descending");
        let curve = kept_energy_curve(&sorted);
        assert_eq!(curve.len(), 17);
        assert_eq!(curve[0], 0.0);
        for k in [0usize, 4, 9, 16] {
            let mask = group_topk_mask(&s, k as f64 / 16.0, 1, 16);
            let dropped: f64 = s
                .data
                .iter()
                .zip(mask.data.iter())
                .filter(|(_, &m)| m == 0.0)
                .map(|(&v, _)| v as f64 * v as f64)
                .sum();
            let want = curve[16] - curve[k];
            assert!(
                (dropped - want).abs() <= 1e-9 * (1.0 + want),
                "k={k}: dropped {dropped} vs curve {want}"
            );
        }
        // Monotone non-decreasing curve.
        assert!(curve.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn semi_structured_respects_both_constraints() {
        let mut rng = Pcg64::seed_from_u64(81);
        let s = Mat::rand_uniform(16, 64, 0.0, 1.0, &mut rng);
        for pat in [PATTERN_2_4, PATTERN_4_8] {
            // keep 43.55% < pattern density 50%.
            let keep = 0.4355;
            let mask = semi_structured_mask(&s, keep, pat, 1, 64);
            pat.validate(&mask).unwrap();
            for i in 0..16 {
                let kept = mask.row(i).iter().filter(|&&v| v != 0.0).count();
                assert_eq!(kept, (keep * 64.0).floor() as usize, "{} row {i}", pat.name());
            }
        }
    }

    #[test]
    fn prop_exact_keep_count_random() {
        let mut rng = Pcg64::seed_from_u64(82);
        for _ in 0..100 {
            let rows = 1 + rng.below_usize(20);
            let cols = 1 + rng.below_usize(60);
            let gr = 1 + rng.below_usize(rows);
            let gc = 1 + rng.below_usize(cols);
            let frac = rng.next_f64();
            let s = Mat::randn(rows, cols, 1.0, &mut rng);
            let mask = group_topk_mask(&s, frac, gr, gc);
            // Verify every full group keeps exactly floor(frac*size).
            let mut r0 = 0;
            while r0 < rows {
                let r1 = (r0 + gr).min(rows);
                let mut c0 = 0;
                while c0 < cols {
                    let c1 = (c0 + gc).min(cols);
                    let size = (r1 - r0) * (c1 - c0);
                    let expect = (frac * size as f64).floor() as usize;
                    let got: usize = (r0..r1)
                        .map(|i| (c0..c1).filter(|&j| mask.at(i, j) != 0.0).count())
                        .sum();
                    assert_eq!(got, expect, "group ({r0},{c0}) size {size} frac {frac}");
                    c0 = c1;
                }
                r0 = r1;
            }
        }
    }

    #[test]
    fn sort_variant_is_equivalent() {
        let mut rng = Pcg64::seed_from_u64(84);
        for _ in 0..20 {
            let rows = 1 + rng.below_usize(12);
            let cols = 1 + rng.below_usize(64);
            let frac = rng.next_f64();
            let s = Mat::randn(rows, cols, 1.0, &mut rng);
            let fast = group_topk_mask(&s, frac, 1, cols);
            let slow = group_topk_mask_sort(&s, frac);
            assert_eq!(fast, slow, "rows={rows} cols={cols} frac={frac}");
        }
    }

    #[test]
    fn mask_values_are_binary() {
        let mut rng = Pcg64::seed_from_u64(83);
        let s = Mat::randn(10, 10, 1.0, &mut rng);
        let mask = group_topk_mask(&s, 0.3, 2, 5);
        assert!(mask.data.iter().all(|&v| v == 0.0 || v == 1.0));
    }
}
