//! Joint refinement of a SLaB decomposition (ROADMAP item 2; the
//! HASSLE-free direction of PAPERS.md).
//!
//! Algorithm 1 fits `W_L` with a *plain* truncated SVD of `|W − W_S|`
//! — the activation statistics only enter through the Wanda mask.
//! [`refine`] runs additional alternating-minimization rounds over an
//! already-decomposed layer, but under the **activation-weighted
//! metric** `‖(W − Ŵ)·diag(s)‖_F` the evaluation actually cares
//! about (`s` = the RMS activation norms of [`ActStats`]):
//!
//! 1. re-threshold the binary part: `W_B ← sign(W − W_S)`;
//! 2. re-fit the rank-r factors against the sparse residual under the
//!    weighted metric — the weighted problem
//!    `min ‖(|W − W_S| − W_L)·diag(s)‖_F` is solved *exactly* by the
//!    truncated SVD of the column-scaled matrix `|W − W_S|·diag(s)`
//!    followed by unscaling the right factors by `1/s`;
//! 3. re-select the sparse mask against the new low-rank-binary
//!    residual (same group-wise Wanda thresholding as Algorithm 1).
//!
//! **Contracts** (DESIGN.md §16, pinned by the tests below and at the
//! job level):
//! * *identity* — `rounds = 0` returns the input decomposition
//!   bit-identically;
//! * *monotonicity* — the per-round weighted error trace never
//!   increases: a round whose re-selection would regress is rejected
//!   (the previous state is kept) and the loop stops early;
//! * *early stop* — the loop also stops once a round improves by less
//!   than `tol · previous`;
//! * *determinism* — given the same inputs the output is bit-exact
//!   regardless of parallelism: the compression pipeline fans whole
//!   linears across `ThreadPool::scoped_map` workers and each linear's
//!   rounds run serially inside its worker, so parallel == serial by
//!   construction (same contract as the decompose stage).

use super::config::{ConfigError, SlabConfig, Structure};
use super::decompose::{low_rank_binary, Decomposition};
use super::scores::{wanda_scores, weighted_frob_norm, ActStats};
use super::threshold::{group_topk_mask, semi_structured_mask};
use crate::report::Table;
use crate::tensor::{svd_truncated, Mat};

/// Seed salt for the refinement SVDs — distinct from the Algorithm-1
/// iteration seeds (`cfg.seed ^ t`) so a refine round never replays a
/// decompose-round subspace initialization.
const REFINE_SEED_SALT: u64 = 0x5ef1_4e00;

/// Knobs of the refinement loop. The *budget* contract (keep
/// fraction, group geometry, structure, rank, SVD iterations) comes
/// from the layer's [`SlabConfig`], which [`refine`] takes alongside —
/// refinement never changes what a layer is allowed to store, only
/// how well it uses it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RefineConfig {
    /// Maximum alternating rounds (0 = identity).
    pub rounds: usize,
    /// Relative early-stop tolerance: stop once a round improves the
    /// weighted error by ≤ `tol · previous`.
    pub tol: f64,
}

impl Default for RefineConfig {
    fn default() -> Self {
        RefineConfig { rounds: 3, tol: 1e-3 }
    }
}

impl RefineConfig {
    pub fn with_rounds(rounds: usize) -> RefineConfig {
        RefineConfig { rounds, ..Default::default() }
    }
}

/// Per-layer refinement diagnostics, serialized through
/// [`refine_table`] into the compression report.
#[derive(Debug, Clone, PartialEq)]
pub struct RefineReport {
    /// Rounds actually *accepted* (≤ `cfg.rounds`).
    pub rounds_run: usize,
    /// Activation-weighted reconstruction error before refinement and
    /// after each accepted round (`len = rounds_run + 1`); monotone
    /// non-increasing by the accept guard.
    pub err_trace: Vec<f32>,
    /// Whether the loop stopped before exhausting its round budget
    /// (tolerance reached or a rejected round).
    pub early_stopped: bool,
}

impl RefineReport {
    /// Weighted error entering refinement (the one-shot Algorithm-1
    /// quality under the activation metric).
    pub fn err_before(&self) -> f32 {
        self.err_trace[0]
    }

    /// Weighted error after the last accepted round.
    pub fn err_after(&self) -> f32 {
        *self.err_trace.last().expect("non-empty trace")
    }

    /// Fractional improvement over the one-shot decomposition.
    pub fn improvement(&self) -> f64 {
        let e0 = self.err_before() as f64;
        if e0 <= 0.0 {
            return 0.0;
        }
        (e0 - self.err_after() as f64) / e0
    }
}

/// Refine `d` (a decomposition of `w`) for up to `rcfg.rounds`
/// alternating rounds under the activation-weighted metric. The
/// budget contract (keep fraction — including any
/// [`SlabConfig::keep_override`] — group geometry, structure, rank)
/// is taken from `cfg`, so a refined layer stores exactly what the
/// one-shot layer stored. Returns the refined decomposition and its
/// per-round [`RefineReport`].
pub fn refine(
    w: &Mat,
    d: &Decomposition,
    stats: &ActStats,
    cfg: &SlabConfig,
    rcfg: &RefineConfig,
) -> Result<(Decomposition, RefineReport), ConfigError> {
    let (dout, din) = w.shape();
    assert_eq!(d.w_s.shape(), (dout, din), "decomposition shape mismatch");
    assert_eq!(stats.din(), din, "stats Din mismatch");

    let mut cur = d.clone();
    let mut trace = vec![weighted_frob_norm(&w.sub(&cur.reconstruct()), stats) as f32];
    if rcfg.rounds == 0 {
        return Ok((
            cur,
            RefineReport { rounds_run: 0, err_trace: trace, early_stopped: false },
        ));
    }

    let keep = cfg.keep_fraction(dout, din)?;
    let (gr, gc) = cfg.group.resolve(dout, din);
    let rank = cfg.rank;
    // Column weights for the low-rank re-fit. A dead input feature
    // (s_j = 0) is invisible to the metric; weight 1 there keeps the
    // unscaling well-defined (the factor values at such columns are
    // arbitrary but deterministic).
    let wt: Vec<f32> = stats
        .col_norms
        .iter()
        .map(|&s| if s > 0.0 { s } else { 1.0 })
        .collect();

    let mut early_stopped = false;
    for round in 0..rcfg.rounds {
        let mut next = cur.clone();

        // (1) binary re-threshold against the sparse residual.
        let y_bl = w.sub(&next.w_s);
        next.w_b = y_bl.sign_pm1();

        // (2) activation-weighted rank-r re-fit: tSVD of
        // |residual|·diag(s), right factors unscaled by 1/s.
        if rank > 0 {
            let mut a = y_bl.abs();
            for i in 0..dout {
                let row = a.row_mut(i);
                for j in 0..din {
                    row[j] *= wt[j];
                }
            }
            let svd = svd_truncated(&a, rank, cfg.svd_iters, cfg.seed ^ (REFINE_SEED_SALT + round as u64));
            next.u.clear();
            next.v.clear();
            for k in 0..rank.min(svd.s.len()) {
                let (uk, mut vk) = svd.sqrt_split(k);
                for (vj, &s) in vk.iter_mut().zip(wt.iter()) {
                    *vj /= s;
                }
                next.u.push(uk);
                next.v.push(vk);
            }
        }

        // (3) sparse re-selection against the low-rank-binary residual.
        let lb = low_rank_binary(&next.u, &next.v, &next.w_b, None);
        let y_s = w.sub(&lb);
        let s = wanda_scores(&y_s, stats);
        let mask = match cfg.structure {
            Structure::Unstructured => group_topk_mask(&s, keep, gr, gc),
            Structure::SemiStructured(p) => semi_structured_mask(&s, keep, p, gr, gc),
        };
        next.w_s = y_s.hadamard(&mask);
        next.kept = mask.count_nonzero();

        let approx = next.w_s.add(&lb);
        let err = weighted_frob_norm(&w.sub(&approx), stats) as f32;
        let prev = *trace.last().expect("non-empty trace");
        // Accept guard: a regressing (or NaN) round is rejected and
        // the loop stops — this is what makes the trace monotone
        // rather than merely "usually decreasing".
        if !(err <= prev) {
            early_stopped = true;
            break;
        }
        next.frob_trace.push(w.frob_dist(&approx));
        cur = next;
        trace.push(err);
        if (prev - err) as f64 <= rcfg.tol * prev as f64 {
            early_stopped = true;
            break;
        }
    }

    let rounds_run = trace.len() - 1;
    Ok((cur, RefineReport { rounds_run, err_trace: trace, early_stopped }))
}

/// Render per-layer refinement reports as a [`Table`] (text + CSV via
/// the usual `render`/`render_csv`) — the auditability surface the
/// compress CLI prints.
pub fn refine_table(rows: &[(String, RefineReport)]) -> Table {
    let mut t = Table::new(
        "Refinement — activation-weighted error per layer",
        &["layer", "rounds", "werr before", "werr after", "improv %", "early stop"],
    );
    for (name, r) in rows {
        t.push_row(vec![
            name.clone(),
            r.rounds_run.to_string(),
            format!("{:.5}", r.err_before()),
            format!("{:.5}", r.err_after()),
            format!("{:.2}", r.improvement() * 100.0),
            r.early_stopped.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::decompose;
    use crate::util::rng::Pcg64;

    fn setup(dout: usize, din: usize, seed: u64) -> (Mat, ActStats) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let w = Mat::randn(dout, din, 0.05, &mut rng);
        let x = Mat::randn(64, din, 1.0, &mut rng);
        (w, ActStats::from_activations(&x))
    }

    fn cfg() -> SlabConfig {
        SlabConfig { cr: 0.5, iters: 2, svd_iters: 8, ..Default::default() }
    }

    #[test]
    fn zero_rounds_is_bit_identical_identity() {
        let (w, stats) = setup(32, 64, 11);
        let c = cfg();
        let d = decompose(&w, &stats, &c).unwrap();
        let (r, rep) = refine(&w, &d, &stats, &c, &RefineConfig::with_rounds(0)).unwrap();
        assert_eq!(r, d, "rounds = 0 must be the identity, bit for bit");
        assert_eq!(rep.rounds_run, 0);
        assert_eq!(rep.err_trace.len(), 1);
        assert!(!rep.early_stopped);
    }

    #[test]
    fn refinement_improves_weighted_error_of_a_short_oneshot() {
        // A 2-iteration one-shot leaves headroom; three weighted
        // rounds must claw some of it back under the weighted metric.
        let (w, stats) = setup(48, 96, 12);
        let c = cfg();
        let d = decompose(&w, &stats, &c).unwrap();
        let (r, rep) = refine(&w, &d, &stats, &c, &RefineConfig { rounds: 3, tol: 0.0 }).unwrap();
        assert!(rep.rounds_run >= 1, "at least one round must be accepted");
        assert!(
            rep.err_after() < rep.err_before(),
            "refined {} vs one-shot {}",
            rep.err_after(),
            rep.err_before()
        );
        // The report's trace is consistent with the returned state.
        let werr = weighted_frob_norm(&w.sub(&r.reconstruct()), &stats) as f32;
        assert!((werr - rep.err_after()).abs() <= 1e-4 * (1.0 + werr.abs()));
        // Budget contract: the refined layer stores what the one-shot
        // layer stored.
        assert_eq!(r.kept, d.kept);
        assert_eq!(r.u.len(), d.u.len());
    }

    #[test]
    fn budget_override_is_honored() {
        // With keep_override the refined mask must track the
        // override's keep count, not Eq. 10's.
        let (w, stats) = setup(32, 64, 13);
        let c = SlabConfig { keep_override: Some(0.25), ..cfg() };
        let d = decompose(&w, &stats, &c).unwrap();
        assert_eq!(d.kept, (0.25 * 64.0) as usize * 32);
        let (r, _) = refine(&w, &d, &stats, &c, &RefineConfig::with_rounds(2)).unwrap();
        assert_eq!(r.kept, d.kept);
    }

    #[test]
    fn prop_err_trace_is_monotone_non_increasing() {
        // The satellite property: every accepted round non-increases
        // the activation-weighted error — exactly, not approximately
        // (the accept guard rejects regressions).
        crate::util::prop::check(
            "refine-monotone-werr",
            10,
            |rng| crate::util::prop::gens::dims(rng, 8, 48),
            |&(dout, din)| {
                let (w, stats) = setup(dout, din, (dout * 977 + din) as u64);
                let c = cfg();
                let d = match decompose(&w, &stats, &c) {
                    Ok(d) => d,
                    Err(_) => return Ok(()), // infeasible tiny shape
                };
                let (_, rep) =
                    refine(&w, &d, &stats, &c, &RefineConfig { rounds: 4, tol: 0.0 }).unwrap();
                for t in 1..rep.err_trace.len() {
                    if rep.err_trace[t] > rep.err_trace[t - 1] {
                        return Err(format!(
                            "{dout}x{din}: round {t} regressed {} → {}",
                            rep.err_trace[t - 1],
                            rep.err_trace[t]
                        ));
                    }
                }
                if rep.rounds_run + 1 != rep.err_trace.len() {
                    return Err("trace length / rounds_run mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn refine_is_deterministic() {
        let (w, stats) = setup(24, 40, 14);
        let c = cfg();
        let d = decompose(&w, &stats, &c).unwrap();
        let rc = RefineConfig::with_rounds(3);
        let (a, ra) = refine(&w, &d, &stats, &c, &rc).unwrap();
        let (b, rb) = refine(&w, &d, &stats, &c, &rc).unwrap();
        assert_eq!(a, b);
        assert_eq!(ra, rb);
    }

    #[test]
    fn tight_tolerance_stops_early() {
        let (w, stats) = setup(32, 48, 15);
        let c = cfg();
        let d = decompose(&w, &stats, &c).unwrap();
        // tol = 1 (100% relative improvement required) stops after the
        // first accepted (or rejected) round.
        let (_, rep) = refine(&w, &d, &stats, &c, &RefineConfig { rounds: 8, tol: 1.0 }).unwrap();
        assert!(rep.early_stopped);
        assert!(rep.rounds_run <= 1);
    }

    #[test]
    fn semi_structured_pattern_survives_refinement() {
        use crate::sparse::PATTERN_2_4;
        let (w, stats) = setup(16, 64, 16);
        let c = SlabConfig {
            structure: Structure::SemiStructured(PATTERN_2_4),
            ..cfg()
        };
        let d = decompose(&w, &stats, &c).unwrap();
        let (r, _) = refine(&w, &d, &stats, &c, &RefineConfig::with_rounds(2)).unwrap();
        PATTERN_2_4.validate(&r.w_s).unwrap();
    }

    #[test]
    fn refine_table_renders_text_and_csv() {
        let rows = vec![(
            "l0.wq".to_string(),
            RefineReport {
                rounds_run: 2,
                err_trace: vec![1.0, 0.8, 0.75],
                early_stopped: false,
            },
        )];
        let t = refine_table(&rows);
        let md = t.render();
        assert!(md.contains("l0.wq"));
        assert!(md.contains("25.00") || md.contains("25.0"), "{md}");
        let csv = t.render_csv();
        assert!(csv.starts_with("layer,rounds,"));
        assert!(csv.contains("l0.wq,2,"));
    }
}
