//! Activation-aware pruning scores (Wanda, Sun et al. 2023).
//!
//! The score of weight `Y_ij` is `S_ij = |Y_ij| · ||X_j||₂` where
//! `||X_j||₂` is the L2 norm of input feature `j` over the calibration
//! batch (paper Algorithm 1 line 3: `S_X = diag(√(XᵀX))`). The SLaB
//! loop reuses the same statistic every iteration, so we compute
//! `S_X` once per layer and keep it in [`ActStats`].

use crate::tensor::Mat;

/// Per-input-feature activation statistics for one linear layer.
///
/// `col_norms` feeds the Wanda/SLaB score; `gram` (optional, `XᵀX`)
/// feeds SparseGPT's OBS Hessian. The Gram diagonal equals the squared
/// column norms, so when `gram` is present the two views are
/// consistent by construction.
#[derive(Debug, Clone, PartialEq)]
pub struct ActStats {
    /// `||X_j||₂` for each input feature j (length Din).
    pub col_norms: Vec<f32>,
    /// Optional `XᵀX` (Din, Din) for Hessian-based methods.
    pub gram: Option<Mat>,
    /// Number of calibration rows folded in (N·L).
    pub samples: usize,
}

impl ActStats {
    /// From a single calibration activation matrix X (N·L, Din).
    /// Norms only — cheap path for Wanda/SLaB.
    pub fn from_activations(x: &Mat) -> ActStats {
        ActStats {
            col_norms: x.col_norms(),
            gram: None,
            samples: x.rows,
        }
    }

    /// Norms + Gram matrix — needed by SparseGPT.
    pub fn from_activations_with_gram(x: &Mat) -> ActStats {
        ActStats {
            col_norms: x.col_norms(),
            gram: Some(crate::tensor::ops::gram(x)),
            samples: x.rows,
        }
    }

    /// Streaming accumulation: fold another batch in. Norms combine as
    /// sqrt(a² + b²) elementwise, Grams add — exact, order-independent.
    pub fn merge(&mut self, other: &ActStats) {
        assert_eq!(self.col_norms.len(), other.col_norms.len());
        for (a, b) in self.col_norms.iter_mut().zip(other.col_norms.iter()) {
            *a = (*a * *a + *b * *b).sqrt();
        }
        match (&mut self.gram, &other.gram) {
            (Some(g), Some(og)) => g.add_assign(og),
            (None, None) => {}
            _ => panic!("ActStats::merge: inconsistent gram presence"),
        }
        self.samples += other.samples;
    }

    /// Uniform statistics (all ones) — reduces Wanda scoring to plain
    /// magnitude pruning; used by tests and the magnitude baseline.
    pub fn uniform(din: usize) -> ActStats {
        ActStats {
            col_norms: vec![1.0; din],
            gram: None,
            samples: 0,
        }
    }

    pub fn din(&self) -> usize {
        self.col_norms.len()
    }
}

/// `S = |Y| ⊙ S_X` (broadcast over rows): the Wanda score of every
/// element of `y` (usually the residual `W − W_L ⊙ W_B`).
pub fn wanda_scores(y: &Mat, stats: &ActStats) -> Mat {
    assert_eq!(y.cols, stats.din(), "score dims: y cols {} vs stats {}", y.cols, stats.din());
    let mut s = Mat::zeros(y.rows, y.cols);
    for i in 0..y.rows {
        let yrow = y.row(i);
        let srow = s.row_mut(i);
        for j in 0..y.cols {
            srow[j] = yrow[j].abs() * stats.col_norms[j];
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn stats_match_manual_norms() {
        let x = Mat::from_vec(2, 2, vec![3.0, 1.0, 4.0, 2.0]);
        let st = ActStats::from_activations(&x);
        assert!((st.col_norms[0] - 5.0).abs() < 1e-6);
        assert!((st.col_norms[1] - 5.0f32.sqrt()).abs() < 1e-6);
        assert_eq!(st.samples, 2);
    }

    #[test]
    fn merge_equals_concat() {
        let mut rng = Pcg64::seed_from_u64(70);
        let a = Mat::randn(13, 6, 1.0, &mut rng);
        let b = Mat::randn(9, 6, 1.0, &mut rng);
        let whole = ActStats::from_activations(&Mat::vstack(&[&a, &b]));
        let mut merged = ActStats::from_activations(&a);
        merged.merge(&ActStats::from_activations(&b));
        for j in 0..6 {
            assert!((whole.col_norms[j] - merged.col_norms[j]).abs() < 1e-4);
        }
        assert_eq!(merged.samples, 22);
    }

    #[test]
    fn gram_merge_equals_concat() {
        let mut rng = Pcg64::seed_from_u64(72);
        let a = Mat::randn(11, 5, 1.0, &mut rng);
        let b = Mat::randn(7, 5, 1.0, &mut rng);
        let whole = ActStats::from_activations_with_gram(&Mat::vstack(&[&a, &b]));
        let mut merged = ActStats::from_activations_with_gram(&a);
        merged.merge(&ActStats::from_activations_with_gram(&b));
        assert!(merged
            .gram
            .as_ref()
            .unwrap()
            .allclose(whole.gram.as_ref().unwrap(), 1e-3, 1e-4));
        // Gram diagonal == squared col norms.
        let g = merged.gram.as_ref().unwrap();
        for j in 0..5 {
            assert!((g.at(j, j) - merged.col_norms[j].powi(2)).abs() < 1e-2);
        }
    }

    #[test]
    fn scores_scale_with_activation_norm() {
        let y = Mat::filled(2, 2, 1.0);
        let stats = ActStats {
            col_norms: vec![2.0, 5.0],
            gram: None,
            samples: 1,
        };
        let s = wanda_scores(&y, &stats);
        assert_eq!(s.at(0, 0), 2.0);
        assert_eq!(s.at(1, 1), 5.0);
    }

    #[test]
    fn scores_are_magnitude_when_uniform() {
        let mut rng = Pcg64::seed_from_u64(71);
        let y = Mat::randn(5, 7, 1.0, &mut rng);
        let s = wanda_scores(&y, &ActStats::uniform(7));
        assert_eq!(s, y.abs());
    }
}
