//! Activation-aware pruning scores (Wanda, Sun et al. 2023).
//!
//! The score of weight `Y_ij` is `S_ij = |Y_ij| · S_X[j]` where `S_X`
//! is a per-input-feature activation statistic (paper Algorithm 1
//! line 3: `S_X = diag(√(XᵀX))`). The SLaB loop reuses the same
//! statistic every iteration, so we compute `S_X` once per layer and
//! keep it in [`ActStats`].
//!
//! **Normalization convention.** [`ActStats`] stores *per-sample*
//! statistics: `col_norms[j] = √(Σ_rows X_ij² / samples)` (the RMS
//! activation) and `gram = XᵀX / samples`. Relative to the paper's raw
//! `‖X_j‖₂` this scales every score of a layer by the same constant
//! `1/√samples`, so every top-k / threshold selection — and therefore
//! every mask, decomposition, and OBS update (SparseGPT's damping is
//! relative to `mean diag H`, so `H → H/n` cancels throughout) — is
//! unchanged. What the normalization buys is *mergeability*: two
//! statistics built from calibration batches of different row counts
//! live on one scale, and [`ActStats::merge`] pools them weighted by
//! `samples`, reproducing the single-pass statistic exactly (pinned by
//! tests below). The raw-norm convention only merged correctly because
//! `√(a² + b²)` happens to equal the concat norm; as soon as a
//! statistic is averaged, resampled, or compared across calibration
//! sizes, sample weighting is load-bearing.

use crate::tensor::Mat;
use crate::util::pool::{chunk_ranges, ThreadPool};

/// Per-input-feature activation statistics for one linear layer.
///
/// `col_norms` feeds the Wanda/SLaB score; `gram` (optional,
/// `XᵀX / samples`) feeds SparseGPT's OBS Hessian. The Gram diagonal
/// equals the squared `col_norms`, so when `gram` is present the two
/// views are consistent by construction.
#[derive(Debug, Clone, PartialEq)]
pub struct ActStats {
    /// RMS activation `√(Σ_rows X_ij² / samples)` per input feature j
    /// (length Din).
    pub col_norms: Vec<f32>,
    /// Optional per-sample Gram `XᵀX / samples` (Din, Din) for
    /// Hessian-based methods.
    pub gram: Option<Mat>,
    /// Number of calibration rows folded in (N·L). `0` marks a
    /// synthetic statistic ([`ActStats::uniform`]) that carries no
    /// weight in a merge.
    pub samples: usize,
}

impl ActStats {
    /// From a single calibration activation matrix X (N·L, Din).
    /// Norms only — cheap path for Wanda/SLaB.
    pub fn from_activations(x: &Mat) -> ActStats {
        ActStats::from_raw(x.col_norms(), None, x.rows)
    }

    /// Norms + Gram matrix — needed by SparseGPT.
    pub fn from_activations_with_gram(x: &Mat) -> ActStats {
        ActStats::from_activations_with_gram_par(x, None)
    }

    /// [`from_activations_with_gram`](ActStats::from_activations_with_gram)
    /// with the Din³-scale Gram accumulation chunked across `pool`
    /// (bit-identical — see [`crate::tensor::ops::gram_par`]); the
    /// capture stage's path for Hessian methods.
    pub fn from_activations_with_gram_par(x: &Mat, pool: Option<&ThreadPool>) -> ActStats {
        let gram = match pool {
            Some(p) => crate::tensor::ops::gram_par(x, p),
            None => crate::tensor::ops::gram(x),
        };
        ActStats::from_raw(x.col_norms(), Some(gram), x.rows)
    }

    /// From raw concat-convention statistics — `norms = ‖X_j‖₂` and
    /// `gram = XᵀX` over `samples` rows (e.g. the outputs of the XLA
    /// `gram_{shape}` kernel); normalized on the way in.
    pub fn from_raw(norms: Vec<f32>, gram: Option<Mat>, samples: usize) -> ActStats {
        assert!(samples > 0, "empty calibration batch");
        let inv = 1.0 / samples as f64;
        let inv_sqrt = inv.sqrt();
        ActStats {
            col_norms: norms.iter().map(|&n| (n as f64 * inv_sqrt) as f32).collect(),
            gram: gram.map(|g| g.scale(inv as f32)),
            samples,
        }
    }

    /// Streaming accumulation: fold another batch in, **weighted by
    /// sample count** — batches of different row counts pool to
    /// exactly the single-pass statistic over their concatenation
    /// (order-independent up to f32 rounding). Zero-sample operands
    /// (synthetic stats) carry no weight.
    pub fn merge(&mut self, other: &ActStats) {
        assert_eq!(self.col_norms.len(), other.col_norms.len());
        // Weightless operands are ignored (and a weightless self is
        // replaced wholesale) *before* the gram-consistency check —
        // synthetic stats never carry a gram, and they never count.
        if other.samples == 0 {
            return;
        }
        if self.samples == 0 {
            *self = other.clone();
            return;
        }
        if self.gram.is_some() != other.gram.is_some() {
            panic!("ActStats::merge: inconsistent gram presence");
        }
        let na = self.samples as f64;
        let nb = other.samples as f64;
        let nt = na + nb;
        for (a, b) in self.col_norms.iter_mut().zip(other.col_norms.iter()) {
            let pooled = ((*a as f64) * (*a as f64) * na + (*b as f64) * (*b as f64) * nb) / nt;
            *a = pooled.sqrt() as f32;
        }
        if let (Some(g), Some(og)) = (&mut self.gram, &other.gram) {
            let wa = (na / nt) as f32;
            let wb = (nb / nt) as f32;
            for (x, y) in g.data.iter_mut().zip(og.data.iter()) {
                *x = *x * wa + *y * wb;
            }
        }
        self.samples += other.samples;
    }

    /// Uniform statistics (all ones) — reduces Wanda scoring to plain
    /// magnitude pruning; used by tests and the magnitude baseline.
    /// `samples = 0`: synthetic, weightless in merges.
    pub fn uniform(din: usize) -> ActStats {
        ActStats {
            col_norms: vec![1.0; din],
            gram: None,
            samples: 0,
        }
    }

    pub fn din(&self) -> usize {
        self.col_norms.len()
    }

    /// Resident bytes of this statistic (the pipeline's peak-memory
    /// accounting).
    pub fn nbytes(&self) -> usize {
        self.col_norms.len() * 4 + self.gram.as_ref().map_or(0, |g| g.numel() * 4)
    }
}

/// Activation-weighted Frobenius norm `‖Y · diag(s)‖_F` (`s` = the
/// RMS activation norms) — the reconstruction-error metric the
/// refinement loop minimizes and the budget allocator probes: column
/// `j`'s contribution to a layer's output error scales with how hard
/// feature `j` is actually driven. f64 accumulation for stability
/// across layer sizes.
pub fn weighted_frob_norm(y: &Mat, stats: &ActStats) -> f64 {
    assert_eq!(y.cols, stats.din(), "weighted norm dims: y cols {} vs stats {}", y.cols, stats.din());
    let mut acc = 0.0f64;
    for i in 0..y.rows {
        let row = y.row(i);
        for j in 0..y.cols {
            let v = row[j] as f64 * stats.col_norms[j] as f64;
            acc += v * v;
        }
    }
    acc.sqrt()
}

/// `S = |Y| ⊙ S_X` (broadcast over rows): the Wanda score of every
/// element of `y` (usually the residual `W − W_L ⊙ W_B`).
pub fn wanda_scores(y: &Mat, stats: &ActStats) -> Mat {
    wanda_scores_par(y, stats, None)
}

/// [`wanda_scores`] with rows chunked across `pool` — bit-identical
/// (each output element is one product either way). `None` or a
/// single-worker pool falls back to the serial loop.
pub fn wanda_scores_par(y: &Mat, stats: &ActStats, pool: Option<&ThreadPool>) -> Mat {
    assert_eq!(y.cols, stats.din(), "score dims: y cols {} vs stats {}", y.cols, stats.din());
    let mut s = Mat::zeros(y.rows, y.cols);
    match pool {
        Some(p) if p.size() > 1 && y.rows > 1 => {
            let cols = y.cols;
            let mut jobs = Vec::new();
            let mut rest: &mut [f32] = &mut s.data;
            for (r0, r1) in chunk_ranges(y.rows, p.size()) {
                let (head, tail) = rest.split_at_mut((r1 - r0) * cols);
                rest = tail;
                jobs.push(move || score_rows(y, stats, r0, r1, head));
            }
            p.scoped(jobs);
        }
        _ => score_rows(y, stats, 0, y.rows, &mut s.data),
    }
    s
}

/// Score rows `[r0, r1)` of `y` into `out` — the shared kernel of the
/// serial and pool-parallel score paths.
fn score_rows(y: &Mat, stats: &ActStats, r0: usize, r1: usize, out: &mut [f32]) {
    let cols = y.cols;
    for i in r0..r1 {
        let yrow = y.row(i);
        let srow = &mut out[(i - r0) * cols..(i - r0 + 1) * cols];
        for j in 0..cols {
            srow[j] = yrow[j].abs() * stats.col_norms[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn stats_match_manual_norms() {
        let x = Mat::from_vec(2, 2, vec![3.0, 1.0, 4.0, 2.0]);
        let st = ActStats::from_activations(&x);
        // RMS convention: ‖X_j‖₂ / √samples.
        let inv = 1.0 / 2.0f32.sqrt();
        assert!((st.col_norms[0] - 5.0 * inv).abs() < 1e-6);
        assert!((st.col_norms[1] - 5.0f32.sqrt() * inv).abs() < 1e-6);
        assert_eq!(st.samples, 2);
        assert_eq!(st.nbytes(), 8);
    }

    #[test]
    fn merge_weights_by_samples() {
        // The satellite pin: batches with very different row counts
        // (3 vs 301) must pool to exactly the single-pass statistic
        // over their concatenation — only sample weighting does this.
        let mut rng = Pcg64::seed_from_u64(70);
        let a = Mat::randn(3, 6, 1.0, &mut rng);
        let b = Mat::randn(301, 6, 0.3, &mut rng);
        let whole = ActStats::from_activations(&Mat::vstack(&[&a, &b]));
        let mut merged = ActStats::from_activations(&a);
        merged.merge(&ActStats::from_activations(&b));
        for j in 0..6 {
            assert!(
                (whole.col_norms[j] - merged.col_norms[j]).abs() < 1e-5,
                "col {j}: {} vs {}",
                whole.col_norms[j],
                merged.col_norms[j]
            );
        }
        assert_eq!(merged.samples, 304);

        // An unweighted pool (the old √(a²+b²) shape on normalized
        // stats) would be visibly wrong here; make sure we are not
        // silently equal to it.
        let unweighted: Vec<f32> = ActStats::from_activations(&a)
            .col_norms
            .iter()
            .zip(ActStats::from_activations(&b).col_norms.iter())
            .map(|(&x, &y)| ((x * x + y * y) / 2.0).sqrt())
            .collect();
        assert!(
            (0..6).any(|j| (unweighted[j] - whole.col_norms[j]).abs() > 1e-3),
            "test vectors too symmetric to distinguish weighting"
        );
    }

    #[test]
    fn merge_equals_concat() {
        let mut rng = Pcg64::seed_from_u64(71);
        let a = Mat::randn(13, 6, 1.0, &mut rng);
        let b = Mat::randn(9, 6, 1.0, &mut rng);
        let whole = ActStats::from_activations(&Mat::vstack(&[&a, &b]));
        let mut merged = ActStats::from_activations(&a);
        merged.merge(&ActStats::from_activations(&b));
        for j in 0..6 {
            assert!((whole.col_norms[j] - merged.col_norms[j]).abs() < 1e-4);
        }
        assert_eq!(merged.samples, 22);
    }

    #[test]
    fn gram_merge_equals_concat() {
        let mut rng = Pcg64::seed_from_u64(72);
        let a = Mat::randn(11, 5, 1.0, &mut rng);
        let b = Mat::randn(40, 5, 1.0, &mut rng);
        let whole = ActStats::from_activations_with_gram(&Mat::vstack(&[&a, &b]));
        let mut merged = ActStats::from_activations_with_gram(&a);
        merged.merge(&ActStats::from_activations_with_gram(&b));
        assert!(merged
            .gram
            .as_ref()
            .unwrap()
            .allclose(whole.gram.as_ref().unwrap(), 1e-4, 1e-4));
        // Gram diagonal == squared col norms (both per-sample).
        let g = merged.gram.as_ref().unwrap();
        for j in 0..5 {
            assert!((g.at(j, j) - merged.col_norms[j].powi(2)).abs() < 1e-3);
        }
        assert_eq!(merged.samples, 51);
    }

    #[test]
    fn merge_ignores_weightless_stats() {
        let x = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let real = ActStats::from_activations(&x);
        // uniform (samples 0) merged into real: no-op.
        let mut a = real.clone();
        a.merge(&ActStats::uniform(3));
        assert_eq!(a, real);
        // real merged into uniform: adopts the real statistic.
        let mut b = ActStats::uniform(3);
        b.merge(&real);
        assert_eq!(b, real);
        // Weightlessness wins over gram-presence checking: a gram-free
        // synthetic stat folds into (or is replaced by) a gram-carrying
        // one without panicking.
        let with_gram = ActStats::from_activations_with_gram(&x);
        let mut c = with_gram.clone();
        c.merge(&ActStats::uniform(3));
        assert_eq!(c, with_gram);
        let mut d = ActStats::uniform(3);
        d.merge(&with_gram);
        assert_eq!(d, with_gram);
    }

    #[test]
    #[should_panic(expected = "inconsistent gram presence")]
    fn merge_rejects_mixed_gram_presence() {
        let mut rng = Pcg64::seed_from_u64(73);
        let a = Mat::randn(4, 3, 1.0, &mut rng);
        let mut with = ActStats::from_activations_with_gram(&a);
        with.merge(&ActStats::from_activations(&a));
    }

    #[test]
    fn scores_scale_with_activation_norm() {
        let y = Mat::filled(2, 2, 1.0);
        let stats = ActStats {
            col_norms: vec![2.0, 5.0],
            gram: None,
            samples: 1,
        };
        let s = wanda_scores(&y, &stats);
        assert_eq!(s.at(0, 0), 2.0);
        assert_eq!(s.at(1, 1), 5.0);
    }

    #[test]
    fn scores_are_magnitude_when_uniform() {
        let mut rng = Pcg64::seed_from_u64(74);
        let y = Mat::randn(5, 7, 1.0, &mut rng);
        let s = wanda_scores(&y, &ActStats::uniform(7));
        assert_eq!(s, y.abs());
    }

    #[test]
    fn weighted_frob_norm_matches_manual_and_reduces_to_frob() {
        // 2x2 hand check: ‖Y·diag(s)‖_F.
        let y = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let stats = ActStats { col_norms: vec![2.0, 0.5], gram: None, samples: 1 };
        let want = (4.0f64 + 1.0 + 36.0 + 4.0).sqrt();
        assert!((weighted_frob_norm(&y, &stats) - want).abs() < 1e-9);
        // Uniform stats: plain Frobenius norm.
        let mut rng = Pcg64::seed_from_u64(76);
        let y = Mat::randn(6, 9, 1.0, &mut rng);
        let w = weighted_frob_norm(&y, &ActStats::uniform(9));
        assert!((w - y.frob_norm() as f64).abs() < 1e-4 * (1.0 + w));
    }

    #[test]
    fn parallel_scores_are_bit_identical() {
        let pool = ThreadPool::new(4);
        let mut rng = Pcg64::seed_from_u64(75);
        for rows in [1usize, 2, 7, 33] {
            let y = Mat::randn(rows, 13, 1.0, &mut rng);
            let stats = ActStats::from_activations(&Mat::randn(24, 13, 1.0, &mut rng));
            assert_eq!(
                wanda_scores_par(&y, &stats, Some(&pool)),
                wanda_scores(&y, &stats),
                "rows {rows}"
            );
        }
    }
}
