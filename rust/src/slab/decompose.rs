//! Algorithm 1 — the SLaB alternating decomposition.
//!
//! Given weight `W (Dout, Din)` and calibration statistics `S_X`,
//! produce `W_S` (sparse), `u, v` (rank-1 √σ-split factors of `W_L`)
//! and `W_B = sign(W − W_S)` such that `W ≈ W_S + W_L ⊙ W_B`:
//!
//! ```text
//! 1: W_S ← 0
//! 2: keep ← 1 − CR − 1/b − 1/Dout − 1/Din            (Eq. 10)
//! 3: S_X ← ||X_j||₂
//! 4: for t = 1..s:
//! 5:   W_B ← sign(W − W_S)
//! 6:   (u, v) ← √σ₀·(u₀, v₀) of |W − W_S|            (rank-1 tSVD)
//! 7:   S ← |W − u vᵀ ⊙ W_B| ⊙ S_X
//! 8:   W_S ← (W − u vᵀ ⊙ W_B) masked by HardThreshold(S, keep)
//! 9: return W_S, u, v, W_B
//! ```
//!
//! Line 8 note: the paper writes `HardThreshold(S, sparsity) ⊘ S_X`,
//! i.e. divide the *score* back by the activation norms — that
//! recovers `|residual|` at the kept positions and loses the sign.
//! The intended semantics (matching Wanda, and what makes ‖·‖_F
//! decrease) is to keep the *signed residual* at the top-scoring
//! positions; that is what we implement, and what
//! `python/compile/decompose.py` implements, so the two paths agree.
//!
//! The rank-1 SVD of `|W − W_S|` is non-negative (Perron–Frobenius /
//! paper Prop. 2), so with `W_B` carrying the sign, `u vᵀ ⊙ W_B`
//! approximates `W − W_S` itself — the insight that lets rank-1 do
//! the work of a much higher plain rank (paper Fig. 3).

use super::config::{ConfigError, SlabConfig, Structure};
use super::scores::{wanda_scores_par, ActStats};
use super::threshold::{group_topk_mask, semi_structured_mask};
use crate::tensor::{svd_truncated, Mat};
use crate::util::pool::{chunk_ranges, ThreadPool};

/// Decomposition output (dense form; see [`crate::slab::layer`] for
/// the packed deployment format).
#[derive(Debug, Clone, PartialEq)]
pub struct Decomposition {
    pub w_s: Mat,
    /// Rank-r factors, √σ-split: w_l = Σ_k u[k]·v[k]ᵀ. Paper default r=1.
    pub u: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// Dense ±1 sign matrix.
    pub w_b: Mat,
    /// Elements kept in `w_s`.
    pub kept: usize,
    /// ‖W − Ŵ‖_F after each iteration (length = iters), for the
    /// convergence diagnostics and Table II(b).
    pub frob_trace: Vec<f32>,
}

impl Decomposition {
    /// `W_L` as a dense matrix.
    pub fn w_l(&self) -> Mat {
        let (dout, din) = (self.w_s.rows, self.w_s.cols);
        let mut m = Mat::zeros(dout, din);
        for k in 0..self.u.len() {
            m.add_assign(&Mat::outer(&self.u[k], &self.v[k]));
        }
        m
    }

    /// Reconstruct `Ŵ = W_S + W_L ⊙ W_B`.
    pub fn reconstruct(&self) -> Mat {
        self.w_s.add(&self.w_l().hadamard(&self.w_b))
    }
}

/// Run Algorithm 1. `stats` must cover the layer's Din.
pub fn decompose(w: &Mat, stats: &ActStats, cfg: &SlabConfig) -> Result<Decomposition, ConfigError> {
    decompose_par(w, stats, cfg, None)
}

/// [`decompose`] with the per-row inner work — the `Σ u_k v_kᵀ ⊙ B`
/// materialization and the Wanda scoring, the two O(Dout·Din) loops
/// of every iteration — chunked across `pool`. **Bit-identical** to
/// the serial path (each row's arithmetic is untouched; pinned by a
/// property test), so callers can pick parallelism freely.
///
/// Same caveat as [`ThreadPool::scoped`]: must not run *inside* a
/// worker of the same pool — the compression pipeline fans across a
/// block's linears at the outer level and keeps the inner loops
/// serial, while single-layer callers (benches, the quickstart) use
/// the inner parallelism directly.
pub fn decompose_par(
    w: &Mat,
    stats: &ActStats,
    cfg: &SlabConfig,
    pool: Option<&ThreadPool>,
) -> Result<Decomposition, ConfigError> {
    let (dout, din) = w.shape();
    assert_eq!(stats.din(), din, "stats Din mismatch");
    let keep = cfg.keep_fraction(dout, din)?;
    let (gr, gc) = cfg.group.resolve(dout, din);
    let rank = cfg.rank.max(0);

    let mut w_s = Mat::zeros(dout, din);
    let mut u: Vec<Vec<f32>> = Vec::new();
    let mut v: Vec<Vec<f32>> = Vec::new();
    let mut w_b = Mat::filled(dout, din, 1.0);
    let mut kept = 0usize;
    let mut frob_trace = Vec::with_capacity(cfg.iters);

    for t in 0..cfg.iters.max(1) {
        // --- W_B and W_L from the current sparse residual ------------
        let y_bl = w.sub(&w_s);
        w_b = y_bl.sign_pm1();
        if rank > 0 {
            let svd = svd_truncated(&y_bl.abs(), rank, cfg.svd_iters, cfg.seed ^ t as u64);
            u.clear();
            v.clear();
            for k in 0..rank.min(svd.s.len()) {
                let (uk, vk) = svd.sqrt_split(k);
                u.push(uk);
                v.push(vk);
            }
        }

        // --- W_S from the low-rank-binary residual --------------------
        let lb = low_rank_binary(&u, &v, &w_b, pool);
        let y_s = w.sub(&lb);
        let s = wanda_scores_par(&y_s, stats, pool);
        let mask = match cfg.structure {
            Structure::Unstructured => group_topk_mask(&s, keep, gr, gc),
            Structure::SemiStructured(p) => semi_structured_mask(&s, keep, p, gr, gc),
        };
        w_s = y_s.hadamard(&mask);
        kept = mask.count_nonzero();

        // --- diagnostics ----------------------------------------------
        let approx = w_s.add(&lb);
        frob_trace.push(w.frob_dist(&approx));
    }

    Ok(Decomposition {
        w_s,
        u,
        v,
        w_b,
        kept,
        frob_trace,
    })
}

/// `Σ_k u_k v_kᵀ ⊙ B` without materializing `W_L` separately; rows
/// optionally chunked across `pool` (row-wise independent, so the
/// parallel result is bit-identical). Shared with [`super::refine`].
pub(crate) fn low_rank_binary(
    u: &[Vec<f32>],
    v: &[Vec<f32>],
    b: &Mat,
    pool: Option<&ThreadPool>,
) -> Mat {
    let (dout, din) = b.shape();
    let mut m = Mat::zeros(dout, din);
    match pool {
        Some(p) if p.size() > 1 && dout > 1 => {
            let mut jobs = Vec::new();
            let mut rest: &mut [f32] = &mut m.data;
            for (r0, r1) in chunk_ranges(dout, p.size()) {
                let (head, tail) = rest.split_at_mut((r1 - r0) * din);
                rest = tail;
                jobs.push(move || low_rank_binary_rows(u, v, b, r0, r1, head));
            }
            p.scoped(jobs);
        }
        _ => low_rank_binary_rows(u, v, b, 0, dout, &mut m.data),
    }
    m
}

/// Rows `[r0, r1)` of `Σ_k u_k v_kᵀ ⊙ B` into `out` — the kernel both
/// the serial and pool-parallel paths share.
fn low_rank_binary_rows(
    u: &[Vec<f32>],
    v: &[Vec<f32>],
    b: &Mat,
    r0: usize,
    r1: usize,
    out: &mut [f32],
) {
    let din = b.cols;
    for k in 0..u.len() {
        let (uk, vk) = (&u[k], &v[k]);
        for i in r0..r1 {
            let ui = uk[i];
            if ui == 0.0 {
                continue;
            }
            let brow = b.row(i);
            let mrow = &mut out[(i - r0) * din..(i - r0 + 1) * din];
            for j in 0..din {
                mrow[j] += ui * vk[j] * brow[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::config::GroupShape;
    use crate::slab::scores::wanda_scores;
    use crate::sparse::PATTERN_2_4;
    use crate::util::rng::Pcg64;

    fn setup(dout: usize, din: usize, seed: u64) -> (Mat, ActStats) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let w = Mat::randn(dout, din, 0.05, &mut rng);
        let x = Mat::randn(64, din, 1.0, &mut rng);
        (w, ActStats::from_activations(&x))
    }

    fn cfg50() -> SlabConfig {
        SlabConfig {
            cr: 0.5,
            iters: 6,
            svd_iters: 10,
            ..Default::default()
        }
    }

    #[test]
    fn output_structure_invariants() {
        let (w, stats) = setup(48, 96, 90);
        let cfg = cfg50();
        let d = decompose(&w, &stats, &cfg).unwrap();
        // W_B strictly ±1.
        assert!(d.w_b.data.iter().all(|&x| x == 1.0 || x == -1.0));
        // Sparsity matches Eq. 10 exactly (per-row groups, floor).
        let keep = cfg.keep_fraction(48, 96).unwrap();
        let per_row = (keep * 96.0).floor() as usize;
        assert_eq!(d.kept, per_row * 48);
        assert_eq!(d.w_s.count_nonzero(), d.kept);
        // Rank-1 factors present.
        assert_eq!(d.u.len(), 1);
        assert_eq!(d.u[0].len(), 48);
        assert_eq!(d.v[0].len(), 96);
    }

    #[test]
    fn rank1_of_abs_is_nonnegative() {
        // Prop. 2: rank-1 tSVD of an elementwise non-negative matrix has
        // a non-negative outer product (Perron–Frobenius).
        let (w, stats) = setup(32, 64, 91);
        let d = decompose(&w, &stats, &cfg50()).unwrap();
        let wl = d.w_l();
        let min = wl.data.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(min >= -1e-4, "W_L should be elementwise ≥ 0, min={min}");
    }

    #[test]
    fn error_not_increasing_over_iterations() {
        let (w, stats) = setup(40, 80, 92);
        let cfg = SlabConfig { iters: 10, ..cfg50() };
        let d = decompose(&w, &stats, &cfg).unwrap();
        // Alternating optimization: allow tiny numerical wobble, but the
        // trace must be essentially monotone non-increasing.
        for t in 1..d.frob_trace.len() {
            assert!(
                d.frob_trace[t] <= d.frob_trace[t - 1] * 1.01 + 1e-6,
                "iter {t}: {} > {}",
                d.frob_trace[t],
                d.frob_trace[t - 1]
            );
        }
        assert!(d.frob_trace.last().unwrap() < &d.frob_trace[0]);
    }

    #[test]
    fn reconstruct_error_matches_final_trace_entry() {
        // The trace's last entry is computed from the same-iteration
        // (W_S, u, v, W_B); reconstructing after the fact must land on
        // the same error (different summation path ⇒ f32 tolerance).
        for seed in [90u64, 91, 92] {
            let (w, stats) = setup(40, 72, seed);
            let d = decompose(&w, &stats, &cfg50()).unwrap();
            let last = *d.frob_trace.last().unwrap();
            let err = w.frob_dist(&d.reconstruct());
            assert!(
                (err - last).abs() <= 1e-4 * (1.0 + last.abs()),
                "seed {seed}: reconstruct {err} vs trace {last}"
            );
        }
    }

    #[test]
    fn parallel_decompose_is_bit_identical_to_serial() {
        // The decompose stage's determinism contract, across
        // adversarial shapes (rows fewer than workers, non-square,
        // shrunk dims where Eq. 10 rejects): the pooled inner loops
        // must reproduce the serial decomposition bit for bit — or
        // fail with the same config error.
        use crate::util::pool::ThreadPool;
        use crate::util::prop::{check, gens};
        let pool = ThreadPool::new(4);
        check(
            "decompose-par-vs-serial",
            10,
            |rng| gens::dims(rng, 8, 48),
            |&(dout, din)| {
                let (w, stats) = setup(dout, din, (dout * 131 + din) as u64);
                let cfg = SlabConfig {
                    iters: 3,
                    svd_iters: 6,
                    rank: 2,
                    ..cfg50()
                };
                match (decompose(&w, &stats, &cfg), decompose_par(&w, &stats, &cfg, Some(&pool))) {
                    (Ok(a), Ok(b)) => {
                        if a.w_s != b.w_s
                            || a.u != b.u
                            || a.v != b.v
                            || a.w_b != b.w_b
                            || a.kept != b.kept
                            || a.frob_trace != b.frob_trace
                        {
                            Err(format!("parallel != serial at {dout}x{din}"))
                        } else {
                            Ok(())
                        }
                    }
                    (Err(_), Err(_)) => Ok(()),
                    (a, b) => Err(format!(
                        "error disagreement at {dout}x{din}: serial ok={} parallel ok={}",
                        a.is_ok(),
                        b.is_ok()
                    )),
                }
            },
        );
    }

    #[test]
    fn beats_wanda_at_same_cr() {
        // SLaB's reconstruction error must undercut plain Wanda pruning
        // at the same CR (the whole point of the paper).
        let (w, stats) = setup(48, 96, 93);
        let cfg = cfg50();
        let d = decompose(&w, &stats, &cfg).unwrap();
        let slab_err = w.frob_dist(&d.reconstruct());
        // Wanda at 50% sparsity = CR 50% for a pure sparse method.
        let scores = wanda_scores(&w, &stats);
        let mask = group_topk_mask(&scores, 0.5, 1, 96);
        let wanda_err = w.frob_dist(&w.hadamard(&mask));
        assert!(
            slab_err < wanda_err,
            "slab {slab_err} should beat wanda {wanda_err}"
        );
    }

    #[test]
    fn reconstruct_matches_components() {
        let (w, stats) = setup(16, 32, 94);
        let d = decompose(&w, &stats, &cfg50()).unwrap();
        let manual = d.w_s.add(&d.w_l().hadamard(&d.w_b));
        assert!(d.reconstruct().allclose(&manual, 1e-6, 1e-6));
    }

    #[test]
    fn semi_structured_pattern_respected() {
        let (w, stats) = setup(16, 64, 95);
        let cfg = SlabConfig {
            structure: Structure::SemiStructured(PATTERN_2_4),
            ..cfg50()
        };
        let d = decompose(&w, &stats, &cfg).unwrap();
        PATTERN_2_4.validate(&d.w_s).unwrap();
    }

    #[test]
    fn rank0_reduces_to_wanda() {
        // rank = 0 disables W_L; with sign⊙0 the reconstruction is just
        // W_S, which should equal Wanda pruning of W at the SLaB keep
        // fraction.
        let (w, stats) = setup(24, 48, 96);
        let cfg = SlabConfig { rank: 0, iters: 1, ..cfg50() };
        let d = decompose(&w, &stats, &cfg).unwrap();
        let keep = cfg.keep_fraction(24, 48).unwrap();
        let mask = group_topk_mask(&wanda_scores(&w, &stats), keep, 1, 48);
        assert!(d.reconstruct().allclose(&w.hadamard(&mask), 1e-5, 1e-5));
    }

    #[test]
    fn more_iterations_help() {
        let (w, stats) = setup(32, 64, 97);
        let err = |iters| {
            let cfg = SlabConfig { iters, ..cfg50() };
            let d = decompose(&w, &stats, &cfg).unwrap();
            w.frob_dist(&d.reconstruct())
        };
        let e1 = err(1);
        let e10 = err(10);
        assert!(e10 <= e1 + 1e-5, "iters=10 ({e10}) vs iters=1 ({e1})");
    }

    #[test]
    fn group_geometry_changes_selection() {
        let (w, stats) = setup(32, 64, 98);
        let d_row = decompose(&w, &stats, &cfg50()).unwrap();
        let cfg_g = SlabConfig {
            group: GroupShape { rows: 16, cols: 0 },
            ..cfg50()
        };
        let d_big = decompose(&w, &stats, &cfg_g).unwrap();
        assert_ne!(d_row.w_s, d_big.w_s);
    }

    #[test]
    fn higher_rank_lowers_error() {
        // Fig 3's premise: rank 1 ≫ rank 0, rank 4 ≥ rank 1 (diminishing).
        let (w, stats) = setup(32, 64, 99);
        let err = |rank| {
            let cfg = SlabConfig { rank, iters: 4, ..cfg50() };
            let d = decompose(&w, &stats, &cfg).unwrap();
            w.frob_dist(&d.reconstruct())
        };
        let e0 = err(0);
        let e1 = err(1);
        let e4 = err(4);
        assert!(e1 < e0, "rank1 {e1} < rank0 {e0}");
        assert!(e4 <= e1 * 1.02, "rank4 {e4} ≤ rank1 {e1}");
    }
}
