//! Ablation variants (paper Table III): what does each component of
//! `W_S + W_L ⊙ W_B` buy?
//!
//! | variant | compensation term |
//! |---|---|
//! | `SparseOnly` | none (pure activation-aware sparse) |
//! | `SparseLowRank { rank }` | plain `W_L` (rank-r tSVD of residual, no binary) |
//! | `SparseFactorBinary` | `f ⊙ W_B` — per-row quantization factor × sign |
//! | `Full` | `W_L ⊙ W_B` — the SLaB term |
//!
//! All variants share the same alternating skeleton and the same
//! comparison-group thresholding so the Table III comparison isolates
//! the compensation term, exactly as in the paper.

use super::config::{ConfigError, SlabConfig, Structure};
use super::scores::{wanda_scores, ActStats};
use super::threshold::{group_topk_mask, semi_structured_mask};
use crate::tensor::{svd_truncated, Mat};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// `W_S` only.
    SparseOnly,
    /// `W_S + W_L` with `W_L` a plain rank-r truncated SVD (no sign
    /// matrix). Table III uses r = 16.
    SparseLowRank { rank: usize },
    /// `W_S + f ⊙ W_B`: `f` is the per-output-row mean |residual| —
    /// the "quantization factor vector" of 1-bit weight quantization.
    SparseFactorBinary,
    /// Full SLaB: `W_S + W_L ⊙ W_B`.
    Full,
}

impl Variant {
    pub fn label(&self) -> String {
        match self {
            Variant::SparseOnly => "W_S".into(),
            Variant::SparseLowRank { rank } => format!("W_S + W_L(r={rank})"),
            Variant::SparseFactorBinary => "W_S + factor ⊙ W_B".into(),
            Variant::Full => "W_S + W_L ⊙ W_B".into(),
        }
    }
}

/// Result of an ablation decomposition: the reconstructed dense weight
/// plus the Frobenius error (the model swap uses the dense form).
#[derive(Debug, Clone)]
pub struct AblationOut {
    pub w_hat: Mat,
    pub frob_err: f32,
    pub kept: usize,
}

/// Run the shared alternating skeleton with the chosen compensation
/// term. `cfg.iters`, `cfg.group`, `cfg.structure` apply to all
/// variants; `cfg.rank` only to `Full`.
pub fn ablate(
    w: &Mat,
    stats: &ActStats,
    cfg: &SlabConfig,
    variant: Variant,
) -> Result<AblationOut, ConfigError> {
    let (dout, din) = w.shape();
    let keep = cfg.keep_fraction(dout, din)?;
    let (gr, gc) = cfg.group.resolve(dout, din);

    let mut w_s = Mat::zeros(dout, din);
    let mut comp = Mat::zeros(dout, din); // the compensation term
    let mut kept = 0usize;

    for t in 0..cfg.iters.max(1) {
        let y = w.sub(&w_s);
        comp = match variant {
            Variant::SparseOnly => Mat::zeros(dout, din),
            Variant::SparseLowRank { rank } => {
                let svd = svd_truncated(&y, rank, cfg.svd_iters, cfg.seed ^ t as u64);
                svd.reconstruct()
            }
            Variant::SparseFactorBinary => {
                let b = y.sign_pm1();
                let mut out = Mat::zeros(dout, din);
                for i in 0..dout {
                    let yrow = y.row(i);
                    let f: f32 =
                        yrow.iter().map(|&x| x.abs()).sum::<f32>() / din as f32;
                    let brow = b.row(i);
                    let orow = out.row_mut(i);
                    for j in 0..din {
                        orow[j] = f * brow[j];
                    }
                }
                out
            }
            Variant::Full => {
                let b = y.sign_pm1();
                let svd = svd_truncated(&y.abs(), cfg.rank.max(1), cfg.svd_iters, cfg.seed ^ t as u64);
                let mut out = Mat::zeros(dout, din);
                for k in 0..cfg.rank.max(1).min(svd.s.len()) {
                    let (u, v) = svd.sqrt_split(k);
                    out.add_assign(&Mat::outer(&u, &v));
                }
                out.hadamard(&b)
            }
        };

        let y_s = w.sub(&comp);
        let s = wanda_scores(&y_s, stats);
        let mask = match cfg.structure {
            Structure::Unstructured => group_topk_mask(&s, keep, gr, gc),
            Structure::SemiStructured(p) => semi_structured_mask(&s, keep, p, gr, gc),
        };
        w_s = y_s.hadamard(&mask);
        kept = mask.count_nonzero();

        if matches!(variant, Variant::SparseOnly) {
            break; // no alternation possible
        }
    }

    let w_hat = w_s.add(&comp);
    Ok(AblationOut {
        frob_err: w.frob_dist(&w_hat),
        w_hat,
        kept,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::PATTERN_2_4;
    use crate::util::rng::Pcg64;

    fn setup() -> (Mat, ActStats, SlabConfig) {
        // NOTE: shape matters for the Table III ordering — rank-16 is a
        // large spectrum fraction for tiny matrices (at 48x96 plain
        // rank-16 beats factor⊙binary on Gaussian weights); at the
        // paper-relevant regime (rank ≪ min dim) the binary variants
        // win, which 128x256 already exhibits.
        let mut rng = Pcg64::seed_from_u64(110);
        let w = Mat::randn(128, 256, 0.05, &mut rng);
        let x = Mat::randn(64, 256, 1.0, &mut rng);
        let cfg = SlabConfig {
            iters: 5,
            svd_iters: 10,
            structure: Structure::SemiStructured(PATTERN_2_4),
            ..Default::default()
        };
        (w, ActStats::from_activations(&x), cfg)
    }

    #[test]
    fn table3_error_ordering() {
        // The paper's Table III ordering (by accuracy) maps to the
        // reconstruction-error ordering:
        //   SparseOnly > SparseLowRank(16) > SparseFactorBinary ≥ Full.
        let (w, stats, cfg) = setup();
        let e = |v| ablate(&w, &stats, &cfg, v).unwrap().frob_err;
        let sparse = e(Variant::SparseOnly);
        let lowrank = e(Variant::SparseLowRank { rank: 16 });
        let factor = e(Variant::SparseFactorBinary);
        let full = e(Variant::Full);
        assert!(lowrank < sparse, "lowrank {lowrank} < sparse {sparse}");
        assert!(factor < lowrank, "factor {factor} < lowrank {lowrank}");
        assert!(full <= factor * 1.02, "full {full} ≲ factor {factor}");
    }

    #[test]
    fn all_variants_respect_pattern() {
        let (w, stats, cfg) = setup();
        for v in [
            Variant::SparseOnly,
            Variant::SparseLowRank { rank: 4 },
            Variant::SparseFactorBinary,
            Variant::Full,
        ] {
            let out = ablate(&w, &stats, &cfg, v).unwrap();
            // The sparse part must obey 2:4; recover it as Ŵ − comp is
            // not directly available, so check kept count instead.
            let keep = cfg.keep_fraction(128, 256).unwrap();
            assert_eq!(out.kept, ((keep * 256.0).floor() as usize) * 128, "{:?}", v);
        }
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(Variant::SparseOnly.label(), "W_S");
        assert_eq!(Variant::SparseLowRank { rank: 16 }.label(), "W_S + W_L(r=16)");
        assert!(Variant::Full.label().contains("W_L ⊙ W_B"));
    }
}
