//! Compression-ratio accounting (paper Eq. 9/10) and the SLaB
//! hyperparameter bundle.
//!
//! For a `(Dout, Din)` weight at `b` bits/element, the SLaB storage is
//! `b·k` (sparse values) + `Dout·Din` (1-bit `W_B`) + `b·(Dout+Din)`
//! (the rank-1 vectors), so
//!
//! ```text
//! CR = 1 − (b·k + Dout·Din + b(Dout+Din)) / (b·Dout·Din)          (9)
//! k/(Dout·Din) = 1 − CR − 1/b − 1/Dout − 1/Din                    (10)
//! ```
//!
//! Note Eq. 9 charges only the sparse *values* (`b·k`); index
//! metadata is accounted separately in [`crate::slab::layer`]'s
//! `nbytes_deploy` (the paper's CR is the standard "parameter bits"
//! convention used by SparseGPT/Wanda, which we follow for all
//! method comparisons).

use crate::sparse::NmPattern;

/// Comparison-group geometry for the score threshold (paper §II-B2,
/// Table II): a `(rows, cols)` window within which scores compete.
/// Wanda's default is `(1, Din)` — per output row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupShape {
    pub rows: usize,
    /// 0 means "all of Din" (resolved per layer).
    pub cols: usize,
}

impl GroupShape {
    pub const PER_ROW: GroupShape = GroupShape { rows: 1, cols: 0 };

    pub fn resolve(&self, dout: usize, din: usize) -> (usize, usize) {
        let r = if self.rows == 0 { dout } else { self.rows.min(dout) };
        let c = if self.cols == 0 { din } else { self.cols.min(din) };
        (r, c)
    }

    pub fn label(&self, _din_sym: &str) -> String {
        let r = if self.rows == 0 { "Dout".to_string() } else { self.rows.to_string() };
        let c = if self.cols == 0 { "Din".to_string() } else { format!("Din/{}", self.cols) };
        // cols is stored as an absolute count; the caller prints nicer
        // labels for the paper's fractional shapes.
        format!("({r}, {c})")
    }
}

/// Sparsity structure for `W_S`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Structure {
    /// Unstructured — the paper's "US".
    Unstructured,
    /// Semi-structured N:M applied before group-wise thresholding.
    SemiStructured(NmPattern),
}

impl Structure {
    pub fn name(&self) -> String {
        match self {
            Structure::Unstructured => "US".to_string(),
            Structure::SemiStructured(p) => p.name(),
        }
    }
}

/// Full SLaB configuration (paper defaults in `Default`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlabConfig {
    /// Target compression ratio (0, 1): fraction of storage removed.
    pub cr: f64,
    /// Bit width of non-binary components (paper: 16 for FP16).
    pub bits: u32,
    /// Alternating-optimization iterations `s` (paper default 20).
    pub iters: usize,
    /// Comparison-group geometry (paper default `(1, Din)`).
    pub group: GroupShape,
    /// Unstructured vs 2:4 / 4:8.
    pub structure: Structure,
    /// Rank of `W_L` (paper: 1; >1 used only by the Fig-3 sweep).
    pub rank: usize,
    /// Power-iteration steps per SVD inside the alternating loop.
    pub svd_iters: usize,
    /// Seed for the (deterministic) SVD initialization.
    pub seed: u64,
    /// Per-layer keep-fraction override. `None` (the default) derives
    /// the keep fraction from `cr` via Eq. 10; `Some(f)` pins it
    /// directly — the hook the budget allocator
    /// (`coordinator::budget`) uses to spend one layer's sparse budget
    /// on another while the *global* parameter count stays fixed.
    pub keep_override: Option<f64>,
}

impl Default for SlabConfig {
    fn default() -> Self {
        SlabConfig {
            cr: 0.5,
            bits: 16,
            iters: 20,
            group: GroupShape::PER_ROW,
            structure: Structure::Unstructured,
            rank: 1,
            svd_iters: 8,
            seed: 0x51ab,
            keep_override: None,
        }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("keep fraction {0:.4} out of (0,1): CR {1} infeasible for {2}x{3} at b={4}")]
    Infeasible(f64, f64, usize, usize, u32),
}

impl SlabConfig {
    /// Eq. 10 — the fraction of elements retained in `W_S` — unless a
    /// [`keep_override`](SlabConfig::keep_override) pins it (the
    /// budget allocator's per-layer hook; validated the same way).
    pub fn keep_fraction(&self, dout: usize, din: usize) -> Result<f64, ConfigError> {
        let f = match self.keep_override {
            Some(f) => f,
            None => 1.0 - self.cr - 1.0 / self.bits as f64 - 1.0 / dout as f64 - 1.0 / din as f64,
        };
        if f <= 0.0 || f >= 1.0 {
            return Err(ConfigError::Infeasible(f, self.cr, dout, din, self.bits));
        }
        Ok(f)
    }

    /// `self` with the keep fraction pinned to `f` (Eq. 10 bypassed).
    pub fn with_keep(&self, f: f64) -> SlabConfig {
        SlabConfig { keep_override: Some(f), ..*self }
    }

    /// Non-zeros `k` retained for a layer (floor, ≥ 0).
    pub fn keep_count(&self, dout: usize, din: usize) -> Result<usize, ConfigError> {
        let f = self.keep_fraction(dout, din)?;
        Ok((f * (dout * din) as f64).floor() as usize)
    }

    /// Eq. 9 — the CR actually achieved for a given `k`.
    pub fn cr_for_count(&self, dout: usize, din: usize, k: usize) -> f64 {
        let b = self.bits as f64;
        let numel = (dout * din) as f64;
        1.0 - (b * k as f64 + numel + b * (dout + din) as f64) / (b * numel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq9_eq10_invert() {
        let cfg = SlabConfig { cr: 0.5, ..Default::default() };
        let (dout, din) = (512, 2048);
        let k = cfg.keep_count(dout, din).unwrap();
        let cr_back = cfg.cr_for_count(dout, din, k);
        // floor() in keep_count can only push CR up by < 1 element.
        assert!((cr_back - 0.5).abs() < 1e-4, "cr_back={cr_back}");
    }

    #[test]
    fn keep_fraction_paper_example() {
        // b=16, large dims: keep ≈ 1 − CR − 1/16.
        let cfg = SlabConfig { cr: 0.5, ..Default::default() };
        let f = cfg.keep_fraction(4096, 4096).unwrap();
        assert!((f - (0.5 - 0.0625 - 2.0 / 4096.0)).abs() < 1e-9);
    }

    #[test]
    fn infeasible_rejected() {
        let cfg = SlabConfig { cr: 0.95, ..Default::default() };
        assert!(cfg.keep_fraction(64, 64).is_err());
        let tiny = SlabConfig { cr: 0.5, ..Default::default() };
        assert!(tiny.keep_fraction(2, 2).is_err()); // 1/2+1/2 overhead alone
    }

    #[test]
    fn higher_cr_keeps_fewer() {
        let mk = |cr| SlabConfig { cr, ..Default::default() }
            .keep_count(256, 1024)
            .unwrap();
        assert!(mk(0.5) > mk(0.6));
        assert!(mk(0.6) > mk(0.7));
        assert!(mk(0.7) > mk(0.8));
    }

    #[test]
    fn keep_override_bypasses_eq10_and_is_validated() {
        // An override that Eq. 10 would reject (CR 0.95 at 64x64) is
        // honored when explicitly pinned…
        let cfg = SlabConfig { cr: 0.95, ..Default::default() }.with_keep(0.3);
        assert_eq!(cfg.keep_fraction(64, 64).unwrap(), 0.3);
        assert_eq!(cfg.keep_count(64, 64).unwrap(), (0.3 * 4096.0) as usize);
        // …but the override itself is range-checked like Eq. 10's value.
        for bad in [0.0, 1.0, -0.2, 1.5] {
            let cfg = SlabConfig::default().with_keep(bad);
            assert!(cfg.keep_fraction(64, 64).is_err(), "keep {bad} must be rejected");
        }
        // No override: unchanged Eq. 10 semantics.
        let base = SlabConfig::default();
        assert!(base.keep_override.is_none());
        let f = base.keep_fraction(4096, 4096).unwrap();
        assert!((f - (0.5 - 0.0625 - 2.0 / 4096.0)).abs() < 1e-9);
    }

    #[test]
    fn group_shape_resolution() {
        let g = GroupShape::PER_ROW;
        assert_eq!(g.resolve(128, 512), (1, 512));
        let g = GroupShape { rows: 16, cols: 0 };
        assert_eq!(g.resolve(128, 512), (16, 512));
        let g = GroupShape { rows: 1, cols: 32 };
        assert_eq!(g.resolve(128, 512), (1, 32));
        // Clamped to layer dims.
        let g = GroupShape { rows: 300, cols: 0 };
        assert_eq!(g.resolve(128, 512), (128, 512));
    }

    #[test]
    fn prop_eq9_eq10_roundtrip_random_shapes() {
        crate::util::prop::check(
            "eq9-eq10-roundtrip",
            100,
            |rng| {
                (
                    16 + rng.below_usize(512),
                    16 + rng.below_usize(512),
                )
            },
            |&(dout, din)| {
                for crx in [0.5, 0.6, 0.7] {
                    let cfg = SlabConfig { cr: crx, ..Default::default() };
                    match cfg.keep_count(dout, din) {
                        Ok(k) => {
                            let back = cfg.cr_for_count(dout, din, k);
                            let tol = 1.0 / (dout * din) as f64 + 1e-9;
                            if (back - crx).abs() > tol {
                                return Err(format!(
                                    "dout={dout} din={din} cr={crx}: back={back}"
                                ));
                            }
                        }
                        Err(_) => continue, // infeasible tiny shapes are fine
                    }
                }
                Ok(())
            },
        );
    }
}
