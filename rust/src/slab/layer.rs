//! Packed deployment format for a SLaB-compressed linear layer, and
//! the compressed forward pass.
//!
//! `y = x·W_Sᵀ + u ⊙ ((x ⊙ v)·W_Bᵀ)` — the rank-1 Hadamard structure
//! means the low-rank-binary term needs one elementwise scale by `v`,
//! one ±1 matmul, and one elementwise scale by `u` (per rank). This is
//! the identity the Pallas kernel (`python/compile/kernels/`) and this
//! native path both implement; integration tests pin them together.

use super::decompose::Decomposition;
use crate::binary::BitMat;
use crate::sparse::Csr;
use crate::tensor::{Checkpoint, Entry, Mat, TensorData};
use crate::util::kernel::KernelMode;
use crate::util::pool::{chunk_ranges, ThreadPool};

/// A compressed linear layer ready to serve.
#[derive(Debug, Clone, PartialEq)]
pub struct SlabLayer {
    /// Sparse component, CSR.
    pub w_s: Csr,
    /// Rank-r √σ-split factors (paper: r = 1).
    pub u: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// 1-bit sign matrix.
    pub w_b: BitMat,
}

impl SlabLayer {
    pub fn from_decomposition(d: &Decomposition) -> SlabLayer {
        SlabLayer {
            w_s: Csr::from_dense(&d.w_s),
            u: d.u.clone(),
            v: d.v.clone(),
            w_b: BitMat::from_sign_of(&d.w_b),
        }
    }

    pub fn dout(&self) -> usize {
        self.w_s.rows
    }

    pub fn din(&self) -> usize {
        self.w_s.cols
    }

    pub fn rank(&self) -> usize {
        self.u.len()
    }

    /// Compressed forward: `y = x·W_Sᵀ + Σ_k u_k ⊙ ((x ⊙ v_k)·W_Bᵀ)`
    /// for a batch `x (B, Din)` → `(B, Dout)`.
    pub fn forward(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.din());
        let mut y = self.w_s.spmm_bt(x);
        let mut scaled = Mat::zeros(x.rows, x.cols);
        for k in 0..self.rank() {
            // xv = x ⊙ v (broadcast v over rows)
            for b in 0..x.rows {
                let xrow = x.row(b);
                let srow = scaled.row_mut(b);
                for j in 0..x.cols {
                    srow[j] = xrow[j] * self.v[k][j];
                }
            }
            let t = self.w_b.matmul_bt(&scaled); // (B, Dout)
            for b in 0..x.rows {
                let trow = t.row(b);
                let yrow = y.row_mut(b);
                for i in 0..self.dout() {
                    yrow[i] += self.u[k][i] * trow[i];
                }
            }
        }
        y
    }

    /// Fused compressed forward — the serving hot path.
    ///
    /// Same contraction as [`forward`](SlabLayer::forward) (and
    /// bit-identical to it: the underlying blocked/parallel kernels
    /// accumulate in the scalar order), but fused: the `x ⊙ v_k`
    /// scale, the ±1 matmul, and the `u_k ⊙ ·` scale-accumulate reuse
    /// two scratch matrices across every rank instead of allocating a
    /// fresh `(B, Dout)` per rank, the sparse and binary matmuls run
    /// cache-blocked, and with `pool = Some(_)` both are row-chunked
    /// across the [`ThreadPool`]. `SlabModel` routes every packed
    /// linear through here.
    pub fn forward_fused(&self, x: &Mat, pool: Option<&ThreadPool>) -> Mat {
        let mut y = Mat::zeros(x.rows, self.dout());
        self.forward_fused_into(x, pool, &mut y);
        y
    }

    /// [`forward_fused`](SlabLayer::forward_fused) writing into a
    /// caller-owned output (overwritten entirely, same bit-identical
    /// contraction), completing the `_into` symmetry the bitplane
    /// kernels already have: a serving loop that holds `y` across
    /// calls drops one `(B, Dout)` allocation per call. `y` must be
    /// `(x.rows, dout)`.
    pub fn forward_fused_into(&self, x: &Mat, pool: Option<&ThreadPool>, y: &mut Mat) {
        assert_eq!(x.cols, self.din());
        assert_eq!((y.rows, y.cols), (x.rows, self.dout()), "forward_fused_into: bad output shape");
        match pool {
            Some(p) => self.w_s.spmm_bt_par_into(x, p, y),
            None => self.w_s.spmm_bt_blocked_into(x, y),
        };
        // One scratch pair reused across all ranks.
        let mut scaled = Mat::zeros(x.rows, x.cols);
        let mut t = Mat::zeros(x.rows, self.dout());
        for k in 0..self.rank() {
            let vk = &self.v[k];
            for b in 0..x.rows {
                let xrow = x.row(b);
                let srow = scaled.row_mut(b);
                for j in 0..x.cols {
                    srow[j] = xrow[j] * vk[j];
                }
            }
            match pool {
                Some(p) => self.w_b.matmul_bt_par_into(&scaled, p, &mut t),
                None => self.w_b.matmul_bt_blocked_into(&scaled, &mut t),
            }
            let uk = &self.u[k];
            for b in 0..x.rows {
                let trow = t.row(b);
                let yrow = y.row_mut(b);
                for i in 0..self.dout() {
                    yrow[i] += uk[i] * trow[i];
                }
            }
        }
    }

    /// Fused batch-1 decode epilogue — the single-token serving path.
    ///
    /// [`forward_fused`](SlabLayer::forward_fused) at batch 1 still
    /// materializes a `(1, Dout)` bitplane product per rank and makes
    /// one pool dispatch per matmul. This epilogue instead computes
    /// each output element in **one pass**:
    ///
    /// `y[i] = Σ_j W_S[i,j]·x[j] + Σ_k u_k[i]·(Σ_j s_k[j] − 2·Σ_{W_B[i,j]=−1} s_k[j])`
    ///
    /// with `s_k = x ⊙ v_k` computed once up front — the activation is
    /// touched once per rank, the sparse and bitplane row kernels run
    /// back-to-back while the row's `y[i]` is live in a register, and
    /// a pooled call makes exactly one dispatch for the whole layer.
    ///
    /// `KernelMode::Exact` uses the scalar-order row kernels
    /// ([`Csr::row_dot`], [`BitMat::row_neg_sum`]) and the per-element
    /// combine matches [`forward`](SlabLayer::forward)'s expression
    /// tree term for term, so the result is **bit-identical** to
    /// `forward`/`forward_fused` (pinned by tests — this is what lets
    /// `SlabModel` route batch-1 decode through here without breaking
    /// the token-identity suites). `KernelMode::Fast` swaps in the
    /// tolerance-gated unrolled row kernels (DESIGN.md §7).
    pub fn forward_decode(&self, x: &Mat, pool: Option<&ThreadPool>, mode: KernelMode) -> Mat {
        assert_eq!(x.rows, 1, "forward_decode is the batch-1 path");
        assert_eq!(x.cols, self.din());
        let mut y = Mat::zeros(1, self.dout());
        self.forward_decode_into(x.row(0), pool, mode, &mut y.data);
        y
    }

    /// [`forward_decode`](SlabLayer::forward_decode) on slices: one
    /// activation row in, one output row (length `dout`) overwritten.
    pub fn forward_decode_into(
        &self,
        x: &[f32],
        pool: Option<&ThreadPool>,
        mode: KernelMode,
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), self.din(), "forward_decode: x len {} vs din {}", x.len(), self.din());
        let dout = self.dout();
        assert_eq!(out.len(), dout, "forward_decode: out len {} vs dout {dout}", out.len());
        // s_k = x ⊙ v_k and its total, one pass over the activation
        // per rank (ascending j — the same order `row_totals` uses, so
        // Exact stays bit-identical to the fused matmul path).
        let r = self.rank();
        let mut scaled: Vec<Vec<f32>> = Vec::with_capacity(r);
        let mut totals: Vec<f32> = Vec::with_capacity(r);
        for k in 0..r {
            let vk = &self.v[k];
            let mut s = vec![0.0f32; x.len()];
            for j in 0..x.len() {
                s[j] = x[j] * vk[j];
            }
            totals.push(s.iter().sum());
            scaled.push(s);
        }
        match pool {
            Some(p) if p.size() > 1 && self.dout() >= 2 => {
                let ranges = chunk_ranges(self.dout(), p.size());
                let mut chunks: Vec<&mut [f32]> = Vec::with_capacity(ranges.len());
                let mut rest = out;
                for &(r0, r1) in &ranges {
                    // mem::take moves the &mut out of `rest` so the split
                    // halves can outlive the loop iteration.
                    let (head, tail) = std::mem::take(&mut rest).split_at_mut(r1 - r0);
                    chunks.push(head);
                    rest = tail;
                }
                let (scaled_ref, totals_ref) = (&scaled, &totals);
                let jobs: Vec<_> = chunks
                    .into_iter()
                    .zip(ranges.iter().copied())
                    .map(|(chunk, (r0, _))| {
                        move || self.decode_rows(x, scaled_ref, totals_ref, mode, r0, chunk)
                    })
                    .collect();
                p.scoped(jobs);
            }
            _ => self.decode_rows(x, &scaled, &totals, mode, 0, out),
        }
    }

    /// Draft forward for self-speculative decoding (DESIGN.md §14):
    /// the **sparse + low-rank components only** —
    ///
    /// `y[i] = Σ_j W_S[i,j]·x[j] + Σ_{k < r'} u_k[i]·(Σ_j x[j]·v_k[j])`
    ///
    /// with `r' = min(rank, rank_cap)`. Dropping the bitplane term is
    /// equivalent to replacing `W_B` with the all-ones sign matrix, so
    /// the per-rank contribution collapses to a scalar `⟨x, v_k⟩` per
    /// activation row — no popcount matmul, no bitplane reads at all.
    /// This is the cheap "draft" view the self-speculative decoder
    /// runs; its outputs are *approximate* by design (the verify pass
    /// through the full packed forward keeps decoding lossless) but
    /// deterministic: the sparse kernel is row-order bit-identical
    /// serial or pooled, and the rank epilogue never fans out.
    /// `rank_cap = usize::MAX` keeps every rank; smaller caps trade
    /// acceptance rate for draft speed.
    pub fn forward_draft(&self, x: &Mat, pool: Option<&ThreadPool>, rank_cap: usize) -> Mat {
        let mut y = Mat::zeros(x.rows, self.dout());
        self.forward_draft_into(x, pool, rank_cap, &mut y);
        y
    }

    /// [`forward_draft`](SlabLayer::forward_draft) writing into a
    /// caller-owned output (overwritten entirely). `y` must be
    /// `(x.rows, dout)`.
    pub fn forward_draft_into(
        &self,
        x: &Mat,
        pool: Option<&ThreadPool>,
        rank_cap: usize,
        y: &mut Mat,
    ) {
        assert_eq!(x.cols, self.din());
        assert_eq!((y.rows, y.cols), (x.rows, self.dout()), "forward_draft_into: bad output shape");
        match pool {
            Some(p) => self.w_s.spmm_bt_par_into(x, p, y),
            None => self.w_s.spmm_bt_blocked_into(x, y),
        };
        let r = self.rank().min(rank_cap);
        for k in 0..r {
            let vk = &self.v[k];
            let uk = &self.u[k];
            for b in 0..x.rows {
                let xrow = x.row(b);
                // Same ascending-j order as forward_decode's totals.
                let mut t = 0.0f32;
                for j in 0..x.cols {
                    t += xrow[j] * vk[j];
                }
                let yrow = y.row_mut(b);
                for i in 0..self.dout() {
                    yrow[i] += uk[i] * t;
                }
            }
        }
    }

    /// The per-row decode sweep over output rows `[r0, r0 + out.len())`.
    fn decode_rows(
        &self,
        x: &[f32],
        scaled: &[Vec<f32>],
        totals: &[f32],
        mode: KernelMode,
        r0: usize,
        out: &mut [f32],
    ) {
        for (oi, slot) in out.iter_mut().enumerate() {
            let i = r0 + oi;
            let mut acc = match mode {
                KernelMode::Exact => self.w_s.row_dot(i, x),
                KernelMode::Fast => self.w_s.row_dot_fast(i, x),
            };
            for k in 0..totals.len() {
                let neg = match mode {
                    KernelMode::Exact => self.w_b.row_neg_sum(i, &scaled[k]),
                    KernelMode::Fast => self.w_b.row_neg_sum_fast(i, &scaled[k]),
                };
                acc += self.u[k][i] * (totals[k] - 2.0 * neg);
            }
            *slot = acc;
        }
    }

    /// Dense reconstruction `Ŵ` — used for artifact-path forwards
    /// (the HLO model consumes dense weights) and correctness checks.
    pub fn reconstruct(&self) -> Mat {
        let mut w = self.w_s.to_dense();
        let b = self.w_b.to_dense();
        for k in 0..self.rank() {
            let lr = Mat::outer(&self.u[k], &self.v[k]);
            w.add_assign(&lr.hadamard(&b));
        }
        w
    }

    /// Deployed bytes: CSR (values+indices) + bitplane + factors.
    /// This is the *engineering* size including sparse metadata; the
    /// paper's Eq. 9 CR (values-only convention) is in
    /// [`crate::slab::SlabConfig::cr_for_count`].
    pub fn nbytes_deploy(&self) -> usize {
        self.w_s.nbytes()
            + self.w_b.nbytes()
            + self.u.iter().map(|c| c.len() * 4).sum::<usize>()
            + self.v.iter().map(|c| c.len() * 4).sum::<usize>()
    }

    /// Paper-convention storage bits (Eq. 9 numerator) at width `b`.
    pub fn storage_bits(&self, b: u32) -> usize {
        let b = b as usize;
        b * self.w_s.nnz() + self.dout() * self.din() + b * self.rank() * (self.dout() + self.din())
    }

    // ------------------------------------------------------------------
    // Serialization (into the shared checkpoint container)
    // ------------------------------------------------------------------

    /// This layer's checkpoint entries under `prefix` — the unit the
    /// pipeline's streaming emit stage appends per block (a
    /// [`crate::tensor::CheckpointWriter`] consumer never holds more
    /// than one block's entries in memory; DESIGN.md §10). The leading
    /// `{prefix}.shape` entry doubles as the layer marker the loader
    /// scans for.
    pub fn entries(&self, prefix: &str) -> Vec<Entry> {
        let mut out = Vec::with_capacity(5 + 2 * self.rank());
        out.push(Entry {
            name: format!("{prefix}.shape"),
            dims: vec![2],
            data: TensorData::I32(vec![self.dout() as i32, self.din() as i32]),
        });
        out.push(Entry {
            name: format!("{prefix}.ws.row_ptr"),
            dims: vec![self.w_s.row_ptr.len()],
            data: TensorData::I32(self.w_s.row_ptr.iter().map(|&x| x as i32).collect()),
        });
        out.push(Entry {
            name: format!("{prefix}.ws.col_idx"),
            dims: vec![self.w_s.col_idx.len()],
            data: TensorData::I32(self.w_s.col_idx.iter().map(|&x| x as i32).collect()),
        });
        out.push(Entry::f32(
            &format!("{prefix}.ws.vals"),
            vec![self.w_s.vals.len()],
            self.w_s.vals.clone(),
        ));
        for k in 0..self.rank() {
            out.push(Entry::f32(
                &format!("{prefix}.u{k}"),
                vec![self.u[k].len()],
                self.u[k].clone(),
            ));
            out.push(Entry::f32(
                &format!("{prefix}.v{k}"),
                vec![self.v[k].len()],
                self.v[k].clone(),
            ));
        }
        // Bit matrix stored as its packed u64 bitplane words
        // (little-endian bytes): the true 1-bit/element size on disk
        // (modulo row padding), 8× smaller than the legacy
        // u8-per-element form, which `load_from` still accepts.
        let words = self.w_b.words();
        let mut bytes = Vec::with_capacity(words.len() * 8);
        for &w in words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        out.push(Entry {
            name: format!("{prefix}.wb.bits"),
            dims: vec![self.dout(), self.w_b.words_per_row() * 8],
            data: TensorData::U8(bytes),
        });
        out
    }

    /// Append this layer's tensors under `prefix` to a checkpoint.
    pub fn save_into(&self, ck: &mut Checkpoint, prefix: &str) {
        for e in self.entries(prefix) {
            ck.push(e);
        }
    }

    /// Load a layer saved by [`save_into`].
    pub fn load_from(ck: &Checkpoint, prefix: &str) -> Option<SlabLayer> {
        let shape = ck.get(&format!("{prefix}.shape"))?.data.as_i32()?.to_vec();
        let (dout, din) = (shape[0] as usize, shape[1] as usize);
        let row_ptr: Vec<u32> = ck
            .get(&format!("{prefix}.ws.row_ptr"))?
            .data
            .as_i32()?
            .iter()
            .map(|&x| x as u32)
            .collect();
        let col_idx: Vec<u32> = ck
            .get(&format!("{prefix}.ws.col_idx"))?
            .data
            .as_i32()?
            .iter()
            .map(|&x| x as u32)
            .collect();
        let vals = ck.get(&format!("{prefix}.ws.vals"))?.data.as_f32()?.to_vec();
        let w_s = Csr {
            rows: dout,
            cols: din,
            row_ptr,
            col_idx,
            vals,
        };
        w_s.validate().ok()?;
        let mut u = Vec::new();
        let mut v = Vec::new();
        let mut k = 0;
        while let (Some(ue), Some(ve)) = (
            ck.get(&format!("{prefix}.u{k}")),
            ck.get(&format!("{prefix}.v{k}")),
        ) {
            u.push(ue.data.as_f32()?.to_vec());
            v.push(ve.data.as_f32()?.to_vec());
            k += 1;
        }
        let w_b = if let Some(e) = ck.get(&format!("{prefix}.wb.bits")) {
            // Packed u64 bitplane words (current format).
            let bytes = e.data.as_u8()?;
            let wpr = din.div_ceil(64);
            if bytes.len() != dout * wpr * 8 {
                return None;
            }
            let words: Vec<u64> = bytes
                .chunks_exact(8)
                .map(|c| {
                    u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
                })
                .collect();
            BitMat::from_words(dout, din, words)
        } else {
            // Legacy u8-per-element form (pre-packed checkpoints).
            let e = ck.get(&format!("{prefix}.wb"))?;
            let bytes = e.data.as_u8()?;
            if bytes.len() != dout * din {
                return None;
            }
            let dense = Mat::from_vec(
                dout,
                din,
                bytes.iter().map(|&b| if b != 0 { 1.0 } else { -1.0 }).collect(),
            );
            BitMat::from_sign_of(&dense)
        };
        Some(SlabLayer { w_s, u, v, w_b })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::config::SlabConfig;
    use crate::slab::decompose::decompose;
    use crate::slab::scores::ActStats;
    use crate::tensor::ops::matmul_bt;
    use crate::util::rng::Pcg64;

    fn layer(seed: u64) -> (Mat, SlabLayer) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let w = Mat::randn(40, 72, 0.05, &mut rng);
        let x = Mat::randn(32, 72, 1.0, &mut rng);
        let stats = ActStats::from_activations(&x);
        let cfg = SlabConfig {
            iters: 4,
            svd_iters: 10,
            ..Default::default()
        };
        let d = decompose(&w, &stats, &cfg).unwrap();
        (w, SlabLayer::from_decomposition(&d))
    }

    #[test]
    fn forward_equals_dense_reconstruction() {
        let (_, l) = layer(100);
        let mut rng = Pcg64::seed_from_u64(101);
        let x = Mat::randn(6, 72, 1.0, &mut rng);
        let y_packed = l.forward(&x);
        let y_dense = matmul_bt(&x, &l.reconstruct());
        assert!(
            y_packed.allclose(&y_dense, 1e-3, 1e-3),
            "packed vs dense forward"
        );
    }

    #[test]
    fn reconstruction_matches_decomposition() {
        let mut rng = Pcg64::seed_from_u64(102);
        let w = Mat::randn(24, 48, 0.05, &mut rng);
        let stats = ActStats::from_activations(&Mat::randn(16, 48, 1.0, &mut rng));
        let cfg = SlabConfig { iters: 3, ..Default::default() };
        let d = decompose(&w, &stats, &cfg).unwrap();
        let l = SlabLayer::from_decomposition(&d);
        assert!(l.reconstruct().allclose(&d.reconstruct(), 1e-5, 1e-5));
    }

    #[test]
    fn deploy_bytes_beat_dense() {
        let (w, l) = layer(103);
        let dense_bytes = w.numel() * 4;
        assert!(
            l.nbytes_deploy() < dense_bytes,
            "{} should be < {dense_bytes}",
            l.nbytes_deploy()
        );
    }

    #[test]
    fn storage_bits_match_eq9() {
        let (w, l) = layer(104);
        let (dout, din) = w.shape();
        let bits = l.storage_bits(16);
        let expect = 16 * l.w_s.nnz() + dout * din + 16 * (dout + din);
        assert_eq!(bits, expect);
        // And the implied CR is near the target 0.5.
        let cr = 1.0 - bits as f64 / (16.0 * (dout * din) as f64);
        assert!((cr - 0.5).abs() < 0.02, "cr={cr}");
    }

    #[test]
    fn checkpoint_roundtrip() {
        let (_, l) = layer(105);
        let mut ck = Checkpoint::new();
        l.save_into(&mut ck, "blk0.q");
        let path = std::env::temp_dir().join("slab-tests/layer.slabckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let l2 = SlabLayer::load_from(&back, "blk0.q").unwrap();
        assert_eq!(l2, l);
    }

    #[test]
    fn checkpoint_wb_is_bitpacked_on_disk() {
        let (_, l) = layer(106);
        let mut ck = Checkpoint::new();
        l.save_into(&mut ck, "p");
        let e = ck.get("p.wb.bits").unwrap();
        let bytes = e.data.as_u8().unwrap();
        // 1 bit per element (+ row padding to the 64-bit word), not 1 byte.
        assert_eq!(bytes.len(), l.dout() * l.din().div_ceil(64) * 8);
        assert!(bytes.len() * 8 < l.dout() * l.din() * 8);
        assert!(ck.get("p.wb").is_none(), "legacy entry must not be written");
    }

    #[test]
    fn checkpoint_loads_legacy_u8_wb() {
        // Simulate a checkpoint written before the packed format: same
        // entries, but W_B as one u8 per element under `{prefix}.wb`.
        let (_, l) = layer(107);
        let mut ck = Checkpoint::new();
        l.save_into(&mut ck, "q");
        ck.entries.retain(|e| e.name != "q.wb.bits");
        let dense = l.w_b.to_dense();
        ck.push(Entry {
            name: "q.wb".into(),
            dims: vec![l.dout(), l.din()],
            data: TensorData::U8(dense.data.iter().map(|&x| (x >= 0.0) as u8).collect()),
        });
        let back = SlabLayer::load_from(&ck, "q").unwrap();
        assert_eq!(back, l);
    }

    #[test]
    fn fused_into_overwrites_reused_output() {
        // The per-tick serving shape: one output matrix held across
        // calls; stale contents (poisoned with NaN) must be fully
        // overwritten, and the result must stay bit-identical to the
        // reference forward.
        let (_, l) = layer(110);
        let mut rng = Pcg64::seed_from_u64(111);
        let pool = ThreadPool::new(4);
        let mut y = Mat::filled(3, l.dout(), f32::NAN);
        let x1 = Mat::randn(3, 72, 1.0, &mut rng);
        l.forward_fused_into(&x1, None, &mut y);
        assert_eq!(y, l.forward(&x1));
        let x2 = Mat::randn(3, 72, 1.0, &mut rng);
        l.forward_fused_into(&x2, Some(&pool), &mut y);
        assert_eq!(y, l.forward(&x2));
    }

    #[test]
    fn fused_decode_is_bit_identical_to_forward() {
        // The batch-1 epilogue must be exact-equal to the reference
        // forward (and hence to forward_fused) in Exact mode, serial
        // and pooled — this is what lets SlabModel route single-token
        // decode through it without perturbing token-identity tests.
        let (_, l) = layer(112);
        let mut rng = Pcg64::seed_from_u64(113);
        let pool = ThreadPool::new(4);
        for _ in 0..5 {
            let x = Mat::randn(1, 72, 1.0, &mut rng);
            let y_ref = l.forward(&x);
            assert_eq!(l.forward_decode(&x, None, KernelMode::Exact), y_ref);
            assert_eq!(l.forward_decode(&x, Some(&pool), KernelMode::Exact), y_ref);
        }
    }

    #[test]
    fn fused_decode_rank0_and_handbuilt_layer() {
        // Adversarial structure without the decompose fixture: rank-0
        // (pure sparse) and a ragged din off the 64-bit word boundary.
        let mut rng = Pcg64::seed_from_u64(114);
        let din = 70;
        let w = Mat::from_fn(9, din, |i, j| if (i * 7 + j) % 5 == 0 { 0.3 } else { 0.0 });
        let rank0 = SlabLayer {
            w_s: Csr::from_dense(&w),
            u: vec![],
            v: vec![],
            w_b: BitMat::ones(9, din),
        };
        let signs = Mat::from_fn(9, din, |i, j| if (i + j) % 3 == 0 { 1.0 } else { -1.0 });
        let rank2 = SlabLayer {
            w_s: Csr::from_dense(&w),
            u: vec![vec![0.5; 9], vec![-0.25; 9]],
            v: vec![vec![1.0; din], vec![0.1; din]],
            w_b: BitMat::from_sign_of(&signs),
        };
        for l in [&rank0, &rank2] {
            let x = Mat::randn(1, din, 1.0, &mut rng);
            let y_ref = l.forward(&x);
            assert_eq!(l.forward_decode(&x, None, KernelMode::Exact), y_ref);
            // Fast mode: tolerance-gated, never ==; generous c·n·ε·mag
            // bound (DESIGN.md §7).
            let y_fast = l.forward_decode(&x, None, KernelMode::Fast);
            let mag: f64 = x.row(0).iter().map(|&v| v.abs() as f64).sum();
            let tol = (16.0 * din as f64 * f32::EPSILON as f64 * (1.0 + mag)) as f32 + 1e-5;
            for i in 0..9 {
                assert!(
                    (y_fast.row(0)[i] - y_ref.row(0)[i]).abs() <= tol,
                    "i={i}: fast {} vs exact {} (tol {tol})",
                    y_fast.row(0)[i],
                    y_ref.row(0)[i]
                );
            }
        }
    }

    #[test]
    fn fused_decode_fast_within_tolerance_on_decomposed_layer() {
        let (_, l) = layer(115);
        let mut rng = Pcg64::seed_from_u64(116);
        let pool = ThreadPool::new(4);
        let x = Mat::randn(1, 72, 1.0, &mut rng);
        let y_ref = l.forward(&x);
        let ws_dense = l.w_s.to_dense();
        for p in [None, Some(&pool)] {
            let y_fast = l.forward_decode(&x, p, KernelMode::Fast);
            for i in 0..l.dout() {
                // Bound from the term magnitudes: sparse row + per-rank
                // |u|·(|total| + 2·Σ|s|) — the §7 reassociation bound.
                let mut mag: f64 = (0..l.din())
                    .map(|j| (ws_dense.at(i, j) * x.row(0)[j]).abs() as f64)
                    .sum();
                for k in 0..l.rank() {
                    let su: f64 = (0..l.din())
                        .map(|j| (x.row(0)[j] * l.v[k][j]).abs() as f64)
                        .sum();
                    mag += (l.u[k][i].abs() as f64) * 3.0 * su;
                }
                let tol = (16.0 * l.din() as f64 * f32::EPSILON as f64 * mag) as f32 + 1e-5;
                assert!(
                    (y_fast.row(0)[i] - y_ref.row(0)[i]).abs() <= tol,
                    "i={i} pooled={}",
                    p.is_some()
                );
            }
        }
    }

    #[test]
    fn draft_forward_equals_all_ones_bitplane() {
        // The draft view is definitionally the layer with W_B replaced
        // by the all-ones sign matrix: per rank the bitplane product
        // degenerates to the scalar ⟨x, v_k⟩. Pin the cheap epilogue
        // against that reference layer, serial and pooled, at several
        // batch shapes.
        let (_, l) = layer(117);
        let ones = SlabLayer {
            w_s: l.w_s.clone(),
            u: l.u.clone(),
            v: l.v.clone(),
            w_b: BitMat::ones(l.dout(), l.din()),
        };
        let mut rng = Pcg64::seed_from_u64(118);
        let pool = ThreadPool::new(4);
        for batch in [1usize, 3, 8] {
            let x = Mat::randn(batch, 72, 1.0, &mut rng);
            let y_ref = ones.forward(&x);
            let y_serial = l.forward_draft(&x, None, usize::MAX);
            let y_pooled = l.forward_draft(&x, Some(&pool), usize::MAX);
            assert!(y_serial.allclose(&y_ref, 1e-5, 1e-5), "draft vs all-ones batch {batch}");
            assert_eq!(y_serial, y_pooled, "draft must be pool-invariant");
        }
    }

    #[test]
    fn draft_forward_rank_truncation() {
        // rank_cap 0 is the pure-sparse draft; caps at or past the
        // layer's rank keep every rank. Exercise a ragged din too.
        let mut rng = Pcg64::seed_from_u64(119);
        let din = 70;
        let w = Mat::from_fn(9, din, |i, j| if (i * 7 + j) % 5 == 0 { 0.3 } else { 0.0 });
        let signs = Mat::from_fn(9, din, |i, j| if (i + j) % 3 == 0 { 1.0 } else { -1.0 });
        let l = SlabLayer {
            w_s: Csr::from_dense(&w),
            u: vec![vec![0.5; 9], vec![-0.25; 9]],
            v: vec![vec![1.0; din], vec![0.1; din]],
            w_b: BitMat::from_sign_of(&signs),
        };
        let x = Mat::randn(2, din, 1.0, &mut rng);
        let sparse_only = l.w_s.spmm_bt(&x);
        assert_eq!(l.forward_draft(&x, None, 0), sparse_only, "rank_cap 0 is pure sparse");
        assert_eq!(
            l.forward_draft(&x, None, 2),
            l.forward_draft(&x, None, usize::MAX),
            "cap at rank keeps every rank"
        );
        // A rank-1 cap must differ from both (the second rank has
        // nonzero factors by construction).
        assert_ne!(l.forward_draft(&x, None, 1), l.forward_draft(&x, None, 2));
        // Into-form overwrites stale contents entirely.
        let mut y = Mat::filled(2, 9, f32::NAN);
        l.forward_draft_into(&x, None, usize::MAX, &mut y);
        assert_eq!(y, l.forward_draft(&x, None, usize::MAX));
    }

    #[test]
    fn fused_forward_is_bit_identical_to_reference() {
        let (_, l) = layer(108);
        let mut rng = Pcg64::seed_from_u64(109);
        let pool = ThreadPool::new(4);
        for batch in [1usize, 2, 7] {
            let x = Mat::randn(batch, 72, 1.0, &mut rng);
            let y_ref = l.forward(&x);
            assert_eq!(l.forward_fused(&x, None), y_ref, "fused batch {batch}");
            assert_eq!(l.forward_fused(&x, Some(&pool)), y_ref, "fused+pool batch {batch}");
        }
    }
}
