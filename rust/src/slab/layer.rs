//! Packed deployment format for a SLaB-compressed linear layer, and
//! the compressed forward pass.
//!
//! `y = x·W_Sᵀ + u ⊙ ((x ⊙ v)·W_Bᵀ)` — the rank-1 Hadamard structure
//! means the low-rank-binary term needs one elementwise scale by `v`,
//! one ±1 matmul, and one elementwise scale by `u` (per rank). This is
//! the identity the Pallas kernel (`python/compile/kernels/`) and this
//! native path both implement; integration tests pin them together.

use super::decompose::Decomposition;
use crate::binary::BitMat;
use crate::sparse::Csr;
use crate::tensor::{Checkpoint, Entry, Mat, TensorData};
use crate::util::pool::ThreadPool;

/// A compressed linear layer ready to serve.
#[derive(Debug, Clone, PartialEq)]
pub struct SlabLayer {
    /// Sparse component, CSR.
    pub w_s: Csr,
    /// Rank-r √σ-split factors (paper: r = 1).
    pub u: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// 1-bit sign matrix.
    pub w_b: BitMat,
}

impl SlabLayer {
    pub fn from_decomposition(d: &Decomposition) -> SlabLayer {
        SlabLayer {
            w_s: Csr::from_dense(&d.w_s),
            u: d.u.clone(),
            v: d.v.clone(),
            w_b: BitMat::from_sign_of(&d.w_b),
        }
    }

    pub fn dout(&self) -> usize {
        self.w_s.rows
    }

    pub fn din(&self) -> usize {
        self.w_s.cols
    }

    pub fn rank(&self) -> usize {
        self.u.len()
    }

    /// Compressed forward: `y = x·W_Sᵀ + Σ_k u_k ⊙ ((x ⊙ v_k)·W_Bᵀ)`
    /// for a batch `x (B, Din)` → `(B, Dout)`.
    pub fn forward(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.din());
        let mut y = self.w_s.spmm_bt(x);
        let mut scaled = Mat::zeros(x.rows, x.cols);
        for k in 0..self.rank() {
            // xv = x ⊙ v (broadcast v over rows)
            for b in 0..x.rows {
                let xrow = x.row(b);
                let srow = scaled.row_mut(b);
                for j in 0..x.cols {
                    srow[j] = xrow[j] * self.v[k][j];
                }
            }
            let t = self.w_b.matmul_bt(&scaled); // (B, Dout)
            for b in 0..x.rows {
                let trow = t.row(b);
                let yrow = y.row_mut(b);
                for i in 0..self.dout() {
                    yrow[i] += self.u[k][i] * trow[i];
                }
            }
        }
        y
    }

    /// Fused compressed forward — the serving hot path.
    ///
    /// Same contraction as [`forward`](SlabLayer::forward) (and
    /// bit-identical to it: the underlying blocked/parallel kernels
    /// accumulate in the scalar order), but fused: the `x ⊙ v_k`
    /// scale, the ±1 matmul, and the `u_k ⊙ ·` scale-accumulate reuse
    /// two scratch matrices across every rank instead of allocating a
    /// fresh `(B, Dout)` per rank, the sparse and binary matmuls run
    /// cache-blocked, and with `pool = Some(_)` both are row-chunked
    /// across the [`ThreadPool`]. `SlabModel` routes every packed
    /// linear through here.
    pub fn forward_fused(&self, x: &Mat, pool: Option<&ThreadPool>) -> Mat {
        let mut y = Mat::zeros(x.rows, self.dout());
        self.forward_fused_into(x, pool, &mut y);
        y
    }

    /// [`forward_fused`](SlabLayer::forward_fused) writing into a
    /// caller-owned output (overwritten entirely, same bit-identical
    /// contraction), completing the `_into` symmetry the bitplane
    /// kernels already have: a serving loop that holds `y` across
    /// calls drops one `(B, Dout)` allocation per call. `y` must be
    /// `(x.rows, dout)`.
    pub fn forward_fused_into(&self, x: &Mat, pool: Option<&ThreadPool>, y: &mut Mat) {
        assert_eq!(x.cols, self.din());
        assert_eq!((y.rows, y.cols), (x.rows, self.dout()), "forward_fused_into: bad output shape");
        match pool {
            Some(p) => self.w_s.spmm_bt_par_into(x, p, y),
            None => self.w_s.spmm_bt_blocked_into(x, y),
        };
        // One scratch pair reused across all ranks.
        let mut scaled = Mat::zeros(x.rows, x.cols);
        let mut t = Mat::zeros(x.rows, self.dout());
        for k in 0..self.rank() {
            let vk = &self.v[k];
            for b in 0..x.rows {
                let xrow = x.row(b);
                let srow = scaled.row_mut(b);
                for j in 0..x.cols {
                    srow[j] = xrow[j] * vk[j];
                }
            }
            match pool {
                Some(p) => self.w_b.matmul_bt_par_into(&scaled, p, &mut t),
                None => self.w_b.matmul_bt_blocked_into(&scaled, &mut t),
            }
            let uk = &self.u[k];
            for b in 0..x.rows {
                let trow = t.row(b);
                let yrow = y.row_mut(b);
                for i in 0..self.dout() {
                    yrow[i] += uk[i] * trow[i];
                }
            }
        }
    }

    /// Dense reconstruction `Ŵ` — used for artifact-path forwards
    /// (the HLO model consumes dense weights) and correctness checks.
    pub fn reconstruct(&self) -> Mat {
        let mut w = self.w_s.to_dense();
        let b = self.w_b.to_dense();
        for k in 0..self.rank() {
            let lr = Mat::outer(&self.u[k], &self.v[k]);
            w.add_assign(&lr.hadamard(&b));
        }
        w
    }

    /// Deployed bytes: CSR (values+indices) + bitplane + factors.
    /// This is the *engineering* size including sparse metadata; the
    /// paper's Eq. 9 CR (values-only convention) is in
    /// [`crate::slab::SlabConfig::cr_for_count`].
    pub fn nbytes_deploy(&self) -> usize {
        self.w_s.nbytes()
            + self.w_b.nbytes()
            + self.u.iter().map(|c| c.len() * 4).sum::<usize>()
            + self.v.iter().map(|c| c.len() * 4).sum::<usize>()
    }

    /// Paper-convention storage bits (Eq. 9 numerator) at width `b`.
    pub fn storage_bits(&self, b: u32) -> usize {
        let b = b as usize;
        b * self.w_s.nnz() + self.dout() * self.din() + b * self.rank() * (self.dout() + self.din())
    }

    // ------------------------------------------------------------------
    // Serialization (into the shared checkpoint container)
    // ------------------------------------------------------------------

    /// This layer's checkpoint entries under `prefix` — the unit the
    /// pipeline's streaming emit stage appends per block (a
    /// [`crate::tensor::CheckpointWriter`] consumer never holds more
    /// than one block's entries in memory; DESIGN.md §10). The leading
    /// `{prefix}.shape` entry doubles as the layer marker the loader
    /// scans for.
    pub fn entries(&self, prefix: &str) -> Vec<Entry> {
        let mut out = Vec::with_capacity(5 + 2 * self.rank());
        out.push(Entry {
            name: format!("{prefix}.shape"),
            dims: vec![2],
            data: TensorData::I32(vec![self.dout() as i32, self.din() as i32]),
        });
        out.push(Entry {
            name: format!("{prefix}.ws.row_ptr"),
            dims: vec![self.w_s.row_ptr.len()],
            data: TensorData::I32(self.w_s.row_ptr.iter().map(|&x| x as i32).collect()),
        });
        out.push(Entry {
            name: format!("{prefix}.ws.col_idx"),
            dims: vec![self.w_s.col_idx.len()],
            data: TensorData::I32(self.w_s.col_idx.iter().map(|&x| x as i32).collect()),
        });
        out.push(Entry::f32(
            &format!("{prefix}.ws.vals"),
            vec![self.w_s.vals.len()],
            self.w_s.vals.clone(),
        ));
        for k in 0..self.rank() {
            out.push(Entry::f32(
                &format!("{prefix}.u{k}"),
                vec![self.u[k].len()],
                self.u[k].clone(),
            ));
            out.push(Entry::f32(
                &format!("{prefix}.v{k}"),
                vec![self.v[k].len()],
                self.v[k].clone(),
            ));
        }
        // Bit matrix stored as its packed u64 bitplane words
        // (little-endian bytes): the true 1-bit/element size on disk
        // (modulo row padding), 8× smaller than the legacy
        // u8-per-element form, which `load_from` still accepts.
        let words = self.w_b.words();
        let mut bytes = Vec::with_capacity(words.len() * 8);
        for &w in words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        out.push(Entry {
            name: format!("{prefix}.wb.bits"),
            dims: vec![self.dout(), self.w_b.words_per_row() * 8],
            data: TensorData::U8(bytes),
        });
        out
    }

    /// Append this layer's tensors under `prefix` to a checkpoint.
    pub fn save_into(&self, ck: &mut Checkpoint, prefix: &str) {
        for e in self.entries(prefix) {
            ck.push(e);
        }
    }

    /// Load a layer saved by [`save_into`].
    pub fn load_from(ck: &Checkpoint, prefix: &str) -> Option<SlabLayer> {
        let shape = ck.get(&format!("{prefix}.shape"))?.data.as_i32()?.to_vec();
        let (dout, din) = (shape[0] as usize, shape[1] as usize);
        let row_ptr: Vec<u32> = ck
            .get(&format!("{prefix}.ws.row_ptr"))?
            .data
            .as_i32()?
            .iter()
            .map(|&x| x as u32)
            .collect();
        let col_idx: Vec<u32> = ck
            .get(&format!("{prefix}.ws.col_idx"))?
            .data
            .as_i32()?
            .iter()
            .map(|&x| x as u32)
            .collect();
        let vals = ck.get(&format!("{prefix}.ws.vals"))?.data.as_f32()?.to_vec();
        let w_s = Csr {
            rows: dout,
            cols: din,
            row_ptr,
            col_idx,
            vals,
        };
        w_s.validate().ok()?;
        let mut u = Vec::new();
        let mut v = Vec::new();
        let mut k = 0;
        while let (Some(ue), Some(ve)) = (
            ck.get(&format!("{prefix}.u{k}")),
            ck.get(&format!("{prefix}.v{k}")),
        ) {
            u.push(ue.data.as_f32()?.to_vec());
            v.push(ve.data.as_f32()?.to_vec());
            k += 1;
        }
        let w_b = if let Some(e) = ck.get(&format!("{prefix}.wb.bits")) {
            // Packed u64 bitplane words (current format).
            let bytes = e.data.as_u8()?;
            let wpr = din.div_ceil(64);
            if bytes.len() != dout * wpr * 8 {
                return None;
            }
            let words: Vec<u64> = bytes
                .chunks_exact(8)
                .map(|c| {
                    u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
                })
                .collect();
            BitMat::from_words(dout, din, words)
        } else {
            // Legacy u8-per-element form (pre-packed checkpoints).
            let e = ck.get(&format!("{prefix}.wb"))?;
            let bytes = e.data.as_u8()?;
            if bytes.len() != dout * din {
                return None;
            }
            let dense = Mat::from_vec(
                dout,
                din,
                bytes.iter().map(|&b| if b != 0 { 1.0 } else { -1.0 }).collect(),
            );
            BitMat::from_sign_of(&dense)
        };
        Some(SlabLayer { w_s, u, v, w_b })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::config::SlabConfig;
    use crate::slab::decompose::decompose;
    use crate::slab::scores::ActStats;
    use crate::tensor::ops::matmul_bt;
    use crate::util::rng::Pcg64;

    fn layer(seed: u64) -> (Mat, SlabLayer) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let w = Mat::randn(40, 72, 0.05, &mut rng);
        let x = Mat::randn(32, 72, 1.0, &mut rng);
        let stats = ActStats::from_activations(&x);
        let cfg = SlabConfig {
            iters: 4,
            svd_iters: 10,
            ..Default::default()
        };
        let d = decompose(&w, &stats, &cfg).unwrap();
        (w, SlabLayer::from_decomposition(&d))
    }

    #[test]
    fn forward_equals_dense_reconstruction() {
        let (_, l) = layer(100);
        let mut rng = Pcg64::seed_from_u64(101);
        let x = Mat::randn(6, 72, 1.0, &mut rng);
        let y_packed = l.forward(&x);
        let y_dense = matmul_bt(&x, &l.reconstruct());
        assert!(
            y_packed.allclose(&y_dense, 1e-3, 1e-3),
            "packed vs dense forward"
        );
    }

    #[test]
    fn reconstruction_matches_decomposition() {
        let mut rng = Pcg64::seed_from_u64(102);
        let w = Mat::randn(24, 48, 0.05, &mut rng);
        let stats = ActStats::from_activations(&Mat::randn(16, 48, 1.0, &mut rng));
        let cfg = SlabConfig { iters: 3, ..Default::default() };
        let d = decompose(&w, &stats, &cfg).unwrap();
        let l = SlabLayer::from_decomposition(&d);
        assert!(l.reconstruct().allclose(&d.reconstruct(), 1e-5, 1e-5));
    }

    #[test]
    fn deploy_bytes_beat_dense() {
        let (w, l) = layer(103);
        let dense_bytes = w.numel() * 4;
        assert!(
            l.nbytes_deploy() < dense_bytes,
            "{} should be < {dense_bytes}",
            l.nbytes_deploy()
        );
    }

    #[test]
    fn storage_bits_match_eq9() {
        let (w, l) = layer(104);
        let (dout, din) = w.shape();
        let bits = l.storage_bits(16);
        let expect = 16 * l.w_s.nnz() + dout * din + 16 * (dout + din);
        assert_eq!(bits, expect);
        // And the implied CR is near the target 0.5.
        let cr = 1.0 - bits as f64 / (16.0 * (dout * din) as f64);
        assert!((cr - 0.5).abs() < 0.02, "cr={cr}");
    }

    #[test]
    fn checkpoint_roundtrip() {
        let (_, l) = layer(105);
        let mut ck = Checkpoint::new();
        l.save_into(&mut ck, "blk0.q");
        let path = std::env::temp_dir().join("slab-tests/layer.slabckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let l2 = SlabLayer::load_from(&back, "blk0.q").unwrap();
        assert_eq!(l2, l);
    }

    #[test]
    fn checkpoint_wb_is_bitpacked_on_disk() {
        let (_, l) = layer(106);
        let mut ck = Checkpoint::new();
        l.save_into(&mut ck, "p");
        let e = ck.get("p.wb.bits").unwrap();
        let bytes = e.data.as_u8().unwrap();
        // 1 bit per element (+ row padding to the 64-bit word), not 1 byte.
        assert_eq!(bytes.len(), l.dout() * l.din().div_ceil(64) * 8);
        assert!(bytes.len() * 8 < l.dout() * l.din() * 8);
        assert!(ck.get("p.wb").is_none(), "legacy entry must not be written");
    }

    #[test]
    fn checkpoint_loads_legacy_u8_wb() {
        // Simulate a checkpoint written before the packed format: same
        // entries, but W_B as one u8 per element under `{prefix}.wb`.
        let (_, l) = layer(107);
        let mut ck = Checkpoint::new();
        l.save_into(&mut ck, "q");
        ck.entries.retain(|e| e.name != "q.wb.bits");
        let dense = l.w_b.to_dense();
        ck.push(Entry {
            name: "q.wb".into(),
            dims: vec![l.dout(), l.din()],
            data: TensorData::U8(dense.data.iter().map(|&x| (x >= 0.0) as u8).collect()),
        });
        let back = SlabLayer::load_from(&ck, "q").unwrap();
        assert_eq!(back, l);
    }

    #[test]
    fn fused_into_overwrites_reused_output() {
        // The per-tick serving shape: one output matrix held across
        // calls; stale contents (poisoned with NaN) must be fully
        // overwritten, and the result must stay bit-identical to the
        // reference forward.
        let (_, l) = layer(110);
        let mut rng = Pcg64::seed_from_u64(111);
        let pool = ThreadPool::new(4);
        let mut y = Mat::filled(3, l.dout(), f32::NAN);
        let x1 = Mat::randn(3, 72, 1.0, &mut rng);
        l.forward_fused_into(&x1, None, &mut y);
        assert_eq!(y, l.forward(&x1));
        let x2 = Mat::randn(3, 72, 1.0, &mut rng);
        l.forward_fused_into(&x2, Some(&pool), &mut y);
        assert_eq!(y, l.forward(&x2));
    }

    #[test]
    fn fused_forward_is_bit_identical_to_reference() {
        let (_, l) = layer(108);
        let mut rng = Pcg64::seed_from_u64(109);
        let pool = ThreadPool::new(4);
        for batch in [1usize, 2, 7] {
            let x = Mat::randn(batch, 72, 1.0, &mut rng);
            let y_ref = l.forward(&x);
            assert_eq!(l.forward_fused(&x, None), y_ref, "fused batch {batch}");
            assert_eq!(l.forward_fused(&x, Some(&pool)), y_ref, "fused+pool batch {batch}");
        }
    }
}
