//! Naive sparse + plain low-rank combination — the strawman of the
//! paper's Fig. 1: `W ≈ W_S + U_r·V_rᵀ` with **no binary matrix**, at
//! a joint compression ratio.
//!
//! Storage at b bits: `b·k + b·r·(Dout+Din)`, so
//!
//! ```text
//! keep_frac = 1 − CR − r·(Dout+Din)/(Dout·Din)
//! ```
//!
//! — every extra unit of rank eats directly into the sparse budget,
//! which is why perplexity *worsens* with rank in Fig. 1 while SLaB's
//! 1-bit `W_B` + rank-1 `W_L` gets the compensation almost for free.

use super::CompressedLayer;
use crate::slab::config::ConfigError;
use crate::slab::scores::{wanda_scores, ActStats};
use crate::slab::threshold::group_topk_mask;
use crate::tensor::{svd_truncated, Mat};

/// Keep fraction for the sparse part at joint `cr` with rank `r`
/// (both components stored at the same bit width, so `b` cancels).
pub fn lowrank_sparse_keep_fraction(
    cr: f64,
    rank: usize,
    dout: usize,
    din: usize,
) -> Result<f64, ConfigError> {
    let overhead = rank as f64 * (dout + din) as f64 / (dout * din) as f64;
    let f = 1.0 - cr - overhead;
    if f <= 0.0 || f >= 1.0 {
        return Err(ConfigError::Infeasible(f, cr, dout, din, 16));
    }
    Ok(f)
}

/// Alternating sparse + rank-r decomposition (Wanda-style scores for
/// the sparse part, plain truncated SVD of the residual for the
/// low-rank part). `rank = 0` degenerates to Wanda at sparsity `cr`.
pub fn lowrank_sparse_compress(
    w: &Mat,
    stats: &ActStats,
    cr: f64,
    rank: usize,
    iters: usize,
) -> Result<CompressedLayer, ConfigError> {
    let (dout, din) = w.shape();
    let keep = lowrank_sparse_keep_fraction(cr, rank, dout, din)?;

    let mut w_s = Mat::zeros(dout, din);
    let mut lr = Mat::zeros(dout, din);
    let mut kept = 0usize;
    for t in 0..iters.max(1) {
        if rank > 0 {
            let y = w.sub(&w_s);
            let svd = svd_truncated(&y, rank, 8, 0x516 ^ t as u64);
            lr = svd.reconstruct();
        }
        let y_s = w.sub(&lr);
        let s = wanda_scores(&y_s, stats);
        let mask = group_topk_mask(&s, keep, 1, din);
        w_s = y_s.hadamard(&mask);
        kept = mask.count_nonzero();
        if rank == 0 {
            break;
        }
    }
    let w_hat = w_s.add(&lr);
    Ok(CompressedLayer {
        kept,
        frob_err: w.frob_dist(&w_hat),
        w_hat,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn budget_shrinks_with_rank() {
        let f0 = lowrank_sparse_keep_fraction(0.5, 0, 256, 512).unwrap();
        let f8 = lowrank_sparse_keep_fraction(0.5, 8, 256, 512).unwrap();
        let f64v = lowrank_sparse_keep_fraction(0.5, 64, 256, 512).unwrap();
        assert!(f0 > f8 && f8 > f64v);
        assert!((f0 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn infeasible_rank_rejected() {
        // rank so large the low-rank factors alone exceed the budget.
        assert!(lowrank_sparse_keep_fraction(0.5, 100, 64, 64).is_err());
    }

    #[test]
    fn rank0_equals_wanda() {
        let mut rng = Pcg64::seed_from_u64(160);
        let w = Mat::randn(16, 64, 0.05, &mut rng);
        let x = Mat::randn(32, 64, 1.0, &mut rng);
        let stats = ActStats::from_activations(&x);
        let ls = lowrank_sparse_compress(&w, &stats, 0.5, 0, 3).unwrap();
        let wa = super::super::wanda::wanda_prune(&w, &stats, 0.5, None);
        assert!(ls.w_hat.allclose(&wa.w_hat, 1e-6, 1e-6));
    }

    #[test]
    fn exact_sparse_count() {
        let mut rng = Pcg64::seed_from_u64(161);
        let w = Mat::randn(32, 128, 0.05, &mut rng);
        let stats = ActStats::from_activations(&Mat::randn(64, 128, 1.0, &mut rng));
        let out = lowrank_sparse_compress(&w, &stats, 0.5, 4, 3).unwrap();
        let keep = lowrank_sparse_keep_fraction(0.5, 4, 32, 128).unwrap();
        assert_eq!(out.kept, ((keep * 128.0).floor() as usize) * 32);
    }

    #[test]
    fn fig1_shape_error_grows_with_rank_on_gaussian() {
        // Fig 1's driver at the weight level: on weights without strong
        // low-rank structure, burning budget on rank hurts.
        let mut rng = Pcg64::seed_from_u64(162);
        let w = Mat::randn(128, 512, 0.05, &mut rng);
        let stats = ActStats::from_activations(&Mat::randn(128, 512, 1.0, &mut rng));
        let e = |r| lowrank_sparse_compress(&w, &stats, 0.5, r, 3).unwrap().frob_err;
        let e0 = e(0);
        let e8 = e(8);
        let e24 = e(24);
        assert!(e24 > e0, "rank24 {e24} should exceed rank0 {e0}");
        assert!(e24 > e8 * 0.99, "monotone-ish tail");
    }
}
