//! SparseGPT (Frantar & Alistarh, ICML 2023) — one-shot pruning with
//! OBS weight reconstruction.
//!
//! Faithful port of the reference algorithm:
//!
//! 1. `H = XᵀX + λI` (λ = percdamp · mean diag),
//! 2. `U = chol(H⁻¹, upper)` so `H⁻¹ = Uᵀ·U`,
//! 3. sweep columns left→right in blocks of `blocksize`; within a
//!    block, score each weight `w_ij² / U_jj²`, prune to the target
//!    sparsity (block-global threshold, or N:M per aligned window),
//!    and propagate the OBS error `(w − q)/U_jj` into the *unpruned*
//!    columns to the right (`W[:, j:] −= err · U[j, j:]`),
//! 4. after each block, push the accumulated error into the remaining
//!    columns (`W[:, j2:] −= Err · U[j1:j2, j2:]`).
//!
//! The weight *update* is what separates SparseGPT from Wanda — and
//! why it needs the full Gram matrix, Cholesky, and O(Din³) work.

use super::CompressedLayer;
use crate::slab::scores::ActStats;
use crate::sparse::NmPattern;
use crate::tensor::linalg::{cholesky, spd_inverse};
use crate::tensor::Mat;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SparseGptConfig {
    /// Lazy-update block width (reference: 128; smaller fits our dims).
    pub blocksize: usize,
    /// Hessian damping as a fraction of mean(diag(H)).
    pub percdamp: f64,
}

impl Default for SparseGptConfig {
    fn default() -> Self {
        SparseGptConfig {
            blocksize: 32,
            percdamp: 0.01,
        }
    }
}

/// Run SparseGPT on one layer. `stats.gram` must be present.
pub fn sparsegpt_prune(
    w: &Mat,
    stats: &ActStats,
    sparsity: f64,
    pattern: Option<NmPattern>,
    cfg: &SparseGptConfig,
) -> Result<CompressedLayer, String> {
    let (dout, din) = w.shape();
    let gram = stats
        .gram
        .as_ref()
        .ok_or_else(|| "SparseGPT requires gram statistics".to_string())?;
    if gram.rows != din {
        return Err(format!("gram dim {} vs Din {}", gram.rows, din));
    }

    // --- Hessian prep -------------------------------------------------
    let mut h = gram.clone();
    // Dead inputs: zero-diagonal columns can't be reconstructed; pin
    // them and zero the weights (reference behaviour).
    let mut dead = vec![false; din];
    for j in 0..din {
        if h.at(j, j) == 0.0 {
            dead[j] = true;
            h.set(j, j, 1.0);
        }
    }
    let mean_diag: f64 = (0..din).map(|j| h.at(j, j) as f64).sum::<f64>() / din as f64;
    let damp = (cfg.percdamp * mean_diag) as f32;
    for j in 0..din {
        *h.at_mut(j, j) += damp;
    }

    // U upper with H⁻¹ = UᵀU.
    let hinv = spd_inverse(&h).map_err(|e| format!("H inverse: {e}"))?;
    let l = cholesky(&hinv).map_err(|e| format!("chol(Hinv): {e}"))?;
    let u = l.transpose();

    // --- column sweep ---------------------------------------------------
    let mut wk = w.clone(); // working copy, mutated in place
    for j in 0..din {
        if dead[j] {
            for i in 0..dout {
                wk.set(i, j, 0.0);
            }
        }
    }
    let bs = cfg.blocksize.max(1);
    let mut kept = 0usize;

    let mut j1 = 0;
    while j1 < din {
        let j2 = (j1 + bs).min(din);
        let width = j2 - j1;
        // Pruning mask for this block (true = prune).
        let mut prune = vec![false; dout * width];
        match pattern {
            None => {
                // Block-global threshold on w²/U_jj².
                let mut scores: Vec<(f32, usize)> = Vec::with_capacity(dout * width);
                for i in 0..dout {
                    for c in 0..width {
                        let d = u.at(j1 + c, j1 + c);
                        let s = (wk.at(i, j1 + c) / d).powi(2);
                        scores.push((s, i * width + c));
                    }
                }
                let n_prune = ((scores.len() as f64) * sparsity).round() as usize;
                if n_prune > 0 && n_prune <= scores.len() {
                    let idx = n_prune - 1;
                    scores.select_nth_unstable_by(idx, |a, b| {
                        a.0.partial_cmp(&b.0)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(a.1.cmp(&b.1))
                    });
                    for &(_, flat) in scores[..n_prune].iter() {
                        prune[flat] = true;
                    }
                }
            }
            Some(p) => {
                // N:M inside aligned windows (block boundaries are
                // chosen divisible by m for our dims; handle ragged
                // windows by proportional pruning).
                let m = p.m;
                for i in 0..dout {
                    let mut c0 = 0;
                    while c0 < width {
                        let c1 = (c0 + m).min(width);
                        let len = c1 - c0;
                        let n_keep = if len == m {
                            p.n
                        } else {
                            (p.n * len).div_ceil(m)
                        };
                        let mut idx: Vec<usize> = (c0..c1).collect();
                        idx.sort_by(|&a, &b| {
                            let sa = (wk.at(i, j1 + a) / u.at(j1 + a, j1 + a)).powi(2);
                            let sb = (wk.at(i, j1 + b) / u.at(j1 + b, j1 + b)).powi(2);
                            sb.partial_cmp(&sa).unwrap_or(std::cmp::Ordering::Equal)
                        });
                        for &c in idx.iter().skip(n_keep) {
                            prune[i * width + c] = true;
                        }
                        c0 = c1;
                    }
                }
            }
        }

        // OBS sweep inside the block.
        let mut err1 = Mat::zeros(dout, width);
        for c in 0..width {
            let j = j1 + c;
            let d = u.at(j, j);
            for i in 0..dout {
                let wij = wk.at(i, j);
                let q = if prune[i * width + c] { 0.0 } else { wij };
                let e = (wij - q) / d;
                if q != 0.0 {
                    kept += 1;
                }
                // Propagate within the remainder of the block.
                if e != 0.0 {
                    for cc in c..width {
                        *wk.at_mut(i, j1 + cc) -= e * u.at(j, j1 + cc);
                    }
                }
                wk.set(i, j, q);
                err1.set(i, c, e);
            }
        }
        // Lazy batch update of all columns right of the block:
        // W[:, j2:] -= Err1 · U[j1:j2, j2:].
        if j2 < din {
            for i in 0..dout {
                let erow = err1.row(i);
                for c in 0..width {
                    let e = erow[c];
                    if e == 0.0 {
                        continue;
                    }
                    let urow = u.row(j1 + c);
                    let wrow = wk.row_mut(i);
                    for jj in j2..din {
                        wrow[jj] -= e * urow[jj];
                    }
                }
            }
        }
        j1 = j2;
    }

    Ok(CompressedLayer {
        kept,
        frob_err: w.frob_dist(&wk),
        w_hat: wk,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::PATTERN_2_4;
    use crate::tensor::ops::matmul_bt;
    use crate::util::rng::Pcg64;

    fn setup(dout: usize, din: usize, seed: u64) -> (Mat, Mat, ActStats) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let w = Mat::randn(dout, din, 0.05, &mut rng);
        let x = Mat::randn(4 * din, din, 1.0, &mut rng);
        let stats = ActStats::from_activations_with_gram(&x);
        (w, x, stats)
    }

    #[test]
    fn sparsity_is_hit() {
        let (w, _, stats) = setup(24, 48, 150);
        let out = sparsegpt_prune(&w, &stats, 0.5, None, &SparseGptConfig::default()).unwrap();
        let nnz = out.w_hat.count_nonzero();
        let total = 24 * 48;
        // Block-global selection: within ±2% of the target.
        assert!(
            (nnz as f64 - total as f64 * 0.5).abs() < total as f64 * 0.02,
            "nnz={nnz}"
        );
    }

    #[test]
    fn requires_gram() {
        let (w, _, _) = setup(8, 16, 151);
        let no_gram = ActStats::uniform(16);
        assert!(sparsegpt_prune(&w, &no_gram, 0.5, None, &SparseGptConfig::default()).is_err());
    }

    #[test]
    fn nm_pattern_respected() {
        let (w, _, stats) = setup(16, 64, 152);
        let out =
            sparsegpt_prune(&w, &stats, 0.5, Some(PATTERN_2_4), &SparseGptConfig::default())
                .unwrap();
        PATTERN_2_4.validate(&out.w_hat).unwrap();
    }

    #[test]
    fn obs_update_beats_wanda_on_output_error() {
        // SparseGPT minimizes ||X·Wᵀ − X·Ŵᵀ||, not ||W − Ŵ||. Verify it
        // beats Wanda on the *output* reconstruction it optimizes.
        let (w, x, stats) = setup(32, 64, 153);
        let sg = sparsegpt_prune(&w, &stats, 0.6, None, &SparseGptConfig::default()).unwrap();
        let wa = super::super::wanda::wanda_prune(&w, &stats, 0.6, None);
        let y = matmul_bt(&x, &w);
        let e_sg = y.frob_dist(&matmul_bt(&x, &sg.w_hat));
        let e_wa = y.frob_dist(&matmul_bt(&x, &wa.w_hat));
        assert!(e_sg < e_wa, "sparsegpt {e_sg} < wanda {e_wa}");
    }

    #[test]
    fn surviving_weights_are_updated_not_copied() {
        // The OBS compensation must actually move surviving weights.
        let (w, _, stats) = setup(16, 32, 154);
        let out = sparsegpt_prune(&w, &stats, 0.5, None, &SparseGptConfig::default()).unwrap();
        let mut moved = 0;
        for i in 0..16 {
            for j in 0..32 {
                let v = out.w_hat.at(i, j);
                if v != 0.0 && (v - w.at(i, j)).abs() > 1e-7 {
                    moved += 1;
                }
            }
        }
        assert!(moved > 0, "no weights were OBS-updated");
    }

    #[test]
    fn dead_columns_are_zeroed() {
        let mut rng = Pcg64::seed_from_u64(155);
        let w = Mat::randn(8, 16, 0.05, &mut rng);
        let mut x = Mat::randn(64, 16, 1.0, &mut rng);
        for i in 0..64 {
            x.set(i, 3, 0.0); // dead input feature
        }
        let stats = ActStats::from_activations_with_gram(&x);
        let out = sparsegpt_prune(&w, &stats, 0.25, None, &SparseGptConfig::default()).unwrap();
        for i in 0..8 {
            assert_eq!(out.w_hat.at(i, 3), 0.0);
        }
    }

    #[test]
    fn blocksize_invariance_of_quality() {
        let (w, x, stats) = setup(16, 48, 156);
        let y = matmul_bt(&x, &w);
        let mut errs = Vec::new();
        for bs in [8, 16, 48] {
            let cfg = SparseGptConfig {
                blocksize: bs,
                ..Default::default()
            };
            let out = sparsegpt_prune(&w, &stats, 0.5, None, &cfg).unwrap();
            errs.push(y.frob_dist(&matmul_bt(&x, &out.w_hat)));
        }
        // Same ballpark across block sizes (lazy update is exact; only
        // mask selection granularity differs).
        let min = errs.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = errs.iter().cloned().fold(0.0f32, f32::max);
        assert!(max < min * 1.5, "errs={errs:?}");
    }
}
