//! Wanda (Sun et al. 2023) — pruning with weights × activation norms.
//!
//! Score `S_ij = |W_ij| · ||X_j||₂`, comparison group `(1, Din)`,
//! prune to target sparsity, no weight update. This is both a Table-I
//! baseline and the `rank = 0` degenerate case of SLaB (the identity
//! is pinned by a test in `slab::decompose`).

use super::CompressedLayer;
use crate::slab::scores::{wanda_scores, ActStats};
use crate::slab::threshold::{group_topk_mask, semi_structured_mask};
use crate::sparse::NmPattern;
use crate::tensor::Mat;

/// Prune to `sparsity` (fraction zeroed), optional N:M pattern.
pub fn wanda_prune(
    w: &Mat,
    stats: &ActStats,
    sparsity: f64,
    pattern: Option<NmPattern>,
) -> CompressedLayer {
    let keep = 1.0 - sparsity;
    let scores = wanda_scores(w, stats);
    let mask = match pattern {
        None => group_topk_mask(&scores, keep, 1, w.cols),
        Some(p) => semi_structured_mask(&scores, keep, p, 1, w.cols),
    };
    let w_hat = w.hadamard(&mask);
    CompressedLayer {
        kept: mask.count_nonzero(),
        frob_err: w.frob_dist(&w_hat),
        w_hat,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::PATTERN_4_8;
    use crate::util::rng::Pcg64;

    #[test]
    fn activation_weighting_changes_selection() {
        // Two equal-magnitude weights; the one feeding the high-norm
        // input column must survive.
        let w = Mat::from_vec(1, 2, vec![0.5, 0.5]);
        let stats = ActStats {
            col_norms: vec![10.0, 0.1],
            gram: None,
            samples: 1,
        };
        let out = wanda_prune(&w, &stats, 0.5, None);
        assert_eq!(out.w_hat.data, vec![0.5, 0.0]);
    }

    #[test]
    fn uniform_stats_reduce_to_magnitude() {
        let mut rng = Pcg64::seed_from_u64(140);
        let w = Mat::randn(12, 48, 1.0, &mut rng);
        let wa = wanda_prune(&w, &ActStats::uniform(48), 0.5, None);
        let ma = super::super::magnitude::magnitude_prune(&w, 0.5, None);
        assert_eq!(wa.w_hat, ma.w_hat);
    }

    #[test]
    fn kept_values_are_original() {
        let mut rng = Pcg64::seed_from_u64(141);
        let w = Mat::randn(8, 32, 1.0, &mut rng);
        let x = Mat::randn(64, 32, 1.0, &mut rng);
        let out = wanda_prune(&w, &ActStats::from_activations(&x), 0.5, None);
        for i in 0..8 {
            for j in 0..32 {
                let v = out.w_hat.at(i, j);
                assert!(v == 0.0 || v == w.at(i, j));
            }
        }
    }

    #[test]
    fn nm_pattern_respected() {
        let mut rng = Pcg64::seed_from_u64(142);
        let w = Mat::randn(8, 64, 1.0, &mut rng);
        let x = Mat::randn(32, 64, 1.0, &mut rng);
        let out = wanda_prune(&w, &ActStats::from_activations(&x), 0.5, Some(PATTERN_4_8));
        PATTERN_4_8.validate(&out.w_hat).unwrap();
    }
}
