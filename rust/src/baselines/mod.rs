//! Baseline compression methods — every comparator in the paper's
//! evaluation (Tables I–III, Fig. 1).
//!
//! All methods implement [`Method::compress_layer`]: given a dense
//! weight `W (Dout, Din)` and calibration [`ActStats`], return the
//! compressed layer's dense reconstruction `Ŵ` (what the model serves)
//! plus bookkeeping. For pure pruning baselines (magnitude, Wanda,
//! SparseGPT) "CR" means *sparsity* — the convention the paper's
//! Table I uses ("Sparsity(CR)").

pub mod lowrank_sparse;
pub mod magnitude;
pub mod sparsegpt;
pub mod wanda;

use crate::slab::{ablate, decompose, ActStats, SlabConfig, Structure, Variant};
use crate::sparse::NmPattern;
use crate::tensor::Mat;

pub use lowrank_sparse::lowrank_sparse_compress;
pub use magnitude::magnitude_prune;
pub use sparsegpt::{sparsegpt_prune, SparseGptConfig};
pub use wanda::wanda_prune;

/// A compression method applied layer-by-layer.
#[derive(Debug, Clone)]
pub enum Method {
    /// No compression (the dense reference row of Table I).
    Dense,
    /// Magnitude pruning at `sparsity`, optional N:M.
    Magnitude {
        sparsity: f64,
        pattern: Option<NmPattern>,
    },
    /// Wanda (activation-aware) pruning at `sparsity`, optional N:M.
    Wanda {
        sparsity: f64,
        pattern: Option<NmPattern>,
    },
    /// SparseGPT (OBS reconstruction) at `sparsity`, optional N:M.
    SparseGpt {
        sparsity: f64,
        pattern: Option<NmPattern>,
        cfg: SparseGptConfig,
    },
    /// SLaB (the paper's method).
    Slab(SlabConfig),
    /// Naive sparse + plain rank-r low-rank at a joint CR (Fig. 1).
    LowrankSparse { cr: f64, rank: usize, iters: usize },
    /// Table III component ablations (share SLaB's budget/config).
    Ablation(SlabConfig, Variant),
}

/// Output of compressing one layer.
#[derive(Debug, Clone)]
pub struct CompressedLayer {
    /// Dense reconstruction served by the model.
    pub w_hat: Mat,
    /// Non-zeros in the sparse component (numel for Dense).
    pub kept: usize,
    /// Frobenius error vs the original weight.
    pub frob_err: f32,
}

#[derive(Debug, thiserror::Error)]
pub enum MethodError {
    #[error("config: {0}")]
    Config(#[from] crate::slab::config::ConfigError),
    #[error("sparsegpt: {0}")]
    SparseGpt(String),
    #[error("method needs gram statistics but ActStats.gram is None")]
    MissingGram,
}

impl Method {
    /// Human-readable method name (Table I row labels).
    pub fn name(&self) -> String {
        match self {
            Method::Dense => "Dense".into(),
            Method::Magnitude { .. } => "Magnitude".into(),
            Method::Wanda { .. } => "Wanda".into(),
            Method::SparseGpt { .. } => "SparseGPT".into(),
            Method::Slab(_) => "SLaB".into(),
            Method::LowrankSparse { rank, .. } => format!("Sparse+LR(r={rank})"),
            Method::Ablation(_, v) => v.label(),
        }
    }

    /// The paper's "Sparsity(CR)" column label.
    pub fn sparsity_label(&self) -> String {
        fn pat_or_us(p: &Option<NmPattern>, s: f64) -> String {
            match p {
                Some(p) => format!("{} ({:.0}%)", p.name(), s * 100.0),
                None => format!("US ({:.0}%)", s * 100.0),
            }
        }
        match self {
            Method::Dense => "0%".into(),
            Method::Magnitude { sparsity, pattern } | Method::Wanda { sparsity, pattern } => {
                pat_or_us(pattern, *sparsity)
            }
            Method::SparseGpt {
                sparsity, pattern, ..
            } => pat_or_us(pattern, *sparsity),
            Method::Slab(cfg) | Method::Ablation(cfg, _) => match cfg.structure {
                Structure::Unstructured => format!("US ({:.0}%)", cfg.cr * 100.0),
                Structure::SemiStructured(p) => {
                    format!("{} ({:.0}%)", p.name(), cfg.cr * 100.0)
                }
            },
            Method::LowrankSparse { cr, .. } => format!("US ({:.0}%)", cr * 100.0),
        }
    }

    /// Whether this method requires Gram (Hessian) statistics.
    pub fn needs_gram(&self) -> bool {
        matches!(self, Method::SparseGpt { .. })
    }

    /// Compress one linear layer.
    pub fn compress_layer(
        &self,
        w: &Mat,
        stats: &ActStats,
    ) -> Result<CompressedLayer, MethodError> {
        let out = match self {
            Method::Dense => CompressedLayer {
                w_hat: w.clone(),
                kept: w.numel(),
                frob_err: 0.0,
            },
            Method::Magnitude { sparsity, pattern } => {
                magnitude_prune(w, *sparsity, *pattern)
            }
            Method::Wanda { sparsity, pattern } => {
                wanda_prune(w, stats, *sparsity, *pattern)
            }
            Method::SparseGpt {
                sparsity,
                pattern,
                cfg,
            } => sparsegpt_prune(w, stats, *sparsity, *pattern, cfg)
                .map_err(MethodError::SparseGpt)?,
            Method::Slab(cfg) => {
                let d = decompose(w, stats, cfg)?;
                CompressedLayer {
                    w_hat: d.reconstruct(),
                    kept: d.kept,
                    frob_err: *d.frob_trace.last().unwrap_or(&0.0),
                }
            }
            Method::LowrankSparse { cr, rank, iters } => {
                lowrank_sparse_compress(w, stats, *cr, *rank, *iters)?
            }
            Method::Ablation(cfg, variant) => {
                let out = ablate(w, stats, cfg, *variant)?;
                CompressedLayer {
                    w_hat: out.w_hat,
                    kept: out.kept,
                    frob_err: out.frob_err,
                }
            }
        };
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn names_and_labels() {
        let m = Method::Wanda {
            sparsity: 0.5,
            pattern: Some(crate::sparse::PATTERN_2_4),
        };
        assert_eq!(m.name(), "Wanda");
        assert_eq!(m.sparsity_label(), "2:4 (50%)");
        assert_eq!(Method::Dense.sparsity_label(), "0%");
        let s = Method::Slab(SlabConfig::default());
        assert_eq!(s.sparsity_label(), "US (50%)");
    }

    #[test]
    fn dense_is_identity() {
        let mut rng = Pcg64::seed_from_u64(120);
        let w = Mat::randn(8, 8, 1.0, &mut rng);
        let out = Method::Dense
            .compress_layer(&w, &ActStats::uniform(8))
            .unwrap();
        assert_eq!(out.w_hat, w);
        assert_eq!(out.frob_err, 0.0);
    }

    #[test]
    fn method_error_ordering_at_50() {
        // The Table-I story at one layer: SLaB < SparseGPT ≈ Wanda in
        // reconstruction error at the same CR.
        let mut rng = Pcg64::seed_from_u64(121);
        let w = Mat::randn(96, 192, 0.05, &mut rng);
        let x = Mat::randn(128, 192, 1.0, &mut rng);
        let stats = ActStats::from_activations_with_gram(&x);
        let err = |m: Method| m.compress_layer(&w, &stats).unwrap().frob_err;
        let slab = err(Method::Slab(SlabConfig {
            iters: 5,
            ..Default::default()
        }));
        let wanda = err(Method::Wanda {
            sparsity: 0.5,
            pattern: None,
        });
        let mag = err(Method::Magnitude {
            sparsity: 0.5,
            pattern: None,
        });
        assert!(slab < wanda, "slab {slab} < wanda {wanda}");
        // On isotropic calibration data wanda ≈ magnitude; both well
        // above slab.
        assert!(slab < mag, "slab {slab} < magnitude {mag}");
    }
}
