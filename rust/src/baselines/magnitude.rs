//! Magnitude pruning — the classic no-calibration baseline
//! (Han et al. 2015). Scores are `|W|`; selection uses the same
//! per-row comparison groups as Wanda so the only difference is the
//! activation weighting.

use super::CompressedLayer;
use crate::slab::threshold::{group_topk_mask, semi_structured_mask};
use crate::sparse::NmPattern;
use crate::tensor::Mat;

/// Prune to `sparsity` (fraction zeroed), optional N:M pattern.
pub fn magnitude_prune(w: &Mat, sparsity: f64, pattern: Option<NmPattern>) -> CompressedLayer {
    let keep = 1.0 - sparsity;
    let scores = w.abs();
    let mask = match pattern {
        None => group_topk_mask(&scores, keep, 1, w.cols),
        Some(p) => semi_structured_mask(&scores, keep, p, 1, w.cols),
    };
    let w_hat = w.hadamard(&mask);
    CompressedLayer {
        kept: mask.count_nonzero(),
        frob_err: w.frob_dist(&w_hat),
        w_hat,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::PATTERN_2_4;
    use crate::util::rng::Pcg64;

    #[test]
    fn keeps_largest_magnitudes() {
        let w = Mat::from_vec(1, 4, vec![0.1, -0.9, 0.5, -0.2]);
        let out = magnitude_prune(&w, 0.5, None);
        assert_eq!(out.w_hat.data, vec![0.0, -0.9, 0.5, 0.0]);
        assert_eq!(out.kept, 2);
    }

    #[test]
    fn sparsity_exact_per_row() {
        let mut rng = Pcg64::seed_from_u64(130);
        let w = Mat::randn(16, 64, 1.0, &mut rng);
        let out = magnitude_prune(&w, 0.75, None);
        assert_eq!(out.kept, 16 * 16);
        for i in 0..16 {
            assert_eq!(out.w_hat.row(i).iter().filter(|&&v| v != 0.0).count(), 16);
        }
    }

    #[test]
    fn nm_pattern_respected() {
        let mut rng = Pcg64::seed_from_u64(131);
        let w = Mat::randn(8, 32, 1.0, &mut rng);
        let out = magnitude_prune(&w, 0.5, Some(PATTERN_2_4));
        PATTERN_2_4.validate(&out.w_hat).unwrap();
        assert_eq!(out.kept, 8 * 16);
    }

    #[test]
    fn error_grows_with_sparsity() {
        let mut rng = Pcg64::seed_from_u64(132);
        let w = Mat::randn(32, 64, 1.0, &mut rng);
        let e50 = magnitude_prune(&w, 0.5, None).frob_err;
        let e80 = magnitude_prune(&w, 0.8, None).frob_err;
        assert!(e80 > e50);
    }
}
