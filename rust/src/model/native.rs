//! Native packed-serving model: a pure-Rust transformer forward that
//! consumes the SLaB deployment format **directly** — no dense `Ŵ`
//! reconstruction, no PJRT client.
//!
//! This is the second serving engine behind
//! [`crate::coordinator::serve::Backend::NativePacked`]: embed →
//! (RMSNorm → RoPE → causal MHA with KV cache → RMSNorm → SwiGLU) ×
//! L → RMSNorm → LM head, with every pruned linear executed out of
//! the packed `W_S + u vᵀ ⊙ W_B` triple via
//! [`SlabLayer::forward_fused`]. The math mirrors
//! `python/compile/model.py` (`prefill` / `decode_step`) operation for
//! operation — same RoPE convention (split halves), same PAD-key
//! masking in prefill, same `s ≤ pos` visibility in decode — so the
//! native engine and the AOT artifacts are interchangeable behind the
//! router (DESIGN.md §6).
//!
//! Scale note: attention here is scalar loops over testbed dims; the
//! linears — where ~all FLOPs live at SLaB's shapes — run the
//! parallel blocked kernels on the model's [`ThreadPool`].

use crate::data::{EOS, PAD};
use crate::model::Params;
use crate::runtime::ModelCfg;
use crate::slab::SlabLayer;
use crate::tensor::ops::softmax_inplace;
use crate::tensor::{matmul_bt, matmul_bt_par, Mat};
use crate::util::kernel::kernel_mode;
use crate::util::pool::{SlotArena, ThreadPool};

/// Matches `model.py::ModelConfig.norm_eps` (not carried by the
/// manifest — it is an architecture constant, not a size).
const NORM_EPS: f32 = 1e-5;
/// Matches `model.py::ModelConfig.rope_theta`.
const ROPE_THETA: f32 = 10000.0;

/// One serving linear: either a dense matrix (unpruned params, or the
/// reconstructed `Ŵ` of a compressed one) or the packed SLaB triple
/// applied straight out of the compressed format.
#[derive(Debug, Clone)]
pub enum Linear {
    Dense(Mat),
    Packed(SlabLayer),
}

impl Linear {
    pub fn dout(&self) -> usize {
        match self {
            Linear::Dense(w) => w.rows,
            Linear::Packed(l) => l.dout(),
        }
    }

    pub fn din(&self) -> usize {
        match self {
            Linear::Dense(w) => w.cols,
            Linear::Packed(l) => l.din(),
        }
    }

    /// `y = x·Wᵀ` for a batch of rows. Dense weights row-chunk the
    /// activation batch across the pool ([`matmul_bt_par`],
    /// bit-identical to the serial kernel); packed ones run the fused
    /// CSR/bitplane kernels. A batch of exactly one row — the
    /// single-session decode shape — takes the fused decode epilogue
    /// ([`SlabLayer::forward_decode`]): one pass per output element
    /// under the process-global [`kernel_mode`]. In the default
    /// `Exact` mode that epilogue is bit-identical to `forward_fused`,
    /// so the routing is invisible to every token-identity test;
    /// `--fast-kernels` / `SLAB_KERNELS=fast` swaps in the
    /// tolerance-gated unrolled row kernels (DESIGN.md §7).
    pub fn apply(&self, x: &Mat, pool: Option<&ThreadPool>) -> Mat {
        match self {
            Linear::Dense(w) => match pool {
                Some(p) => matmul_bt_par(x, w, p),
                None => matmul_bt(x, w),
            },
            Linear::Packed(l) if x.rows == 1 => l.forward_decode(x, pool, kernel_mode()),
            Linear::Packed(l) => l.forward_fused(x, pool),
        }
    }

    /// The **draft** application for self-speculative decoding
    /// (DESIGN.md §14): packed linears forward only their sparse +
    /// low-rank components ([`SlabLayer::forward_draft`], capped at
    /// `rank_cap` ranks) — no bitplane work at all — while dense
    /// linears have no cheap split and run [`apply`](Linear::apply)
    /// unchanged. Draft outputs are approximate by design; the verify
    /// pass through the full forward keeps decoding lossless.
    pub fn apply_draft(&self, x: &Mat, pool: Option<&ThreadPool>, rank_cap: usize) -> Mat {
        match self {
            Linear::Packed(l) => l.forward_draft(x, pool, rank_cap),
            Linear::Dense(_) => self.apply(x, pool),
        }
    }

    /// Weight bytes this linear occupies in the serving process.
    pub fn nbytes(&self) -> usize {
        match self {
            Linear::Dense(w) => w.numel() * 4,
            Linear::Packed(l) => l.nbytes_deploy(),
        }
    }
}

/// Which linear application a decode forward runs: the full packed
/// path (the lossless reference — [`Linear::apply`] verbatim) or the
/// sparse+low-rank draft ([`Linear::apply_draft`]). Threaded through
/// one shared compute body so the two paths can never drift in
/// operation order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LinPath {
    Full,
    Draft { rank_cap: usize },
}

impl LinPath {
    #[inline]
    fn apply(self, lin: &Linear, x: &Mat, pool: Option<&ThreadPool>) -> Mat {
        match self {
            LinPath::Full => lin.apply(x, pool),
            LinPath::Draft { rank_cap } => lin.apply_draft(x, pool, rank_cap),
        }
    }
}

/// One transformer block's parameters in serving form.
#[derive(Debug, Clone)]
struct Block {
    attn_norm: Vec<f32>,
    wq: Linear,
    wk: Linear,
    wv: Linear,
    wo: Linear,
    mlp_norm: Vec<f32>,
    w_gate: Linear,
    w_up: Linear,
    w_down: Linear,
}

/// Per-layer KV tensors, `(B, max_seq, dim)` row-major — the native
/// twin of the artifacts' `(L, B, S, H, Hd)` caches (head and feature
/// axes are contiguous either way).
pub struct KvCache {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    bsz: usize,
    max_seq: usize,
    dim: usize,
}

impl KvCache {
    fn new(n_layers: usize, bsz: usize, max_seq: usize, dim: usize) -> KvCache {
        // Lazily materialized: buffers start empty and `write` grows
        // them to the highest written offset, so a session costs
        // O(positions actually written) bytes instead of the
        // worst-case `bsz · max_seq` up-front (DESIGN.md §13).
        KvCache {
            k: vec![Vec::new(); n_layers],
            v: vec![Vec::new(); n_layers],
            bsz,
            max_seq,
            dim,
        }
    }

    pub fn batch_size(&self) -> usize {
        self.bsz
    }

    #[inline]
    fn base(&self, b: usize, s: usize) -> usize {
        (b * self.max_seq + s) * self.dim
    }

    pub(crate) fn write(&mut self, layer: usize, b: usize, s: usize, krow: &[f32], vrow: &[f32]) {
        let o = self.base(b, s);
        let dim = self.dim;
        if self.k[layer].len() < o + dim {
            // Zero-fill any gap (e.g. across the per-batch stride):
            // reads only ever touch written positions (`s ≤ pos`), so
            // the filler is never observed.
            self.k[layer].resize(o + dim, 0.0);
            self.v[layer].resize(o + dim, 0.0);
        }
        self.k[layer][o..o + dim].copy_from_slice(krow);
        self.v[layer][o..o + dim].copy_from_slice(vrow);
    }

    #[inline]
    pub(crate) fn k_at(&self, layer: usize, b: usize, s: usize) -> &[f32] {
        let o = self.base(b, s);
        &self.k[layer][o..o + self.dim]
    }

    #[inline]
    pub(crate) fn v_at(&self, layer: usize, b: usize, s: usize) -> &[f32] {
        let o = self.base(b, s);
        &self.v[layer][o..o + self.dim]
    }

    /// Resident bytes of this cache's K and V tensors.
    pub fn nbytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|l| l.len() * 4).sum()
    }
}

/// Arena of single-session KV caches — the per-session state store
/// behind the continuous-batching scheduler
/// ([`crate::coordinator::serve::Scheduler`]).
///
/// Each admitted request prefill-builds its own batch-1 [`KvCache`]
/// (prefill-then-join), the pool [`adopt`](KvCachePool::adopt)s it
/// under a stable session handle, and [`SlabModel::decode_batch`]
/// reads and writes per-session positions straight out of the arena.
/// A session's cache is [`release`](KvCachePool::release)d the moment
/// it terminates (EOS / budget / eviction), so the resident KV
/// footprint tracks *live* sessions, and the fixed capacity is the
/// scheduler's hard batch cap.
pub struct KvCachePool {
    arena: SlotArena<KvCache>,
    n_layers: usize,
    max_seq: usize,
    dim: usize,
}

impl KvCachePool {
    /// Pool shaped for `model`, holding at most `max_sessions` live
    /// sessions (`≥ 1` enforced).
    pub fn for_model(model: &SlabModel, max_sessions: usize) -> KvCachePool {
        KvCachePool {
            arena: SlotArena::with_capacity(max_sessions),
            n_layers: model.cfg.n_layers,
            max_seq: model.cfg.max_seq,
            dim: model.cfg.dim,
        }
    }

    /// Adopt a freshly prefilled single-session cache (the output of
    /// [`SlabModel::prefill_session`]); returns its session handle, or
    /// `None` when the pool is at capacity — the scheduler's signal to
    /// stop admitting. Panics if the cache's shape does not match the
    /// pool's model.
    pub fn adopt(&mut self, cache: KvCache) -> Option<usize> {
        assert_eq!(cache.bsz, 1, "pool caches are single-session");
        assert_eq!(cache.k.len(), self.n_layers, "pool/cache layer count mismatch");
        assert_eq!(cache.max_seq, self.max_seq, "pool/cache max_seq mismatch");
        assert_eq!(cache.dim, self.dim, "pool/cache dim mismatch");
        self.arena.insert(cache)
    }

    /// Free a terminated session's cache; its handle may be reused by
    /// a later [`adopt`](KvCachePool::adopt). Returns whether the
    /// handle was live.
    pub fn release(&mut self, session: usize) -> bool {
        self.arena.remove(session).is_some()
    }

    /// Live sessions.
    pub fn active(&self) -> usize {
        self.arena.len()
    }

    /// Hard cap on live sessions.
    pub fn capacity(&self) -> usize {
        self.arena.capacity()
    }

    pub fn is_full(&self) -> bool {
        self.arena.is_full()
    }

    /// Resident KV bytes across live sessions.
    pub fn nbytes(&self) -> usize {
        self.arena.iter().map(|(_, c)| c.nbytes()).sum()
    }

    fn cache(&self, session: usize) -> &KvCache {
        self.arena.get(session).expect("live session handle")
    }

    fn cache_mut(&mut self, session: usize) -> &mut KvCache {
        self.arena.get_mut(session).expect("live session handle")
    }
}

/// Uniform KV addressing for the batched decode forward: the
/// contiguous per-session arena ([`KvCachePool`]) and the block-paged
/// pool ([`crate::model::PagedKvPool`]) implement the same read/write
/// surface, so [`SlabModel::decode_batch`] and
/// [`SlabModel::decode_batch_paged`] share one compute body
/// ([`SlabModel::decode_batch_in`]) verbatim. Paging can therefore
/// only change *address computation*, never operation order — the
/// whole bit-identity argument of DESIGN.md §13: same ops in the same
/// accumulation order, different offsets.
pub(crate) trait KvStore {
    /// Panic unless the store was shaped for `cfg`'s model.
    fn assert_model(&self, cfg: &ModelCfg);
    fn has_session(&self, session: usize) -> bool;
    /// Hook run once per step after validation, before any layer
    /// touches the cache: the paged store asserts the write target is
    /// resident and unshared (the scheduler's
    /// [`prepare_write`](crate::model::PagedKvPool::prepare_write)
    /// contract — decode itself never allocates); contiguous is a
    /// no-op.
    fn begin_write(&mut self, session: usize, pos: usize);
    fn write_row(&mut self, layer: usize, session: usize, pos: usize, krow: &[f32], vrow: &[f32]);
    fn k_row(&self, layer: usize, session: usize, pos: usize) -> &[f32];
    fn v_row(&self, layer: usize, session: usize, pos: usize) -> &[f32];
}

impl KvStore for KvCachePool {
    fn assert_model(&self, cfg: &ModelCfg) {
        assert_eq!(self.n_layers, cfg.n_layers, "pool built for another model");
        assert_eq!(self.dim, cfg.dim, "pool built for another model");
        assert_eq!(self.max_seq, cfg.max_seq, "pool built for another model");
    }

    fn has_session(&self, session: usize) -> bool {
        self.arena.get(session).is_some()
    }

    fn begin_write(&mut self, _session: usize, _pos: usize) {}

    fn write_row(&mut self, layer: usize, session: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        self.cache_mut(session).write(layer, 0, pos, krow, vrow);
    }

    fn k_row(&self, layer: usize, session: usize, pos: usize) -> &[f32] {
        self.cache(session).k_at(layer, 0, pos)
    }

    fn v_row(&self, layer: usize, session: usize, pos: usize) -> &[f32] {
        self.cache(session).v_at(layer, 0, pos)
    }
}

/// One session's contribution to a batched decode step
/// ([`SlabModel::decode_batch`]): feed `token` at cache position
/// `pos` for pool session `session`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeSlot {
    pub session: usize,
    pub token: i32,
    pub pos: usize,
}

/// One session's contribution to a batched **multi-token** scoring
/// pass ([`SlabModel::decode_batch_multi`]): feed `tokens[j]` at cache
/// position `pos + j` for every `j`, attending causally within the
/// run. The speculative verify pass feeds the last emitted token plus
/// the draft run through this and reads one logits row per fed token —
/// row `j` is bit-identical to what a sequential
/// [`decode_batch`](SlabModel::decode_batch) of `tokens[..=j]` would
/// have produced (DESIGN.md §14's losslessness anchor).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifySlot {
    pub session: usize,
    /// Position of `tokens[0]`; token `j` lands at `pos + j`.
    pub pos: usize,
    pub tokens: Vec<i32>,
}

/// A whole model in serving form: per-layer [`Linear`]s (packed where
/// a SLaB layer exists, dense otherwise), owning the thread pool its
/// kernels fan out on.
///
/// Construction: [`SlabModel::from_dense`] for an all-dense engine
/// (the parity reference), [`SlabModel::from_packed`] to serve the
/// compression pipeline's output without ever rebuilding `Ŵ`.
pub struct SlabModel {
    pub cfg: ModelCfg,
    tok_emb: Mat,
    layers: Vec<Block>,
    final_norm: Vec<f32>,
    lm_head: Mat,
    pool: ThreadPool,
}

impl SlabModel {
    /// All-dense engine over `params` (`threads = 0` ⇒ available
    /// parallelism, as [`ThreadPool::new`]).
    pub fn from_dense(params: &Params, threads: usize) -> SlabModel {
        SlabModel::build(params, &[], threads)
    }

    /// Engine over `params` with every linear that appears in `packed`
    /// (the `compress_model` output's `slab_layers`, keyed by param
    /// name) served out of its packed form; everything else dense.
    pub fn from_packed(
        params: &Params,
        packed: &[(String, SlabLayer)],
        threads: usize,
    ) -> SlabModel {
        SlabModel::build(params, packed, threads)
    }

    fn build(params: &Params, packed: &[(String, SlabLayer)], threads: usize) -> SlabModel {
        let cfg = params.cfg.clone();
        assert_eq!(
            cfg.dim % cfg.n_heads,
            0,
            "dim {} not divisible by heads {}",
            cfg.dim,
            cfg.n_heads
        );
        assert_eq!(cfg.head_dim() % 2, 0, "RoPE needs an even head_dim, got {}", cfg.head_dim());
        let linear = |name: &str| -> Linear {
            match packed.iter().find(|(pn, _)| pn == name) {
                Some((_, l)) => {
                    let (dout, din) = (l.dout(), l.din());
                    let i = cfg.param_index(name).unwrap_or_else(|| panic!("no param {name}"));
                    assert_eq!(
                        &cfg.param_shapes[i][..],
                        &[dout, din][..],
                        "packed layer {name} shape mismatch"
                    );
                    Linear::Packed(l.clone())
                }
                None => Linear::Dense(params.mat(name)),
            }
        };
        let vec1 = |name: &str| -> Vec<f32> {
            let i = params.index(name).unwrap_or_else(|| panic!("no param {name}"));
            params.tensors[i].clone()
        };
        let layers = (0..cfg.n_layers)
            .map(|l| Block {
                attn_norm: vec1(&format!("l{l}.attn_norm")),
                wq: linear(&format!("l{l}.wq")),
                wk: linear(&format!("l{l}.wk")),
                wv: linear(&format!("l{l}.wv")),
                wo: linear(&format!("l{l}.wo")),
                mlp_norm: vec1(&format!("l{l}.mlp_norm")),
                w_gate: linear(&format!("l{l}.w_gate")),
                w_up: linear(&format!("l{l}.w_up")),
                w_down: linear(&format!("l{l}.w_down")),
            })
            .collect();
        SlabModel {
            tok_emb: params.mat("tok_emb"),
            layers,
            final_norm: vec1("final_norm"),
            lm_head: params.mat("lm_head"),
            cfg,
            pool: ThreadPool::new(threads),
        }
    }

    /// Total weight bytes resident in this engine (packed linears at
    /// their deployed size) — the byte-ratio numerator the serving
    /// demo reports.
    pub fn weights_nbytes(&self) -> usize {
        let mut n = self.tok_emb.numel() * 4 + self.lm_head.numel() * 4;
        n += self.final_norm.len() * 4;
        for blk in &self.layers {
            n += (blk.attn_norm.len() + blk.mlp_norm.len()) * 4;
            for lin in [&blk.wq, &blk.wk, &blk.wv, &blk.wo, &blk.w_gate, &blk.w_up, &blk.w_down] {
                n += lin.nbytes();
            }
        }
        n
    }

    /// How many of this model's linears run packed.
    pub fn packed_linear_count(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|blk| {
                [&blk.wq, &blk.wk, &blk.wv, &blk.wo, &blk.w_gate, &blk.w_up, &blk.w_down]
            })
            .filter(|l| matches!(l, Linear::Packed(_)))
            .count()
    }

    fn embed(&self, tokens: &[i32]) -> Mat {
        embed_rows(&self.tok_emb, tokens)
    }

    /// Prefill `tokens` (flat `(B, T)` row-major, left-aligned,
    /// PAD-padded) → (last-position logits `(B, vocab)`, KV cache with
    /// positions `0..T` written). Mirrors the `prefill_{cfg}` artifact:
    /// causal masking plus PAD-key masking.
    pub fn prefill(&self, tokens: &[i32], bsz: usize) -> (Mat, KvCache) {
        assert!(bsz > 0 && tokens.len() % bsz == 0, "ragged prefill batch");
        let t = tokens.len() / bsz;
        assert!(
            t > 0 && t <= self.cfg.max_seq,
            "prefill length {t} vs max_seq {}",
            self.cfg.max_seq
        );
        let (dim, nh) = (self.cfg.dim, self.cfg.n_heads);
        let hd = dim / nh;
        let pool = Some(&self.pool);

        let mut h = self.embed(tokens);
        let key_ok: Vec<bool> = tokens.iter().map(|&tk| tk != PAD).collect();
        let mut cache = KvCache::new(self.cfg.n_layers, bsz, self.cfg.max_seq, dim);
        let tables: Vec<Vec<(f32, f32)>> = (0..t).map(|pos| rope_table(hd, pos)).collect();

        for (li, blk) in self.layers.iter().enumerate() {
            let x = rmsnorm(&h, &blk.attn_norm);
            let mut q = blk.wq.apply(&x, pool);
            let mut k = blk.wk.apply(&x, pool);
            let v = blk.wv.apply(&x, pool);
            for r in 0..bsz * t {
                let table = &tables[r % t];
                rope_apply(q.row_mut(r), nh, hd, table);
                rope_apply(k.row_mut(r), nh, hd, table);
            }
            for b in 0..bsz {
                for s in 0..t {
                    cache.write(li, b, s, k.row(b * t + s), v.row(b * t + s));
                }
            }
            let att = causal_attention(&q, &k, &v, bsz, t, nh, hd, Some(key_ok.as_slice()));
            let proj = blk.wo.apply(&att, pool);
            h.add_assign(&proj);
            self.mlp_inplace(blk, &mut h, pool);
        }

        let xf = rmsnorm(&h, &self.final_norm);
        let mut last = Mat::zeros(bsz, dim);
        for b in 0..bsz {
            last.row_mut(b).copy_from_slice(xf.row(b * t + t - 1));
        }
        (matmul_bt(&last, &self.lm_head), cache)
    }

    /// Prefill one session's prompt exactly as the serving router
    /// does: left-aligned, PAD-padded to `prompt_len`, token ids
    /// clamped into the vocab (one malformed request must not panic
    /// the scheduler). Returns the last-position logits `(1, vocab)`
    /// and a single-session KV cache ready for
    /// [`KvCachePool::adopt`] — the "prefill" half of
    /// prefill-then-join admission.
    pub fn prefill_session(&self, prompt: &[i32]) -> (Mat, KvCache) {
        self.prefill(&self.pad_prompt(prompt), 1)
    }

    /// The padding [`prefill_session`](SlabModel::prefill_session)
    /// applies, exposed on its own: left-aligned, PAD-padded to
    /// `prompt_len`, ids clamped into the vocab. The padded form is
    /// the prefix-sharing cache key (DESIGN.md §13) — two prompts
    /// share prefilled pages iff their padded forms are equal, which
    /// is exactly the condition under which their prefills are
    /// bit-identical.
    pub fn pad_prompt(&self, prompt: &[i32]) -> Vec<i32> {
        let t = self.cfg.prompt_len;
        let vmax = self.cfg.vocab.saturating_sub(1) as i32;
        let mut flat = vec![PAD; t];
        let n = prompt.len().min(t);
        for (j, &tok) in prompt[..n].iter().enumerate() {
            flat[j] = tok.clamp(0, vmax);
        }
        flat
    }

    /// One decode step for N independent sessions at *per-session*
    /// positions — the continuous-batching hot path. `steps[r]` feeds
    /// its token through row `r` of one shared forward pass: every
    /// linear (packed or dense) runs once over the `(N, dim)`
    /// activation batch via [`Linear::apply`], so the weight pass —
    /// where ~all the bytes move — is amortized across sessions
    /// instead of repeated per session.
    ///
    /// Row-wise the math is exactly [`decode_step`](SlabModel::decode_step)
    /// at batch 1 (the kernels chunk over *weight* rows and accumulate
    /// each output element in a fixed order, so batching rows is
    /// bit-identical to serial calls — the token-identity guarantee
    /// the scheduler's tests pin). Returns logits `(N, vocab)`; `N = 0`
    /// (an empty scheduler tick) is a no-op returning a 0-row matrix.
    ///
    /// Panics on a dead session handle, a duplicate session within
    /// `steps` (one cache cannot take two writes in one step), a
    /// position past `max_seq`, or a pool shaped for another model.
    pub fn decode_batch(&self, kvpool: &mut KvCachePool, steps: &[DecodeSlot]) -> Mat {
        self.decode_batch_in(kvpool, steps)
    }

    /// [`decode_batch`](SlabModel::decode_batch) over the block-paged
    /// KV pool — the same compute body through the same [`KvStore`]
    /// surface, so the logits are bit-identical to the contiguous
    /// pool's for equal cache contents (the conformance suite's
    /// invariant). Every step's write target must have been secured
    /// via [`PagedKvPool::prepare_write`](crate::model::PagedKvPool::prepare_write)
    /// first; decode never allocates or COW-splits.
    pub fn decode_batch_paged(
        &self,
        kvpool: &mut crate::model::PagedKvPool,
        steps: &[DecodeSlot],
    ) -> Mat {
        self.decode_batch_in(kvpool, steps)
    }

    fn decode_batch_in<S: KvStore>(&self, kv: &mut S, steps: &[DecodeSlot]) -> Mat {
        let slots: Vec<VerifySlot> = steps
            .iter()
            .map(|st| VerifySlot { session: st.session, pos: st.pos, tokens: vec![st.token] })
            .collect();
        self.decode_multi_in(kv, &slots, LinPath::Full)
    }

    /// Batched **multi-token** scoring over the contiguous pool — the
    /// speculative *verify* pass (DESIGN.md §14). Each [`VerifySlot`]
    /// feeds its run of tokens at consecutive positions; the cache rows
    /// for every fed position are (over)written with full-model K/V,
    /// and logits row `j` of a slot attends over `s ≤ pos + j`. Because
    /// every kernel chunks over *weight* rows with a fixed accumulation
    /// order, row `j` is bit-identical to a sequential
    /// [`decode_batch`](SlabModel::decode_batch) of the same prefix —
    /// the losslessness anchor the speculation tests pin. Returns
    /// logits `(Σ tokens.len(), vocab)` in slot order.
    pub fn decode_batch_multi(&self, kvpool: &mut KvCachePool, slots: &[VerifySlot]) -> Mat {
        self.decode_multi_in(kvpool, slots, LinPath::Full)
    }

    /// [`decode_batch_multi`](SlabModel::decode_batch_multi) over the
    /// block-paged pool. Every fed position must have been secured via
    /// [`PagedKvPool::prepare_write`](crate::model::PagedKvPool::prepare_write)
    /// first; scoring never allocates or COW-splits.
    pub fn decode_batch_multi_paged(
        &self,
        kvpool: &mut crate::model::PagedKvPool,
        slots: &[VerifySlot],
    ) -> Mat {
        self.decode_multi_in(kvpool, slots, LinPath::Full)
    }

    fn decode_multi_in<S: KvStore>(&self, kv: &mut S, slots: &[VerifySlot], path: LinPath) -> Mat {
        if slots.is_empty() {
            return Mat::zeros(0, self.cfg.vocab);
        }
        kv.assert_model(&self.cfg);
        for (i, sl) in slots.iter().enumerate() {
            assert!(!sl.tokens.is_empty(), "empty token run for session {}", sl.session);
            assert!(
                sl.pos + sl.tokens.len() <= self.cfg.max_seq,
                "pos {}+{} vs max_seq {}",
                sl.pos,
                sl.tokens.len(),
                self.cfg.max_seq
            );
            assert!(kv.has_session(sl.session), "dead session {}", sl.session);
            for other in &slots[i + 1..] {
                assert_ne!(sl.session, other.session, "duplicate session in batch");
            }
        }
        for sl in slots {
            for j in 0..sl.tokens.len() {
                kv.begin_write(sl.session, sl.pos + j);
            }
        }
        let (dim, nh) = (self.cfg.dim, self.cfg.n_heads);
        let hd = dim / nh;
        let scale = 1.0 / (hd as f32).sqrt();
        let pool = Some(&self.pool);

        // Flatten slot-runs into rows; `rows[r]` = (session, position).
        let mut toks: Vec<i32> = Vec::new();
        let mut rows: Vec<(usize, usize)> = Vec::new();
        for sl in slots {
            for (j, &t) in sl.tokens.iter().enumerate() {
                toks.push(t);
                rows.push((sl.session, sl.pos + j));
            }
        }
        let n = rows.len();
        let mut h = self.embed(&toks);
        let tables: Vec<Vec<(f32, f32)>> =
            rows.iter().map(|&(_, pos)| rope_table(hd, pos)).collect();
        let mut scores: Vec<f32> = Vec::with_capacity(self.cfg.max_seq);
        for (li, blk) in self.layers.iter().enumerate() {
            let x = rmsnorm(&h, &blk.attn_norm);
            let mut q = path.apply(&blk.wq, &x, pool);
            let mut k = path.apply(&blk.wk, &x, pool);
            let v = path.apply(&blk.wv, &x, pool);
            for r in 0..n {
                rope_apply(q.row_mut(r), nh, hd, &tables[r]);
                rope_apply(k.row_mut(r), nh, hd, &tables[r]);
            }
            // Write *every* fed row before any attention read: row j of
            // a run attends over its own and earlier fed positions.
            for (r, &(session, pos)) in rows.iter().enumerate() {
                kv.write_row(li, session, pos, k.row(r), v.row(r));
            }
            let mut att = Mat::zeros(n, dim);
            for (r, &(session, pos)) in rows.iter().enumerate() {
                scores.clear();
                scores.resize(pos + 1, 0.0);
                let qrow = q.row(r);
                let arow = att.row_mut(r);
                for hh in 0..nh {
                    let qh = &qrow[hh * hd..(hh + 1) * hd];
                    for (s, sc) in scores.iter_mut().enumerate() {
                        let kh = &kv.k_row(li, session, s)[hh * hd..(hh + 1) * hd];
                        let mut d = 0.0f32;
                        for e in 0..hd {
                            d += qh[e] * kh[e];
                        }
                        *sc = d * scale;
                    }
                    softmax_inplace(&mut scores);
                    for (s, &p) in scores.iter().enumerate() {
                        if p != 0.0 {
                            let vh = &kv.v_row(li, session, s)[hh * hd..(hh + 1) * hd];
                            for e in 0..hd {
                                arow[hh * hd + e] += p * vh[e];
                            }
                        }
                    }
                }
            }
            let proj = path.apply(&blk.wo, &att, pool);
            h.add_assign(&proj);
            self.mlp_inplace_in(blk, &mut h, pool, path);
        }
        let xf = rmsnorm(&h, &self.final_norm);
        matmul_bt(&xf, &self.lm_head)
    }

    /// [`decode_batch`](SlabModel::decode_batch) followed by the
    /// serving argmax — the continuous batcher's per-tick *emit hook*:
    /// returns `steps.len()` next tokens (`out[r]` ↔ `steps[r]`),
    /// computed from the same shared weight pass, so callers that only
    /// stream tokens (the session router, the HTTP front-end) never
    /// touch the `(N, vocab)` logits buffer. Row `r` is exactly
    /// `greedy_token(decode_batch(..).row(r))` — the token-identity
    /// guarantee the streaming tests pin.
    pub fn decode_batch_greedy(&self, kvpool: &mut KvCachePool, steps: &[DecodeSlot]) -> Vec<i32> {
        let logits = self.decode_batch(kvpool, steps);
        (0..logits.rows).map(|r| greedy_token(logits.row(r))).collect()
    }

    /// [`decode_batch_greedy`](SlabModel::decode_batch_greedy) over
    /// the block-paged pool — same emit hook, same argmax policy,
    /// token-identical to the contiguous form for equal cache
    /// contents.
    pub fn decode_batch_greedy_paged(
        &self,
        kvpool: &mut crate::model::PagedKvPool,
        steps: &[DecodeSlot],
    ) -> Vec<i32> {
        let logits = self.decode_batch_paged(kvpool, steps);
        (0..logits.rows).map(|r| greedy_token(logits.row(r))).collect()
    }

    /// The self-speculative **draft view** over this model: same
    /// weights, same KV machinery, but every packed linear forwards
    /// only its sparse + low-rank components ([`Linear::apply_draft`]),
    /// optionally truncated to the top `rank_cap` Hadamard rank-1
    /// terms (`None` = full rank). Dense linears are unchanged, so on
    /// an all-dense model the draft *is* the full model and every
    /// speculated token is accepted. See DESIGN.md §14.
    pub fn draft(&self, rank_cap: Option<usize>) -> DraftModel<'_> {
        DraftModel { model: self, rank_cap: rank_cap.unwrap_or(usize::MAX) }
    }

    /// One decode step for the whole batch at shared position `pos`
    /// (the dynamic batcher aligns sequences): writes `pos` into the
    /// cache and attends over `s ≤ pos` — the `decode_step_{cfg}`
    /// artifact's semantics. Returns logits `(B, vocab)`.
    pub fn decode_step(&self, cache: &mut KvCache, tokens: &[i32], pos: usize) -> Mat {
        let bsz = tokens.len();
        assert_eq!(bsz, cache.bsz, "decode batch vs cache batch");
        assert!(pos < self.cfg.max_seq, "pos {pos} vs max_seq {}", self.cfg.max_seq);
        let (dim, nh) = (self.cfg.dim, self.cfg.n_heads);
        let hd = dim / nh;
        let scale = 1.0 / (hd as f32).sqrt();
        let pool = Some(&self.pool);

        let mut h = self.embed(tokens);
        let table = rope_table(hd, pos);
        for (li, blk) in self.layers.iter().enumerate() {
            let x = rmsnorm(&h, &blk.attn_norm);
            let mut q = blk.wq.apply(&x, pool);
            let mut k = blk.wk.apply(&x, pool);
            let v = blk.wv.apply(&x, pool);
            for b in 0..bsz {
                rope_apply(q.row_mut(b), nh, hd, &table);
                rope_apply(k.row_mut(b), nh, hd, &table);
            }
            for b in 0..bsz {
                cache.write(li, b, pos, k.row(b), v.row(b));
            }
            let mut att = Mat::zeros(bsz, dim);
            let mut scores = vec![0.0f32; pos + 1];
            for b in 0..bsz {
                let qrow = q.row(b);
                for hh in 0..nh {
                    let qh = &qrow[hh * hd..(hh + 1) * hd];
                    for (s, sc) in scores.iter_mut().enumerate() {
                        let kh = &cache.k_at(li, b, s)[hh * hd..(hh + 1) * hd];
                        let mut d = 0.0f32;
                        for e in 0..hd {
                            d += qh[e] * kh[e];
                        }
                        *sc = d * scale;
                    }
                    softmax_inplace(&mut scores);
                    let arow = att.row_mut(b);
                    for (s, &p) in scores.iter().enumerate() {
                        if p != 0.0 {
                            let vh = &cache.v_at(li, b, s)[hh * hd..(hh + 1) * hd];
                            for e in 0..hd {
                                arow[hh * hd + e] += p * vh[e];
                            }
                        }
                    }
                }
            }
            let proj = blk.wo.apply(&att, pool);
            h.add_assign(&proj);
            self.mlp_inplace(blk, &mut h, pool);
        }
        let xf = rmsnorm(&h, &self.final_norm);
        matmul_bt(&xf, &self.lm_head)
    }

    /// Full-sequence causal logits for *scoring*: `tokens` is a flat
    /// `(B, T)` row-major batch; returns `(B·T, vocab)` logits at every
    /// position. Mirrors `model.py::forward` — the forward inside the
    /// `eval_nll_{cfg}` artifact — operation for operation: **pure
    /// causal** masking (no PAD-key masking; PAD only ever masks
    /// *targets* in NLL), no KV cache, RoPE at positions `0..T`.
    ///
    /// `pool` selects the kernels' fan-out explicitly (`None` =
    /// serial) instead of the model's own pool: the native eval
    /// harness calls this from inside `ThreadPool::scoped_map`
    /// workers, where nesting a fork-join on one pool could deadlock
    /// (see [`ThreadPool::scoped`]). Row-wise the result is
    /// bit-identical for any pool and any batch grouping — every
    /// kernel chunks over *weight* rows and accumulates each output
    /// element in a fixed order, and attention is per-sequence — the
    /// invariance `eval::native`'s property tests pin.
    pub fn forward_full(&self, tokens: &[i32], bsz: usize, pool: Option<&ThreadPool>) -> Mat {
        assert!(bsz > 0 && tokens.len() % bsz == 0, "ragged eval batch");
        let t = tokens.len() / bsz;
        assert!(
            t > 0 && t <= self.cfg.max_seq,
            "eval length {t} vs max_seq {}",
            self.cfg.max_seq
        );
        let (dim, nh) = (self.cfg.dim, self.cfg.n_heads);
        let hd = dim / nh;

        let mut h = self.embed(tokens);
        let tables: Vec<Vec<(f32, f32)>> = (0..t).map(|pos| rope_table(hd, pos)).collect();
        for blk in &self.layers {
            let x = rmsnorm(&h, &blk.attn_norm);
            let mut q = blk.wq.apply(&x, pool);
            let mut k = blk.wk.apply(&x, pool);
            let v = blk.wv.apply(&x, pool);
            for r in 0..bsz * t {
                rope_apply(q.row_mut(r), nh, hd, &tables[r % t]);
                rope_apply(k.row_mut(r), nh, hd, &tables[r % t]);
            }
            let att = causal_attention(&q, &k, &v, bsz, t, nh, hd, None);
            let proj = blk.wo.apply(&att, pool);
            h.add_assign(&proj);
            self.mlp_inplace(blk, &mut h, pool);
        }
        let xf = rmsnorm(&h, &self.final_norm);
        match pool {
            Some(p) => matmul_bt_par(&xf, &self.lm_head, p),
            None => matmul_bt(&xf, &self.lm_head),
        }
    }

    /// Pre-norm SwiGLU MLP, residual-added into `h`.
    fn mlp_inplace(&self, blk: &Block, h: &mut Mat, pool: Option<&ThreadPool>) {
        self.mlp_inplace_in(blk, h, pool, LinPath::Full);
    }

    /// [`mlp_inplace`](SlabModel::mlp_inplace) with the linear path
    /// (full packed vs sparse+low-rank draft) chosen by `path`.
    fn mlp_inplace_in(&self, blk: &Block, h: &mut Mat, pool: Option<&ThreadPool>, path: LinPath) {
        let x = rmsnorm(h, &blk.mlp_norm);
        let gate = path.apply(&blk.w_gate, &x, pool);
        let up = path.apply(&blk.w_up, &x, pool);
        let ffn = gate.cols;
        let mut inner = Mat::zeros(h.rows, ffn);
        for r in 0..h.rows {
            let g = gate.row(r);
            let u = up.row(r);
            let irow = inner.row_mut(r);
            for j in 0..ffn {
                irow[j] = silu(g[j]) * u[j];
            }
        }
        let down = path.apply(&blk.w_down, &inner, pool);
        h.add_assign(&down);
    }

    /// Greedy batched generation — the native analogue of the serving
    /// router's decode loop (same padding to `prompt_len`, same argmax
    /// policy, EOS stops a sequence). Returns generated tokens per
    /// prompt, EOS excluded.
    pub fn generate_batch(&self, prompts: &[Vec<i32>], max_new: usize) -> Vec<Vec<i32>> {
        let bsz = prompts.len();
        assert!(bsz > 0, "empty batch");
        let t = self.cfg.prompt_len;
        let mut flat = vec![PAD; bsz * t];
        for (s, p) in prompts.iter().enumerate() {
            let n = p.len().min(t);
            flat[s * t..s * t + n].copy_from_slice(&p[..n]);
        }
        let (mut logits, mut cache) = self.prefill(&flat, bsz);
        let max_new = max_new.min(self.cfg.max_seq.saturating_sub(t));
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); bsz];
        let mut done = vec![false; bsz];
        for step in 0..max_new {
            let mut next = vec![EOS; bsz];
            for s in 0..bsz {
                if done[s] {
                    continue;
                }
                let tok = greedy_token(logits.row(s));
                next[s] = tok;
                if tok == EOS {
                    done[s] = true;
                } else {
                    generated[s].push(tok);
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
            logits = self.decode_step(&mut cache, &next, t + step);
        }
        generated
    }
}

/// Cheap-forward view over a [`SlabModel`] for self-speculative
/// decoding ([`SlabModel::draft`]): runs the *same* decode body over
/// the *same* KV cache, but every packed linear skips its binary
/// bit-planes and forwards only `W_S + Σ u_k v_kᵀ` — no popcount, no
/// bit-plane traffic, `O(nnz + r·(din+dout))` per token instead of the
/// dense-equivalent bit-matrix pass.
///
/// The draft writes its (approximate) K/V rows into the session's real
/// cache; the verify pass re-feeds the same positions through the full
/// model and **overwrites every row it fed** before any of them is
/// read again, so draft-quality cache rows are never observed by an
/// emitted token — the reason losslessness needs no separate draft
/// cache (DESIGN.md §14).
#[derive(Debug, Clone, Copy)]
pub struct DraftModel<'a> {
    model: &'a SlabModel,
    rank_cap: usize,
}

impl DraftModel<'_> {
    /// Per-tick greedy draft step over the contiguous pool — the
    /// cheap-path analogue of
    /// [`decode_batch_greedy`](SlabModel::decode_batch_greedy), same
    /// argmax policy. Any deterministic output preserves losslessness;
    /// only its *agreement* with the full model buys speedup.
    pub fn decode_batch_greedy(&self, kvpool: &mut KvCachePool, steps: &[DecodeSlot]) -> Vec<i32> {
        let slots: Vec<VerifySlot> = steps
            .iter()
            .map(|st| VerifySlot { session: st.session, pos: st.pos, tokens: vec![st.token] })
            .collect();
        let logits =
            self.model.decode_multi_in(kvpool, &slots, LinPath::Draft { rank_cap: self.rank_cap });
        (0..logits.rows).map(|r| greedy_token(logits.row(r))).collect()
    }

    /// [`decode_batch_greedy`](DraftModel::decode_batch_greedy) over
    /// the block-paged pool; every step's write target must already be
    /// secured via `prepare_write`, exactly as for the full model.
    pub fn decode_batch_greedy_paged(
        &self,
        kvpool: &mut crate::model::PagedKvPool,
        steps: &[DecodeSlot],
    ) -> Vec<i32> {
        let slots: Vec<VerifySlot> = steps
            .iter()
            .map(|st| VerifySlot { session: st.session, pos: st.pos, tokens: vec![st.token] })
            .collect();
        let logits =
            self.model.decode_multi_in(kvpool, &slots, LinPath::Draft { rank_cap: self.rank_cap });
        (0..logits.rows).map(|r| greedy_token(logits.row(r))).collect()
    }
}

/// Token-embedding gather: `h[r] = tok_emb[tokens[r]]` — shared by
/// the serving forwards and the calibration-capture path. Panics on
/// out-of-vocab ids (serving clamps before calling; calibration
/// streams are in-vocab by construction).
pub fn embed_rows(tok_emb: &Mat, tokens: &[i32]) -> Mat {
    let mut h = Mat::zeros(tokens.len(), tok_emb.cols);
    for (r, &tok) in tokens.iter().enumerate() {
        assert!(
            tok >= 0 && (tok as usize) < tok_emb.rows,
            "token {tok} out of vocab {}",
            tok_emb.rows
        );
        h.row_mut(r).copy_from_slice(tok_emb.row(tok as usize));
    }
    h
}

/// Causal self-attention over a full `(B, T)` batch: `q`, `k`, `v`
/// are `(B·T, dim)` row-major (RoPE already applied), `key_ok`
/// optionally masks PAD keys (the serving prefill); `None` means every
/// key is visible under causality — the calibration-capture case,
/// where packed rows carry no padding. Returns the pre-`wo` context
/// `(B·T, dim)`. Same additive-mask semantics as model.py
/// `_attention`; an all-masked PAD-query row degrades to uniform
/// attention there and here.
fn causal_attention(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    bsz: usize,
    t: usize,
    nh: usize,
    hd: usize,
    key_ok: Option<&[bool]>,
) -> Mat {
    let scale = 1.0 / (hd as f32).sqrt();
    let mut att = Mat::zeros(bsz * t, nh * hd);
    let mut scores = vec![0.0f32; t];
    for b in 0..bsz {
        for tq in 0..t {
            let qrow = q.row(b * t + tq);
            for hh in 0..nh {
                let qh = &qrow[hh * hd..(hh + 1) * hd];
                for (s, sc) in scores.iter_mut().enumerate() {
                    let masked = s > tq || key_ok.is_some_and(|ok| !ok[b * t + s]);
                    *sc = if masked {
                        // Same additive-mask value as model.py.
                        -1e30
                    } else {
                        let kh = &k.row(b * t + s)[hh * hd..(hh + 1) * hd];
                        let mut d = 0.0f32;
                        for e in 0..hd {
                            d += qh[e] * kh[e];
                        }
                        d * scale
                    };
                }
                softmax_inplace(&mut scores);
                let arow = att.row_mut(b * t + tq);
                for (s, &p) in scores.iter().enumerate() {
                    if p != 0.0 {
                        let vh = &v.row(b * t + s)[hh * hd..(hh + 1) * hd];
                        for e in 0..hd {
                            arow[hh * hd + e] += p * vh[e];
                        }
                    }
                }
            }
        }
    }
    att
}

/// The four activation sources of one block plus the updated residual
/// stream — the native twin of the `block_capture_{cfg}` artifact's
/// outputs (aot.py `block_capture_flat`). All matrices are `(B·T, ·)`
/// row-major; sources map to the pruned linears as: `x_attn` →
/// wq/wk/wv, `att_out` → wo, `x_mlp` → w_gate/w_up, `mlp_inner` →
/// w_down.
pub struct BlockActs {
    pub h_out: Mat,
    pub x_attn: Mat,
    pub att_out: Mat,
    pub x_mlp: Mat,
    pub mlp_inner: Mat,
}

/// One transformer block's dense weights, borrowed, in
/// calibration-capture form — the compression pipeline's capture
/// stage builds one per block from the *current* (already partially
/// pruned) weights and forwards every calibration batch through it
/// without touching an XLA artifact (DESIGN.md §10).
pub struct CaptureBlock<'a> {
    pub attn_norm: &'a [f32],
    pub wq: &'a Mat,
    pub wk: &'a Mat,
    pub wv: &'a Mat,
    pub wo: &'a Mat,
    pub mlp_norm: &'a [f32],
    pub w_gate: &'a Mat,
    pub w_up: &'a Mat,
    pub w_down: &'a Mat,
    pub n_heads: usize,
}

impl CaptureBlock<'_> {
    /// Forward a `(B·T, dim)` residual batch through the block,
    /// capturing the four activation sources. Mirrors aot.py
    /// `block_capture_flat` operation for operation: RoPE at positions
    /// `0..T`, **pure causal** masking (calibration rows are packed,
    /// never padded), pre-norm attention and SwiGLU residuals. Built
    /// on the same RoPE/MHA/SwiGLU machinery as the serving forwards —
    /// the dense matmuls run [`matmul_bt_par`] on `pool`, and every
    /// kernel is row-wise bit-identical to its serial form, so the
    /// capture is deterministic for any thread count.
    pub fn capture_forward(&self, h: &Mat, bsz: usize, pool: Option<&ThreadPool>) -> BlockActs {
        assert!(bsz > 0 && h.rows % bsz == 0, "ragged capture batch");
        let t = h.rows / bsz;
        let dim = self.wq.cols;
        assert_eq!(h.cols, dim, "capture h width {} vs dim {dim}", h.cols);
        let nh = self.n_heads;
        let hd = dim / nh;
        let mm = |x: &Mat, w: &Mat| match pool {
            Some(p) => matmul_bt_par(x, w, p),
            None => matmul_bt(x, w),
        };

        let x_attn = rmsnorm(h, self.attn_norm);
        let mut q = mm(&x_attn, self.wq);
        let mut k = mm(&x_attn, self.wk);
        let v = mm(&x_attn, self.wv);
        let tables: Vec<Vec<(f32, f32)>> = (0..t).map(|pos| rope_table(hd, pos)).collect();
        for r in 0..bsz * t {
            rope_apply(q.row_mut(r), nh, hd, &tables[r % t]);
            rope_apply(k.row_mut(r), nh, hd, &tables[r % t]);
        }
        let att_out = causal_attention(&q, &k, &v, bsz, t, nh, hd, None);
        let mut h_out = h.clone();
        h_out.add_assign(&mm(&att_out, self.wo));

        let x_mlp = rmsnorm(&h_out, self.mlp_norm);
        let gate = mm(&x_mlp, self.w_gate);
        let up = mm(&x_mlp, self.w_up);
        let ffn = gate.cols;
        let mut mlp_inner = Mat::zeros(h.rows, ffn);
        for r in 0..h.rows {
            let g = gate.row(r);
            let u = up.row(r);
            let irow = mlp_inner.row_mut(r);
            for j in 0..ffn {
                irow[j] = silu(g[j]) * u[j];
            }
        }
        h_out.add_assign(&mm(&mlp_inner, self.w_down));
        BlockActs {
            h_out,
            x_attn,
            att_out,
            x_mlp,
            mlp_inner,
        }
    }
}

/// The serving argmax: first maximum wins, initialized past the
/// special tokens so an all-(−inf)/NaN row can never emit PAD/BOS/EOS
/// by tie-break — exactly the artifact router's policy.
pub fn greedy_token(row: &[f32]) -> i32 {
    let mut best = 4usize;
    let mut best_v = f32::NEG_INFINITY;
    for (tid, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = tid;
        }
    }
    best as i32
}

/// RMSNorm per row: `x · γ / sqrt(mean(x²) + ε)` (model.py `_rmsnorm`).
fn rmsnorm(x: &Mat, gamma: &[f32]) -> Mat {
    assert_eq!(x.cols, gamma.len(), "rmsnorm width");
    let mut y = Mat::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms: f32 = row.iter().map(|&v| v * v).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (ms + NORM_EPS).sqrt();
        let yrow = y.row_mut(r);
        for j in 0..x.cols {
            yrow[j] = row[j] * gamma[j] * inv;
        }
    }
    y
}

/// Per-position rotation table: `(sin, cos)` of `pos · θ^(−f/(Hd/2))`
/// for each frequency (model.py `_rope_angles`). Built once per
/// position and shared across heads, rows, and q/k, so the decode hot
/// path pays `Hd/2` transcendentals per step instead of
/// `n_heads · rows` times that.
fn rope_table(head_dim: usize, pos: usize) -> Vec<(f32, f32)> {
    let half = head_dim / 2;
    (0..half)
        .map(|f| {
            let inv_freq = ROPE_THETA.powf(-(f as f32) / half as f32);
            (pos as f32 * inv_freq).sin_cos()
        })
        .collect()
}

/// Rotary embedding on one token's `(H, Hd)` q or k row, split-half
/// convention (model.py `_apply_rope`): lanes `f` and `f + Hd/2`
/// rotate together by the table's angle for `f`.
fn rope_apply(row: &mut [f32], n_heads: usize, head_dim: usize, table: &[(f32, f32)]) {
    let half = head_dim / 2;
    debug_assert_eq!(table.len(), half);
    for h in 0..n_heads {
        let o = h * head_dim;
        for (f, &(sin, cos)) in table.iter().enumerate() {
            let x1 = row[o + f];
            let x2 = row[o + half + f];
            row[o + f] = x1 * cos - x2 * sin;
            row[o + half + f] = x1 * sin + x2 * cos;
        }
    }
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::{decompose, ActStats, SlabConfig};
    use crate::util::rng::Pcg64;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg::llama("tiny-native", 32, 8, 2, 2, 16, 16, 6)
    }

    /// Decompose every pruned linear of `params` natively (no runtime
    /// needed) → (packed layers, params with `Ŵ` swapped in).
    fn compress_native(params: &Params, seed: u64) -> (Vec<(String, SlabLayer)>, Params) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let scfg = SlabConfig {
            iters: 3,
            svd_iters: 6,
            ..Default::default()
        };
        let mut packed = Vec::new();
        let mut swapped = params.clone();
        for (name, (_, din)) in params.cfg.pruned.clone() {
            let w = params.mat(&name);
            let stats = ActStats::from_activations(&Mat::randn(48, din, 1.0, &mut rng));
            let d = decompose(&w, &stats, &scfg).expect("decompose");
            let layer = SlabLayer::from_decomposition(&d);
            swapped.set_mat(&name, &layer.reconstruct());
            packed.push((name, layer));
        }
        (packed, swapped)
    }

    #[test]
    fn decode_continuation_matches_full_prefill() {
        // KV-cache correctness: decoding token t over the cache of a
        // t-token prefill must reproduce the last-position logits of a
        // (t+1)-token prefill.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 201);
        let model = SlabModel::from_dense(&params, 2);
        let prompt: Vec<i32> = vec![5, 9, 17, 4];
        let (logits, mut cache) = model.prefill(&prompt, 1);
        let next = greedy_token(logits.row(0));
        let step_logits = model.decode_step(&mut cache, &[next], prompt.len());
        let mut extended = prompt.clone();
        extended.push(next);
        let (full_logits, _) = model.prefill(&extended, 1);
        assert!(
            step_logits.allclose(&full_logits, 1e-4, 1e-4),
            "decode-vs-prefill logits diverged"
        );
    }

    #[test]
    fn packed_and_dense_engines_generate_identical_tokens() {
        // The acceptance-criterion e2e: the packed engine consumes the
        // compressed format directly; the dense engine serves the
        // reconstructed Ŵ of the *same* decomposition. Same math ⇒
        // token-identical greedy outputs (logits agree to kernel
        // rounding, far below argmax gaps at these scales).
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 202);
        let (packed, swapped) = compress_native(&params, 203);
        assert_eq!(packed.len(), cfg.pruned.len());
        let packed_model = SlabModel::from_packed(&params, &packed, 3);
        let dense_model = SlabModel::from_dense(&swapped, 1);
        assert_eq!(packed_model.packed_linear_count(), 14);
        assert_eq!(dense_model.packed_linear_count(), 0);
        // (No byte-savings assert at these 8-dim toy shapes: CSR
        // metadata overhead only amortizes at real widths — the
        // integration e2e checks the byte claim at 16+ dims.)

        let prompts: Vec<Vec<i32>> = vec![vec![5, 6, 7], vec![9, 10, 11, 12, 13, 14], vec![21]];
        // Logits parity at prefill.
        let t = cfg.prompt_len;
        let mut flat = vec![PAD; prompts.len() * t];
        for (s, p) in prompts.iter().enumerate() {
            let n = p.len().min(t);
            flat[s * t..s * t + n].copy_from_slice(&p[..n]);
        }
        let (lp, _) = packed_model.prefill(&flat, prompts.len());
        let (ld, _) = dense_model.prefill(&flat, prompts.len());
        assert!(lp.allclose(&ld, 1e-3, 1e-3), "prefill logits diverged");

        // Token-identical greedy generation.
        let gp = packed_model.generate_batch(&prompts, 8);
        let gd = dense_model.generate_batch(&prompts, 8);
        assert_eq!(gp, gd, "packed vs dense-reconstruction tokens");
    }

    #[test]
    fn decode_batch_is_bit_identical_to_serial_decode() {
        // The continuous-batching invariant: N sessions sharing one
        // batched forward must produce exactly the rows each would
        // have produced decoding alone — including mid-stream joins at
        // different positions.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 205);
        let model = SlabModel::from_dense(&params, 2);
        let t = cfg.prompt_len;
        let pa: Vec<i32> = vec![5, 6, 7];
        let pb: Vec<i32> = vec![9, 10, 11, 12];

        // Serial reference: each session decodes alone via decode_step.
        let (la, mut ca) = model.prefill_session(&pa);
        let (lb, mut cb) = model.prefill_session(&pb);
        let ta0 = greedy_token(la.row(0));
        let tb0 = greedy_token(lb.row(0));
        let la1 = model.decode_step(&mut ca, &[ta0], t);
        let ta1 = greedy_token(la1.row(0));
        let la2 = model.decode_step(&mut ca, &[ta1], t + 1);
        let lb1 = model.decode_step(&mut cb, &[tb0], t);

        // Batched: A decodes one step alone, then B joins one position
        // behind — the prefill-then-join shape.
        let mut kv = KvCachePool::for_model(&model, 4);
        let (la_p, ca_p) = model.prefill_session(&pa);
        let (lb_p, cb_p) = model.prefill_session(&pb);
        assert_eq!(la_p.data, la.data, "prefill must be deterministic");
        assert_eq!(lb_p.data, lb.data, "prefill must be deterministic");
        let sa = kv.adopt(ca_p).unwrap();
        let sb = kv.adopt(cb_p).unwrap();
        assert_eq!(kv.active(), 2);
        assert!(kv.nbytes() > 0);
        let l1 = model.decode_batch(
            &mut kv,
            &[DecodeSlot { session: sa, token: ta0, pos: t }],
        );
        assert_eq!(l1.row(0), la1.row(0), "batch-of-1 row");
        let l2 = model.decode_batch(
            &mut kv,
            &[
                DecodeSlot { session: sa, token: ta1, pos: t + 1 },
                DecodeSlot { session: sb, token: tb0, pos: t },
            ],
        );
        assert_eq!(l2.row(0), la2.row(0), "mid-stream session row");
        assert_eq!(l2.row(1), lb1.row(0), "joining session row");

        assert!(kv.release(sa));
        assert!(!kv.release(sa), "double release");
        assert_eq!(kv.active(), 1);
    }

    #[test]
    fn decode_batch_greedy_matches_argmax_rows() {
        // The emit hook must be exactly decode_batch + per-row argmax:
        // run both over identical pool states and compare.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 216);
        let model = SlabModel::from_dense(&params, 1);
        let t = cfg.prompt_len;
        let mk_steps = |kv: &mut KvCachePool| -> Vec<DecodeSlot> {
            [vec![5, 6, 7], vec![9, 10]]
                .iter()
                .map(|p| {
                    let (logits, cache) = model.prefill_session(p);
                    DecodeSlot {
                        session: kv.adopt(cache).unwrap(),
                        token: greedy_token(logits.row(0)),
                        pos: t,
                    }
                })
                .collect()
        };
        let mut kv_a = KvCachePool::for_model(&model, 2);
        let steps_a = mk_steps(&mut kv_a);
        let logits = model.decode_batch(&mut kv_a, &steps_a);
        let expect: Vec<i32> = (0..logits.rows).map(|r| greedy_token(logits.row(r))).collect();
        let mut kv_b = KvCachePool::for_model(&model, 2);
        let steps_b = mk_steps(&mut kv_b);
        let got = model.decode_batch_greedy(&mut kv_b, &steps_b);
        assert_eq!(got, expect);
        assert!(model.decode_batch_greedy(&mut kv_b, &[]).is_empty(), "empty tick");
    }

    #[test]
    fn multi_token_verify_is_bit_identical_to_sequential_decode() {
        // The speculative verify pass scores a run of fed tokens in one
        // forward; logits row j of a slot must be *bit-identical* to
        // what a sequential decode_batch of the same prefix produces —
        // the losslessness anchor of DESIGN.md §14 — on both engines
        // and with slots of different run lengths sharing one batch.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 230);
        let (packed, _) = compress_native(&params, 231);
        for model in
            [SlabModel::from_dense(&params, 2), SlabModel::from_packed(&params, &packed, 2)]
        {
            let t = cfg.prompt_len;
            let runs: [(Vec<i32>, Vec<i32>); 2] =
                [(vec![5, 9, 17, 4], vec![7, 12, 3, 19]), (vec![21, 11], vec![8, 14])];
            // Sequential reference, each session decoding alone.
            let mut want: Vec<Vec<f32>> = Vec::new();
            for (prompt, fed) in &runs {
                let mut kv = KvCachePool::for_model(&model, 1);
                let s = kv.adopt(model.prefill_session(prompt).1).unwrap();
                for (j, &tok) in fed.iter().enumerate() {
                    let l = model
                        .decode_batch(&mut kv, &[DecodeSlot { session: s, token: tok, pos: t + j }]);
                    want.push(l.row(0).to_vec());
                }
            }
            // One batched multi-token pass over both sessions.
            let mut kv = KvCachePool::for_model(&model, 2);
            let slots: Vec<VerifySlot> = runs
                .iter()
                .map(|(prompt, fed)| VerifySlot {
                    session: kv.adopt(model.prefill_session(prompt).1).unwrap(),
                    pos: t,
                    tokens: fed.clone(),
                })
                .collect();
            let got = model.decode_batch_multi(&mut kv, &slots);
            assert_eq!(got.rows, want.len());
            for (r, wrow) in want.iter().enumerate() {
                assert_eq!(got.row(r), &wrow[..], "verify row {r}");
            }
        }
    }

    #[test]
    fn draft_overrun_rows_are_overwritten_before_any_emitted_read() {
        // Contiguous "rollback" is a no-op by construction: the draft
        // (and a rejected verify suffix) leave stale rows only at
        // positions the accepted stream hasn't reached, and decode
        // overwrites a position before attention ever reads it. Run a
        // full draft → verify → accept → continue round against a pool
        // that never speculated, then stomp NaN into every
        // past-the-stream row to prove staleness is never observed.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 232);
        let (packed, _) = compress_native(&params, 233);
        let model = SlabModel::from_packed(&params, &packed, 2);
        let t = cfg.prompt_len;
        let prompt: Vec<i32> = vec![6, 19, 3];
        let k = 3;

        // Plain-greedy reference stream + per-step logits.
        let (rl, rc) = model.prefill_session(&prompt);
        let t0 = greedy_token(rl.row(0));
        let mut kv_r = KvCachePool::for_model(&model, 1);
        let sr = kv_r.adopt(rc).unwrap();
        let total = k + 4;
        let mut ref_toks = vec![t0];
        let mut ref_logits: Vec<Vec<f32>> = Vec::new();
        for i in 0..total {
            let l = model
                .decode_batch(&mut kv_r, &[DecodeSlot { session: sr, token: ref_toks[i], pos: t + i }]);
            ref_toks.push(greedy_token(l.row(0)));
            ref_logits.push(l.row(0).to_vec());
        }

        // Speculative pool: draft k tokens with the adversarial
        // pure-sparse draft (rank cap 0) — writes draft-quality K/V at
        // t..t+k-1 — then verify all k+1 fed tokens in one pass.
        let mut kv = KvCachePool::for_model(&model, 1);
        let s = kv.adopt(model.prefill_session(&prompt).1).unwrap();
        let draft = model.draft(Some(0));
        let mut fed = vec![t0];
        for j in 0..k {
            let d = draft
                .decode_batch_greedy(&mut kv, &[DecodeSlot { session: s, token: fed[j], pos: t + j }]);
            fed.push(d[0]);
        }
        let vl = model
            .decode_batch_multi(&mut kv, &[VerifySlot { session: s, pos: t, tokens: fed.clone() }]);
        // Verify row j is conditioned on fed[..=j]; it matches the
        // reference exactly while the fed prefix matches the stream.
        let mut a = 0;
        while a < k && fed[a + 1] == greedy_token(vl.row(a)) {
            a += 1;
        }
        for j in 0..=a.min(k - 1) {
            assert_eq!(vl.row(j), &ref_logits[j][..], "accepted verify row {j}");
            assert_eq!(greedy_token(vl.row(j)), ref_toks[j + 1], "emitted token {j}");
        }

        // After the round the stream stands at pos t+a+1; rows past it
        // hold rejected-suffix state. Make staleness unmissable: any
        // read of those rows now poisons the logits with NaN.
        let garbage = vec![f32::NAN; cfg.dim];
        for li in 0..cfg.n_layers {
            for pos in (t + a + 1)..(t + k + 1) {
                kv.write_row(li, s, pos, &garbage, &garbage);
            }
        }
        // Continue plain decode past the divergence point: every step
        // must be bit-identical to the never-speculated reference.
        for step in 0..3 {
            let i = a + 1 + step;
            let l = model
                .decode_batch(&mut kv, &[DecodeSlot { session: s, token: ref_toks[i], pos: t + i }]);
            assert_eq!(l.row(0), &ref_logits[i][..], "post-rollback step {step}");
        }
    }

    #[test]
    fn draft_view_on_dense_model_matches_full_path() {
        // Dense linears have no sparse/low-rank split, so apply_draft
        // falls through to apply and the draft view agrees with the
        // full model token for token — the acceptance-rate-1.0 anchor
        // the HTTP e2e leans on.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 234);
        let model = SlabModel::from_dense(&params, 1);
        let t = cfg.prompt_len;
        let prompt: Vec<i32> = vec![5, 9, 4];
        let (logits, cache) = model.prefill_session(&prompt);
        let mut kv_a = KvCachePool::for_model(&model, 1);
        let sa = kv_a.adopt(cache).unwrap();
        let mut kv_b = KvCachePool::for_model(&model, 1);
        let sb = kv_b.adopt(model.prefill_session(&prompt).1).unwrap();
        let draft = model.draft(None);
        let mut tok = greedy_token(logits.row(0));
        for i in 0..5 {
            let d = draft
                .decode_batch_greedy(&mut kv_a, &[DecodeSlot { session: sa, token: tok, pos: t + i }]);
            let f = model
                .decode_batch_greedy(&mut kv_b, &[DecodeSlot { session: sb, token: tok, pos: t + i }]);
            assert_eq!(d, f, "draft vs full on dense model, step {i}");
            tok = f[0];
        }
    }

    #[test]
    fn decode_batch_empty_tick_is_noop() {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 206);
        let model = SlabModel::from_dense(&params, 1);
        let mut kv = KvCachePool::for_model(&model, 2);
        let logits = model.decode_batch(&mut kv, &[]);
        assert_eq!((logits.rows, logits.cols), (0, cfg.vocab));
        assert_eq!(kv.active(), 0);
    }

    #[test]
    fn kv_cache_pool_enforces_capacity_and_reuses_handles() {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 207);
        let model = SlabModel::from_dense(&params, 1);
        let mut kv = KvCachePool::for_model(&model, 2);
        assert_eq!(kv.capacity(), 2);
        let s0 = kv.adopt(model.prefill_session(&[5, 6]).1).unwrap();
        let s1 = kv.adopt(model.prefill_session(&[7]).1).unwrap();
        assert!(kv.is_full());
        assert!(kv.adopt(model.prefill_session(&[8]).1).is_none(), "over capacity");
        kv.release(s0);
        let s2 = kv.adopt(model.prefill_session(&[9]).1).unwrap();
        assert_eq!(s2, s0, "freed handle is reused");
        assert_eq!(kv.active(), 2);
        let _ = s1;
    }

    #[test]
    fn kv_cache_allocates_lazily_per_written_position() {
        // Satellite: the contiguous fallback must not pay worst-case
        // `max_seq` bytes up-front — a prefilled session materializes
        // exactly its prompt positions and grows one position per
        // decode write.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 217);
        let model = SlabModel::from_dense(&params, 1);
        let per_pos = cfg.n_layers * 2 * cfg.dim * 4;
        let (_, cache) = model.prefill_session(&[5, 6]);
        assert_eq!(cache.nbytes(), cfg.prompt_len * per_pos, "prompt positions only");
        let mut kv = KvCachePool::for_model(&model, 1);
        let s = kv.adopt(cache).unwrap();
        let before = kv.nbytes();
        model.decode_batch(&mut kv, &[DecodeSlot { session: s, token: 5, pos: cfg.prompt_len }]);
        assert_eq!(kv.nbytes(), before + per_pos, "one position per decode write");
        assert!(
            kv.nbytes() < cfg.n_layers * 2 * cfg.max_seq * cfg.dim * 4,
            "never the worst-case footprint"
        );
    }

    #[test]
    #[should_panic(expected = "single-session")]
    fn kv_cache_pool_rejects_multi_session_caches() {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 208);
        let model = SlabModel::from_dense(&params, 1);
        let (_, cache) = model.prefill(&vec![5; 2 * cfg.prompt_len], 2);
        let mut kv = KvCachePool::for_model(&model, 2);
        kv.adopt(cache);
    }

    #[test]
    #[should_panic(expected = "duplicate session")]
    fn decode_batch_rejects_duplicate_sessions() {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 209);
        let model = SlabModel::from_dense(&params, 1);
        let mut kv = KvCachePool::for_model(&model, 2);
        let s = kv.adopt(model.prefill_session(&[5, 6]).1).unwrap();
        let t = cfg.prompt_len;
        model.decode_batch(
            &mut kv,
            &[
                DecodeSlot { session: s, token: 5, pos: t },
                DecodeSlot { session: s, token: 6, pos: t + 1 },
            ],
        );
    }

    #[test]
    fn generation_is_deterministic_and_respects_budget() {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 204);
        let model = SlabModel::from_dense(&params, 0);
        let prompts = vec![vec![3, 4, 5], vec![8, 9]];
        let a = model.generate_batch(&prompts, 5);
        let b = model.generate_batch(&prompts, 5);
        assert_eq!(a, b);
        for g in &a {
            assert!(g.len() <= 5);
            assert!(g.iter().all(|&tk| tk != EOS && tk != PAD));
        }
        // Budget larger than max_seq headroom is clamped, not panicking.
        let c = model.generate_batch(&prompts, 1000);
        for g in &c {
            assert!(g.len() <= cfg.max_seq - cfg.prompt_len);
        }
    }

    /// Borrow pre-materialized block tensors as a [`CaptureBlock`].
    fn capture_block(mats: &[Mat; 7], norms: &[Vec<f32>; 2], n_heads: usize) -> CaptureBlock<'_> {
        CaptureBlock {
            attn_norm: &norms[0],
            wq: &mats[0],
            wk: &mats[1],
            wv: &mats[2],
            wo: &mats[3],
            mlp_norm: &norms[1],
            w_gate: &mats[4],
            w_up: &mats[5],
            w_down: &mats[6],
            n_heads,
        }
    }

    fn block_tensors(params: &Params, layer: usize) -> ([Mat; 7], [Vec<f32>; 2]) {
        let idx = |n: &str| params.index(&format!("l{layer}.{n}")).unwrap();
        let mats = [
            params.mat(&format!("l{layer}.wq")),
            params.mat(&format!("l{layer}.wk")),
            params.mat(&format!("l{layer}.wv")),
            params.mat(&format!("l{layer}.wo")),
            params.mat(&format!("l{layer}.w_gate")),
            params.mat(&format!("l{layer}.w_up")),
            params.mat(&format!("l{layer}.w_down")),
        ];
        let norms = [
            params.tensors[idx("attn_norm")].clone(),
            params.tensors[idx("mlp_norm")].clone(),
        ];
        (mats, norms)
    }

    #[test]
    fn capture_forward_chain_is_bit_identical_to_prefill() {
        // The capture path and the serving prefill share the block
        // machinery (rmsnorm, RoPE, causal_attention, SwiGLU, the row
        // kernels), so with a pad-free prompt the chained h_out of
        // every block — finished with final-norm + head — must land on
        // prefill's last-position logits *bit for bit*, pool or not.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 210);
        let model = SlabModel::from_dense(&params, 2);
        let (bsz, t) = (2usize, cfg.max_seq);
        let tokens: Vec<i32> = (0..bsz * t).map(|i| 5 + (i as i32 % 20)).collect();
        let (logits, _) = model.prefill(&tokens, bsz);

        let pool = ThreadPool::new(3);
        for pool in [None, Some(&pool)] {
            let mut h = embed_rows(&params.mat("tok_emb"), &tokens);
            for layer in 0..cfg.n_layers {
                let (mats, norms) = block_tensors(&params, layer);
                let blk = capture_block(&mats, &norms, cfg.n_heads);
                let acts = blk.capture_forward(&h, bsz, pool);
                assert_eq!(acts.x_attn.shape(), (bsz * t, cfg.dim));
                assert_eq!(acts.att_out.shape(), (bsz * t, cfg.dim));
                assert_eq!(acts.x_mlp.shape(), (bsz * t, cfg.dim));
                assert_eq!(acts.mlp_inner.shape(), (bsz * t, cfg.ffn));
                h = acts.h_out;
            }
            let xf = rmsnorm(&h, &model.final_norm);
            let mut last = Mat::zeros(bsz, cfg.dim);
            for b in 0..bsz {
                last.row_mut(b).copy_from_slice(xf.row(b * t + t - 1));
            }
            let chained = matmul_bt(&last, &model.lm_head);
            assert_eq!(chained.data, logits.data, "pool={}", pool.is_some());
        }
    }

    #[test]
    fn capture_sources_match_block_definitions() {
        // Spot-check the four captured sources against their paper
        // definitions: x_attn = rmsnorm(h), mlp_inner = silu(gate)⊙up,
        // h_out = h + att·woᵀ + inner·w_downᵀ.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 211);
        let mut rng = Pcg64::seed_from_u64(212);
        let h = Mat::randn(2 * cfg.max_seq, cfg.dim, 1.0, &mut rng);
        let (mats, norms) = block_tensors(&params, 0);
        let blk = capture_block(&mats, &norms, cfg.n_heads);
        let acts = blk.capture_forward(&h, 2, None);
        assert_eq!(acts.x_attn, rmsnorm(&h, &norms[0]));
        let gate = matmul_bt(&acts.x_mlp, &mats[4]);
        let up = matmul_bt(&acts.x_mlp, &mats[5]);
        for r in 0..h.rows {
            for j in 0..cfg.ffn {
                let expect = silu(gate.at(r, j)) * up.at(r, j);
                assert!((acts.mlp_inner.at(r, j) - expect).abs() < 1e-6);
            }
        }
        let mut expect_h = h.clone();
        expect_h.add_assign(&matmul_bt(&acts.att_out, &mats[3]));
        expect_h.add_assign(&matmul_bt(&acts.mlp_inner, &mats[6]));
        assert!(acts.h_out.allclose(&expect_h, 1e-5, 1e-5));
    }

    #[test]
    fn forward_full_matches_prefill_and_is_pool_invariant() {
        // The scoring forward shares every op with prefill; on a
        // pad-free batch (key masking degenerates to pure causality)
        // its last-position rows must land on prefill's logits bit for
        // bit, for both engines (dense and packed) and any pool.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 213);
        let (packed, _) = compress_native(&params, 214);
        let pool = ThreadPool::new(3);
        let engines = [
            SlabModel::from_dense(&params, 1),
            SlabModel::from_packed(&params, &packed, 1),
        ];
        for model in engines {
            let (bsz, t) = (2usize, cfg.max_seq);
            let tokens: Vec<i32> = (0..bsz * t).map(|i| 5 + (i as i32 % 20)).collect();
            let (plogits, _) = model.prefill(&tokens, bsz);
            let serial = model.forward_full(&tokens, bsz, None);
            assert_eq!(serial.shape(), (bsz * t, cfg.vocab));
            for b in 0..bsz {
                assert_eq!(serial.row(b * t + t - 1), plogits.row(b), "batch row {b}");
            }
            let par = model.forward_full(&tokens, bsz, Some(&pool));
            assert_eq!(par.data, serial.data, "pool must be invisible");
        }
    }

    #[test]
    fn forward_full_rows_are_independent_of_batching() {
        // Row independence is what makes the native eval harness's
        // parallel-over-rows reduction bit-identical to serial: each
        // sequence's logits must not depend on its batch neighbours or
        // its slot in the batch.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 215);
        let model = SlabModel::from_dense(&params, 1);
        let t = cfg.max_seq;
        let ra: Vec<i32> = (0..t).map(|i| 5 + (i as i32 % 11)).collect();
        let rb: Vec<i32> = (0..t).map(|i| 7 + (i as i32 % 13)).collect();
        let mut ab = ra.clone();
        ab.extend_from_slice(&rb);
        let mut ba = rb.clone();
        ba.extend_from_slice(&ra);
        let la = model.forward_full(&ra, 1, None);
        let lb = model.forward_full(&rb, 1, None);
        let lab = model.forward_full(&ab, 2, None);
        let lba = model.forward_full(&ba, 2, None);
        for pos in 0..t {
            assert_eq!(lab.row(pos), la.row(pos), "a@pos{pos} batched first");
            assert_eq!(lab.row(t + pos), lb.row(pos), "b@pos{pos} batched second");
            assert_eq!(lba.row(pos), lb.row(pos), "b@pos{pos} batched first");
            assert_eq!(lba.row(t + pos), la.row(pos), "a@pos{pos} batched second");
        }
    }

    #[test]
    fn greedy_token_policy() {
        assert_eq!(greedy_token(&[9.0, 1.0, 2.0, 3.0, 4.0]), 0);
        assert_eq!(greedy_token(&[0.0, 0.0, 0.0, 0.0, 1.0, 5.0]), 5);
        // All -inf: falls back to the first non-special id.
        assert_eq!(greedy_token(&[f32::NEG_INFINITY; 8]), 4);
    }
}
