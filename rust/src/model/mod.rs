//! Model-side state: the canonical parameter store (matching the
//! manifest's flat order), initialization, checkpoint I/O, and the
//! native packed-serving model.
//!
//! The transformer *computation* has two homes: the AOT artifacts
//! (L2) consumed through [`crate::runtime`], and the pure-Rust
//! [`native::SlabModel`] forward that serves straight from the packed
//! SLaB format — the engine behind
//! [`crate::coordinator::serve::Backend::NativePacked`]
//! (DESIGN.md §6). This module owns the host-side representations the
//! coordinator mutates when it swaps compressed weights in.

pub mod native;
pub mod params;

pub use native::{
    embed_rows, greedy_token, BlockActs, CaptureBlock, DecodeSlot, KvCache, KvCachePool, Linear,
    SlabModel,
};
pub use params::Params;
