//! Model-side state: the canonical parameter store (matching the
//! manifest's flat order), initialization, and checkpoint I/O.
//!
//! The transformer *computation* lives in the AOT artifacts (L2); this
//! module owns the host-side representation the coordinator mutates
//! when it swaps compressed weights in.

pub mod params;

pub use params::Params;
