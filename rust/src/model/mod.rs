//! Model-side state: the canonical parameter store (matching the
//! manifest's flat order), initialization, checkpoint I/O, and the
//! native packed-serving model.
//!
//! The transformer *computation* has two homes: the AOT artifacts
//! (L2) consumed through [`crate::runtime`], and the pure-Rust
//! [`native::SlabModel`] forward that serves straight from the packed
//! SLaB format — the engine behind
//! [`crate::coordinator::serve::Backend::NativePacked`]
//! (DESIGN.md §6). This module owns the host-side representations the
//! coordinator mutates when it swaps compressed weights in.
//!
//! It also owns the **block-paged KV pool** ([`PagedKvPool`],
//! DESIGN.md §13): per-session page tables over a refcounted
//! [`PageArena`], with copy-on-write shared prefixes keyed on the
//! padded prompt. Paging changes only *address computation* — the
//! decode forward runs the same operations in the same accumulation
//! order through [`native::KvStore`] — so paged decode is
//! bit-identical to the contiguous [`KvCachePool`].

pub mod native;
pub mod params;

pub use native::{
    embed_rows, greedy_token, BlockActs, CaptureBlock, DecodeSlot, DraftModel, KvCache,
    KvCachePool, Linear, SlabModel, VerifySlot,
};
pub use params::Params;

use crate::runtime::ModelCfg;
use crate::util::pool::{PageArena, SlotArena};
use native::KvStore;

/// Geometry and policy knobs for the block-paged KV pool
/// ([`PagedKvPool`], DESIGN.md §13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PagedKvConfig {
    /// Tokens per KV page (`≥ 1`). Small pages track actual usage
    /// tightly; large pages amortize page-table overhead.
    pub page_size: usize,
    /// Hard page budget. `0` picks the worst-case-safe default
    /// `max_sessions · ⌈max_seq / page_size⌉` — the budget at which
    /// paging can never reject a session the contiguous pool would
    /// have admitted. Non-zero budgets are clamped up to one
    /// worst-case session (`⌈max_seq / page_size⌉`) so the scheduler
    /// can always make progress.
    pub n_pages: usize,
    /// Share prefilled pages between sessions whose padded prompts
    /// are identical, copy-on-write on the first divergent write.
    pub prefix_sharing: bool,
}

impl Default for PagedKvConfig {
    fn default() -> PagedKvConfig {
        PagedKvConfig {
            page_size: 8,
            n_pages: 0,
            prefix_sharing: true,
        }
    }
}

/// Paged-pool observability, surfaced through the scheduler's
/// `ServeStats` → `/metrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PagedKvCounters {
    /// Admissions that joined an already-prefilled prefix (no prefill
    /// forward was run).
    pub prefix_hits: usize,
    /// Admissions that prefilled fresh pages.
    pub prefix_misses: usize,
    /// Copy-on-write page splits on first divergent write to a shared
    /// page.
    pub cow_splits: usize,
    /// Prefix-index entries dropped to reclaim pages under pressure.
    pub prefix_evictions: usize,
    /// Pages currently allocated (gauge, filled at read time).
    pub pages_in_use: usize,
    /// High-water mark of allocated pages.
    pub pages_peak: usize,
}

/// One session's page table: `pages[i]` holds cache positions
/// `[i·page_size, (i+1)·page_size)`; `len` is one past the highest
/// written position.
#[derive(Debug, Clone)]
struct PageTable {
    pages: Vec<usize>,
    len: usize,
}

/// One cached prefill in the prefix index: the padded prompt (the
/// lookup key — DESIGN.md §13's sharing condition), the pages holding
/// its KV rows (the index owns one reference on each), and the
/// last-position logits so a hit skips the prefill forward entirely.
struct PrefixEntry {
    key: Vec<i32>,
    pages: Vec<usize>,
    logits: Vec<f32>,
}

/// Block-paged per-session KV storage — the paged twin of
/// [`KvCachePool`] behind the continuous-batching scheduler.
///
/// Layout: per layer, one flat K and one flat V buffer addressed as
/// `(page · page_size + slot) · dim`, grown lazily to the high-water
/// page; a [`PageArena`] refcounts pages; each session maps cache
/// positions to pages through its private [`PageTable`].
///
/// Sharing: [`adopt_prefill`](PagedKvPool::adopt_prefill) registers
/// the padded prompt in a prefix index (the index retains the pages),
/// and [`admit_shared`](PagedKvPool::admit_shared) lets a later
/// session with the same padded prompt join those pages without
/// running prefill. The first write into a shared page
/// ([`prepare_write`](PagedKvPool::prepare_write)) copy-on-write
/// splits it, so sharers can never observe each other's tokens.
///
/// Allocation is confined to `prepare_write` (plus admission): the
/// decode forward itself never allocates, so a batched tick can never
/// fail mid-layer — the scheduler secures every write target first
/// and evicts sessions it cannot secure.
pub struct PagedKvPool {
    /// Per layer, pages-major K/V rows, materialized lazily.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    pages: PageArena,
    sessions: SlotArena<PageTable>,
    /// FIFO prefix index (oldest evicted first under page pressure).
    prefix: Vec<PrefixEntry>,
    counters: PagedKvCounters,
    n_layers: usize,
    max_seq: usize,
    dim: usize,
    prompt_len: usize,
    page_size: usize,
    prefix_sharing: bool,
}

impl PagedKvPool {
    /// Pool shaped for `model`, holding at most `max_sessions` live
    /// sessions under `cfg`'s page geometry.
    pub fn for_model(model: &SlabModel, max_sessions: usize, cfg: PagedKvConfig) -> PagedKvPool {
        assert!(cfg.page_size >= 1, "page_size must be ≥ 1");
        let m = &model.cfg;
        let worst = m.max_seq.div_ceil(cfg.page_size);
        let n_pages = if cfg.n_pages == 0 {
            max_sessions.max(1) * worst
        } else {
            cfg.n_pages.max(worst)
        };
        PagedKvPool {
            k: vec![Vec::new(); m.n_layers],
            v: vec![Vec::new(); m.n_layers],
            pages: PageArena::with_capacity(n_pages),
            sessions: SlotArena::with_capacity(max_sessions),
            prefix: Vec::new(),
            counters: PagedKvCounters::default(),
            n_layers: m.n_layers,
            max_seq: m.max_seq,
            dim: m.dim,
            prompt_len: m.prompt_len,
            page_size: cfg.page_size,
            prefix_sharing: cfg.prefix_sharing,
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Pages needed to hold `len` cache positions.
    fn pages_for(&self, len: usize) -> usize {
        len.div_ceil(self.page_size)
    }

    /// Pages a fresh prompt occupies.
    pub fn prompt_pages(&self) -> usize {
        self.pages_for(self.prompt_len)
    }

    pub fn free_pages(&self) -> usize {
        self.pages.free_pages()
    }

    pub fn allocated_pages(&self) -> usize {
        self.pages.allocated()
    }

    pub fn capacity_pages(&self) -> usize {
        self.pages.capacity()
    }

    /// Live sessions.
    pub fn active(&self) -> usize {
        self.sessions.len()
    }

    /// Hard cap on live sessions (the scheduler's batch cap).
    pub fn capacity(&self) -> usize {
        self.sessions.capacity()
    }

    pub fn is_full(&self) -> bool {
        self.sessions.is_full()
    }

    /// Materialized KV bytes (tracks the high-water page, not the
    /// worst-case budget).
    pub fn nbytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|l| l.len() * 4).sum()
    }

    /// Counter snapshot with the live-page gauge filled in.
    pub fn counters(&self) -> PagedKvCounters {
        let mut c = self.counters;
        c.pages_in_use = self.pages.allocated();
        c
    }

    /// Cache positions written for a live session.
    pub fn session_len(&self, session: usize) -> usize {
        self.sessions.get(session).expect("live session handle").len
    }

    /// A live session's page table (test/diagnostic observability).
    pub fn session_pages(&self, session: usize) -> &[usize] {
        &self.sessions.get(session).expect("live session handle").pages
    }

    /// A page's current reference count (`0` when free).
    pub fn page_refcount(&self, page: usize) -> u32 {
        self.pages.refcount(page)
    }

    /// Prefix-index entries currently cached.
    pub fn cached_prefixes(&self) -> usize {
        self.prefix.len()
    }

    /// Whether `padded` (a [`SlabModel::pad_prompt`] output) would hit
    /// the prefix index — i.e. admission needs **zero** new pages.
    pub fn has_prefix(&self, padded: &[i32]) -> bool {
        self.prefix_sharing && self.prefix.iter().any(|e| e.key == padded)
    }

    fn alloc_page(&mut self) -> Option<usize> {
        let p = self.pages.alloc()?;
        let need = (p + 1) * self.page_size * self.dim;
        for li in 0..self.n_layers {
            if self.k[li].len() < need {
                self.k[li].resize(need, 0.0);
                self.v[li].resize(need, 0.0);
            }
        }
        self.counters.pages_peak = self.counters.pages_peak.max(self.pages.allocated());
        Some(p)
    }

    #[inline]
    fn offset(&self, page: usize, slot: usize) -> usize {
        (page * self.page_size + slot) * self.dim
    }

    fn row_offset(&self, session: usize, pos: usize) -> usize {
        let t = self.sessions.get(session).expect("live session handle");
        let page = t.pages[pos / self.page_size];
        self.offset(page, pos % self.page_size)
    }

    /// Whether a write at `pos` is already secured: the page exists
    /// and is exclusively owned. The decode forward's
    /// [`KvStore::begin_write`] assertion.
    fn write_ready(&self, session: usize, pos: usize) -> bool {
        let Some(t) = self.sessions.get(session) else {
            return false;
        };
        let pi = pos / self.page_size;
        t.pages.get(pi).is_some_and(|&p| self.pages.refcount(p) == 1)
    }

    /// Adopt a freshly prefilled single-session cache (the output of
    /// [`SlabModel::prefill_session`] on `padded`'s prompt), scattering
    /// its rows into fresh pages; returns the session handle, or
    /// `None` when sessions or pages are exhausted — the scheduler's
    /// signal to evict prefixes or stop admitting. With sharing on,
    /// the prefix is registered (pages retained by the index, `logits`
    /// memoized) so later identical prompts can
    /// [`admit_shared`](PagedKvPool::admit_shared).
    pub fn adopt_prefill(
        &mut self,
        padded: &[i32],
        logits: &[f32],
        cache: &KvCache,
    ) -> Option<usize> {
        assert_eq!(cache.batch_size(), 1, "pool caches are single-session");
        assert_eq!(padded.len(), self.prompt_len, "padded prompt vs prompt_len");
        let need = self.prompt_pages();
        if self.sessions.is_full() || self.pages.free_pages() < need {
            return None;
        }
        let pages: Vec<usize> = (0..need)
            .map(|_| self.alloc_page().expect("free_pages pre-checked"))
            .collect();
        let dim = self.dim;
        for li in 0..self.n_layers {
            for s in 0..self.prompt_len {
                let o = self.offset(pages[s / self.page_size], s % self.page_size);
                self.k[li][o..o + dim].copy_from_slice(cache.k_at(li, 0, s));
                self.v[li][o..o + dim].copy_from_slice(cache.v_at(li, 0, s));
            }
        }
        self.counters.prefix_misses += 1;
        if self.prefix_sharing && !self.has_prefix(padded) {
            for &p in &pages {
                self.pages.retain(p);
            }
            self.prefix.push(PrefixEntry {
                key: padded.to_vec(),
                pages: pages.clone(),
                logits: logits.to_vec(),
            });
        }
        let len = self.prompt_len;
        let sid = self
            .sessions
            .insert(PageTable { pages, len })
            .expect("session capacity pre-checked");
        Some(sid)
    }

    /// Join an already-prefilled prefix: the new session's page table
    /// aliases the index's pages (each retained once) and the memoized
    /// last-position logits are returned in place of a prefill
    /// forward. `None` when sharing is off, the key misses, or the
    /// session arena is full — the caller falls back to
    /// [`adopt_prefill`](PagedKvPool::adopt_prefill).
    pub fn admit_shared(&mut self, padded: &[i32]) -> Option<(usize, Vec<f32>)> {
        if !self.prefix_sharing || self.sessions.is_full() {
            return None;
        }
        let idx = self.prefix.iter().position(|e| e.key == padded)?;
        let (pages, logits) = {
            let e = &self.prefix[idx];
            (e.pages.clone(), e.logits.clone())
        };
        for &p in &pages {
            self.pages.retain(p);
        }
        let len = self.prompt_len;
        let sid = self
            .sessions
            .insert(PageTable { pages, len })
            .expect("capacity pre-checked above");
        self.counters.prefix_hits += 1;
        Some((sid, logits))
    }

    /// Free a terminated session: its table is dropped and every page
    /// reference released (pages shared with the index or other
    /// sessions stay allocated). Returns whether the handle was live.
    pub fn release(&mut self, session: usize) -> bool {
        let Some(table) = self.sessions.remove(session) else {
            return false;
        };
        for p in table.pages {
            self.pages.release(p);
        }
        true
    }

    /// Whether [`prepare_write`](PagedKvPool::prepare_write) for
    /// `pos` would succeed *right now* (without mutating anything):
    /// either the target page is exclusively owned, or a free page
    /// exists for the grow / COW-split.
    pub fn can_write(&self, session: usize, pos: usize) -> bool {
        assert!(pos < self.max_seq, "pos {pos} vs max_seq {}", self.max_seq);
        let t = self.sessions.get(session).expect("live session handle");
        match t.pages.get(pos / self.page_size) {
            Some(&p) => self.pages.refcount(p) == 1 || self.pages.free_pages() >= 1,
            None => self.pages.free_pages() >= 1,
        }
    }

    /// Secure the write target for `pos` before a decode tick:
    /// grows the table by one fresh page when `pos` starts a new one,
    /// copy-on-write splits the page when it is shared (first
    /// divergent write — the sharer gets a private copy, the shared
    /// original keeps its other holders), and is a no-op when the
    /// page is already exclusive. Idempotent. Returns `false` — with
    /// **no state change** — when a needed page cannot be allocated;
    /// the scheduler then evicts prefixes and retries, or evicts the
    /// session.
    pub fn prepare_write(&mut self, session: usize, pos: usize) -> bool {
        assert!(pos < self.max_seq, "pos {pos} vs max_seq {}", self.max_seq);
        let pi = pos / self.page_size;
        let existing = {
            let t = self.sessions.get(session).expect("live session handle");
            assert!(pi <= t.pages.len(), "non-contiguous page growth at pos {pos}");
            t.pages.get(pi).copied()
        };
        match existing {
            Some(p) if self.pages.refcount(p) > 1 => {
                // COW split: private copy of the whole page, release
                // one reference on the shared original.
                let Some(np) = self.alloc_page() else {
                    return false;
                };
                let row = self.page_size * self.dim;
                let (src, dst) = (p * row, np * row);
                for li in 0..self.n_layers {
                    self.k[li].copy_within(src..src + row, dst);
                    self.v[li].copy_within(src..src + row, dst);
                }
                self.pages.release(p);
                let t = self.sessions.get_mut(session).expect("live session handle");
                t.pages[pi] = np;
                t.len = t.len.max(pos + 1);
                self.counters.cow_splits += 1;
                true
            }
            Some(_) => {
                let t = self.sessions.get_mut(session).expect("live session handle");
                t.len = t.len.max(pos + 1);
                true
            }
            None => {
                let Some(np) = self.alloc_page() else {
                    return false;
                };
                let t = self.sessions.get_mut(session).expect("live session handle");
                t.pages.push(np);
                t.len = t.len.max(pos + 1);
                true
            }
        }
    }

    /// Roll a session back past a rejected speculative suffix
    /// (DESIGN.md §14): shrink `len` to `new_len` and release every
    /// page wholly past it. `new_len` must not exceed the current
    /// length — speculation only ever rolls *back*. Stale rows inside
    /// the last kept page are left in place; decode overwrites a
    /// position before attention ever reads it, so they are never
    /// observed. Maintains the §13 audit's `pages == pages_for(len)`
    /// invariant; released pages that are still held elsewhere (a COW
    /// original retained by the prefix index or a sharer) merely drop
    /// one reference.
    pub fn truncate(&mut self, session: usize, new_len: usize) {
        let keep = self.pages_for(new_len);
        let dropped = {
            let t = self.sessions.get_mut(session).expect("live session handle");
            assert!(new_len <= t.len, "truncate to {new_len} past len {}", t.len);
            t.len = new_len;
            t.pages.split_off(keep)
        };
        for p in dropped {
            self.pages.release(p);
        }
    }

    /// Drop prefix-index entries (oldest first) until at least
    /// `need_free` pages are free or the index is empty; returns how
    /// many entries were dropped. Pages still shared by live sessions
    /// stay allocated — only the index's own references are released.
    pub fn evict_prefixes(&mut self, need_free: usize) -> usize {
        let mut dropped = 0;
        while self.pages.free_pages() < need_free && !self.prefix.is_empty() {
            let e = self.prefix.remove(0);
            for p in e.pages {
                self.pages.release(p);
            }
            self.counters.prefix_evictions += 1;
            dropped += 1;
        }
        dropped
    }

    /// Exhaustive bookkeeping audit for the fuzz suites: every page
    /// referenced by a session table or the prefix index is live, each
    /// page's refcount equals its number of holders, and the arena's
    /// allocated count equals the number of distinct referenced pages
    /// — no leaks, no double-frees, free list consistent. Panics with
    /// a description on any violation.
    pub fn check_invariants(&self) {
        use std::collections::HashMap;
        let mut held: HashMap<usize, u32> = HashMap::new();
        for (_, t) in self.sessions.iter() {
            assert!(t.len <= self.max_seq, "session len past max_seq");
            assert_eq!(t.pages.len(), self.pages_for(t.len), "table size vs len");
            for &p in &t.pages {
                *held.entry(p).or_insert(0) += 1;
            }
        }
        for e in &self.prefix {
            for &p in &e.pages {
                *held.entry(p).or_insert(0) += 1;
            }
        }
        assert_eq!(
            held.len(),
            self.pages.allocated(),
            "allocated pages vs distinct referenced pages (leak or stray)"
        );
        for (&p, &n) in &held {
            assert_eq!(self.pages.refcount(p), n, "refcount of page {p} vs holders");
        }
        assert_eq!(
            self.pages.free_pages(),
            self.pages.capacity() - held.len(),
            "free-list accounting"
        );
    }
}

impl KvStore for PagedKvPool {
    fn assert_model(&self, cfg: &ModelCfg) {
        assert_eq!(self.n_layers, cfg.n_layers, "paged pool built for another model");
        assert_eq!(self.dim, cfg.dim, "paged pool built for another model");
        assert_eq!(self.max_seq, cfg.max_seq, "paged pool built for another model");
    }

    fn has_session(&self, session: usize) -> bool {
        self.sessions.get(session).is_some()
    }

    fn begin_write(&mut self, session: usize, pos: usize) {
        assert!(
            self.write_ready(session, pos),
            "page for session {session} pos {pos} not secured — call prepare_write before decode"
        );
    }

    fn write_row(&mut self, layer: usize, session: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        let o = self.row_offset(session, pos);
        let dim = self.dim;
        self.k[layer][o..o + dim].copy_from_slice(krow);
        self.v[layer][o..o + dim].copy_from_slice(vrow);
    }

    fn k_row(&self, layer: usize, session: usize, pos: usize) -> &[f32] {
        let o = self.row_offset(session, pos);
        &self.k[layer][o..o + self.dim]
    }

    fn v_row(&self, layer: usize, session: usize, pos: usize) -> &[f32] {
        let o = self.row_offset(session, pos);
        &self.v[layer][o..o + self.dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use std::collections::HashMap;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg::llama("tiny-paged", 32, 8, 2, 2, 16, 16, 6)
    }

    /// Pinned default, overridable via `SLAB_FUZZ_SEED` so CI failures
    /// replay deterministically (the CI test job pins it explicitly).
    fn fuzz_seed(default: u64) -> u64 {
        std::env::var("SLAB_FUZZ_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    #[test]
    fn page_allocator_fuzz_no_leaks_no_double_frees() {
        // Satellite: random admit/share/grow/COW/release/evict
        // interleavings, audited after every op against the reference
        // bookkeeping in `check_invariants` (allocated == distinct
        // referenced pages, refcount == holder count, free-list
        // consistent) plus exact refcount deltas on release.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 401);
        let model = SlabModel::from_dense(&params, 1);
        let prompts: [Vec<i32>; 3] = [vec![5, 6, 7], vec![9, 10], vec![11, 12, 13, 14]];
        let prefills: Vec<(Vec<i32>, Vec<f32>, KvCache)> = prompts
            .iter()
            .map(|p| {
                let (logits, cache) = model.prefill_session(p);
                (model.pad_prompt(p), logits.row(0).to_vec(), cache)
            })
            .collect();
        let seed = fuzz_seed(0x9a6e5);
        eprintln!("page_allocator_fuzz seed = {seed} (set SLAB_FUZZ_SEED to replay)");
        let mut rng = Pcg64::seed_from_u64(seed);
        for round in 0..4u64 {
            // Tight budget (prompt = 3 pages, worst-case session = 8,
            // 11 total) so rejection and eviction paths run hot;
            // sharing toggles per round.
            let mut pool = PagedKvPool::for_model(
                &model,
                4,
                PagedKvConfig {
                    page_size: 2,
                    n_pages: 11,
                    prefix_sharing: round % 2 == 0,
                },
            );
            let mut live: Vec<usize> = Vec::new();
            let mut next_pos: HashMap<usize, usize> = HashMap::new();
            for _ in 0..300 {
                match rng.below(5) {
                    0 | 1 => {
                        let (key, logits, cache) = &prefills[rng.below_usize(prefills.len())];
                        let sid = match pool.admit_shared(key) {
                            Some((sid, shared_logits)) => {
                                assert_eq!(&shared_logits, logits, "memoized logits replay");
                                Some(sid)
                            }
                            None => pool.adopt_prefill(key, logits, cache),
                        };
                        if let Some(sid) = sid {
                            assert!(!live.contains(&sid), "handle collision");
                            live.push(sid);
                            next_pos.insert(sid, cfg.prompt_len);
                        }
                    }
                    2 => {
                        if live.is_empty() {
                            continue;
                        }
                        let i = rng.below_usize(live.len());
                        let sid = live.swap_remove(i);
                        next_pos.remove(&sid);
                        // A table's pages are pairwise distinct, so each
                        // refcount must drop by exactly one — hitting
                        // zero (free) exactly when this was the last
                        // holder.
                        let held: Vec<(usize, u32)> = pool
                            .session_pages(sid)
                            .iter()
                            .map(|&p| (p, pool.page_refcount(p)))
                            .collect();
                        assert!(pool.release(sid));
                        assert!(!pool.release(sid), "double release must be a no-op");
                        for (p, rc) in held {
                            assert!(rc >= 1);
                            assert_eq!(pool.page_refcount(p), rc - 1, "one ref per release");
                        }
                    }
                    3 => {
                        if live.is_empty() {
                            continue;
                        }
                        let sid = live[rng.below_usize(live.len())];
                        let pos = next_pos.get_mut(&sid).unwrap();
                        if *pos < cfg.max_seq {
                            assert_eq!(pool.can_write(sid, *pos), {
                                // can_write is a pure preview of
                                // prepare_write's outcome.
                                let ok = pool.prepare_write(sid, *pos);
                                if ok {
                                    *pos += 1;
                                }
                                ok
                            });
                        }
                    }
                    _ => {
                        pool.evict_prefixes(rng.below_usize(3) + 1);
                    }
                }
                pool.check_invariants();
                assert_eq!(pool.allocated_pages() + pool.free_pages(), pool.capacity_pages());
            }
            for sid in live.drain(..) {
                assert!(pool.release(sid));
            }
            pool.evict_prefixes(pool.capacity_pages());
            pool.check_invariants();
            assert_eq!(pool.allocated_pages(), 0, "drained arena leaks pages");
            assert!(pool.counters().prefix_misses > 0, "fuzz exercised admission");
        }
    }

    #[test]
    fn cow_split_isolates_sharers_and_preserves_prefix() {
        // prompt_len 6 with page_size 4: pages [0..4) and [4..8), the
        // second half-full — position 6 is the first divergent write
        // and must COW-split, never mutate the shared page.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 402);
        let model = SlabModel::from_dense(&params, 1);
        let prompt = vec![5, 6, 7];
        let padded = model.pad_prompt(&prompt);
        let (logits, cache) = model.prefill_session(&prompt);
        let mut pool = PagedKvPool::for_model(
            &model,
            4,
            PagedKvConfig { page_size: 4, ..Default::default() },
        );
        let s0 = pool.adopt_prefill(&padded, logits.row(0), &cache).unwrap();
        let (s1, shared) = pool.admit_shared(&padded).unwrap();
        assert_eq!(shared, logits.row(0).to_vec(), "memoized prefill logits");
        assert_eq!(pool.session_pages(s0), pool.session_pages(s1), "sharers alias pages");
        assert_eq!(pool.session_len(s1), cfg.prompt_len);
        assert_eq!(pool.counters().prefix_hits, 1);
        assert_eq!(pool.counters().prefix_misses, 1);
        let shared_page = pool.session_pages(s1)[1];
        assert_eq!(pool.page_refcount(shared_page), 3, "prefix index + two sessions");

        let before = pool.k_row(0, s0, 5).to_vec();
        assert!(pool.prepare_write(s1, 6));
        assert_ne!(pool.session_pages(s0)[1], pool.session_pages(s1)[1], "private copy");
        assert_eq!(pool.page_refcount(shared_page), 2);
        assert_eq!(pool.counters().cow_splits, 1);
        // The split copied the prefix rows into the private page…
        assert_eq!(pool.k_row(0, s1, 5), &before[..]);
        // …and a divergent write stays invisible to the other sharer.
        let junk = vec![7.0f32; cfg.dim];
        pool.write_row(0, s1, 6, &junk, &junk);
        assert_eq!(pool.k_row(0, s0, 5), &before[..], "sharer s0 unchanged");
        assert_eq!(pool.k_row(0, s1, 6), &junk[..]);
        // Idempotent once exclusive.
        assert!(pool.prepare_write(s1, 6));
        assert_eq!(pool.counters().cow_splits, 1);

        // Sessions die; the prefix stays cached and still admits.
        assert!(pool.release(s0));
        assert!(pool.release(s1));
        assert!(pool.has_prefix(&padded));
        let (s2, _) = pool.admit_shared(&padded).unwrap();
        assert_eq!(pool.counters().prefix_hits, 2);
        assert!(pool.release(s2));
        assert_eq!(pool.evict_prefixes(pool.capacity_pages()), 1);
        assert_eq!(pool.allocated_pages(), 0);
        assert_eq!(pool.cached_prefixes(), 0);
        pool.check_invariants();
    }

    #[test]
    fn paged_decode_is_bit_identical_to_contiguous() {
        // The tentpole oracle (DESIGN.md §13): same sessions, one pool
        // contiguous and one paged with sharing + a page size that
        // forces COW on the very first decode write — logits must
        // match *bit for bit* at every step.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 403);
        let model = SlabModel::from_dense(&params, 2);
        let t = cfg.prompt_len;
        let prompts: [Vec<i32>; 3] = [vec![5, 6, 7], vec![5, 6, 7], vec![9, 10]];
        let mut kv = KvCachePool::for_model(&model, 4);
        let mut paged = PagedKvPool::for_model(
            &model,
            4,
            PagedKvConfig { page_size: 4, n_pages: 0, prefix_sharing: true },
        );
        let mut steps_c: Vec<DecodeSlot> = Vec::new();
        let mut steps_p: Vec<DecodeSlot> = Vec::new();
        for p in &prompts {
            let padded = model.pad_prompt(p);
            let (cl, cc) = model.prefill_session(p);
            let ctok = greedy_token(cl.row(0));
            let cs = kv.adopt(cc).unwrap();
            steps_c.push(DecodeSlot { session: cs, token: ctok, pos: t });
            let (ps, plog) = match paged.admit_shared(&padded) {
                Some((sid, logits)) => (sid, logits),
                None => {
                    let (pl, pc) = model.prefill_session(p);
                    let sid = paged.adopt_prefill(&padded, pl.row(0), &pc).unwrap();
                    (sid, pl.row(0).to_vec())
                }
            };
            let ptok = greedy_token(&plog);
            assert_eq!(ptok, ctok, "first token from memoized logits");
            steps_p.push(DecodeSlot { session: ps, token: ptok, pos: t });
        }
        assert_eq!(paged.counters().prefix_hits, 1, "second sharer hit the index");

        for step in 0..4 {
            for st in &steps_p {
                assert!(paged.prepare_write(st.session, st.pos), "worst-case-safe budget");
            }
            let lc = model.decode_batch(&mut kv, &steps_c);
            let lp = model.decode_batch_paged(&mut paged, &steps_p);
            assert_eq!(lp.data, lc.data, "paged vs contiguous logits at step {step}");
            for r in 0..steps_c.len() {
                let tok = greedy_token(lc.row(r));
                steps_c[r] = DecodeSlot { session: steps_c[r].session, token: tok, pos: steps_c[r].pos + 1 };
                steps_p[r] = DecodeSlot { session: steps_p[r].session, token: tok, pos: steps_p[r].pos + 1 };
            }
            paged.check_invariants();
        }
        // First decode write (pos 6) fell inside the half-full shared
        // page for all three sessions (two sharers + the distinct
        // prompt's own prefix entry) — each needed a private copy.
        assert_eq!(paged.counters().cow_splits, 3);
        // And the greedy emit hooks agree too.
        for st in &steps_p {
            assert!(paged.prepare_write(st.session, st.pos));
        }
        let gc = model.decode_batch_greedy(&mut kv, &steps_c);
        let gp = model.decode_batch_greedy_paged(&mut paged, &steps_p);
        assert_eq!(gp, gc, "greedy emit parity");
    }

    #[test]
    fn multi_token_paged_scoring_matches_contiguous_and_truncate_rolls_back() {
        // The speculative verify pass over pages: multi-token scoring
        // must be bit-identical to the contiguous pool, and truncating
        // a rejected suffix must release exactly the wholly-dead pages
        // while keeping the §13 audit green and later decodes
        // bit-identical.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 405);
        let model = SlabModel::from_dense(&params, 2);
        let t = cfg.prompt_len;
        let prompt = vec![5, 6, 7];
        let padded = model.pad_prompt(&prompt);
        let (logits, cache) = model.prefill_session(&prompt);
        let fed: Vec<i32> = vec![greedy_token(logits.row(0)), 9, 14, 3];

        let mut kv = KvCachePool::for_model(&model, 1);
        let sc = kv.adopt(model.prefill_session(&prompt).1).unwrap();
        let lc = model
            .decode_batch_multi(&mut kv, &[VerifySlot { session: sc, pos: t, tokens: fed.clone() }]);
        assert_eq!(lc.rows, fed.len());

        let mut paged = PagedKvPool::for_model(
            &model,
            2,
            PagedKvConfig { page_size: 2, n_pages: 0, prefix_sharing: true },
        );
        let sp = paged.adopt_prefill(&padded, logits.row(0), &cache).unwrap();
        // A sharer keeps the prompt pages multi-referenced so rollback
        // interacts with live sharing.
        let (sq, _) = paged.admit_shared(&padded).unwrap();
        for j in 0..fed.len() {
            assert!(paged.prepare_write(sp, t + j), "worst-case-safe budget");
        }
        let lp = model.decode_batch_multi_paged(
            &mut paged,
            &[VerifySlot { session: sp, pos: t, tokens: fed.clone() }],
        );
        assert_eq!(lp.data, lc.data, "paged vs contiguous multi-token logits");

        // Overran by 3: only fed[0] stands. Roll back to len t+1.
        assert_eq!(paged.session_len(sp), t + fed.len());
        let pages_before = paged.session_pages(sp).len();
        let free_before = paged.free_pages();
        paged.truncate(sp, t + 1);
        assert_eq!(paged.session_len(sp), t + 1);
        assert_eq!(paged.session_pages(sp).len(), (t + 1).div_ceil(2), "audit shape");
        assert_eq!(
            paged.free_pages(),
            free_before + (pages_before - paged.session_pages(sp).len()),
            "dead pages returned to the arena"
        );
        paged.check_invariants();
        // Idempotent at the same length.
        paged.truncate(sp, t + 1);
        assert_eq!(paged.session_pages(sp).len(), (t + 1).div_ceil(2));
        paged.check_invariants();

        // Continue decoding past the rollback point: position t+1 is
        // re-secured and overwritten, and the logits still match the
        // contiguous pool (whose stale rows are likewise overwritten).
        let next = greedy_token(lp.row(0));
        assert!(paged.prepare_write(sp, t + 1));
        let step_p = model
            .decode_batch_paged(&mut paged, &[DecodeSlot { session: sp, token: next, pos: t + 1 }]);
        let step_c =
            model.decode_batch(&mut kv, &[DecodeSlot { session: sc, token: next, pos: t + 1 }]);
        assert_eq!(step_p.data, step_c.data, "post-rollback decode parity");
        paged.check_invariants();

        assert!(paged.release(sq));
        assert!(paged.release(sp));
        paged.evict_prefixes(paged.capacity_pages());
        assert_eq!(paged.allocated_pages(), 0, "rollback leaked pages");
    }

    #[test]
    fn page_budget_floor_and_exhaustion_signaling() {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 404);
        let model = SlabModel::from_dense(&params, 1);
        // A sub-floor budget is clamped to one worst-case session…
        let pool = PagedKvPool::for_model(
            &model,
            2,
            PagedKvConfig { page_size: 4, n_pages: 1, prefix_sharing: false },
        );
        assert_eq!(pool.capacity_pages(), 4, "⌈16/4⌉ floor");
        // …and budget 0 is the worst-case-safe default.
        let pool = PagedKvPool::for_model(
            &model,
            3,
            PagedKvConfig { page_size: 4, ..Default::default() },
        );
        assert_eq!(pool.capacity_pages(), 12);

        // Exhaustion: 2 pages, 1-page prompts, sharing off.
        let mut pool = PagedKvPool::for_model(
            &model,
            4,
            PagedKvConfig { page_size: 8, n_pages: 2, prefix_sharing: false },
        );
        let prompt = vec![3, 4];
        let padded = model.pad_prompt(&prompt);
        let (logits, cache) = model.prefill_session(&prompt);
        let s0 = pool.adopt_prefill(&padded, logits.row(0), &cache).unwrap();
        let s1 = pool.adopt_prefill(&padded, logits.row(0), &cache).unwrap();
        assert!(pool.adopt_prefill(&padded, logits.row(0), &cache).is_none(), "pages exhausted");
        assert!(pool.admit_shared(&padded).is_none(), "sharing disabled");
        assert_eq!(pool.nbytes(), cfg.n_layers * 2 * 2 * 8 * cfg.dim * 4, "two pages materialized");
        // In-place writes inside the exclusively-owned page still work…
        assert!(pool.can_write(s0, 6) && pool.prepare_write(s0, 6));
        assert!(pool.prepare_write(s0, 7));
        // …but growth past it is refused without a free page, with no
        // state change (the scheduler's evict signal).
        assert!(!pool.can_write(s0, 8));
        assert!(!pool.prepare_write(s0, 8));
        pool.check_invariants();
        assert!(pool.release(s1));
        assert!(pool.can_write(s0, 8) && pool.prepare_write(s0, 8), "freed page reused at once");
        assert_eq!(pool.session_pages(s0).len(), 2);
        pool.check_invariants();
        assert!(pool.release(s0));
        assert_eq!(pool.allocated_pages(), 0);
    }
}
