//! The canonical parameter store.
//!
//! Tensors are kept in the manifest's flat order — the exact
//! positional ABI of every model artifact. Initialization mirrors
//! `python/compile/model.py::init_params` (scaled-normal, residual
//! projections down-weighted, norms at one) so rust-initialized
//! models match what the JAX side would produce distributionally.

use crate::runtime::manifest::ModelCfg;
use crate::runtime::{lit_f32, to_vec_f32};
use crate::tensor::{Checkpoint, Entry, Mat, TensorData};
use crate::util::rng::Pcg64;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Params {
    pub cfg: ModelCfg,
    /// One tensor per manifest entry, row-major.
    pub tensors: Vec<Vec<f32>>,
}

impl Params {
    /// Scaled-normal init (std 0.02; `wo`/`w_down` scaled by
    /// 1/√(2·n_layers); norms = 1).
    pub fn init(cfg: &ModelCfg, seed: u64) -> Params {
        let mut rng = Pcg64::seed_from_u64(seed);
        let scale_resid = 1.0 / ((2 * cfg.n_layers) as f32).sqrt();
        let mut tensors = Vec::with_capacity(cfg.param_names.len());
        for (name, shape) in cfg.param_names.iter().zip(cfg.param_shapes.iter()) {
            let numel: usize = shape.iter().product();
            let base = name.rsplit('.').next().unwrap();
            let mut data = vec![0.0f32; numel];
            if shape.len() == 1 {
                data.fill(1.0);
            } else {
                let std = if base == "wo" || base == "w_down" {
                    0.02 * scale_resid
                } else {
                    0.02
                };
                rng.fill_normal(&mut data, std);
            }
            tensors.push(data);
        }
        Params {
            cfg: cfg.clone(),
            tensors,
        }
    }

    /// Zero-filled (optimizer moment init).
    pub fn zeros_like(cfg: &ModelCfg) -> Params {
        Params {
            cfg: cfg.clone(),
            tensors: cfg
                .param_shapes
                .iter()
                .map(|s| vec![0.0f32; s.iter().product()])
                .collect(),
        }
    }

    pub fn index(&self, name: &str) -> Option<usize> {
        self.cfg.param_index(name)
    }

    /// 2-D parameter as a Mat, `None` when `name` is not in the
    /// config — the checked lookup job pipelines use to fail with
    /// context instead of panicking. Still panics on 1-D entries:
    /// shape is a config contract, not caller input.
    pub fn try_mat(&self, name: &str) -> Option<Mat> {
        let i = self.index(name)?;
        let shape = &self.cfg.param_shapes[i];
        assert_eq!(shape.len(), 2, "param {name} is not 2-D");
        Some(Mat::from_vec(shape[0], shape[1], self.tensors[i].clone()))
    }

    /// 2-D parameter as a Mat (panics on unknown names and 1-D
    /// entries — the trusted-name convenience over [`Params::try_mat`]).
    pub fn mat(&self, name: &str) -> Mat {
        self.try_mat(name).unwrap_or_else(|| panic!("no param {name}"))
    }

    /// Replace a 2-D parameter (the compression swap).
    pub fn set_mat(&mut self, name: &str, m: &Mat) {
        let i = self.index(name).unwrap_or_else(|| panic!("no param {name}"));
        let shape = &self.cfg.param_shapes[i];
        assert_eq!(&[m.rows, m.cols][..], shape.as_slice(), "shape mismatch for {name}");
        self.tensors[i] = m.data.clone();
    }

    /// All tensors as literals in canonical order (artifact inputs).
    pub fn to_literals(&self) -> Vec<xla::Literal> {
        self.tensors
            .iter()
            .zip(self.cfg.param_shapes.iter())
            .map(|(t, s)| lit_f32(t, s))
            .collect()
    }

    /// Rebuild from artifact outputs (e.g. the updated params slice of
    /// a train_step result).
    pub fn from_literals(cfg: &ModelCfg, lits: &[xla::Literal]) -> Params {
        assert_eq!(lits.len(), cfg.param_names.len());
        Params {
            cfg: cfg.clone(),
            tensors: lits.iter().map(to_vec_f32).collect(),
        }
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut ck = Checkpoint::new();
        let tag = self.cfg.name.as_bytes().to_vec();
        ck.push(Entry {
            name: "__config".into(),
            dims: vec![tag.len()],
            data: TensorData::U8(tag),
        });
        for ((name, shape), data) in self
            .cfg
            .param_names
            .iter()
            .zip(self.cfg.param_shapes.iter())
            .zip(self.tensors.iter())
        {
            ck.push(Entry::f32(name, shape.clone(), data.clone()));
        }
        ck.save(path)
    }

    /// Load; the checkpoint's `__config` tag must match `cfg.name`.
    pub fn load(cfg: &ModelCfg, path: &Path) -> std::io::Result<Params> {
        let ck = Checkpoint::load(path)?;
        if let Some(tag) = ck.get("__config") {
            let name = String::from_utf8_lossy(tag.data.as_u8().unwrap_or(&[]));
            if name != cfg.name {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("checkpoint is for config '{name}', expected '{}'", cfg.name),
                ));
            }
        }
        let mut tensors = Vec::with_capacity(cfg.param_names.len());
        for (name, shape) in cfg.param_names.iter().zip(cfg.param_shapes.iter()) {
            let e = ck.get(name).ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("missing param {name}"),
                )
            })?;
            if &e.dims != shape {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("param {name}: shape {:?} vs {:?}", e.dims, shape),
                ));
            }
            tensors.push(e.data.as_f32().unwrap().to_vec());
        }
        Ok(Params {
            cfg: cfg.clone(),
            tensors,
        })
    }

    /// Dense bits of all *pruned* linears at width b (the Table-I CR
    /// denominator; embeddings/norms/head excluded, paper §III-A4).
    pub fn pruned_weight_bits(&self, b: u32) -> usize {
        self.cfg
            .pruned
            .iter()
            .map(|(_, (dout, din))| b as usize * dout * din)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            name: "tiny".into(),
            vocab: 32,
            dim: 8,
            n_layers: 1,
            n_heads: 2,
            ffn: 16,
            max_seq: 8,
            prompt_len: 4,
            param_names: vec![
                "tok_emb".into(),
                "l0.attn_norm".into(),
                "l0.wq".into(),
                "l0.wk".into(),
                "l0.wv".into(),
                "l0.wo".into(),
                "l0.mlp_norm".into(),
                "l0.w_gate".into(),
                "l0.w_up".into(),
                "l0.w_down".into(),
                "final_norm".into(),
                "lm_head".into(),
            ],
            param_shapes: vec![
                vec![32, 8],
                vec![8],
                vec![8, 8],
                vec![8, 8],
                vec![8, 8],
                vec![8, 8],
                vec![8],
                vec![16, 8],
                vec![16, 8],
                vec![8, 16],
                vec![8],
                vec![32, 8],
            ],
            pruned: vec![
                ("l0.wq".into(), (8, 8)),
                ("l0.wk".into(), (8, 8)),
                ("l0.wv".into(), (8, 8)),
                ("l0.wo".into(), (8, 8)),
                ("l0.w_gate".into(), (16, 8)),
                ("l0.w_up".into(), (16, 8)),
                ("l0.w_down".into(), (8, 16)),
            ],
            slab_param_names: vec![],
        }
    }

    #[test]
    fn init_statistics() {
        let cfg = tiny_cfg();
        let p = Params::init(&cfg, 1);
        // Norms at 1.
        let norm_idx = p.index("l0.attn_norm").unwrap();
        assert!(p.tensors[norm_idx].iter().all(|&x| x == 1.0));
        // Matrices near std 0.02.
        let wq = p.mat("l0.wq");
        assert!(wq.max_abs() < 0.2);
        assert!(wq.data.iter().any(|&x| x != 0.0));
        // Residual projections down-scaled.
        let wo = p.mat("l0.wo");
        let var = |m: &Mat| m.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / m.numel() as f64;
        // With 64 samples each this is noisy; just check ordering holds
        // for the deterministic seed.
        assert!(var(&wo) < var(&wq) * 1.5);
    }

    #[test]
    fn mat_set_roundtrip() {
        let cfg = tiny_cfg();
        let mut p = Params::init(&cfg, 2);
        let mut m = p.mat("l0.w_gate");
        m.map_inplace(|x| x * 2.0);
        p.set_mat("l0.w_gate", &m);
        assert_eq!(p.mat("l0.w_gate"), m);
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = tiny_cfg();
        let p = Params::init(&cfg, 3);
        let path = std::env::temp_dir().join("slab-tests/params.slabckpt");
        p.save(&path).unwrap();
        let q = Params::load(&cfg, &path).unwrap();
        assert_eq!(p.tensors, q.tensors);
    }

    #[test]
    fn load_rejects_wrong_config() {
        let cfg = tiny_cfg();
        let p = Params::init(&cfg, 4);
        let path = std::env::temp_dir().join("slab-tests/params2.slabckpt");
        p.save(&path).unwrap();
        let mut other = tiny_cfg();
        other.name = "other".into();
        assert!(Params::load(&other, &path).is_err());
    }

    #[test]
    fn pruned_bits_counts_only_linears() {
        let cfg = tiny_cfg();
        let p = Params::init(&cfg, 5);
        let bits = p.pruned_weight_bits(16);
        let expect = 16 * (4 * 64 + 2 * 128 + 128);
        assert_eq!(bits, expect);
    }
}
