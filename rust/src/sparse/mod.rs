//! Sparse-matrix substrate: CSR storage for `W_S` and semi-structured
//! N:M (2:4, 4:8) patterns with packed hardware-style storage.

pub mod csr;
pub mod semi;

pub use csr::Csr;
pub use semi::{NmPacked, NmPattern, PATTERN_2_4, PATTERN_4_8};
