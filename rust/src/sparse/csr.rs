//! Compressed Sparse Row storage for `W_S`.
//!
//! The sparse component of the SLaB decomposition is stored as CSR:
//! `row_ptr` (rows+1), `col_idx` (nnz), `vals` (nnz). This is the
//! deploy-time format — the compression pipeline emits dense masks,
//! packs them here, and the serving path multiplies out of CSR
//! directly (`spmv_t` / `spmm_bt`).

use crate::tensor::Mat;
use crate::util::pool::{chunk_ranges, ThreadPool};

/// Batch-block width for the cache-blocked kernels: the CSR metadata
/// (`col_idx` + `vals`) is streamed once per block of activation rows
/// instead of once per row, and `BB` activation rows (≤ a few KiB each
/// at testbed widths) stay L1-resident across the stream.
const BB: usize = 8;

#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr {
    /// Pack a dense matrix: every non-zero entry is kept.
    pub fn from_dense(m: &Mat) -> Csr {
        let mut row_ptr = Vec::with_capacity(m.rows + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0u32);
        for i in 0..m.rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(j as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Csr {
            rows: m.rows,
            cols: m.cols,
            row_ptr,
            col_idx,
            vals,
        }
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            for k in s..e {
                m.set(i, self.col_idx[k] as usize, self.vals[k]);
            }
        }
        m
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Storage density: nnz / numel.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Bytes to store this matrix (vals f32 + idx u32 + row_ptr u32);
    /// used by the compression-ratio accounting and benchmarks.
    pub fn nbytes(&self) -> usize {
        self.vals.len() * 4 + self.col_idx.len() * 4 + self.row_ptr.len() * 4
    }

    /// y = W·x where W is this CSR matrix, x dense: the decode-path
    /// primitive (`W_S · activation`).
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        for i in 0..self.rows {
            y[i] = self.row_dot(i, x);
        }
        y
    }

    /// One sparse row · dense vector, accumulated in ascending-k
    /// (scalar reference) order — the exact-kernel building block
    /// shared by [`spmv`](Csr::spmv), [`spmm_bt`](Csr::spmm_bt), and
    /// the fused decode epilogue
    /// ([`SlabLayer::forward_decode`](crate::slab::SlabLayer::forward_decode)),
    /// which is what keeps all three bit-identical to each other.
    #[inline]
    pub fn row_dot(&self, i: usize, x: &[f32]) -> f32 {
        let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
        let mut acc = 0.0f32;
        for k in s..e {
            acc += self.vals[k] * x[self.col_idx[k] as usize];
        }
        acc
    }

    /// Fast-path [`row_dot`](Csr::row_dot): the nnz stream is unrolled
    /// 4-wide into independent accumulator chains so the gathers and
    /// FP adds overlap instead of serializing on one add-latency
    /// chain. `col_idx`/`vals` reads inside the unrolled body are
    /// unchecked (provably in-bounds — see SAFETY), the `x` gather
    /// stays bounds-checked so a hand-built CSR with out-of-range
    /// indices panics rather than reading out of bounds.
    ///
    /// **Tolerance-gated** (DESIGN.md §7): the 4-chain unroll
    /// reassociates the sum — never compare with `==`; the error bound
    /// is asserted in this module's property tests.
    pub fn row_dot_fast(&self, i: usize, x: &[f32]) -> f32 {
        assert_eq!(x.len(), self.cols);
        let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
        let idx = &self.col_idx[s..e];
        let vals = &self.vals[s..e];
        let mut acc = [0.0f32; 4];
        let chunks = idx.len() / 4;
        for c in 0..chunks {
            let k = c * 4;
            for t in 0..4 {
                // SAFETY: k + t < chunks*4 <= idx.len() == vals.len()
                // (both are the same s..e subslice).
                let j = unsafe { *idx.get_unchecked(k + t) } as usize;
                let v = unsafe { *vals.get_unchecked(k + t) };
                acc[t] += v * x[j];
            }
        }
        for k in chunks * 4..idx.len() {
            acc[0] += vals[k] * x[idx[k] as usize];
        }
        (acc[0] + acc[1]) + (acc[2] + acc[3])
    }

    /// Fast-path [`spmv`](Csr::spmv) built on
    /// [`row_dot_fast`](Csr::row_dot_fast). Tolerance-gated.
    pub fn spmv_fast(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        for i in 0..self.rows {
            y[i] = self.row_dot_fast(i, x);
        }
        y
    }

    /// Fast-path `spmm_bt`: the unrolled sparse dot per output
    /// element, weight rows chunked across `pool` when given.
    /// Tolerance-gated like every `*_fast` kernel (the chunking itself
    /// is deterministic — the unroll is what reassociates).
    pub fn spmm_bt_fast(&self, x: &Mat, pool: Option<&ThreadPool>) -> Mat {
        assert_eq!(x.cols, self.cols, "spmm_bt_fast: x cols {} vs W cols {}", x.cols, self.cols);
        let mut y = Mat::zeros(x.rows, self.rows);
        match pool {
            Some(p) if p.size() > 1 && self.rows >= 2 => {
                let ranges = chunk_ranges(self.rows, p.size());
                let mut strips: Vec<Vec<f32>> = ranges
                    .iter()
                    .map(|&(r0, r1)| vec![0.0f32; x.rows * (r1 - r0)])
                    .collect();
                let jobs: Vec<_> = strips
                    .iter_mut()
                    .zip(ranges.iter().copied())
                    .map(|(strip, (r0, r1))| move || self.spmm_rows_fast(x, r0, r1, strip))
                    .collect();
                p.scoped(jobs);
                for (strip, &(r0, r1)) in strips.iter().zip(ranges.iter()) {
                    let w = r1 - r0;
                    for b in 0..x.rows {
                        y.row_mut(b)[r0..r1].copy_from_slice(&strip[b * w..(b + 1) * w]);
                    }
                }
            }
            _ => self.spmm_rows_fast(x, 0, self.rows, &mut y.data),
        }
        y
    }

    /// Fast unrolled kernel over weight rows `[r0, r1)`; `out` is a
    /// strip in `[b][i - r0]` layout like
    /// [`spmm_rows_blocked`](Csr::spmm_rows_blocked).
    fn spmm_rows_fast(&self, x: &Mat, r0: usize, r1: usize, out: &mut [f32]) {
        let w = r1 - r0;
        debug_assert_eq!(out.len(), x.rows * w);
        for b in 0..x.rows {
            let xb = x.row(b);
            for i in r0..r1 {
                out[b * w + (i - r0)] = self.row_dot_fast(i, xb);
            }
        }
    }

    /// Y = X·Wᵀ for activations X (B, Din) against this (Dout, Din)
    /// matrix — the layout every linear layer uses. Row-parallel over
    /// the batch; each output element is one sparse dot product.
    pub fn spmm_bt(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.cols, "spmm_bt: x cols {} vs W cols {}", x.cols, self.cols);
        let mut y = Mat::zeros(x.rows, self.rows);
        for b in 0..x.rows {
            let xrow = x.row(b);
            let yrow = y.row_mut(b);
            for i in 0..self.rows {
                yrow[i] = self.row_dot(i, xrow);
            }
        }
        y
    }

    /// Cache-blocked `spmm_bt`: identical math to [`spmm_bt`]
    /// (bit-identical output — each `y[b][i]` accumulates the same
    /// products in the same order), but the sparse row's metadata is
    /// read once per [`BB`]-row batch block. Single-threaded; the
    /// serving path composes it with [`spmm_bt_par`].
    ///
    /// [`spmm_bt`]: Csr::spmm_bt
    /// [`spmm_bt_par`]: Csr::spmm_bt_par
    pub fn spmm_bt_blocked(&self, x: &Mat) -> Mat {
        let mut y = Mat::zeros(x.rows, self.rows);
        self.spmm_bt_blocked_into(x, &mut y);
        y
    }

    /// [`spmm_bt_blocked`](Csr::spmm_bt_blocked) writing into a
    /// caller-owned output (overwritten entirely) — the allocation-free
    /// form for per-tick serving loops. `y` must be `(x.rows, self.rows)`.
    pub fn spmm_bt_blocked_into(&self, x: &Mat, y: &mut Mat) {
        assert_eq!(x.cols, self.cols, "spmm_bt_blocked: x cols {} vs W cols {}", x.cols, self.cols);
        assert_eq!((y.rows, y.cols), (x.rows, self.rows), "spmm_bt_into: bad output shape");
        // Full-range strip layout coincides with y's row-major layout.
        self.spmm_rows_blocked(x, 0, self.rows, &mut y.data);
    }

    /// [`ThreadPool`]-parallel `spmm_bt`: weight rows are chunked
    /// across the pool (so a batch of 1 still parallelizes over
    /// `Dout`), each chunk runs the cache-blocked kernel into a
    /// private strip, and strips are scattered into `y` afterwards.
    /// Output is bit-identical to the scalar [`spmm_bt`](Csr::spmm_bt).
    pub fn spmm_bt_par(&self, x: &Mat, pool: &ThreadPool) -> Mat {
        let mut y = Mat::zeros(x.rows, self.rows);
        self.spmm_bt_par_into(x, pool, &mut y);
        y
    }

    /// [`spmm_bt_par`](Csr::spmm_bt_par) into a caller-owned output
    /// (overwritten entirely).
    pub fn spmm_bt_par_into(&self, x: &Mat, pool: &ThreadPool, y: &mut Mat) {
        assert_eq!(x.cols, self.cols, "spmm_bt_par: x cols {} vs W cols {}", x.cols, self.cols);
        assert_eq!((y.rows, y.cols), (x.rows, self.rows), "spmm_bt_into: bad output shape");
        if pool.size() <= 1 || self.rows < 2 {
            self.spmm_rows_blocked(x, 0, self.rows, &mut y.data);
            return;
        }
        let ranges = chunk_ranges(self.rows, pool.size());
        let mut strips: Vec<Vec<f32>> = ranges
            .iter()
            .map(|&(r0, r1)| vec![0.0f32; x.rows * (r1 - r0)])
            .collect();
        let jobs: Vec<_> = strips
            .iter_mut()
            .zip(ranges.iter().copied())
            .map(|(strip, (r0, r1))| move || self.spmm_rows_blocked(x, r0, r1, strip))
            .collect();
        pool.scoped(jobs);
        for (strip, &(r0, r1)) in strips.iter().zip(ranges.iter()) {
            let w = r1 - r0;
            for b in 0..x.rows {
                y.row_mut(b)[r0..r1].copy_from_slice(&strip[b * w..(b + 1) * w]);
            }
        }
    }

    /// Blocked kernel over weight rows `[r0, r1)`; `out` is a strip in
    /// `[b][i - r0]` layout (length `x.rows * (r1 - r0)`).
    fn spmm_rows_blocked(&self, x: &Mat, r0: usize, r1: usize, out: &mut [f32]) {
        let w = r1 - r0;
        debug_assert_eq!(out.len(), x.rows * w);
        for b0 in (0..x.rows).step_by(BB) {
            let bw = (x.rows - b0).min(BB);
            for i in r0..r1 {
                let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
                let mut acc = [0.0f32; BB];
                for k in s..e {
                    let j = self.col_idx[k] as usize;
                    let v = self.vals[k];
                    for bi in 0..bw {
                        acc[bi] += v * x.data[(b0 + bi) * x.cols + j];
                    }
                }
                for bi in 0..bw {
                    out[(b0 + bi) * w + (i - r0)] = acc[bi];
                }
            }
        }
    }

    /// Structural validation (sorted unique col indices per row,
    /// monotone row_ptr, bounds). Used by property tests and after
    /// deserialization.
    pub fn validate(&self) -> Result<(), String> {
        if self.row_ptr.len() != self.rows + 1 {
            return Err("row_ptr length".into());
        }
        if self.row_ptr[0] != 0 || *self.row_ptr.last().unwrap() as usize != self.vals.len() {
            return Err("row_ptr endpoints".into());
        }
        if self.col_idx.len() != self.vals.len() {
            return Err("idx/val length mismatch".into());
        }
        for i in 0..self.rows {
            let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            if s > e {
                return Err(format!("row_ptr not monotone at {i}"));
            }
            for k in s..e {
                if self.col_idx[k] as usize >= self.cols {
                    return Err(format!("col index OOB at row {i}"));
                }
                if k > s && self.col_idx[k] <= self.col_idx[k - 1] {
                    return Err(format!("col indices not strictly sorted in row {i}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{matmul_bt, matvec};
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    fn sparse_random(rows: usize, cols: usize, density: f64, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(rows, cols, |_, _| {
            if rng.bernoulli(density) {
                rng.normal_f32(0.0, 1.0)
            } else {
                0.0
            }
        })
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(40);
        let m = sparse_random(17, 23, 0.3, &mut rng);
        let csr = Csr::from_dense(&m);
        csr.validate().unwrap();
        assert_eq!(csr.to_dense(), m);
        assert_eq!(csr.nnz(), m.count_nonzero());
    }

    #[test]
    fn spmv_matches_dense() {
        let mut rng = Pcg64::seed_from_u64(41);
        let m = sparse_random(12, 9, 0.4, &mut rng);
        let csr = Csr::from_dense(&m);
        let x: Vec<f32> = (0..9).map(|i| (i as f32).sin()).collect();
        let y1 = csr.spmv(&x);
        let y2 = matvec(&m, &x);
        for i in 0..12 {
            assert!((y1[i] - y2[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn spmm_bt_matches_dense() {
        let mut rng = Pcg64::seed_from_u64(42);
        let w = sparse_random(10, 16, 0.25, &mut rng);
        let x = Mat::randn(5, 16, 1.0, &mut rng);
        let yd = matmul_bt(&x, &w);
        let ys = Csr::from_dense(&w).spmm_bt(&x);
        assert!(ys.allclose(&yd, 1e-5, 1e-5));
    }

    #[test]
    fn empty_and_full_extremes() {
        let z = Mat::zeros(4, 4);
        let csr = Csr::from_dense(&z);
        assert_eq!(csr.nnz(), 0);
        csr.validate().unwrap();
        let f = Mat::filled(4, 4, 2.0);
        let csr = Csr::from_dense(&f);
        assert_eq!(csr.nnz(), 16);
        assert_eq!(csr.to_dense(), f);
    }

    #[test]
    #[cfg_attr(miri, ignore = "randomized bulk roundtrips are slow under miri")]
    fn prop_roundtrip_random_matrices() {
        prop::check(
            "csr-roundtrip",
            50,
            |rng| {
                let (r, c) = prop::gens::dims(rng, 1, 24);
                let m = sparse_random(r, c, 0.3, rng);
                m.data.clone().into_iter().collect::<Vec<f32>>()
            },
            |_| Ok(()),
        );
        // The real property: parametrized over shapes directly.
        let mut rng = Pcg64::seed_from_u64(43);
        for _ in 0..50 {
            let r = 1 + rng.below_usize(24);
            let c = 1 + rng.below_usize(24);
            let m = sparse_random(r, c, 0.3, &mut rng);
            let csr = Csr::from_dense(&m);
            csr.validate().unwrap();
            assert_eq!(csr.to_dense(), m);
        }
    }

    #[test]
    fn nbytes_accounting() {
        let mut rng = Pcg64::seed_from_u64(44);
        let m = sparse_random(8, 8, 0.5, &mut rng);
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.nbytes(), csr.nnz() * 8 + (8 + 1) * 4);
    }

    #[test]
    fn blocked_is_bit_identical_to_scalar() {
        // Same products in the same order — not merely allclose.
        let mut rng = Pcg64::seed_from_u64(45);
        for (rows, cols, batch) in [(33, 17, 1), (64, 64, 9), (7, 130, 4), (1, 5, 11)] {
            let w = sparse_random(rows, cols, 0.3, &mut rng);
            let x = Mat::randn(batch, cols, 1.0, &mut rng);
            let csr = Csr::from_dense(&w);
            assert_eq!(csr.spmm_bt_blocked(&x), csr.spmm_bt(&x), "{rows}x{cols} b{batch}");
        }
    }

    /// Reassociation tolerance: c·n·ε·Σ|terms| (see `binary::tests`;
    /// the same bound form is the §7 fast-kernel contract).
    fn reassoc_tol(n: usize, mag: f64) -> f32 {
        (4.0 * n.max(1) as f64 * f32::EPSILON as f64 * mag) as f32 + 1e-6
    }

    #[test]
    fn fast_unrolled_kernel_boundary_rows() {
        // Deterministic, pool-free, and small: the miri/ASan CI job's
        // coverage of the `unsafe` idx/val reads — empty rows, a fully
        // dense row, and tail lengths 1..3 off the 4-wide unroll.
        let mut w = Mat::zeros(6, 11);
        for j in 0..11 {
            w.set(1, j, 0.5 - j as f32 * 0.1); // dense row
        }
        w.set(2, 3, 2.0); // nnz = 1
        w.set(3, 0, -1.0);
        w.set(3, 7, 0.25);
        w.set(3, 10, 4.0); // nnz = 3
        for j in [1, 2, 5, 6, 8] {
            w.set(4, j, j as f32); // nnz = 5 (one full chunk + 1)
        }
        // rows 0 and 5 stay empty
        let csr = Csr::from_dense(&w);
        csr.validate().unwrap();
        let x: Vec<f32> = (0..11).map(|j| (j as f32 * 0.7).cos()).collect();
        let exact = csr.spmv(&x);
        let fast = csr.spmv_fast(&x);
        for i in 0..6 {
            let (s, e) = (csr.row_ptr[i] as usize, csr.row_ptr[i + 1] as usize);
            let mag: f64 = (s..e)
                .map(|k| (csr.vals[k] * x[csr.col_idx[k] as usize]).abs() as f64)
                .sum();
            let tol = reassoc_tol(e - s, mag);
            assert!(
                (fast[i] - exact[i]).abs() <= tol,
                "row {i}: fast {} vs exact {} (tol {tol})",
                fast[i],
                exact[i]
            );
        }
        assert_eq!(fast[0], 0.0);
        assert_eq!(fast[5], 0.0);
    }

    #[test]
    #[cfg_attr(miri, ignore = "randomized shapes + pool fan-out are too slow under miri")]
    fn prop_fast_matches_exact_within_tolerance() {
        // Adversarial shapes for the tolerance-gated path: empty rows
        // (low density), dense rows (high density), batch 1 and >1,
        // serial and pooled — with the §7 error bound asserted, so a
        // fast kernel that drops or duplicates a term fails here while
        // pure reassociation passes with wide margin.
        let pool4 = crate::util::pool::ThreadPool::new(4);
        crate::util::prop::check(
            "csr-fast-vs-exact",
            25,
            |rng| (1 + rng.below_usize(60), 1 + rng.below_usize(60)),
            |&(rows, cols)| {
                let mut rng = Pcg64::seed_from_u64((rows * 173 + cols) as u64);
                // Alternate near-empty and near-dense rows so both the
                // unroll tail and the full chunks are exercised.
                let density = if (rows + cols) % 2 == 0 { 0.08 } else { 0.9 };
                let w = sparse_random(rows, cols, density, &mut rng);
                let csr = Csr::from_dense(&w);
                for batch in [1usize, 5] {
                    let x = Mat::randn(batch, cols, 1.0, &mut rng);
                    let y_ref = csr.spmm_bt(&x);
                    for y_fast in [csr.spmm_bt_fast(&x, None), csr.spmm_bt_fast(&x, Some(&pool4))]
                    {
                        for b in 0..batch {
                            for i in 0..rows {
                                let (s, e) =
                                    (csr.row_ptr[i] as usize, csr.row_ptr[i + 1] as usize);
                                let mag: f64 = (s..e)
                                    .map(|k| {
                                        (csr.vals[k] * x.row(b)[csr.col_idx[k] as usize]).abs()
                                            as f64
                                    })
                                    .sum();
                                let tol = reassoc_tol(e - s, mag);
                                let (f, ex) = (y_fast.row(b)[i], y_ref.row(b)[i]);
                                if (f - ex).abs() > tol {
                                    return Err(format!(
                                        "{rows}x{cols} d={density} batch {batch} b={b} i={i}: \
                                         fast {f} vs exact {ex} exceeds tol {tol}"
                                    ));
                                }
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    #[cfg_attr(miri, ignore = "pool fan-out + randomized shapes are too slow under miri")]
    fn prop_parallel_matches_scalar_adversarial_shapes() {
        // Pool of 1 vs N, batch of 1, rows with no nonzeros, shapes
        // around the cache-block boundary.
        let pool1 = crate::util::pool::ThreadPool::new(1);
        let pool4 = crate::util::pool::ThreadPool::new(4);
        crate::util::prop::check(
            "csr-par-vs-scalar",
            30,
            |rng| {
                (
                    1 + rng.below_usize(70), // rows
                    1 + rng.below_usize(70), // cols
                )
            },
            |&(rows, cols)| {
                let mut rng = Pcg64::seed_from_u64((rows * 131 + cols) as u64);
                // Low density so some rows are entirely empty.
                let w = sparse_random(rows, cols, 0.08, &mut rng);
                let csr = Csr::from_dense(&w);
                for batch in [1usize, 3, 8, 13] {
                    let x = Mat::randn(batch, cols, 1.0, &mut rng);
                    let y_ref = csr.spmm_bt(&x);
                    for pool in [&pool1, &pool4] {
                        let y = csr.spmm_bt_par(&x, pool);
                        if y != y_ref {
                            return Err(format!(
                                "{rows}x{cols} batch {batch} pool {}",
                                pool.size()
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
