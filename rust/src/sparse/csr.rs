//! Compressed Sparse Row storage for `W_S`.
//!
//! The sparse component of the SLaB decomposition is stored as CSR:
//! `row_ptr` (rows+1), `col_idx` (nnz), `vals` (nnz). This is the
//! deploy-time format — the compression pipeline emits dense masks,
//! packs them here, and the serving path multiplies out of CSR
//! directly (`spmv_t` / `spmm_bt`).

use crate::tensor::Mat;
use crate::util::pool::{chunk_ranges, ThreadPool};

/// Batch-block width for the cache-blocked kernels: the CSR metadata
/// (`col_idx` + `vals`) is streamed once per block of activation rows
/// instead of once per row, and `BB` activation rows (≤ a few KiB each
/// at testbed widths) stay L1-resident across the stream.
const BB: usize = 8;

#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr {
    /// Pack a dense matrix: every non-zero entry is kept.
    pub fn from_dense(m: &Mat) -> Csr {
        let mut row_ptr = Vec::with_capacity(m.rows + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0u32);
        for i in 0..m.rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(j as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Csr {
            rows: m.rows,
            cols: m.cols,
            row_ptr,
            col_idx,
            vals,
        }
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            for k in s..e {
                m.set(i, self.col_idx[k] as usize, self.vals[k]);
            }
        }
        m
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Storage density: nnz / numel.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Bytes to store this matrix (vals f32 + idx u32 + row_ptr u32);
    /// used by the compression-ratio accounting and benchmarks.
    pub fn nbytes(&self) -> usize {
        self.vals.len() * 4 + self.col_idx.len() * 4 + self.row_ptr.len() * 4
    }

    /// y = W·x where W is this CSR matrix, x dense: the decode-path
    /// primitive (`W_S · activation`).
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        for i in 0..self.rows {
            let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            let mut acc = 0.0f32;
            for k in s..e {
                acc += self.vals[k] * x[self.col_idx[k] as usize];
            }
            y[i] = acc;
        }
        y
    }

    /// Y = X·Wᵀ for activations X (B, Din) against this (Dout, Din)
    /// matrix — the layout every linear layer uses. Row-parallel over
    /// the batch; each output element is one sparse dot product.
    pub fn spmm_bt(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.cols, "spmm_bt: x cols {} vs W cols {}", x.cols, self.cols);
        let mut y = Mat::zeros(x.rows, self.rows);
        for b in 0..x.rows {
            let xrow = x.row(b);
            let yrow = y.row_mut(b);
            for i in 0..self.rows {
                let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
                let mut acc = 0.0f32;
                for k in s..e {
                    acc += self.vals[k] * xrow[self.col_idx[k] as usize];
                }
                yrow[i] = acc;
            }
        }
        y
    }

    /// Cache-blocked `spmm_bt`: identical math to [`spmm_bt`]
    /// (bit-identical output — each `y[b][i]` accumulates the same
    /// products in the same order), but the sparse row's metadata is
    /// read once per [`BB`]-row batch block. Single-threaded; the
    /// serving path composes it with [`spmm_bt_par`].
    ///
    /// [`spmm_bt`]: Csr::spmm_bt
    /// [`spmm_bt_par`]: Csr::spmm_bt_par
    pub fn spmm_bt_blocked(&self, x: &Mat) -> Mat {
        let mut y = Mat::zeros(x.rows, self.rows);
        self.spmm_bt_blocked_into(x, &mut y);
        y
    }

    /// [`spmm_bt_blocked`](Csr::spmm_bt_blocked) writing into a
    /// caller-owned output (overwritten entirely) — the allocation-free
    /// form for per-tick serving loops. `y` must be `(x.rows, self.rows)`.
    pub fn spmm_bt_blocked_into(&self, x: &Mat, y: &mut Mat) {
        assert_eq!(x.cols, self.cols, "spmm_bt_blocked: x cols {} vs W cols {}", x.cols, self.cols);
        assert_eq!((y.rows, y.cols), (x.rows, self.rows), "spmm_bt_into: bad output shape");
        // Full-range strip layout coincides with y's row-major layout.
        self.spmm_rows_blocked(x, 0, self.rows, &mut y.data);
    }

    /// [`ThreadPool`]-parallel `spmm_bt`: weight rows are chunked
    /// across the pool (so a batch of 1 still parallelizes over
    /// `Dout`), each chunk runs the cache-blocked kernel into a
    /// private strip, and strips are scattered into `y` afterwards.
    /// Output is bit-identical to the scalar [`spmm_bt`](Csr::spmm_bt).
    pub fn spmm_bt_par(&self, x: &Mat, pool: &ThreadPool) -> Mat {
        let mut y = Mat::zeros(x.rows, self.rows);
        self.spmm_bt_par_into(x, pool, &mut y);
        y
    }

    /// [`spmm_bt_par`](Csr::spmm_bt_par) into a caller-owned output
    /// (overwritten entirely).
    pub fn spmm_bt_par_into(&self, x: &Mat, pool: &ThreadPool, y: &mut Mat) {
        assert_eq!(x.cols, self.cols, "spmm_bt_par: x cols {} vs W cols {}", x.cols, self.cols);
        assert_eq!((y.rows, y.cols), (x.rows, self.rows), "spmm_bt_into: bad output shape");
        if pool.size() <= 1 || self.rows < 2 {
            self.spmm_rows_blocked(x, 0, self.rows, &mut y.data);
            return;
        }
        let ranges = chunk_ranges(self.rows, pool.size());
        let mut strips: Vec<Vec<f32>> = ranges
            .iter()
            .map(|&(r0, r1)| vec![0.0f32; x.rows * (r1 - r0)])
            .collect();
        let jobs: Vec<_> = strips
            .iter_mut()
            .zip(ranges.iter().copied())
            .map(|(strip, (r0, r1))| move || self.spmm_rows_blocked(x, r0, r1, strip))
            .collect();
        pool.scoped(jobs);
        for (strip, &(r0, r1)) in strips.iter().zip(ranges.iter()) {
            let w = r1 - r0;
            for b in 0..x.rows {
                y.row_mut(b)[r0..r1].copy_from_slice(&strip[b * w..(b + 1) * w]);
            }
        }
    }

    /// Blocked kernel over weight rows `[r0, r1)`; `out` is a strip in
    /// `[b][i - r0]` layout (length `x.rows * (r1 - r0)`).
    fn spmm_rows_blocked(&self, x: &Mat, r0: usize, r1: usize, out: &mut [f32]) {
        let w = r1 - r0;
        debug_assert_eq!(out.len(), x.rows * w);
        for b0 in (0..x.rows).step_by(BB) {
            let bw = (x.rows - b0).min(BB);
            for i in r0..r1 {
                let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
                let mut acc = [0.0f32; BB];
                for k in s..e {
                    let j = self.col_idx[k] as usize;
                    let v = self.vals[k];
                    for bi in 0..bw {
                        acc[bi] += v * x.data[(b0 + bi) * x.cols + j];
                    }
                }
                for bi in 0..bw {
                    out[(b0 + bi) * w + (i - r0)] = acc[bi];
                }
            }
        }
    }

    /// Structural validation (sorted unique col indices per row,
    /// monotone row_ptr, bounds). Used by property tests and after
    /// deserialization.
    pub fn validate(&self) -> Result<(), String> {
        if self.row_ptr.len() != self.rows + 1 {
            return Err("row_ptr length".into());
        }
        if self.row_ptr[0] != 0 || *self.row_ptr.last().unwrap() as usize != self.vals.len() {
            return Err("row_ptr endpoints".into());
        }
        if self.col_idx.len() != self.vals.len() {
            return Err("idx/val length mismatch".into());
        }
        for i in 0..self.rows {
            let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            if s > e {
                return Err(format!("row_ptr not monotone at {i}"));
            }
            for k in s..e {
                if self.col_idx[k] as usize >= self.cols {
                    return Err(format!("col index OOB at row {i}"));
                }
                if k > s && self.col_idx[k] <= self.col_idx[k - 1] {
                    return Err(format!("col indices not strictly sorted in row {i}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{matmul_bt, matvec};
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    fn sparse_random(rows: usize, cols: usize, density: f64, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(rows, cols, |_, _| {
            if rng.bernoulli(density) {
                rng.normal_f32(0.0, 1.0)
            } else {
                0.0
            }
        })
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(40);
        let m = sparse_random(17, 23, 0.3, &mut rng);
        let csr = Csr::from_dense(&m);
        csr.validate().unwrap();
        assert_eq!(csr.to_dense(), m);
        assert_eq!(csr.nnz(), m.count_nonzero());
    }

    #[test]
    fn spmv_matches_dense() {
        let mut rng = Pcg64::seed_from_u64(41);
        let m = sparse_random(12, 9, 0.4, &mut rng);
        let csr = Csr::from_dense(&m);
        let x: Vec<f32> = (0..9).map(|i| (i as f32).sin()).collect();
        let y1 = csr.spmv(&x);
        let y2 = matvec(&m, &x);
        for i in 0..12 {
            assert!((y1[i] - y2[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn spmm_bt_matches_dense() {
        let mut rng = Pcg64::seed_from_u64(42);
        let w = sparse_random(10, 16, 0.25, &mut rng);
        let x = Mat::randn(5, 16, 1.0, &mut rng);
        let yd = matmul_bt(&x, &w);
        let ys = Csr::from_dense(&w).spmm_bt(&x);
        assert!(ys.allclose(&yd, 1e-5, 1e-5));
    }

    #[test]
    fn empty_and_full_extremes() {
        let z = Mat::zeros(4, 4);
        let csr = Csr::from_dense(&z);
        assert_eq!(csr.nnz(), 0);
        csr.validate().unwrap();
        let f = Mat::filled(4, 4, 2.0);
        let csr = Csr::from_dense(&f);
        assert_eq!(csr.nnz(), 16);
        assert_eq!(csr.to_dense(), f);
    }

    #[test]
    fn prop_roundtrip_random_matrices() {
        prop::check(
            "csr-roundtrip",
            50,
            |rng| {
                let (r, c) = prop::gens::dims(rng, 1, 24);
                let m = sparse_random(r, c, 0.3, rng);
                m.data.clone().into_iter().collect::<Vec<f32>>()
            },
            |_| Ok(()),
        );
        // The real property: parametrized over shapes directly.
        let mut rng = Pcg64::seed_from_u64(43);
        for _ in 0..50 {
            let r = 1 + rng.below_usize(24);
            let c = 1 + rng.below_usize(24);
            let m = sparse_random(r, c, 0.3, &mut rng);
            let csr = Csr::from_dense(&m);
            csr.validate().unwrap();
            assert_eq!(csr.to_dense(), m);
        }
    }

    #[test]
    fn nbytes_accounting() {
        let mut rng = Pcg64::seed_from_u64(44);
        let m = sparse_random(8, 8, 0.5, &mut rng);
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.nbytes(), csr.nnz() * 8 + (8 + 1) * 4);
    }

    #[test]
    fn blocked_is_bit_identical_to_scalar() {
        // Same products in the same order — not merely allclose.
        let mut rng = Pcg64::seed_from_u64(45);
        for (rows, cols, batch) in [(33, 17, 1), (64, 64, 9), (7, 130, 4), (1, 5, 11)] {
            let w = sparse_random(rows, cols, 0.3, &mut rng);
            let x = Mat::randn(batch, cols, 1.0, &mut rng);
            let csr = Csr::from_dense(&w);
            assert_eq!(csr.spmm_bt_blocked(&x), csr.spmm_bt(&x), "{rows}x{cols} b{batch}");
        }
    }

    #[test]
    fn prop_parallel_matches_scalar_adversarial_shapes() {
        // Pool of 1 vs N, batch of 1, rows with no nonzeros, shapes
        // around the cache-block boundary.
        let pool1 = crate::util::pool::ThreadPool::new(1);
        let pool4 = crate::util::pool::ThreadPool::new(4);
        crate::util::prop::check(
            "csr-par-vs-scalar",
            30,
            |rng| {
                (
                    1 + rng.below_usize(70), // rows
                    1 + rng.below_usize(70), // cols
                )
            },
            |&(rows, cols)| {
                let mut rng = Pcg64::seed_from_u64((rows * 131 + cols) as u64);
                // Low density so some rows are entirely empty.
                let w = sparse_random(rows, cols, 0.08, &mut rng);
                let csr = Csr::from_dense(&w);
                for batch in [1usize, 3, 8, 13] {
                    let x = Mat::randn(batch, cols, 1.0, &mut rng);
                    let y_ref = csr.spmm_bt(&x);
                    for pool in [&pool1, &pool4] {
                        let y = csr.spmm_bt_par(&x, pool);
                        if y != y_ref {
                            return Err(format!(
                                "{rows}x{cols} batch {batch} pool {}",
                                pool.size()
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
