//! Semi-structured N:M sparsity (2:4, 4:8) — Mishra et al. 2021.
//!
//! An N:M pattern keeps at most N non-zeros in every aligned group of
//! M consecutive elements along the input dimension. The paper's §II-B2
//! applies N:M *first*, then group-wise pruning on top to reach the
//! target sparsity. This module provides mask construction from a
//! score matrix, validation, and the packed "every group carries
//! exactly N slots" storage that real N:M hardware uses.

use crate::tensor::Mat;

/// An N:M sparsity pattern along rows (the Din axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NmPattern {
    pub n: usize,
    pub m: usize,
}

pub const PATTERN_2_4: NmPattern = NmPattern { n: 2, m: 4 };
pub const PATTERN_4_8: NmPattern = NmPattern { n: 4, m: 8 };

impl NmPattern {
    pub fn name(&self) -> String {
        format!("{}:{}", self.n, self.m)
    }

    /// Max fraction of non-zeros the pattern allows.
    pub fn density(&self) -> f64 {
        self.n as f64 / self.m as f64
    }

    /// Build the keep-mask that maximizes total score per group:
    /// within every aligned window of `m` columns of each row, keep
    /// the `n` highest-scoring elements. Trailing ragged groups (cols
    /// not divisible by m) keep ⌈n·len/m⌉ elements.
    pub fn mask_from_scores(&self, scores: &Mat) -> Mat {
        let mut mask = Mat::zeros(scores.rows, scores.cols);
        let mut idx: Vec<usize> = Vec::with_capacity(self.m);
        for i in 0..scores.rows {
            let row = scores.row(i);
            let mut j = 0;
            while j < scores.cols {
                let end = (j + self.m).min(scores.cols);
                let len = end - j;
                let keep = if len == self.m {
                    self.n
                } else {
                    (self.n * len).div_ceil(self.m)
                };
                idx.clear();
                idx.extend(j..end);
                idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
                for &k in idx.iter().take(keep) {
                    mask.set(i, k, 1.0);
                }
                j = end;
            }
        }
        mask
    }

    /// Check a dense matrix obeys the pattern (each aligned group of m
    /// has ≤ n non-zeros).
    pub fn validate(&self, m: &Mat) -> Result<(), String> {
        for i in 0..m.rows {
            let row = m.row(i);
            let mut j = 0;
            while j < m.cols {
                let end = (j + self.m).min(m.cols);
                let nnz = row[j..end].iter().filter(|&&v| v != 0.0).count();
                let cap = if end - j == self.m {
                    self.n
                } else {
                    (self.n * (end - j)).div_ceil(self.m)
                };
                if nnz > cap {
                    return Err(format!(
                        "row {i} group at col {j}: {nnz} nnz > {cap} allowed ({})",
                        self.name()
                    ));
                }
                j = end;
            }
        }
        Ok(())
    }
}

/// Packed N:M storage: for every aligned group, exactly `n` value
/// slots + `n` intra-group indices (u8). Mirrors the metadata layout
/// of sparse tensor cores; used for size accounting and the packed
/// matmul in benches.
#[derive(Debug, Clone, PartialEq)]
pub struct NmPacked {
    pub pattern: NmPattern,
    pub rows: usize,
    pub cols: usize,
    /// (rows × groups_per_row × n) values; zero-padded when a group has
    /// fewer than n non-zeros.
    pub vals: Vec<f32>,
    /// Matching intra-group column offsets (0..m).
    pub offs: Vec<u8>,
}

impl NmPacked {
    /// Pack a dense matrix that already satisfies the pattern.
    pub fn pack(pattern: NmPattern, m: &Mat) -> Result<NmPacked, String> {
        pattern.validate(m)?;
        if m.cols % pattern.m != 0 {
            return Err(format!(
                "cols {} not divisible by m={} — pad before packing",
                m.cols, pattern.m
            ));
        }
        let groups = m.cols / pattern.m;
        let mut vals = Vec::with_capacity(m.rows * groups * pattern.n);
        let mut offs = Vec::with_capacity(vals.capacity());
        for i in 0..m.rows {
            let row = m.row(i);
            for g in 0..groups {
                let base = g * pattern.m;
                let mut filled = 0;
                for o in 0..pattern.m {
                    let v = row[base + o];
                    if v != 0.0 {
                        vals.push(v);
                        offs.push(o as u8);
                        filled += 1;
                    }
                }
                while filled < pattern.n {
                    vals.push(0.0);
                    offs.push(0);
                    filled += 1;
                }
            }
        }
        Ok(NmPacked {
            pattern,
            rows: m.rows,
            cols: m.cols,
            vals,
            offs,
        })
    }

    pub fn unpack(&self) -> Mat {
        let groups = self.cols / self.pattern.m;
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for g in 0..groups {
                let slot = (i * groups + g) * self.pattern.n;
                for k in 0..self.pattern.n {
                    let v = self.vals[slot + k];
                    if v != 0.0 {
                        let col = g * self.pattern.m + self.offs[slot + k] as usize;
                        m.set(i, col, v);
                    }
                }
            }
        }
        m
    }

    /// Storage bytes: f32 vals + 2-bit (2:4) / 3-bit (4:8) metadata —
    /// we charge ceil(log2 m) bits per kept element like the hardware
    /// format, rounded up to whole bytes at the matrix level.
    pub fn nbytes(&self) -> usize {
        let meta_bits = (self.pattern.m as f64).log2().ceil() as usize;
        self.vals.len() * 4 + (self.offs.len() * meta_bits).div_ceil(8)
    }

    /// Y = X·Wᵀ directly out of the packed representation.
    pub fn spmm_bt(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.cols);
        let groups = self.cols / self.pattern.m;
        let mut y = Mat::zeros(x.rows, self.rows);
        for b in 0..x.rows {
            let xrow = x.row(b);
            let yrow = y.row_mut(b);
            for i in 0..self.rows {
                let mut acc = 0.0f32;
                for g in 0..groups {
                    let slot = (i * groups + g) * self.pattern.n;
                    let base = g * self.pattern.m;
                    for k in 0..self.pattern.n {
                        acc += self.vals[slot + k] * xrow[base + self.offs[slot + k] as usize];
                    }
                }
                yrow[i] = acc;
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul_bt;
    use crate::util::rng::Pcg64;

    #[test]
    fn mask_keeps_exactly_n_per_group() {
        let mut rng = Pcg64::seed_from_u64(50);
        let scores = Mat::rand_uniform(6, 16, 0.0, 1.0, &mut rng);
        for pat in [PATTERN_2_4, PATTERN_4_8] {
            let mask = pat.mask_from_scores(&scores);
            pat.validate(&mask).unwrap();
            // exactly n per full group since scores are all positive
            for i in 0..6 {
                for g in 0..(16 / pat.m) {
                    let nnz = (0..pat.m)
                        .filter(|&o| mask.at(i, g * pat.m + o) != 0.0)
                        .count();
                    assert_eq!(nnz, pat.n);
                }
            }
        }
    }

    #[test]
    fn mask_picks_top_scores() {
        let scores = Mat::from_vec(1, 4, vec![0.1, 0.9, 0.5, 0.8]);
        let mask = PATTERN_2_4.mask_from_scores(&scores);
        assert_eq!(mask.data, vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn validate_rejects_violations() {
        let m = Mat::from_vec(1, 4, vec![1.0, 1.0, 1.0, 0.0]); // 3 nnz in group of 4
        assert!(PATTERN_2_4.validate(&m).is_err());
        let ok = Mat::from_vec(1, 4, vec![1.0, 0.0, 1.0, 0.0]);
        assert!(PATTERN_2_4.validate(&ok).is_ok());
    }

    #[test]
    fn ragged_tail_groups() {
        let scores = Mat::filled(1, 6, 1.0); // one full group of 4 + tail of 2
        let mask = PATTERN_2_4.mask_from_scores(&scores);
        PATTERN_2_4.validate(&mask).unwrap();
        let tail_nnz = (4..6).filter(|&j| mask.at(0, j) != 0.0).count();
        assert_eq!(tail_nnz, 1); // ceil(2*2/4) = 1
    }

    #[test]
    fn pack_roundtrip_and_matmul() {
        let mut rng = Pcg64::seed_from_u64(51);
        let scores = Mat::rand_uniform(8, 24, 0.0, 1.0, &mut rng);
        let dense = Mat::randn(8, 24, 1.0, &mut rng);
        let mask = PATTERN_2_4.mask_from_scores(&scores);
        let w = dense.hadamard(&mask);
        let packed = NmPacked::pack(PATTERN_2_4, &w).unwrap();
        assert_eq!(packed.unpack(), w);
        let x = Mat::randn(3, 24, 1.0, &mut rng);
        let y1 = packed.spmm_bt(&x);
        let y2 = matmul_bt(&x, &w);
        assert!(y1.allclose(&y2, 1e-5, 1e-5));
    }

    #[test]
    fn packed_size_is_half_plus_metadata() {
        let mut rng = Pcg64::seed_from_u64(52);
        let scores = Mat::rand_uniform(16, 64, 0.0, 1.0, &mut rng);
        let dense = Mat::randn(16, 64, 1.0, &mut rng);
        let w = dense.hadamard(&PATTERN_2_4.mask_from_scores(&scores));
        let packed = NmPacked::pack(PATTERN_2_4, &w).unwrap();
        let dense_bytes = 16 * 64 * 4;
        // 2:4: half the values + 2 bits per kept value.
        let expect = dense_bytes / 2 + (16 * 64 / 2 * 2) / 8;
        assert_eq!(packed.nbytes(), expect);
    }
}
