//! Semi-structured N:M sparsity (2:4, 4:8) — Mishra et al. 2021.
//!
//! An N:M pattern keeps at most N non-zeros in every aligned group of
//! M consecutive elements along the input dimension. The paper's §II-B2
//! applies N:M *first*, then group-wise pruning on top to reach the
//! target sparsity. This module provides mask construction from a
//! score matrix, validation, and the packed "every group carries
//! exactly N slots" storage that real N:M hardware uses.

use crate::tensor::Mat;

/// An N:M sparsity pattern along rows (the Din axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NmPattern {
    pub n: usize,
    pub m: usize,
}

pub const PATTERN_2_4: NmPattern = NmPattern { n: 2, m: 4 };
pub const PATTERN_4_8: NmPattern = NmPattern { n: 4, m: 8 };

impl NmPattern {
    pub fn name(&self) -> String {
        format!("{}:{}", self.n, self.m)
    }

    /// Max fraction of non-zeros the pattern allows.
    pub fn density(&self) -> f64 {
        self.n as f64 / self.m as f64
    }

    /// Build the keep-mask that maximizes total score per group:
    /// within every aligned window of `m` columns of each row, keep
    /// the `n` highest-scoring elements. Trailing ragged groups (cols
    /// not divisible by m) keep ⌈n·len/m⌉ elements.
    pub fn mask_from_scores(&self, scores: &Mat) -> Mat {
        let mut mask = Mat::zeros(scores.rows, scores.cols);
        let mut idx: Vec<usize> = Vec::with_capacity(self.m);
        for i in 0..scores.rows {
            let row = scores.row(i);
            let mut j = 0;
            while j < scores.cols {
                let end = (j + self.m).min(scores.cols);
                let len = end - j;
                let keep = if len == self.m {
                    self.n
                } else {
                    (self.n * len).div_ceil(self.m)
                };
                idx.clear();
                idx.extend(j..end);
                idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
                for &k in idx.iter().take(keep) {
                    mask.set(i, k, 1.0);
                }
                j = end;
            }
        }
        mask
    }

    /// Check a dense matrix obeys the pattern (each aligned group of m
    /// has ≤ n non-zeros).
    pub fn validate(&self, m: &Mat) -> Result<(), String> {
        for i in 0..m.rows {
            let row = m.row(i);
            let mut j = 0;
            while j < m.cols {
                let end = (j + self.m).min(m.cols);
                let nnz = row[j..end].iter().filter(|&&v| v != 0.0).count();
                let cap = if end - j == self.m {
                    self.n
                } else {
                    (self.n * (end - j)).div_ceil(self.m)
                };
                if nnz > cap {
                    return Err(format!(
                        "row {i} group at col {j}: {nnz} nnz > {cap} allowed ({})",
                        self.name()
                    ));
                }
                j = end;
            }
        }
        Ok(())
    }
}

/// Packed N:M storage: for every aligned group, exactly `n` value
/// slots + `n` intra-group indices (u8). Mirrors the metadata layout
/// of sparse tensor cores; used for size accounting and the packed
/// matmul in benches.
#[derive(Debug, Clone, PartialEq)]
pub struct NmPacked {
    pub pattern: NmPattern,
    pub rows: usize,
    pub cols: usize,
    /// (rows × groups_per_row × n) values; zero-padded when a group has
    /// fewer than n non-zeros.
    pub vals: Vec<f32>,
    /// Matching intra-group column offsets (0..m).
    pub offs: Vec<u8>,
}

impl NmPacked {
    /// Pack a dense matrix that already satisfies the pattern.
    pub fn pack(pattern: NmPattern, m: &Mat) -> Result<NmPacked, String> {
        pattern.validate(m)?;
        if m.cols % pattern.m != 0 {
            return Err(format!(
                "cols {} not divisible by m={} — pad before packing",
                m.cols, pattern.m
            ));
        }
        let groups = m.cols / pattern.m;
        let mut vals = Vec::with_capacity(m.rows * groups * pattern.n);
        let mut offs = Vec::with_capacity(vals.capacity());
        for i in 0..m.rows {
            let row = m.row(i);
            for g in 0..groups {
                let base = g * pattern.m;
                let mut filled = 0;
                for o in 0..pattern.m {
                    let v = row[base + o];
                    if v != 0.0 {
                        vals.push(v);
                        offs.push(o as u8);
                        filled += 1;
                    }
                }
                while filled < pattern.n {
                    vals.push(0.0);
                    offs.push(0);
                    filled += 1;
                }
            }
        }
        Ok(NmPacked {
            pattern,
            rows: m.rows,
            cols: m.cols,
            vals,
            offs,
        })
    }

    pub fn unpack(&self) -> Mat {
        let groups = self.cols / self.pattern.m;
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for g in 0..groups {
                let slot = (i * groups + g) * self.pattern.n;
                for k in 0..self.pattern.n {
                    let v = self.vals[slot + k];
                    if v != 0.0 {
                        let col = g * self.pattern.m + self.offs[slot + k] as usize;
                        m.set(i, col, v);
                    }
                }
            }
        }
        m
    }

    /// Storage bytes: f32 vals + 2-bit (2:4) / 3-bit (4:8) metadata —
    /// we charge ceil(log2 m) bits per kept element like the hardware
    /// format, rounded up to whole bytes at the matrix level.
    pub fn nbytes(&self) -> usize {
        let meta_bits = (self.pattern.m as f64).log2().ceil() as usize;
        self.vals.len() * 4 + (self.offs.len() * meta_bits).div_ceil(8)
    }

    /// Re-pack a CSR matrix whose sparsity already obeys `pattern`
    /// (e.g. the `W_S` a `--semi` compression run emits) into the
    /// hardware-style N:M layout. Errors if the CSR violates the
    /// pattern or its width is not a multiple of `m`.
    pub fn from_csr(pattern: NmPattern, csr: &crate::sparse::Csr) -> Result<NmPacked, String> {
        NmPacked::pack(pattern, &csr.to_dense())
    }

    /// One packed row · dense vector through the dedicated 2:4 kernel:
    /// exactly two value/offset slots per group of four activations,
    /// so the inner loop is two fixed-stride multiply-adds — no length
    /// branch, no metadata scan. Accumulation order is the generic
    /// [`spmm_bt`](NmPacked::spmm_bt)'s (groups ascending, slots in
    /// order), so this is **bit-identical** to it (pinned by tests).
    /// Panics unless `pattern` is 2:4.
    #[inline]
    pub fn row_dot_24(&self, i: usize, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.cols);
        assert_eq!(self.pattern, PATTERN_2_4, "row_dot_24 on a {} matrix", self.pattern.name());
        let groups = self.cols / 4;
        let rv = &self.vals[i * groups * 2..(i + 1) * groups * 2];
        let ro = &self.offs[i * groups * 2..(i + 1) * groups * 2];
        let mut acc = 0.0f32;
        for g in 0..groups {
            let s = g * 2;
            let base = g * 4;
            acc += rv[s] * x[base + ro[s] as usize];
            acc += rv[s + 1] * x[base + ro[s + 1] as usize];
        }
        acc
    }

    /// Fast-path [`row_dot_24`](NmPacked::row_dot_24): two groups per
    /// step feeding four independent accumulator chains, with the
    /// slot/offset reads unchecked (provably inside this row's slice —
    /// see SAFETY) and the activation gather bounds-checked (a
    /// deserialized `offs` entry ≥ 4 panics instead of reading out of
    /// bounds). Tolerance-gated (DESIGN.md §7) — the 4-chain unroll
    /// reassociates the group sum.
    pub fn row_dot_24_fast(&self, i: usize, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.cols);
        assert_eq!(self.pattern, PATTERN_2_4, "row_dot_24 on a {} matrix", self.pattern.name());
        let groups = self.cols / 4;
        let rv = &self.vals[i * groups * 2..(i + 1) * groups * 2];
        let ro = &self.offs[i * groups * 2..(i + 1) * groups * 2];
        let mut acc = [0.0f32; 4];
        let pairs = groups / 2;
        for p in 0..pairs {
            let s = p * 4; // two groups = four slots
            let base = p * 8;
            for t in 0..4 {
                // SAFETY: s + t < pairs*4 <= groups*2 == rv.len() ==
                // ro.len() (both are the same row subslice).
                let v = unsafe { *rv.get_unchecked(s + t) };
                let o = unsafe { *ro.get_unchecked(s + t) } as usize;
                acc[t] += v * x[base + (t / 2) * 4 + o];
            }
        }
        for g in pairs * 2..groups {
            let s = g * 2;
            let base = g * 4;
            acc[0] += rv[s] * x[base + ro[s] as usize];
            acc[1] += rv[s + 1] * x[base + ro[s + 1] as usize];
        }
        (acc[0] + acc[1]) + (acc[2] + acc[3])
    }

    /// [`spmm_bt`](NmPacked::spmm_bt) through the dedicated 2:4 kernel
    /// (`fast = false` ⇒ bit-identical to the generic path, `true` ⇒
    /// the tolerance-gated unrolled variant).
    pub fn spmm_bt_24(&self, x: &Mat, fast: bool) -> Mat {
        assert_eq!(x.cols, self.cols);
        let mut y = Mat::zeros(x.rows, self.rows);
        for b in 0..x.rows {
            let xrow = x.row(b);
            let yrow = y.row_mut(b);
            for i in 0..self.rows {
                yrow[i] = if fast {
                    self.row_dot_24_fast(i, xrow)
                } else {
                    self.row_dot_24(i, xrow)
                };
            }
        }
        y
    }

    /// Y = X·Wᵀ directly out of the packed representation.
    pub fn spmm_bt(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.cols);
        let groups = self.cols / self.pattern.m;
        let mut y = Mat::zeros(x.rows, self.rows);
        for b in 0..x.rows {
            let xrow = x.row(b);
            let yrow = y.row_mut(b);
            for i in 0..self.rows {
                let mut acc = 0.0f32;
                for g in 0..groups {
                    let slot = (i * groups + g) * self.pattern.n;
                    let base = g * self.pattern.m;
                    for k in 0..self.pattern.n {
                        acc += self.vals[slot + k] * xrow[base + self.offs[slot + k] as usize];
                    }
                }
                yrow[i] = acc;
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul_bt;
    use crate::util::rng::Pcg64;

    #[test]
    fn mask_keeps_exactly_n_per_group() {
        let mut rng = Pcg64::seed_from_u64(50);
        let scores = Mat::rand_uniform(6, 16, 0.0, 1.0, &mut rng);
        for pat in [PATTERN_2_4, PATTERN_4_8] {
            let mask = pat.mask_from_scores(&scores);
            pat.validate(&mask).unwrap();
            // exactly n per full group since scores are all positive
            for i in 0..6 {
                for g in 0..(16 / pat.m) {
                    let nnz = (0..pat.m)
                        .filter(|&o| mask.at(i, g * pat.m + o) != 0.0)
                        .count();
                    assert_eq!(nnz, pat.n);
                }
            }
        }
    }

    #[test]
    fn mask_picks_top_scores() {
        let scores = Mat::from_vec(1, 4, vec![0.1, 0.9, 0.5, 0.8]);
        let mask = PATTERN_2_4.mask_from_scores(&scores);
        assert_eq!(mask.data, vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn validate_rejects_violations() {
        let m = Mat::from_vec(1, 4, vec![1.0, 1.0, 1.0, 0.0]); // 3 nnz in group of 4
        assert!(PATTERN_2_4.validate(&m).is_err());
        let ok = Mat::from_vec(1, 4, vec![1.0, 0.0, 1.0, 0.0]);
        assert!(PATTERN_2_4.validate(&ok).is_ok());
    }

    #[test]
    fn ragged_tail_groups() {
        let scores = Mat::filled(1, 6, 1.0); // one full group of 4 + tail of 2
        let mask = PATTERN_2_4.mask_from_scores(&scores);
        PATTERN_2_4.validate(&mask).unwrap();
        let tail_nnz = (4..6).filter(|&j| mask.at(0, j) != 0.0).count();
        assert_eq!(tail_nnz, 1); // ceil(2*2/4) = 1
    }

    #[test]
    fn pack_roundtrip_and_matmul() {
        let mut rng = Pcg64::seed_from_u64(51);
        let scores = Mat::rand_uniform(8, 24, 0.0, 1.0, &mut rng);
        let dense = Mat::randn(8, 24, 1.0, &mut rng);
        let mask = PATTERN_2_4.mask_from_scores(&scores);
        let w = dense.hadamard(&mask);
        let packed = NmPacked::pack(PATTERN_2_4, &w).unwrap();
        assert_eq!(packed.unpack(), w);
        let x = Mat::randn(3, 24, 1.0, &mut rng);
        let y1 = packed.spmm_bt(&x);
        let y2 = matmul_bt(&x, &w);
        assert!(y1.allclose(&y2, 1e-5, 1e-5));
    }

    #[test]
    fn kernel_24_bit_identical_to_generic() {
        // The dedicated 2:4 kernel accumulates in the generic packed
        // kernel's order — equality is exact, not allclose. Small and
        // deterministic: this is the miri/ASan coverage of the unsafe
        // slot reads (ragged `groups % 2 != 0` tail included).
        let mut rng = Pcg64::seed_from_u64(53);
        for cols in [4usize, 8, 12, 24] {
            let scores = Mat::rand_uniform(5, cols, 0.0, 1.0, &mut rng);
            let dense = Mat::randn(5, cols, 1.0, &mut rng);
            let w = dense.hadamard(&PATTERN_2_4.mask_from_scores(&scores));
            let packed = NmPacked::pack(PATTERN_2_4, &w).unwrap();
            let x = Mat::randn(3, cols, 1.0, &mut rng);
            let y_ref = packed.spmm_bt(&x);
            assert_eq!(packed.spmm_bt_24(&x, false), y_ref, "cols={cols}");
            // Fast variant: tolerance-gated (4-chain reassociation).
            let y_fast = packed.spmm_bt_24(&x, true);
            for b in 0..3 {
                for i in 0..5 {
                    let tol = 4.0 * cols as f32 * f32::EPSILON * 16.0 + 1e-6;
                    assert!(
                        (y_fast.row(b)[i] - y_ref.row(b)[i]).abs() <= tol,
                        "cols={cols} b={b} i={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn from_csr_roundtrips_pattern_obeying_sparse() {
        let mut rng = Pcg64::seed_from_u64(54);
        let scores = Mat::rand_uniform(6, 16, 0.0, 1.0, &mut rng);
        let dense = Mat::randn(6, 16, 1.0, &mut rng);
        let w = dense.hadamard(&PATTERN_2_4.mask_from_scores(&scores));
        let csr = crate::sparse::Csr::from_dense(&w);
        let packed = NmPacked::from_csr(PATTERN_2_4, &csr).unwrap();
        assert_eq!(packed.unpack(), w);
        // A CSR that violates the pattern must be rejected.
        let bad = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 0.0]);
        assert!(NmPacked::from_csr(PATTERN_2_4, &crate::sparse::Csr::from_dense(&bad)).is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore = "randomized bulk shapes are slow under miri")]
    fn prop_24_masks_valid_across_shapes() {
        // 2:4 mask-validity property: for any score matrix (ties,
        // negatives, ragged widths), the constructed mask validates,
        // every full group keeps exactly n entries, and the kept
        // entries are a top-n of the group's scores.
        crate::util::prop::check(
            "semi-24-mask-validity",
            40,
            |rng| (1 + rng.below_usize(12), 1 + rng.below_usize(40)),
            |&(rows, cols)| {
                let mut rng = Pcg64::seed_from_u64((rows * 211 + cols) as u64);
                let mut scores = Mat::randn(rows, cols, 1.0, &mut rng);
                if (rows + cols) % 3 == 0 {
                    // Adversarial ties: quantize scores.
                    for v in scores.data.iter_mut() {
                        *v = (*v * 2.0).round() / 2.0;
                    }
                }
                let scores = &scores;
                let mask = PATTERN_2_4.mask_from_scores(scores);
                PATTERN_2_4.validate(&mask).map_err(|e| format!("mask invalid: {e}"))?;
                for i in 0..scores.rows {
                    let mut g = 0;
                    while g + 4 <= scores.cols {
                        let kept: Vec<usize> =
                            (g..g + 4).filter(|&j| mask.at(i, j) != 0.0).collect();
                        if kept.len() != 2 {
                            return Err(format!("row {i} group {g}: kept {}", kept.len()));
                        }
                        // Top-n: every kept score >= every dropped score.
                        let min_kept =
                            kept.iter().map(|&j| scores.at(i, j)).fold(f32::INFINITY, f32::min);
                        for j in g..g + 4 {
                            if mask.at(i, j) == 0.0 && scores.at(i, j) > min_kept {
                                return Err(format!("row {i} group {g}: dropped a higher score"));
                            }
                        }
                        g += 4;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn packed_size_is_half_plus_metadata() {
        let mut rng = Pcg64::seed_from_u64(52);
        let scores = Mat::rand_uniform(16, 64, 0.0, 1.0, &mut rng);
        let dense = Mat::randn(16, 64, 1.0, &mut rng);
        let w = dense.hadamard(&PATTERN_2_4.mask_from_scores(&scores));
        let packed = NmPacked::pack(PATTERN_2_4, &w).unwrap();
        let dense_bytes = 16 * 64 * 4;
        // 2:4: half the values + 2 bits per kept value.
        let expect = dense_bytes / 2 + (16 * 64 / 2 * 2) / 8;
        assert_eq!(packed.nbytes(), expect);
    }
}
