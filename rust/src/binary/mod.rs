//! Binary ±1 matrix substrate — the `W_B` component.
//!
//! `W_B ∈ {+1,−1}^(Dout×Din)` stores one bit per element (bit set ⇔ +1),
//! packed 64 signs per `u64` word along the row (Din) axis. The paper's
//! hardware claim is exactly this 16×-vs-fp16 (32×-vs-fp32) storage
//! saving; on CPU we additionally exploit it with a sign-select matmul
//! that processes signs word-at-a-time.

use crate::tensor::Mat;
use crate::util::pool::{chunk_ranges, ThreadPool};

/// Batch-block width for the cache-blocked kernels: the packed
/// bitplane is streamed once per block of activation rows instead of
/// once per row — on this matrix the bitplane IS the weight traffic,
/// so the block factor divides the dominant byte stream directly
/// (DESIGN.md §3).
const BB: usize = 8;

#[derive(Debug, Clone, PartialEq)]
pub struct BitMat {
    pub rows: usize,
    pub cols: usize,
    /// ceil(cols/64) words per row, row-major.
    words_per_row: usize,
    bits: Vec<u64>,
}

impl BitMat {
    /// Pack from a dense ±1 (or arbitrary-sign) matrix: bit = (v >= 0).
    /// Matches `Mat::sign_pm1` (sign(0) = +1).
    pub fn from_sign_of(m: &Mat) -> BitMat {
        let words_per_row = m.cols.div_ceil(64);
        let mut bits = vec![0u64; m.rows * words_per_row];
        for i in 0..m.rows {
            let row = m.row(i);
            for (j, &v) in row.iter().enumerate() {
                if v >= 0.0 {
                    bits[i * words_per_row + j / 64] |= 1u64 << (j % 64);
                }
            }
        }
        BitMat {
            rows: m.rows,
            cols: m.cols,
            words_per_row,
            bits,
        }
    }

    /// All +1.
    pub fn ones(rows: usize, cols: usize) -> BitMat {
        BitMat::from_sign_of(&Mat::filled(rows, cols, 1.0))
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        if self.bits[i * self.words_per_row + j / 64] >> (j % 64) & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }

    pub fn to_dense(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |i, j| self.get(i, j))
    }

    /// Storage bytes (the 1-bit/elem claim; row padding included).
    pub fn nbytes(&self) -> usize {
        self.bits.len() * 8
    }

    /// The raw packed sign words, row-major: [`words_per_row`] words
    /// per row, bit set ⇔ +1, padding bits beyond `cols` clear. This
    /// is the on-disk checkpoint payload (`slab::layer::save_into`).
    ///
    /// [`words_per_row`]: BitMat::words_per_row
    pub fn words(&self) -> &[u64] {
        &self.bits
    }

    /// ceil(cols / 64) — the row stride of [`words`](BitMat::words).
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// Rebuild from packed words in the layout of
    /// [`words`](BitMat::words). Padding bits in each row's last word
    /// are cleared so equality stays canonical regardless of what the
    /// serializer wrote there.
    pub fn from_words(rows: usize, cols: usize, mut bits: Vec<u64>) -> BitMat {
        let words_per_row = cols.div_ceil(64);
        assert_eq!(
            bits.len(),
            rows * words_per_row,
            "from_words: {} words for {rows}x{cols}",
            bits.len()
        );
        if cols % 64 != 0 {
            let mask = (1u64 << (cols % 64)) - 1;
            for i in 0..rows {
                bits[i * words_per_row + words_per_row - 1] &= mask;
            }
        }
        BitMat {
            rows,
            cols,
            words_per_row,
            bits,
        }
    }

    /// Fraction of +1 entries.
    pub fn positive_fraction(&self) -> f64 {
        let mut count = 0u64;
        for i in 0..self.rows {
            for w in 0..self.words_per_row {
                let mut word = self.bits[i * self.words_per_row + w];
                // Mask padding bits in the last word.
                if w == self.words_per_row - 1 && self.cols % 64 != 0 {
                    word &= (1u64 << (self.cols % 64)) - 1;
                }
                count += word.count_ones() as u64;
            }
        }
        count as f64 / (self.rows * self.cols) as f64
    }

    /// y[i] = Σ_j x[j] · B[i,j]  (B ∈ ±1).
    ///
    /// Sign-select kernel: acc = total − 2·Σ_{bit=0} x[j], computed
    /// per 64-bit word. When a word is all-ones or all-zeros the inner
    /// loop collapses to a precomputed prefix sum — on real ±1-times-
    /// activation workloads most of the win comes from the packed
    /// memory traffic, mirroring the TPU HBM argument in DESIGN.md §3.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let total: f32 = x.iter().sum();
        let mut y = vec![0.0f32; self.rows];
        for i in 0..self.rows {
            y[i] = total - 2.0 * self.row_neg_sum(i, x);
        }
        y
    }

    /// Σ x[j] over this row's −1 lanes, accumulated in ascending-j
    /// (scalar reference) order — the exact-kernel building block:
    /// [`matvec`](BitMat::matvec) and the fused decode epilogue
    /// ([`SlabLayer::forward_decode`](crate::slab::SlabLayer::forward_decode))
    /// both derive `y[i] = total − 2·row_neg_sum(i)` from it, which is
    /// what keeps them bit-identical to each other.
    #[inline]
    pub fn row_neg_sum(&self, i: usize, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.cols);
        let base = i * self.words_per_row;
        let mut neg_sum = 0.0f32; // Σ x[j] where bit=0 (sign −1)
        for w in 0..self.words_per_row {
            let mut word = !self.bits[base + w]; // set bits = −1 lanes
            let jbase = w * 64;
            let lanes = (self.cols - jbase).min(64);
            if lanes < 64 {
                word &= (1u64 << lanes) - 1;
            }
            while word != 0 {
                let t = word.trailing_zeros() as usize;
                neg_sum += x[jbase + t];
                word &= word - 1;
            }
        }
        neg_sum
    }

    /// Fast-path [`row_neg_sum`](BitMat::row_neg_sum): consumes each
    /// packed word whole — every lane contributes through a branchless
    /// sign-select (`x & mask`, −1 lanes keep `x`, +1 lanes add +0.0)
    /// into 8 independent accumulator chains, so the compiler can
    /// vectorize across lanes and the CPU overlaps FP-add latency
    /// instead of serializing one chain per `trailing_zeros` bit.
    ///
    /// **Tolerance-gated** (DESIGN.md §7): the 8-chain striping
    /// reassociates the sum, so results differ from the exact kernel
    /// by a few ULPs — never compare with `==`. The error bound is
    /// asserted in this module's property tests.
    pub fn row_neg_sum_fast(&self, i: usize, x: &[f32]) -> f32 {
        assert_eq!(x.len(), self.cols);
        assert!(i < self.rows);
        let base = i * self.words_per_row;
        let full = self.cols / 64;
        let mut acc = [0.0f32; 8];
        for wd in 0..full {
            // SAFETY: i < rows and wd < full <= words_per_row, so
            // base + wd < rows * words_per_row == bits.len().
            let word = !unsafe { *self.bits.get_unchecked(base + wd) };
            let xw = &x[wd * 64..wd * 64 + 64];
            for c in 0..8 {
                let lanes = (word >> (c * 8)) as u32 & 0xff;
                let xc = &xw[c * 8..c * 8 + 8];
                for t in 0..8 {
                    // lane bit set ⇒ −1 weight ⇒ keep x[j]; clear ⇒
                    // +1 weight ⇒ add +0.0. An accumulator that starts
                    // at +0.0 and only ever adds can never turn into
                    // −0.0, so the +0.0 padding is value-preserving.
                    let keep = (lanes >> t) & 1;
                    acc[t] += f32::from_bits(xc[t].to_bits() & keep.wrapping_neg());
                }
            }
        }
        if self.cols % 64 != 0 {
            // Ragged tail word: scalar extraction, folded into chain 0.
            let mut word = !self.bits[base + full];
            let jbase = full * 64;
            word &= (1u64 << (self.cols - jbase)) - 1;
            while word != 0 {
                let t = word.trailing_zeros() as usize;
                acc[0] += x[jbase + t];
                word &= word - 1;
            }
        }
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
    }

    /// Fast-path [`matvec`](BitMat::matvec) built on
    /// [`row_neg_sum_fast`](BitMat::row_neg_sum_fast). Tolerance-gated.
    pub fn matvec_fast(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let total: f32 = x.iter().sum();
        let mut y = vec![0.0f32; self.rows];
        for i in 0..self.rows {
            y[i] = total - 2.0 * self.row_neg_sum_fast(i, x);
        }
        y
    }

    /// Fast-path `matmul_bt`: the word-at-a-time striped kernel per
    /// row, weight rows chunked across `pool` when given. Tolerance-
    /// gated like every `*_fast` kernel (the parallel chunking itself
    /// is deterministic — the striping is what reassociates).
    pub fn matmul_bt_fast(&self, x: &Mat, pool: Option<&ThreadPool>) -> Mat {
        assert_eq!(x.cols, self.cols, "matmul_bt: x cols {} vs B cols {}", x.cols, self.cols);
        let totals = row_totals(x);
        let mut y = Mat::zeros(x.rows, self.rows);
        match pool {
            Some(p) if p.size() > 1 && self.rows >= 2 => {
                let ranges = chunk_ranges(self.rows, p.size());
                let mut strips: Vec<Vec<f32>> = ranges
                    .iter()
                    .map(|&(r0, r1)| vec![0.0f32; x.rows * (r1 - r0)])
                    .collect();
                let totals_ref = &totals;
                let jobs: Vec<_> = strips
                    .iter_mut()
                    .zip(ranges.iter().copied())
                    .map(|(strip, (r0, r1))| {
                        move || self.matmul_rows_fast(x, totals_ref, r0, r1, strip)
                    })
                    .collect();
                p.scoped(jobs);
                for (strip, &(r0, r1)) in strips.iter().zip(ranges.iter()) {
                    let w = r1 - r0;
                    for b in 0..x.rows {
                        y.row_mut(b)[r0..r1].copy_from_slice(&strip[b * w..(b + 1) * w]);
                    }
                }
            }
            _ => self.matmul_rows_fast(x, &totals, 0, self.rows, &mut y.data),
        }
        y
    }

    /// Fast striped kernel over weight rows `[r0, r1)`; `out` is a
    /// strip in `[b][i - r0]` layout like
    /// [`matmul_rows_blocked`](BitMat::matmul_rows_blocked).
    fn matmul_rows_fast(&self, x: &Mat, totals: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        let w = r1 - r0;
        debug_assert_eq!(out.len(), x.rows * w);
        for b in 0..x.rows {
            let xb = x.row(b);
            for i in r0..r1 {
                out[b * w + (i - r0)] = totals[b] - 2.0 * self.row_neg_sum_fast(i, xb);
            }
        }
    }

    /// Y = X·Bᵀ for a batch X (B, Din): the `(x ⊙ v)·Bᵀ` step of the
    /// SLaB forward.
    pub fn matmul_bt(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.cols);
        let mut y = Mat::zeros(x.rows, self.rows);
        for b in 0..x.rows {
            let yb = self.matvec(x.row(b));
            y.row_mut(b).copy_from_slice(&yb);
        }
        y
    }

    /// Cache-blocked `matmul_bt`: identical math to
    /// [`matmul_bt`](BitMat::matmul_bt) (bit-identical output), but the
    /// packed bitplane is streamed once per [`BB`]-row batch block.
    pub fn matmul_bt_blocked(&self, x: &Mat) -> Mat {
        let mut y = Mat::zeros(x.rows, self.rows);
        self.matmul_bt_blocked_into(x, &mut y);
        y
    }

    /// [`matmul_bt_blocked`](BitMat::matmul_bt_blocked) writing into a
    /// caller-owned output — the allocation-free form the fused
    /// [`SlabLayer::forward_fused`](crate::slab::SlabLayer::forward_fused)
    /// scratch loop uses. `y` must be `(x.rows, self.rows)`; it is
    /// overwritten entirely.
    pub fn matmul_bt_blocked_into(&self, x: &Mat, y: &mut Mat) {
        assert_eq!(x.cols, self.cols, "matmul_bt: x cols {} vs B cols {}", x.cols, self.cols);
        assert_eq!((y.rows, y.cols), (x.rows, self.rows), "matmul_bt_into: bad output shape");
        let totals = row_totals(x);
        self.matmul_rows_blocked(x, &totals, 0, self.rows, &mut y.data);
    }

    /// [`ThreadPool`]-parallel `matmul_bt`: weight rows chunked across
    /// the pool (parallel even at batch 1), each chunk cache-blocked.
    /// Bit-identical to the scalar [`matmul_bt`](BitMat::matmul_bt).
    pub fn matmul_bt_par(&self, x: &Mat, pool: &ThreadPool) -> Mat {
        let mut y = Mat::zeros(x.rows, self.rows);
        self.matmul_bt_par_into(x, pool, &mut y);
        y
    }

    /// [`matmul_bt_par`](BitMat::matmul_bt_par) into a caller-owned
    /// output (overwritten entirely).
    pub fn matmul_bt_par_into(&self, x: &Mat, pool: &ThreadPool, y: &mut Mat) {
        assert_eq!(x.cols, self.cols, "matmul_bt: x cols {} vs B cols {}", x.cols, self.cols);
        assert_eq!((y.rows, y.cols), (x.rows, self.rows), "matmul_bt_into: bad output shape");
        if pool.size() <= 1 || self.rows < 2 {
            let totals = row_totals(x);
            self.matmul_rows_blocked(x, &totals, 0, self.rows, &mut y.data);
            return;
        }
        let totals = row_totals(x);
        let ranges = chunk_ranges(self.rows, pool.size());
        let mut strips: Vec<Vec<f32>> = ranges
            .iter()
            .map(|&(r0, r1)| vec![0.0f32; x.rows * (r1 - r0)])
            .collect();
        let totals_ref = &totals;
        let jobs: Vec<_> = strips
            .iter_mut()
            .zip(ranges.iter().copied())
            .map(|(strip, (r0, r1))| {
                move || self.matmul_rows_blocked(x, totals_ref, r0, r1, strip)
            })
            .collect();
        pool.scoped(jobs);
        for (strip, &(r0, r1)) in strips.iter().zip(ranges.iter()) {
            let w = r1 - r0;
            for b in 0..x.rows {
                y.row_mut(b)[r0..r1].copy_from_slice(&strip[b * w..(b + 1) * w]);
            }
        }
    }

    /// Blocked sign-select kernel over weight rows `[r0, r1)`; `out`
    /// is a strip in `[b][i - r0]` layout. `totals[b]` is Σ_j x[b][j]
    /// (hoisted so the parallel chunks don't recompute it).
    fn matmul_rows_blocked(&self, x: &Mat, totals: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        let w = r1 - r0;
        debug_assert_eq!(out.len(), x.rows * w);
        for b0 in (0..x.rows).step_by(BB) {
            let bw = (x.rows - b0).min(BB);
            for i in r0..r1 {
                let base = i * self.words_per_row;
                let mut neg = [0.0f32; BB]; // Σ x[b][j] where bit=0 (sign −1)
                for wd in 0..self.words_per_row {
                    let mut word = !self.bits[base + wd]; // set bits = −1 lanes
                    let jbase = wd * 64;
                    let lanes = (self.cols - jbase).min(64);
                    if lanes < 64 {
                        word &= (1u64 << lanes) - 1;
                    }
                    while word != 0 {
                        let t = word.trailing_zeros() as usize;
                        let j = jbase + t;
                        for bi in 0..bw {
                            neg[bi] += x.data[(b0 + bi) * x.cols + j];
                        }
                        word &= word - 1;
                    }
                }
                for bi in 0..bw {
                    out[(b0 + bi) * w + (i - r0)] = totals[b0 + bi] - 2.0 * neg[bi];
                }
            }
        }
    }

    /// XNOR-popcount path for *binary* activations (x ∈ ±1 packed):
    /// dot(a,b) = 64·matches − lanes. Included as the classic binary-
    /// network kernel the paper's `W_B` enables when activations are
    /// also binarized (not used on the main SLaB path, exercised by
    /// benches as the roofline reference).
    pub fn xnor_dot(&self, row: usize, other: &BitMat, other_row: usize) -> i64 {
        assert_eq!(self.cols, other.cols);
        let a = &self.bits[row * self.words_per_row..(row + 1) * self.words_per_row];
        let b = &other.bits[other_row * other.words_per_row..(other_row + 1) * other.words_per_row];
        let mut matches = 0i64;
        for w in 0..self.words_per_row {
            let mut eq = !(a[w] ^ b[w]);
            let jbase = w * 64;
            let lanes = (self.cols - jbase).min(64);
            if lanes < 64 {
                eq &= (1u64 << lanes) - 1;
            }
            matches += eq.count_ones() as i64;
        }
        2 * matches - self.cols as i64
    }
}

/// Per-row activation sums, accumulated in the same order as
/// [`BitMat::matvec`]'s `total`, so the blocked/parallel kernels stay
/// bit-identical to the scalar reference.
fn row_totals(x: &Mat) -> Vec<f32> {
    (0..x.rows).map(|b| x.row(b).iter().sum()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{matmul_bt, matvec};
    use crate::util::rng::Pcg64;

    fn random_sign(rows: usize, cols: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(rows, cols, |_, _| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
    }

    #[test]
    fn pack_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(60);
        for cols in [1, 63, 64, 65, 130] {
            let m = random_sign(5, cols, &mut rng);
            let b = BitMat::from_sign_of(&m);
            assert_eq!(b.to_dense(), m, "cols={cols}");
        }
    }

    #[test]
    fn sign_of_zero_is_positive() {
        let m = Mat::from_vec(1, 3, vec![0.0, -0.5, 2.0]);
        let b = BitMat::from_sign_of(&m);
        assert_eq!(b.get(0, 0), 1.0);
        assert_eq!(b.get(0, 1), -1.0);
        assert_eq!(b.get(0, 2), 1.0);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg64::seed_from_u64(61);
        for cols in [7, 64, 100] {
            let m = random_sign(9, cols, &mut rng);
            let b = BitMat::from_sign_of(&m);
            let x: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.3).sin()).collect();
            let y1 = b.matvec(&x);
            let y2 = matvec(&m, &x);
            for i in 0..9 {
                assert!((y1[i] - y2[i]).abs() < 1e-3, "cols={cols} i={i}");
            }
        }
    }

    #[test]
    fn matmul_bt_matches_dense() {
        let mut rng = Pcg64::seed_from_u64(62);
        let w = random_sign(11, 70, &mut rng);
        let x = Mat::randn(4, 70, 1.0, &mut rng);
        let b = BitMat::from_sign_of(&w);
        let y1 = b.matmul_bt(&x);
        let y2 = matmul_bt(&x, &w);
        assert!(y1.allclose(&y2, 1e-3, 1e-3));
    }

    #[test]
    fn storage_is_one_bit_per_element() {
        let b = BitMat::ones(128, 512);
        assert_eq!(b.nbytes(), 128 * 512 / 8);
        // vs f32 dense: 32× smaller; vs f16: 16×.
        assert_eq!(128 * 512 * 4 / b.nbytes(), 32);
    }

    #[test]
    fn positive_fraction_counts() {
        let m = Mat::from_vec(2, 3, vec![1.0, -1.0, 1.0, -1.0, -1.0, -1.0]);
        let b = BitMat::from_sign_of(&m);
        assert!((b.positive_fraction() - 2.0 / 6.0).abs() < 1e-9);
    }

    #[test]
    fn words_roundtrip_and_padding_canonical() {
        let mut rng = Pcg64::seed_from_u64(64);
        for cols in [1usize, 63, 64, 65, 130] {
            let m = random_sign(4, cols, &mut rng);
            let b = BitMat::from_sign_of(&m);
            let back = BitMat::from_words(4, cols, b.words().to_vec());
            assert_eq!(back, b, "cols={cols}");
            // Dirty padding bits must be scrubbed by from_words.
            if cols % 64 != 0 {
                let mut dirty = b.words().to_vec();
                let wpr = b.words_per_row();
                dirty[wpr - 1] |= !((1u64 << (cols % 64)) - 1);
                assert_eq!(BitMat::from_words(4, cols, dirty), b, "cols={cols} dirty");
            }
        }
    }

    /// Reassociation tolerance for a fast-vs-exact comparison over
    /// `n` terms whose absolute sum is `mag`: both kernels sum the
    /// same terms, each in some order, so their difference is bounded
    /// by c·n·ε·Σ|terms| (standard recursive-summation error, DESIGN.md
    /// §7). The constant is deliberately generous; the point is that
    /// the bound is *explicit* and scales correctly, not that it is
    /// tight.
    fn reassoc_tol(n: usize, mag: f64) -> f32 {
        (4.0 * n as f64 * f32::EPSILON as f64 * mag) as f32 + 1e-6
    }

    #[test]
    fn fast_word_kernel_boundary_shapes() {
        // Deterministic, pool-free, and small: this is the test the
        // miri/ASan CI job runs over the `unsafe` word loads — word
        // boundaries (63/64/65), sub-word rows, all-(+1) and all-(−1)
        // rows, and a padded tail.
        for cols in [1usize, 8, 63, 64, 65, 128, 130] {
            let mut w = Mat::from_fn(4, cols, |i, j| {
                if (i + j) % 3 == 0 {
                    1.0
                } else {
                    -1.0
                }
            });
            w.row_mut(1).fill(1.0); // all +1: neg_sum must be exactly 0.0
            w.row_mut(2).fill(-1.0); // all −1: neg_sum = Σ x
            let b = BitMat::from_sign_of(&w);
            let x: Vec<f32> = (0..cols).map(|j| (j as f32 * 0.37).sin() + 0.1).collect();
            let exact = b.matvec(&x);
            let fast = b.matvec_fast(&x);
            for i in 0..4 {
                let mag: f64 = x.iter().map(|&v| v.abs() as f64).sum();
                let tol = reassoc_tol(cols, 2.0 * mag);
                assert!(
                    (fast[i] - exact[i]).abs() <= tol,
                    "cols={cols} i={i}: fast {} vs exact {} (tol {tol})",
                    fast[i],
                    exact[i]
                );
            }
            assert_eq!(b.row_neg_sum_fast(1, &x), 0.0, "all-ones row, cols={cols}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "randomized shapes + pool fan-out are too slow under miri")]
    fn prop_fast_matches_exact_within_tolerance() {
        // Adversarial shapes for the tolerance-gated path: cols off
        // the word boundary, batch 1 and >1, serial and pooled. The
        // bound itself is part of the contract — a fast kernel that
        // drops or duplicates a term fails it immediately, while pure
        // reassociation passes with huge margin.
        let pool4 = crate::util::pool::ThreadPool::new(4);
        crate::util::prop::check(
            "bitmat-fast-vs-exact",
            25,
            |rng| (1 + rng.below_usize(40), 1 + rng.below_usize(150)),
            |&(rows, cols)| {
                let mut rng = Pcg64::seed_from_u64((rows * 257 + cols) as u64);
                let w = random_sign(rows, cols, &mut rng);
                let b = BitMat::from_sign_of(&w);
                for batch in [1usize, 3] {
                    let x = Mat::randn(batch, cols, 1.0, &mut rng);
                    let y_ref = b.matmul_bt(&x);
                    for y_fast in [b.matmul_bt_fast(&x, None), b.matmul_bt_fast(&x, Some(&pool4))]
                    {
                        for bi in 0..batch {
                            let mag: f64 =
                                x.row(bi).iter().map(|&v| v.abs() as f64).sum();
                            let tol = reassoc_tol(cols, 2.0 * mag);
                            for i in 0..rows {
                                let (f, e) = (y_fast.row(bi)[i], y_ref.row(bi)[i]);
                                if (f - e).abs() > tol {
                                    return Err(format!(
                                        "{rows}x{cols} batch {batch} b={bi} i={i}: \
                                         fast {f} vs exact {e} exceeds tol {tol}"
                                    ));
                                }
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    #[cfg_attr(miri, ignore = "pool fan-out + randomized shapes are too slow under miri")]
    fn prop_blocked_and_parallel_match_scalar() {
        // Adversarial shapes: cols off the 64-bit word boundary,
        // batch of 1, pool of 1 vs N. The kernels accumulate in the
        // scalar order, so equality is exact.
        let pool1 = crate::util::pool::ThreadPool::new(1);
        let pool4 = crate::util::pool::ThreadPool::new(4);
        crate::util::prop::check(
            "bitmat-par-vs-scalar",
            25,
            |rng| (1 + rng.below_usize(40), 1 + rng.below_usize(150)),
            |&(rows, cols)| {
                let mut rng = Pcg64::seed_from_u64((rows * 151 + cols) as u64);
                let w = Mat::from_fn(rows, cols, |_, _| if rng.bernoulli(0.5) { 1.0 } else { -1.0 });
                let b = BitMat::from_sign_of(&w);
                for batch in [1usize, 2, 9] {
                    let x = Mat::randn(batch, cols, 1.0, &mut rng);
                    let y_ref = b.matmul_bt(&x);
                    if b.matmul_bt_blocked(&x) != y_ref {
                        return Err(format!("blocked {rows}x{cols} batch {batch}"));
                    }
                    for pool in [&pool1, &pool4] {
                        if b.matmul_bt_par(&x, pool) != y_ref {
                            return Err(format!(
                                "par {rows}x{cols} batch {batch} pool {}",
                                pool.size()
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn xnor_dot_matches_float() {
        let mut rng = Pcg64::seed_from_u64(63);
        let a = random_sign(3, 77, &mut rng);
        let c = random_sign(3, 77, &mut rng);
        let ba = BitMat::from_sign_of(&a);
        let bc = BitMat::from_sign_of(&c);
        for i in 0..3 {
            let expect: f32 = a.row(i).iter().zip(c.row(i).iter()).map(|(&x, &y)| x * y).sum();
            assert_eq!(ba.xnor_dot(i, &bc, i), expect as i64);
        }
    }
}
