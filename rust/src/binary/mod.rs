//! Binary ±1 matrix substrate — the `W_B` component.
//!
//! `W_B ∈ {+1,−1}^(Dout×Din)` stores one bit per element (bit set ⇔ +1),
//! packed 64 signs per `u64` word along the row (Din) axis. The paper's
//! hardware claim is exactly this 16×-vs-fp16 (32×-vs-fp32) storage
//! saving; on CPU we additionally exploit it with a sign-select matmul
//! that processes signs word-at-a-time.

use crate::tensor::Mat;

#[derive(Debug, Clone, PartialEq)]
pub struct BitMat {
    pub rows: usize,
    pub cols: usize,
    /// ceil(cols/64) words per row, row-major.
    words_per_row: usize,
    bits: Vec<u64>,
}

impl BitMat {
    /// Pack from a dense ±1 (or arbitrary-sign) matrix: bit = (v >= 0).
    /// Matches `Mat::sign_pm1` (sign(0) = +1).
    pub fn from_sign_of(m: &Mat) -> BitMat {
        let words_per_row = m.cols.div_ceil(64);
        let mut bits = vec![0u64; m.rows * words_per_row];
        for i in 0..m.rows {
            let row = m.row(i);
            for (j, &v) in row.iter().enumerate() {
                if v >= 0.0 {
                    bits[i * words_per_row + j / 64] |= 1u64 << (j % 64);
                }
            }
        }
        BitMat {
            rows: m.rows,
            cols: m.cols,
            words_per_row,
            bits,
        }
    }

    /// All +1.
    pub fn ones(rows: usize, cols: usize) -> BitMat {
        BitMat::from_sign_of(&Mat::filled(rows, cols, 1.0))
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        if self.bits[i * self.words_per_row + j / 64] >> (j % 64) & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }

    pub fn to_dense(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |i, j| self.get(i, j))
    }

    /// Storage bytes (the 1-bit/elem claim; row padding included).
    pub fn nbytes(&self) -> usize {
        self.bits.len() * 8
    }

    /// Fraction of +1 entries.
    pub fn positive_fraction(&self) -> f64 {
        let mut count = 0u64;
        for i in 0..self.rows {
            for w in 0..self.words_per_row {
                let mut word = self.bits[i * self.words_per_row + w];
                // Mask padding bits in the last word.
                if w == self.words_per_row - 1 && self.cols % 64 != 0 {
                    word &= (1u64 << (self.cols % 64)) - 1;
                }
                count += word.count_ones() as u64;
            }
        }
        count as f64 / (self.rows * self.cols) as f64
    }

    /// y[i] = Σ_j x[j] · B[i,j]  (B ∈ ±1).
    ///
    /// Sign-select kernel: acc = total − 2·Σ_{bit=0} x[j], computed
    /// per 64-bit word. When a word is all-ones or all-zeros the inner
    /// loop collapses to a precomputed prefix sum — on real ±1-times-
    /// activation workloads most of the win comes from the packed
    /// memory traffic, mirroring the TPU HBM argument in DESIGN.md §3.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let total: f32 = x.iter().sum();
        let mut y = vec![0.0f32; self.rows];
        for i in 0..self.rows {
            let base = i * self.words_per_row;
            let mut neg_sum = 0.0f32; // Σ x[j] where bit=0 (sign −1)
            for w in 0..self.words_per_row {
                let mut word = !self.bits[base + w]; // set bits = −1 lanes
                let jbase = w * 64;
                let lanes = (self.cols - jbase).min(64);
                if lanes < 64 {
                    word &= (1u64 << lanes) - 1;
                }
                while word != 0 {
                    let t = word.trailing_zeros() as usize;
                    neg_sum += x[jbase + t];
                    word &= word - 1;
                }
            }
            y[i] = total - 2.0 * neg_sum;
        }
        y
    }

    /// Y = X·Bᵀ for a batch X (B, Din): the `(x ⊙ v)·Bᵀ` step of the
    /// SLaB forward.
    pub fn matmul_bt(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.cols);
        let mut y = Mat::zeros(x.rows, self.rows);
        for b in 0..x.rows {
            let yb = self.matvec(x.row(b));
            y.row_mut(b).copy_from_slice(&yb);
        }
        y
    }

    /// XNOR-popcount path for *binary* activations (x ∈ ±1 packed):
    /// dot(a,b) = 64·matches − lanes. Included as the classic binary-
    /// network kernel the paper's `W_B` enables when activations are
    /// also binarized (not used on the main SLaB path, exercised by
    /// benches as the roofline reference).
    pub fn xnor_dot(&self, row: usize, other: &BitMat, other_row: usize) -> i64 {
        assert_eq!(self.cols, other.cols);
        let a = &self.bits[row * self.words_per_row..(row + 1) * self.words_per_row];
        let b = &other.bits[other_row * other.words_per_row..(other_row + 1) * other.words_per_row];
        let mut matches = 0i64;
        for w in 0..self.words_per_row {
            let mut eq = !(a[w] ^ b[w]);
            let jbase = w * 64;
            let lanes = (self.cols - jbase).min(64);
            if lanes < 64 {
                eq &= (1u64 << lanes) - 1;
            }
            matches += eq.count_ones() as i64;
        }
        2 * matches - self.cols as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{matmul_bt, matvec};
    use crate::util::rng::Pcg64;

    fn random_sign(rows: usize, cols: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(rows, cols, |_, _| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
    }

    #[test]
    fn pack_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(60);
        for cols in [1, 63, 64, 65, 130] {
            let m = random_sign(5, cols, &mut rng);
            let b = BitMat::from_sign_of(&m);
            assert_eq!(b.to_dense(), m, "cols={cols}");
        }
    }

    #[test]
    fn sign_of_zero_is_positive() {
        let m = Mat::from_vec(1, 3, vec![0.0, -0.5, 2.0]);
        let b = BitMat::from_sign_of(&m);
        assert_eq!(b.get(0, 0), 1.0);
        assert_eq!(b.get(0, 1), -1.0);
        assert_eq!(b.get(0, 2), 1.0);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg64::seed_from_u64(61);
        for cols in [7, 64, 100] {
            let m = random_sign(9, cols, &mut rng);
            let b = BitMat::from_sign_of(&m);
            let x: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.3).sin()).collect();
            let y1 = b.matvec(&x);
            let y2 = matvec(&m, &x);
            for i in 0..9 {
                assert!((y1[i] - y2[i]).abs() < 1e-3, "cols={cols} i={i}");
            }
        }
    }

    #[test]
    fn matmul_bt_matches_dense() {
        let mut rng = Pcg64::seed_from_u64(62);
        let w = random_sign(11, 70, &mut rng);
        let x = Mat::randn(4, 70, 1.0, &mut rng);
        let b = BitMat::from_sign_of(&w);
        let y1 = b.matmul_bt(&x);
        let y2 = matmul_bt(&x, &w);
        assert!(y1.allclose(&y2, 1e-3, 1e-3));
    }

    #[test]
    fn storage_is_one_bit_per_element() {
        let b = BitMat::ones(128, 512);
        assert_eq!(b.nbytes(), 128 * 512 / 8);
        // vs f32 dense: 32× smaller; vs f16: 16×.
        assert_eq!(128 * 512 * 4 / b.nbytes(), 32);
    }

    #[test]
    fn positive_fraction_counts() {
        let m = Mat::from_vec(2, 3, vec![1.0, -1.0, 1.0, -1.0, -1.0, -1.0]);
        let b = BitMat::from_sign_of(&m);
        assert!((b.positive_fraction() - 2.0 / 6.0).abs() < 1e-9);
    }

    #[test]
    fn xnor_dot_matches_float() {
        let mut rng = Pcg64::seed_from_u64(63);
        let a = random_sign(3, 77, &mut rng);
        let c = random_sign(3, 77, &mut rng);
        let ba = BitMat::from_sign_of(&a);
        let bc = BitMat::from_sign_of(&c);
        for i in 0..3 {
            let expect: f32 = a.row(i).iter().zip(c.row(i).iter()).map(|(&x, &y)| x * y).sum();
            assert_eq!(ba.xnor_dot(i, &bc, i), expect as i64);
        }
    }
}
