//! Paper-style table rendering (markdown-ish monospace) used by the
//! `slab table*` / `slab fig*` commands and EXPERIMENTS.md.

use crate::util::fmt_metric;

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn metric(v: f64) -> String {
        fmt_metric(v)
    }

    pub fn pct(v: f64) -> String {
        format!("{:.1}", v * 100.0)
    }

    /// Render with column alignment; also valid markdown.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n### {}\n\n", self.title));
        }
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Render as CSV (header + rows; cells containing commas, quotes,
    /// or newlines are quoted RFC-4180 style) — the machine-readable
    /// twin of [`render`](Table::render) for downstream
    /// plotting/diffing; the sweep CLI writes it next to the markdown.
    pub fn render_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        let line = |cells: &[String]| {
            cells.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
        };
        out.push_str(&line(&self.header));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    /// Write the CSV rendering, overwriting — one file per run, unlike
    /// the append-only markdown log.
    pub fn save_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.render_csv())
    }

    /// Append to a results file (EXPERIMENTS.md workflow).
    pub fn append_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        f.write_all(self.render().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["Method", "ppl", "acc"]);
        t.push_row(vec!["SLaB".into(), Table::metric(5.493), Table::pct(0.662)]);
        t.push_row(vec!["Wanda".into(), Table::metric(6.45), Table::pct(0.64)]);
        let r = t.render();
        assert!(r.contains("### Demo"));
        assert!(r.contains("| SLaB"));
        assert!(r.contains("5.49"));
        assert!(r.contains("66.2"));
        // Markdown separator present.
        assert!(r.lines().any(|l| l.starts_with("|--") || l.starts_with("|-")));
    }

    #[test]
    fn csv_escapes_and_roundtrips_to_disk() {
        let mut t = Table::new("csv", &["Method", "Sparsity(CR)", "ppl"]);
        t.push_row(vec!["SLaB".into(), "US (50%)".into(), "5.49".into()]);
        t.push_row(vec!["a,b".into(), "q\"q".into(), "1".into()]);
        let csv = t.render_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "Method,Sparsity(CR),ppl");
        assert_eq!(lines[1], "SLaB,US (50%),5.49");
        assert_eq!(lines[2], "\"a,b\",\"q\"\"q\",1");
        let path = std::env::temp_dir().join("slab-tests/report.csv");
        t.save_csv(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), csv);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.push_row(vec!["only-one".into()]);
    }
}
