//! Paper-style table rendering (markdown-ish monospace) used by the
//! `slab table*` / `slab fig*` commands and EXPERIMENTS.md.

use crate::util::fmt_metric;

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn metric(v: f64) -> String {
        fmt_metric(v)
    }

    pub fn pct(v: f64) -> String {
        format!("{:.1}", v * 100.0)
    }

    /// Render with column alignment; also valid markdown.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n### {}\n\n", self.title));
        }
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Append to a results file (EXPERIMENTS.md workflow).
    pub fn append_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        f.write_all(self.render().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["Method", "ppl", "acc"]);
        t.push_row(vec!["SLaB".into(), Table::metric(5.493), Table::pct(0.662)]);
        t.push_row(vec!["Wanda".into(), Table::metric(6.45), Table::pct(0.64)]);
        let r = t.render();
        assert!(r.contains("### Demo"));
        assert!(r.contains("| SLaB"));
        assert!(r.contains("5.49"));
        assert!(r.contains("66.2"));
        // Markdown separator present.
        assert!(r.lines().any(|l| l.starts_with("|--") || l.starts_with("|-")));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.push_row(vec!["only-one".into()]);
    }
}
