//! Typed bridges between the crate's tensors and XLA literals.

use crate::tensor::Mat;

/// f32 tensor literal of arbitrary shape.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> xla::Literal {
    assert_eq!(
        data.len(),
        dims.iter().product::<usize>(),
        "lit_f32 shape {:?} vs len {}",
        dims,
        data.len()
    );
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&d)
        .expect("lit_f32 reshape")
}

/// i32 tensor literal of arbitrary shape.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> xla::Literal {
    assert_eq!(data.len(), dims.iter().product::<usize>());
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&d)
        .expect("lit_i32 reshape")
}

/// Scalar i32 literal (e.g. the `step` / `pos` inputs).
pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Scalar f32 literal (e.g. `keep_frac`).
pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// 2-D matrix literal.
pub fn lit_mat(m: &Mat) -> xla::Literal {
    lit_f32(&m.data, &[m.rows, m.cols])
}

/// Literal → Vec<f32> (any shape, row-major).
pub fn to_vec_f32(lit: &xla::Literal) -> Vec<f32> {
    lit.to_vec::<f32>().expect("literal to f32 vec")
}

/// Literal → Mat with the given shape.
pub fn to_mat(lit: &xla::Literal, rows: usize, cols: usize) -> Mat {
    Mat::from_vec(rows, cols, to_vec_f32(lit))
}

/// Literal → scalar f32.
pub fn to_scalar_f32(lit: &xla::Literal) -> f32 {
    lit.get_first_element::<f32>().expect("scalar literal")
}
