//! PJRT runtime — load AOT HLO-text artifacts and execute them from
//! the rust hot path.
//!
//! * [`manifest`] — the `artifacts/manifest.json` ABI contract
//!   (configs, parameter order, artifact I/O specs).
//! * [`client`] — `PjRtClient` wrapper with a compile cache.
//! * [`literal`] — typed bridges between our tensors and XLA literals.
//!
//! Python runs only at `make artifacts` time; everything here is
//! self-contained given the artifact directory.

pub mod client;
pub mod literal;
pub mod manifest;

pub use client::Runtime;
pub use literal::{lit_f32, lit_i32, lit_mat, lit_scalar_i32, to_mat, to_vec_f32};
pub use manifest::{Manifest, ModelCfg};
