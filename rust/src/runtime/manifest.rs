//! `artifacts/manifest.json` — the ABI contract between `aot.py` and
//! this runtime: model configs, canonical parameter order, and the
//! input/output signature of every artifact.

use crate::util::json::Json;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One model configuration exported by aot.py (mirrors
/// `python/compile/model.py::ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub prompt_len: usize,
    /// Canonical parameter order (load-bearing for the call ABI).
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    /// The seven-per-layer pruned linears: (name, (dout, din)).
    pub pruned: Vec<(String, (usize, usize))>,
    /// Flat arg order of the compressed (slab_fwd) artifact.
    pub slab_param_names: Vec<String>,
}

impl ModelCfg {
    /// Build a Llama-family config programmatically, mirroring
    /// `model.py::ModelConfig.{param_names,param_shapes,pruned_linears,
    /// slab_param_names}` — the shape contract shared by the native
    /// engine, the tests, and the manifest, usable without an
    /// artifact directory.
    #[allow(clippy::too_many_arguments)]
    pub fn llama(
        name: &str,
        vocab: usize,
        dim: usize,
        n_layers: usize,
        n_heads: usize,
        ffn: usize,
        max_seq: usize,
        prompt_len: usize,
    ) -> ModelCfg {
        let mut param_names = vec!["tok_emb".to_string()];
        let mut param_shapes = vec![vec![vocab, dim]];
        let mut slab_param_names = vec!["tok_emb".to_string()];
        let mut pruned = Vec::new();
        for l in 0..n_layers {
            let block: [(&str, Vec<usize>); 9] = [
                ("attn_norm", vec![dim]),
                ("wq", vec![dim, dim]),
                ("wk", vec![dim, dim]),
                ("wv", vec![dim, dim]),
                ("wo", vec![dim, dim]),
                ("mlp_norm", vec![dim]),
                ("w_gate", vec![ffn, dim]),
                ("w_up", vec![ffn, dim]),
                ("w_down", vec![dim, ffn]),
            ];
            for (base, shape) in block {
                let pname = format!("l{l}.{base}");
                if shape.len() == 2 {
                    pruned.push((pname.clone(), (shape[0], shape[1])));
                    for suffix in ["ws", "u", "v", "b"] {
                        slab_param_names.push(format!("{pname}.{suffix}"));
                    }
                } else {
                    slab_param_names.push(pname.clone());
                }
                param_names.push(pname);
                param_shapes.push(shape);
            }
        }
        for (pname, shape) in [("final_norm", vec![dim]), ("lm_head", vec![vocab, dim])] {
            param_names.push(pname.to_string());
            slab_param_names.push(pname.to_string());
            param_shapes.push(shape);
        }
        ModelCfg {
            name: name.to_string(),
            vocab,
            dim,
            n_layers,
            n_heads,
            ffn,
            max_seq,
            prompt_len,
            param_names,
            param_shapes,
            pruned,
            slab_param_names,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    pub fn n_params(&self) -> usize {
        self.param_shapes
            .iter()
            .map(|s| s.iter().product::<usize>())
            .sum()
    }

    /// Index of a parameter in the canonical order.
    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.param_names.iter().position(|n| n == name)
    }

    /// The nine per-layer parameter names in `block_capture` artifact
    /// order — the per-block shape contract the compression pipeline's
    /// capture stage shares with aot.py (`block_capture_flat`).
    pub fn block_param_names(&self, layer: usize) -> [String; 9] {
        [
            format!("l{layer}.attn_norm"),
            format!("l{layer}.wq"),
            format!("l{layer}.wk"),
            format!("l{layer}.wv"),
            format!("l{layer}.wo"),
            format!("l{layer}.mlp_norm"),
            format!("l{layer}.w_gate"),
            format!("l{layer}.w_up"),
            format!("l{layer}.w_down"),
        ]
    }

    /// The seven pruned linears of block `layer` paired with their
    /// activation-source index in the capture outputs: 0 = `x_attn`
    /// (wq/wk/wv), 1 = `att_out` (wo), 2 = `x_mlp` (w_gate/w_up),
    /// 3 = `mlp_inner` (w_down). This order is the canonical reduction
    /// order of the decompose stage — reports and packed layers are
    /// emitted in it whether the stage ran serial or fanned out.
    pub fn block_linears(&self, layer: usize) -> [(String, usize); 7] {
        [
            (format!("l{layer}.wq"), 0),
            (format!("l{layer}.wk"), 0),
            (format!("l{layer}.wv"), 0),
            (format!("l{layer}.wo"), 1),
            (format!("l{layer}.w_gate"), 2),
            (format!("l{layer}.w_up"), 2),
            (format!("l{layer}.w_down"), 3),
        ]
    }
}

#[derive(Debug, Clone, Copy)]
pub struct TrainHyper {
    pub peak_lr: f64,
    pub warmup: usize,
    pub total_steps: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub train_batch: usize,
    pub eval_batch: usize,
    pub serve_batch: usize,
    pub kernel_bench_batch: usize,
    pub pad_id: i32,
    pub train_hyper: TrainHyper,
    pub configs: Vec<ModelCfg>,
    pub artifacts: Vec<ArtifactSpec>,
}

#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("json: {0}")]
    Json(#[from] crate::util::json::JsonError),
    #[error("manifest: {0}")]
    Schema(String),
}

fn specs(v: &Json) -> Result<Vec<TensorSpec>, ManifestError> {
    v.as_arr()
        .ok_or_else(|| ManifestError::Schema("specs not array".into()))?
        .iter()
        .map(|s| {
            Ok(TensorSpec {
                name: s
                    .get("name")
                    .as_str()
                    .ok_or_else(|| ManifestError::Schema("spec.name".into()))?
                    .to_string(),
                shape: s
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| ManifestError::Schema("spec.shape".into()))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                dtype: s.get("dtype").as_str().unwrap_or("f32").to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&text)?;
        if j.get("format").as_str() != Some("slab-aot-v1") {
            return Err(ManifestError::Schema("unknown manifest format".into()));
        }
        let consts = j.get("constants");
        let hp = j.get("train_hyper");
        let mut configs = Vec::new();
        for (name, c) in j
            .get("configs")
            .as_obj()
            .ok_or_else(|| ManifestError::Schema("configs".into()))?
        {
            let get = |k: &str| {
                c.get(k)
                    .as_usize()
                    .ok_or_else(|| ManifestError::Schema(format!("configs.{name}.{k}")))
            };
            configs.push(ModelCfg {
                name: name.clone(),
                vocab: get("vocab")?,
                dim: get("dim")?,
                n_layers: get("n_layers")?,
                n_heads: get("n_heads")?,
                ffn: get("ffn")?,
                max_seq: get("max_seq")?,
                prompt_len: get("prompt_len")?,
                param_names: c
                    .get("param_names")
                    .as_arr()
                    .ok_or_else(|| ManifestError::Schema("param_names".into()))?
                    .iter()
                    .map(|s| s.as_str().unwrap_or("").to_string())
                    .collect(),
                param_shapes: c
                    .get("param_shapes")
                    .as_arr()
                    .ok_or_else(|| ManifestError::Schema("param_shapes".into()))?
                    .iter()
                    .map(|s| {
                        s.as_arr()
                            .map(|a| a.iter().map(|d| d.as_usize().unwrap_or(0)).collect())
                            .unwrap_or_default()
                    })
                    .collect(),
                pruned: c
                    .get("pruned")
                    .as_arr()
                    .ok_or_else(|| ManifestError::Schema("pruned".into()))?
                    .iter()
                    .map(|p| {
                        let shape = p.get("shape");
                        (
                            p.get("name").as_str().unwrap_or("").to_string(),
                            (
                                shape.at(0).as_usize().unwrap_or(0),
                                shape.at(1).as_usize().unwrap_or(0),
                            ),
                        )
                    })
                    .collect(),
                slab_param_names: c
                    .get("slab_param_names")
                    .as_arr()
                    .ok_or_else(|| ManifestError::Schema("slab_param_names".into()))?
                    .iter()
                    .map(|s| s.as_str().unwrap_or("").to_string())
                    .collect(),
            });
        }
        let mut artifacts = Vec::new();
        for (name, a) in j
            .get("artifacts")
            .as_obj()
            .ok_or_else(|| ManifestError::Schema("artifacts".into()))?
        {
            artifacts.push(ArtifactSpec {
                name: name.clone(),
                file: a
                    .get("file")
                    .as_str()
                    .ok_or_else(|| ManifestError::Schema("artifact.file".into()))?
                    .to_string(),
                inputs: specs(a.get("inputs"))?,
                outputs: specs(a.get("outputs"))?,
            });
        }
        Ok(Manifest {
            train_batch: consts.get("train_batch").as_usize().unwrap_or(8),
            eval_batch: consts.get("eval_batch").as_usize().unwrap_or(8),
            serve_batch: consts.get("serve_batch").as_usize().unwrap_or(4),
            kernel_bench_batch: consts.get("kernel_bench_batch").as_usize().unwrap_or(32),
            pad_id: consts.get("pad_id").as_i64().unwrap_or(0) as i32,
            train_hyper: TrainHyper {
                peak_lr: hp.get("peak_lr").as_f64().unwrap_or(3e-3),
                warmup: hp.get("warmup").as_usize().unwrap_or(30),
                total_steps: hp.get("total_steps").as_usize().unwrap_or(600),
            },
            configs,
            artifacts,
        })
    }

    pub fn config(&self, name: &str) -> Option<&ModelCfg> {
        self.configs.iter().find(|c| c.name == name)
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "slab-aot-v1",
      "constants": {"train_batch": 8, "eval_batch": 8, "serve_batch": 4,
                    "kernel_bench_batch": 32, "pad_id": 0},
      "train_hyper": {"peak_lr": 0.003, "warmup": 30, "total_steps": 600},
      "configs": {
        "tiny": {
          "vocab": 64, "dim": 16, "n_layers": 1, "n_heads": 2, "ffn": 32,
          "max_seq": 8, "prompt_len": 4,
          "param_names": ["tok_emb", "l0.wq", "final_norm", "lm_head"],
          "param_shapes": [[64, 16], [16, 16], [16], [64, 16]],
          "pruned": [{"name": "l0.wq", "shape": [16, 16]}],
          "slab_param_names": ["tok_emb", "l0.wq.ws", "l0.wq.u", "l0.wq.v", "l0.wq.b", "final_norm", "lm_head"]
        }
      },
      "artifacts": {
        "eval_nll_tiny": {
          "file": "eval_nll_tiny.hlo.txt",
          "inputs": [{"name": "tok_emb", "shape": [64, 16], "dtype": "f32"}],
          "outputs": [{"name": "nll_sum", "shape": [8], "dtype": "f32"}]
        }
      }
    }"#;

    fn write_sample() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("slab-tests/manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        dir
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::load(&write_sample()).unwrap();
        assert_eq!(m.train_batch, 8);
        assert_eq!(m.pad_id, 0);
        let cfg = m.config("tiny").unwrap();
        assert_eq!(cfg.dim, 16);
        assert_eq!(cfg.param_names.len(), 4);
        assert_eq!(cfg.pruned, vec![("l0.wq".to_string(), (16, 16))]);
        assert_eq!(cfg.param_index("final_norm"), Some(2));
        assert_eq!(cfg.n_params(), 64 * 16 + 256 + 16 + 64 * 16);
        let a = m.artifact("eval_nll_tiny").unwrap();
        assert_eq!(a.inputs[0].shape, vec![64, 16]);
        assert_eq!(a.outputs[0].name, "nll_sum");
    }

    #[test]
    fn llama_cfg_matches_model_py_contract() {
        let cfg = ModelCfg::llama("t", 48, 16, 2, 4, 24, 20, 6);
        assert_eq!(cfg.param_names.len(), 1 + 2 * 9 + 2);
        assert_eq!(cfg.param_names.len(), cfg.param_shapes.len());
        assert_eq!(cfg.pruned.len(), 7 * cfg.n_layers);
        // slab order: dense entries stay, pruned expand to 4.
        assert_eq!(cfg.slab_param_names.len(), 1 + 2 * (2 + 7 * 4) + 2);
        assert_eq!(cfg.param_index("l1.w_down"), Some(1 + 9 + 8));
        assert_eq!(cfg.head_dim(), 4);
        assert_eq!(
            cfg.pruned[0],
            ("l0.wq".to_string(), (16, 16))
        );
        assert_eq!(&cfg.slab_param_names[1..5], &["l0.attn_norm", "l0.wq.ws", "l0.wq.u", "l0.wq.v"]);
    }

    #[test]
    fn block_layout_matches_canonical_param_order() {
        // The per-block helpers must agree with the flat manifest
        // order and with `pruned` — they are the same contract viewed
        // block-wise.
        let cfg = ModelCfg::llama("t", 48, 16, 2, 4, 24, 20, 6);
        for layer in 0..cfg.n_layers {
            let names = cfg.block_param_names(layer);
            for (i, n) in names.iter().enumerate() {
                assert_eq!(cfg.param_index(n), Some(1 + layer * 9 + i), "{n}");
            }
            let linears = cfg.block_linears(layer);
            for (n, src) in &linears {
                assert!(cfg.pruned.iter().any(|(pn, _)| pn == n), "{n} not pruned");
                assert!(*src < 4);
            }
            // Exactly the layer's pruned entries, in pruned order.
            let from_pruned: Vec<&String> = cfg
                .pruned
                .iter()
                .map(|(n, _)| n)
                .filter(|n| n.starts_with(&format!("l{layer}.")))
                .collect();
            let from_block: Vec<&String> = linears.iter().map(|(n, _)| n).collect();
            assert_eq!(from_block, from_pruned);
        }
    }

    #[test]
    fn missing_fields_error() {
        let dir = std::env::temp_dir().join("slab-tests/manifest-bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"format": "nope"}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
