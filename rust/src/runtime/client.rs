//! PJRT client wrapper: compile cache + typed execution over the
//! artifact registry.
//!
//! Follows the `/opt/xla-example/load_hlo` pattern: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Artifacts are compiled lazily and
//! cached for the process lifetime (compilation of the larger train
//! graphs takes seconds; the request path must never pay it twice).

use super::manifest::Manifest;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("xla: {0}")]
    Xla(String),
    #[error("unknown artifact '{0}' (not in manifest)")]
    UnknownArtifact(String),
    #[error("artifact '{0}': expected {1} inputs, got {2}")]
    Arity(String, usize, usize),
    #[error("artifact '{0}': expected at least {1} outputs, got {2}")]
    Outputs(String, usize, usize),
    #[error("router: {0}")]
    Router(String),
    #[error("missing parameter '{0}' in model config")]
    MissingParam(String),
    #[error("manifest: {0}")]
    Manifest(#[from] super::manifest::ManifestError),
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// The L3↔artifact bridge. One per process.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Runtime, RuntimeError> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn executable(
        &self,
        name: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>, RuntimeError> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?;
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        eprintln!(
            "[runtime] compiled {name} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with literal inputs; returns the flattened
    /// output tuple (aot.py lowers with `return_tuple=True`).
    pub fn execute(
        &self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>, RuntimeError> {
        let spec_len = self
            .manifest
            .artifact(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?
            .inputs
            .len();
        if inputs.len() != spec_len {
            return Err(RuntimeError::Arity(name.to_string(), spec_len, inputs.len()));
        }
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with borrowed literals — callers keep long-lived inputs
    /// (model parameters) host-side and splice per-call inputs in
    /// without cloning. NOTE: `buffer_from_host_literal`/`execute_b`
    /// device-resident buffers intermittently abort inside
    /// xla_extension 0.5.1's ShapeUtil on this CPU plugin
    /// (`pointer_size > 0` check), so the literal path is the
    /// supported one; see DESIGN.md §Perf for the measured cost.
    pub fn execute_refs(
        &self,
        name: &str,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>, RuntimeError> {
        let exe = self.executable(name)?;
        let result = exe.execute::<&xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Compile every artifact whose name starts with `prefix`
    /// (warm-up for benches / serving start-up).
    pub fn precompile(&self, prefix: &str) -> Result<usize, RuntimeError> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.name.starts_with(prefix))
            .map(|a| a.name.clone())
            .collect();
        for n in &names {
            self.executable(n)?;
        }
        Ok(names.len())
    }
}
