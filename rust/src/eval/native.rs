//! Native evaluation harness — batched NLL, corpus perplexity, task
//! accuracy, and the zero-shot sweep computed **directly on a
//! [`SlabModel`]**: the packed `W_S + u vᵀ ⊙ W_B` triples (or dense
//! weights) are scored through the serving engine's own forward
//! machinery ([`SlabModel::forward_full`]), so none of the
//! `embed_*`/`eval_nll_*` XLA artifacts are required anywhere — the
//! paper's evidence tables become reproducible on a fresh clone
//! (DESIGN.md §11).
//!
//! **Semantics.** Identical to the `eval_nll_{cfg}` artifact
//! (`model.py::eval_nll`): a row of `width` tokens scores
//! `inputs = row[..width−1]`, `targets = row[1..]` under the pure
//! causal forward; PAD targets are masked out of both the NLL sum and
//! the token count. Because trailing PAD *inputs* can only influence
//! positions whose targets are PAD (causality), a row's `(nll, count)`
//! never depends on its padding, its batch neighbours, or its slot.
//!
//! **Determinism contract** (same shape as the compression pipeline's
//! decompose stage, DESIGN.md §10): eval rows fan out across
//! [`ThreadPool::scoped_map`] workers in contiguous chunks with a
//! slot-ordered reduction, and each worker scores its rows through
//! serial kernels — so `threads(N)` is **bit-identical** to
//! `threads(1)`, and per-row results are invariant to row order and
//! batch size, pinned at unit, property, and integration levels.
//! Workers never touch the model's own pool (nesting a fork-join on
//! one pool could deadlock — see [`ThreadPool::scoped`]); here the
//! parallelism budget belongs to rows, not weight chunks.

use crate::data::tasks::{Task, TaskItem};
use crate::data::{TokenSet, PAD};
use crate::eval::{build_task_rows, count_correct};
use crate::model::SlabModel;
use crate::tensor::ops::logsumexp;
use crate::tensor::Mat;
use crate::util::pool::{chunk_ranges, ThreadPool};

/// How the native harness schedules eval rows.
#[derive(Debug, Clone, Copy)]
pub struct EvalOptions {
    /// Rows per forward call within one worker — amortizes per-call
    /// overhead; per-row results are bit-identical for any setting.
    pub batch: usize,
    /// Worker threads for the row fan-out: `1` = serial (the
    /// reference path), `0` = available parallelism, `n` = exactly
    /// `n`. Any setting is bit-identical to serial.
    pub threads: usize,
}

impl Default for EvalOptions {
    fn default() -> EvalOptions {
        EvalOptions { batch: 8, threads: 1 }
    }
}

impl EvalOptions {
    pub fn with_threads(threads: usize) -> EvalOptions {
        EvalOptions {
            threads,
            ..Default::default()
        }
    }
}

/// Score a slice of uniform-width rows: per row `(Σ nll, Σ tokens)`
/// with PAD targets masked — the native twin of the XLA engine's
/// [`crate::eval::nll_rows`], and the function the cross-engine
/// conformance tests compare. Rows must share one width in
/// `2..=max_seq+1`; token ids must be in-vocab (PAD fill is).
pub fn batched_nll(model: &SlabModel, rows: &[Vec<i32>], opts: EvalOptions) -> Vec<(f64, f64)> {
    if rows.is_empty() {
        return Vec::new();
    }
    let width = rows[0].len();
    assert!(
        width >= 2 && width - 1 <= model.cfg.max_seq,
        "eval row width {width} vs max_seq {}",
        model.cfg.max_seq
    );
    for r in rows {
        assert_eq!(r.len(), width, "ragged eval rows");
    }
    let batch = opts.batch.max(1);

    // One worker's serial pass over rows [r0, r1): forwards `batch`
    // rows at a time through serial kernels (pool = None).
    let score_chunk = |r0: usize, r1: usize| -> Vec<(f64, f64)> {
        let t = width - 1;
        let mut out = Vec::with_capacity(r1 - r0);
        let mut i = r0;
        while i < r1 {
            let take = (r1 - i).min(batch);
            let mut flat = Vec::with_capacity(take * t);
            for k in 0..take {
                flat.extend_from_slice(&rows[i + k][..t]);
            }
            let logits = model.forward_full(&flat, take, None);
            for k in 0..take {
                out.push(row_nll(&logits, k, t, &rows[i + k]));
            }
            i += take;
        }
        out
    };

    if opts.threads == 1 {
        return score_chunk(0, rows.len());
    }
    // Contiguous near-equal chunks, one per worker; `scoped_map`
    // returns results in input (= slot) order, so the concatenation
    // below is the same reduction the serial loop performs.
    let pool = ThreadPool::new(opts.threads);
    let ranges = chunk_ranges(rows.len(), pool.size());
    pool.scoped_map(ranges, |(r0, r1)| score_chunk(r0, r1))
        .into_iter()
        .flatten()
        .collect()
}

/// One row's `(Σ nll, Σ tokens)` from a `(take·t, vocab)` logits
/// batch: `nll(pos) = logsumexp(logits) − logits[target]` (the stable
/// `-log_softmax[target]`), PAD targets skipped — `model.py::eval_nll`
/// per position.
fn row_nll(logits: &Mat, k: usize, t: usize, row: &[i32]) -> (f64, f64) {
    let mut nll = 0.0f64;
    let mut cnt = 0.0f64;
    for pos in 0..t {
        let target = row[pos + 1];
        if target == PAD {
            continue;
        }
        let lrow = logits.row(k * t + pos);
        debug_assert!((target as usize) < lrow.len(), "target {target} out of vocab");
        nll += (logsumexp(lrow) - lrow[target as usize]) as f64;
        cnt += 1.0;
    }
    (nll, cnt)
}

/// Corpus `(Σ nll, Σ tokens)` over a held-out shard — the perplexity
/// numerator/denominator, exposed for benches and cross-checks.
pub fn corpus_nll(model: &SlabModel, shard: &TokenSet, opts: EvalOptions) -> (f64, f64) {
    assert_eq!(shard.seq_len, model.cfg.max_seq, "shard width vs model seq");
    batched_nll(model, &shard.to_rows(), opts)
        .into_iter()
        .fold((0.0, 0.0), |(a, b), (n, c)| (a + n, b + c))
}

/// Corpus perplexity `exp(Σ nll / Σ tokens)` over a held-out shard —
/// the native twin of [`crate::eval::perplexity`].
pub fn perplexity(model: &SlabModel, shard: &TokenSet, opts: EvalOptions) -> f64 {
    let (nll, cnt) = corpus_nll(model, shard, opts);
    (nll / cnt.max(1.0)).exp()
}

/// Tightest row width for a task suite: the longest real
/// `prompt ⧺ option` row, clamped into `[2, max_seq + 1]`. The XLA
/// engine must pad task rows to its artifact's static `max_seq + 1`
/// shape; the native engine has no such constraint, and trailing-PAD
/// invariance (module docs) makes a tight width a pure speedup —
/// attention is O(t²) per layer, and task rows are far shorter than
/// the window on the larger configs — with bit-identical scores.
fn task_width(items: &[TaskItem], max_seq: usize) -> usize {
    let longest = items
        .iter()
        .map(|it| {
            let opt = it.options.iter().map(|o| o.len()).max().unwrap_or(0);
            it.prompt.len() + opt
        })
        .max()
        .unwrap_or(0);
    longest.clamp(2, max_seq + 1)
}

/// Score one task suite: length-normalized option likelihoods with
/// the [`crate::eval::pick_option`] strict-less tie-break; items with
/// no options score incorrect; an empty suite scores 0.0. Same rows
/// (up to trailing PAD, which cannot change a score), same scoring
/// rule as the XLA engine — only the NLL numbers come from the native
/// forward.
pub fn task_accuracy(model: &SlabModel, items: &[TaskItem], opts: EvalOptions) -> f64 {
    let width = task_width(items, model.cfg.max_seq);
    let (rows, index) = build_task_rows(items, width);
    let row_nll: Vec<f64> = batched_nll(model, &rows, opts)
        .into_iter()
        .map(|(n, _)| n)
        .collect();
    count_correct(items, &index, &row_nll) as f64 / items.len().max(1) as f64
}

/// Full zero-shot sweep: (task, accuracy) per suite plus the macro
/// average. All suites' rows are scored through **one** batched pass
/// so the row fan-out amortizes across the whole sweep.
pub fn zero_shot(
    model: &SlabModel,
    suites: &[(Task, Vec<TaskItem>)],
    opts: EvalOptions,
) -> (Vec<(Task, f64)>, f64) {
    // One shared (tight) width so every suite rides one batched pass.
    let width = suites
        .iter()
        .map(|(_, items)| task_width(items, model.cfg.max_seq))
        .max()
        .unwrap_or(2);
    let mut all_rows: Vec<Vec<i32>> = Vec::new();
    let mut spans: Vec<(usize, usize, Vec<(usize, Vec<usize>)>)> = Vec::with_capacity(suites.len());
    for (_, items) in suites {
        let (rows, index) = build_task_rows(items, width);
        spans.push((all_rows.len(), rows.len(), index));
        all_rows.extend(rows);
    }
    let nll: Vec<f64> = batched_nll(model, &all_rows, opts)
        .into_iter()
        .map(|(n, _)| n)
        .collect();
    let mut per_task = Vec::with_capacity(suites.len());
    for ((task, items), (off, n, index)) in suites.iter().zip(spans.iter()) {
        let correct = count_correct(items, index, &nll[*off..off + n]);
        per_task.push((*task, correct as f64 / items.len().max(1) as f64));
    }
    let avg = per_task.iter().map(|(_, a)| a).sum::<f64>() / per_task.len().max(1) as f64;
    (per_task, avg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Params;
    use crate::runtime::ModelCfg;
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg::llama("tiny-eval", 32, 8, 2, 2, 16, 12, 4)
    }

    fn tiny_model(seed: u64) -> SlabModel {
        SlabModel::from_dense(&Params::init(&tiny_cfg(), seed), 1)
    }

    fn random_rows(rng: &mut Pcg64, n: usize, width: usize, vocab: usize) -> Vec<Vec<i32>> {
        (0..n)
            .map(|_| {
                (0..width)
                    .map(|_| 4 + rng.below_usize(vocab - 4) as i32)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn nll_masks_pad_targets_and_counts_tokens() {
        let model = tiny_model(500);
        let width = model.cfg.max_seq + 1;
        // A fully PAD-padded tail: count must equal the real prefix's
        // target count, and padding must not change the scores.
        let mut short = vec![5, 9, 11];
        short.resize(width, PAD);
        let out = batched_nll(&model, &[short.clone()], EvalOptions::default());
        assert_eq!(out.len(), 1);
        let (nll, cnt) = out[0];
        // Targets: 9, 11 (then PADs, masked).
        assert_eq!(cnt, 2.0);
        assert!(nll.is_finite() && nll > 0.0, "nll {nll}");
        // Full rows count width-1 targets.
        let full: Vec<i32> = (0..width).map(|i| 5 + (i as i32 % 20)).collect();
        let (_, cfull) = batched_nll(&model, &[full], EvalOptions::default())[0];
        assert_eq!(cfull, (width - 1) as f64);
    }

    #[test]
    fn parallel_is_bit_identical_to_serial_and_invariant_to_batch_and_order() {
        // The tentpole determinism contract as a property: for random
        // row sets, any (threads, batch) schedule reproduces the
        // serial batch-1 result bit for bit, and permuting rows
        // permutes results.
        let model = tiny_model(501);
        let width = model.cfg.max_seq + 1;
        let vocab = model.cfg.vocab;
        prop::check(
            "native-nll-schedule-invariance",
            6,
            |rng| (1 + rng.below_usize(10), 1 + rng.below_usize(5)),
            |&(n, batch)| {
                let mut rng = Pcg64::seed_from_u64((n * 31 + batch) as u64);
                let rows = random_rows(&mut rng, n, width, vocab);
                let reference = batched_nll(&model, &rows, EvalOptions { batch: 1, threads: 1 });
                for threads in [1usize, 3] {
                    let got = batched_nll(&model, &rows, EvalOptions { batch, threads });
                    if got != reference {
                        return Err(format!("threads={threads} batch={batch} diverged"));
                    }
                }
                // Row-order invariance: reversed rows → reversed results.
                let rev: Vec<Vec<i32>> = rows.iter().rev().cloned().collect();
                let got_rev = batched_nll(&model, &rev, EvalOptions { batch, threads: 2 });
                let want: Vec<(f64, f64)> = reference.iter().rev().cloned().collect();
                if got_rev != want {
                    return Err("row order leaked into results".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn perplexity_of_untrained_model_is_near_uniform() {
        let model = tiny_model(502);
        let shard = TokenSet::synthetic(6, model.cfg.max_seq, model.cfg.vocab);
        let p1 = perplexity(&model, &shard, EvalOptions::default());
        let p2 = perplexity(&model, &shard, EvalOptions::with_threads(4));
        assert_eq!(p1, p2, "threads must be invisible");
        // Scaled-normal init ≈ uniform logits: ppl near vocab size.
        let v = model.cfg.vocab as f64;
        assert!(p1 > v * 0.5 && p1 < v * 2.0, "ppl {p1} vs vocab {v}");
    }

    #[test]
    fn task_accuracy_edge_cases() {
        let model = tiny_model(503);
        // Empty suite: defined as 0.0, not NaN.
        assert_eq!(task_accuracy(&model, &[], EvalOptions::default()), 0.0);
        // An item with no options scores incorrect regardless of the
        // model (no argmin exists), never spuriously correct.
        let no_opts = vec![TaskItem {
            prompt: vec![5, 6],
            options: vec![],
            answer: 0,
        }];
        assert_eq!(task_accuracy(&model, &no_opts, EvalOptions::default()), 0.0);
        // A two-option item always picks *some* option → accuracy over
        // {correct item, empty item} is 0.0 or 0.5.
        let mixed = vec![
            TaskItem {
                prompt: vec![5, 6],
                options: vec![vec![7], vec![8]],
                answer: 0,
            },
            TaskItem {
                prompt: vec![5],
                options: vec![],
                answer: 0,
            },
        ];
        let acc = task_accuracy(&model, &mixed, EvalOptions::default());
        assert!(acc == 0.0 || acc == 0.5, "acc {acc}");
    }

    #[test]
    fn zero_shot_single_pass_matches_per_suite_calls() {
        use crate::data::Grammar;
        let cfg = ModelCfg::llama("tiny-eval-zs", 512, 8, 1, 2, 16, 48, 4);
        let model = SlabModel::from_dense(&Params::init(&cfg, 504), 1);
        let g = Grammar::standard();
        let suites: Vec<(Task, Vec<TaskItem>)> = [Task::Piqa, Task::BoolQ]
            .iter()
            .map(|t| (*t, t.generate(&g, 3, 99)))
            .collect();
        let opts = EvalOptions { batch: 4, threads: 2 };
        let (per_task, avg) = zero_shot(&model, &suites, opts);
        assert_eq!(per_task.len(), 2);
        for ((task, items), (t2, acc)) in suites.iter().zip(per_task.iter()) {
            assert_eq!(task, t2);
            assert_eq!(*acc, task_accuracy(&model, items, opts), "{}", task.name());
        }
        let want = per_task.iter().map(|(_, a)| a).sum::<f64>() / 2.0;
        assert_eq!(avg, want);
        // Empty sweep is defined.
        assert_eq!(zero_shot(&model, &[], opts).1, 0.0);
    }
}
