//! Evaluation harness: perplexity (WikiText-2 substitute) and the
//! seven zero-shot suites (LM-Eval substitute), behind **two
//! engines** (DESIGN.md §11):
//!
//! * the XLA path in this module — runs through the `eval_nll_{cfg}`
//!   artifact with model parameters pinned once per evaluation
//!   ([`ParamsOnDevice`]); the cross-check engine, and the only one
//!   that can score through the AOT Pallas forward.
//! * [`native`] — batched NLL / corpus perplexity / task accuracy /
//!   zero-shot computed directly on a [`crate::model::SlabModel`]
//!   (packed CSR + bitplane + low-rank triples or dense weights), no
//!   artifacts anywhere — the path that makes the paper's results
//!   tables reproducible on a fresh clone.
//!
//! Both engines share the row construction ([`build_task_rows`]) and
//! the option scoring ([`pick_option`] / [`count_correct`]) below, so
//! cross-engine conformance reduces to per-row NLL agreement — which
//! the integration suite pins within tolerance.

pub mod native;

use crate::data::tasks::{Task, TaskItem};
use crate::data::TokenSet;
use crate::model::Params;
use crate::runtime::client::RuntimeError;
use crate::runtime::{lit_i32, to_vec_f32, Runtime};

/// Host-pinned model parameter literals, built once per evaluation
/// and borrowed by every artifact call (the device-buffer path is
/// unreliable in xla_extension 0.5.1 — see `Runtime::execute_refs`).
pub struct ParamsOnDevice {
    pub lits: Vec<xla::Literal>,
}

impl ParamsOnDevice {
    pub fn upload(rt: &Runtime, params: &Params) -> Result<ParamsOnDevice, RuntimeError> {
        let _ = rt;
        Ok(ParamsOnDevice {
            lits: params.to_literals(),
        })
    }
}

// ---------------------------------------------------------------------------
// Engine-shared scoring: row construction + option selection
// ---------------------------------------------------------------------------

/// Build the NLL rows of a task suite: per item, the bare prompt row
/// followed by one `prompt ⧺ option` row per option, each PAD-padded
/// to `width`. Returns the rows plus, per item, `(prompt_row,
/// option_rows)` indices into them. Shared by the XLA and native
/// engines so both score *exactly* the same token rows.
pub fn build_task_rows(
    items: &[TaskItem],
    width: usize,
) -> (Vec<Vec<i32>>, Vec<(usize, Vec<usize>)>) {
    let mut rows: Vec<Vec<i32>> = Vec::new();
    let mut index: Vec<(usize, Vec<usize>)> = Vec::new();
    for it in items {
        let pad_to = |mut v: Vec<i32>| {
            assert!(v.len() <= width, "task row too long: {}", v.len());
            v.resize(width, 0);
            v
        };
        let p_row = rows.len();
        rows.push(pad_to(it.prompt.clone()));
        let mut opt_rows = Vec::with_capacity(it.options.len());
        for opt in &it.options {
            let mut full = it.prompt.clone();
            full.extend_from_slice(opt);
            opt_rows.push(rows.len());
            rows.push(pad_to(full));
        }
        index.push((p_row, opt_rows));
    }
    (rows, index)
}

/// Argmin over option scores with the **strict-less tie-break rule**:
/// an option wins only by being *strictly* lower than every earlier
/// option, so equal normalized NLLs keep the earliest option — the
/// deterministic analogue of LM-Eval's first-argmax convention, and
/// now an explicit contract rather than an accident of the loop.
/// Returns `None` for an empty option list (no argmin exists; the
/// caller scores such an item as incorrect).
pub fn pick_option(scores: &[f64]) -> Option<usize> {
    let mut best: Option<usize> = None;
    let mut best_score = f64::INFINITY;
    for (o, &s) in scores.iter().enumerate() {
        if s < best_score {
            best_score = s;
            best = Some(o);
        }
    }
    // NaN scores never satisfy `<`: an all-NaN row keeps `None` and
    // scores as incorrect instead of silently picking option 0.
    best
}

/// Count correct items given every row's NLL: per item, the option
/// with the lowest length-normalized score
/// `(nll(prompt ⧺ opt) − nll(prompt)) / |opt|` wins under the
/// [`pick_option`] strict-less rule; items with no options score
/// incorrect. Shared by both engines.
pub fn count_correct(
    items: &[TaskItem],
    index: &[(usize, Vec<usize>)],
    row_nll: &[f64],
) -> usize {
    let mut correct = 0usize;
    for (it, (p_row, opt_rows)) in items.iter().zip(index.iter()) {
        let base = row_nll[*p_row];
        let scores: Vec<f64> = opt_rows
            .iter()
            .enumerate()
            .map(|(o, &r)| (row_nll[r] - base) / it.options[o].len().max(1) as f64)
            .collect();
        if pick_option(&scores) == Some(it.answer) {
            correct += 1;
        }
    }
    correct
}

// ---------------------------------------------------------------------------
// XLA engine
// ---------------------------------------------------------------------------

/// Per-row `(nll, token_count)` through the `eval_nll_{cfg_name}`
/// artifact — the XLA engine's conformance surface: the native engine
/// must reproduce these numbers within tolerance on the same rows
/// (pinned by the cross-engine integration tests). Takes the config
/// *name* rather than a `Params` because the parameters actually
/// scored are the ones pinned in `dev` — a wider signature would
/// invite passing host params that silently disagree with the upload.
/// Rows are grouped into the artifact's static `batch` with PAD-row
/// padding; PAD fill rows cost compute but never leak into the
/// results.
pub fn nll_rows(
    rt: &Runtime,
    cfg_name: &str,
    dev: &ParamsOnDevice,
    rows: &[Vec<i32>],
    width: usize,
) -> Result<Vec<(f64, f64)>, RuntimeError> {
    let name = format!("eval_nll_{cfg_name}");
    let batch = rt.manifest.eval_batch;
    let mut out = Vec::with_capacity(rows.len());
    let mut i = 0;
    while i < rows.len() {
        let take = (rows.len() - i).min(batch);
        let mut flat = Vec::with_capacity(batch * width);
        for k in 0..batch {
            if k < take {
                flat.extend_from_slice(&rows[i + k]);
            } else {
                flat.extend(std::iter::repeat(0).take(width)); // PAD rows
            }
        }
        let tok = lit_i32(&flat, &[batch, width]);
        let mut inputs: Vec<&xla::Literal> = dev.lits.iter().collect();
        inputs.push(&tok);
        let outs = rt.execute_refs(&name, &inputs)?;
        let nll = to_vec_f32(&outs[0]);
        let cnt = to_vec_f32(&outs[1]);
        for k in 0..take {
            out.push((nll[k] as f64, cnt[k] as f64));
        }
        i += take;
    }
    Ok(out)
}

/// Corpus perplexity: `exp(Σ nll / Σ tokens)` over a held-out shard.
pub fn perplexity(
    rt: &Runtime,
    params: &Params,
    shard: &TokenSet,
) -> Result<f64, RuntimeError> {
    let cfg = &params.cfg;
    let width = cfg.max_seq + 1;
    assert_eq!(shard.seq_len + 1, width, "shard width vs model seq");
    let dev = ParamsOnDevice::upload(rt, params)?;
    let rows = shard.to_rows();
    let per_row = nll_rows(rt, &cfg.name, &dev, &rows, width)?;
    let (nll, cnt) = per_row
        .iter()
        .fold((0.0f64, 0.0f64), |(a, b), (n, c)| (a + n, b + c));
    Ok((nll / cnt.max(1.0)).exp())
}

/// Score one task: length-normalized option likelihoods via
/// `nll(prompt ⧺ option) − nll(prompt)`, ties broken by the
/// [`pick_option`] strict-less rule. An empty suite scores 0.0.
pub fn task_accuracy(
    rt: &Runtime,
    params: &Params,
    dev: &ParamsOnDevice,
    items: &[TaskItem],
) -> Result<f64, RuntimeError> {
    let width = params.cfg.max_seq + 1;
    let (rows, index) = build_task_rows(items, width);
    let row_nll: Vec<f64> = nll_rows(rt, &params.cfg.name, dev, &rows, width)?
        .into_iter()
        .map(|(n, _)| n)
        .collect();
    Ok(count_correct(items, &index, &row_nll) as f64 / items.len().max(1) as f64)
}

/// Full zero-shot sweep: (task, accuracy) plus the macro average.
pub fn zero_shot(
    rt: &Runtime,
    params: &Params,
    suites: &[(Task, Vec<TaskItem>)],
) -> Result<(Vec<(Task, f64)>, f64), RuntimeError> {
    let dev = ParamsOnDevice::upload(rt, params)?;
    let mut per_task = Vec::with_capacity(suites.len());
    for (task, items) in suites {
        let acc = task_accuracy(rt, params, &dev, items)?;
        per_task.push((*task, acc));
    }
    let avg = per_task.iter().map(|(_, a)| a).sum::<f64>() / per_task.len().max(1) as f64;
    Ok((per_task, avg))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_option_is_strict_less_first_wins() {
        // Ties keep the earliest option: 1.0 at index 0 is never
        // displaced by the equal 1.0 at index 2.
        assert_eq!(pick_option(&[1.0, 2.0, 1.0]), Some(0));
        assert_eq!(pick_option(&[3.0, 2.0, 2.0]), Some(1));
        assert_eq!(pick_option(&[2.0, -1.0, 0.5]), Some(1));
        assert_eq!(pick_option(&[]), None, "no options → no argmin");
        assert_eq!(pick_option(&[f64::NAN, f64::NAN]), None, "all-NaN → incorrect");
        // NaN entries are skipped, finite entries still win.
        assert_eq!(pick_option(&[f64::NAN, 4.0]), Some(1));
    }

    #[test]
    fn count_correct_hand_computed_length_normalization() {
        // One item, two options of different lengths. Row NLLs chosen
        // so the *unnormalized* deltas would pick option 0
        // (3.0 < 4.0) but per-token normalization picks option 1
        // (3.0/1 = 3.0 vs 4.0/2 = 2.0).
        let items = vec![TaskItem {
            prompt: vec![5, 6],
            options: vec![vec![7], vec![8, 9]],
            answer: 1,
        }];
        let index = vec![(0usize, vec![1usize, 2])];
        // nll(prompt)=10, nll(p⧺opt0)=13, nll(p⧺opt1)=14.
        let row_nll = vec![10.0, 13.0, 14.0];
        assert_eq!(count_correct(&items, &index, &row_nll), 1);
        // Exact tie on normalized scores (13.0 → 12.0: both 2.0/tok):
        // strict-less keeps option 0, so answer 1 now scores wrong.
        let tied = vec![10.0, 12.0, 14.0];
        assert_eq!(count_correct(&items, &index, &tied), 0);
        // …and an item whose answer IS the earliest tied option wins.
        let items0 = vec![TaskItem {
            prompt: vec![5, 6],
            options: vec![vec![7], vec![8, 9]],
            answer: 0,
        }];
        assert_eq!(count_correct(&items0, &index, &tied), 1);
    }

    #[test]
    fn count_correct_empty_options_scores_incorrect() {
        // An item with no options has no argmin; it must not count as
        // correct just because `answer == 0`.
        let items = vec![TaskItem {
            prompt: vec![5],
            options: vec![],
            answer: 0,
        }];
        let index = vec![(0usize, vec![])];
        assert_eq!(count_correct(&items, &index, &[2.0]), 0);
    }

    #[test]
    fn build_task_rows_layout_and_padding() {
        let items = vec![
            TaskItem {
                prompt: vec![5, 6],
                options: vec![vec![7], vec![8, 9]],
                answer: 0,
            },
            TaskItem {
                prompt: vec![10],
                options: vec![],
                answer: 0,
            },
        ];
        let (rows, index) = build_task_rows(&items, 6);
        assert_eq!(rows.len(), 4); // prompt+2 options, prompt+0 options
        assert_eq!(index, vec![(0, vec![1, 2]), (3, vec![])]);
        assert_eq!(rows[0], vec![5, 6, 0, 0, 0, 0]);
        assert_eq!(rows[2], vec![5, 6, 8, 9, 0, 0]);
        assert_eq!(rows[3], vec![10, 0, 0, 0, 0, 0]);
    }
}
