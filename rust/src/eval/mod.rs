//! Evaluation harness: perplexity (WikiText-2 substitute) and the
//! seven zero-shot suites (LM-Eval substitute).
//!
//! Both run exclusively through the `eval_nll_{cfg}` artifact, with
//! model parameters uploaded to the device once per evaluation
//! (`ParamsOnDevice`) — the paper's Table I sweeps evaluate dozens of
//! compressed variants, so parameter re-upload is the hot cost.

use crate::data::tasks::{Task, TaskItem};
use crate::data::TokenSet;
use crate::model::Params;
use crate::runtime::{lit_i32, to_vec_f32, Runtime};
use crate::runtime::client::RuntimeError;

/// Host-pinned model parameter literals, built once per evaluation
/// and borrowed by every artifact call (the device-buffer path is
/// unreliable in xla_extension 0.5.1 — see `Runtime::execute_refs`).
pub struct ParamsOnDevice {
    pub lits: Vec<xla::Literal>,
}

impl ParamsOnDevice {
    pub fn upload(rt: &Runtime, params: &Params) -> Result<ParamsOnDevice, RuntimeError> {
        let _ = rt;
        Ok(ParamsOnDevice {
            lits: params.to_literals(),
        })
    }
}

/// Run `eval_nll_{cfg}` over row-batches of a token set; returns
/// (Σ nll, Σ tokens).
fn nll_over_rows(
    rt: &Runtime,
    cfg_name: &str,
    dev: &ParamsOnDevice,
    rows: &[Vec<i32>],
    width: usize,
    batch: usize,
) -> Result<(f64, f64), RuntimeError> {
    let name = format!("eval_nll_{cfg_name}");
    let mut total_nll = 0.0f64;
    let mut total_cnt = 0.0f64;
    let mut i = 0;
    while i < rows.len() {
        let take = (rows.len() - i).min(batch);
        let mut flat = Vec::with_capacity(batch * width);
        for k in 0..batch {
            if k < take {
                flat.extend_from_slice(&rows[i + k]);
            } else {
                flat.extend(std::iter::repeat(0).take(width)); // PAD rows
            }
        }
        let tok = lit_i32(&flat, &[batch, width]);
        let mut inputs: Vec<&xla::Literal> = dev.lits.iter().collect();
        inputs.push(&tok);
        let out = rt.execute_refs(&name, &inputs)?;
        let nll = to_vec_f32(&out[0]);
        let cnt = to_vec_f32(&out[1]);
        for k in 0..take {
            total_nll += nll[k] as f64;
            total_cnt += cnt[k] as f64;
        }
        i += take;
    }
    Ok((total_nll, total_cnt))
}

/// Corpus perplexity: `exp(Σ nll / Σ tokens)` over a held-out shard.
pub fn perplexity(
    rt: &Runtime,
    params: &Params,
    shard: &TokenSet,
) -> Result<f64, RuntimeError> {
    let cfg = &params.cfg;
    let width = cfg.max_seq + 1;
    assert_eq!(shard.seq_len + 1, width, "shard width vs model seq");
    let dev = ParamsOnDevice::upload(rt, params)?;
    let rows: Vec<Vec<i32>> = (0..shard.rows).map(|i| shard.row(i).to_vec()).collect();
    let (nll, cnt) = nll_over_rows(rt, &cfg.name, &dev, &rows, width, rt.manifest.eval_batch)?;
    Ok((nll / cnt.max(1.0)).exp())
}

/// Score one task: length-normalized option likelihoods via
/// `nll(prompt ⧺ option) − nll(prompt)`.
pub fn task_accuracy(
    rt: &Runtime,
    params: &Params,
    dev: &ParamsOnDevice,
    items: &[TaskItem],
) -> Result<f64, RuntimeError> {
    let cfg = &params.cfg;
    let width = cfg.max_seq + 1;
    // Build all rows: per item, the prompt row then each option row.
    let mut rows: Vec<Vec<i32>> = Vec::new();
    let mut index: Vec<(usize, Vec<usize>)> = Vec::new(); // (prompt_row, option_rows)
    for it in items {
        let pad_to = |mut v: Vec<i32>| {
            assert!(v.len() <= width, "task row too long: {}", v.len());
            v.resize(width, 0);
            v
        };
        let p_row = rows.len();
        rows.push(pad_to(it.prompt.clone()));
        let mut opt_rows = Vec::with_capacity(it.options.len());
        for opt in &it.options {
            let mut full = it.prompt.clone();
            full.extend_from_slice(opt);
            opt_rows.push(rows.len());
            rows.push(pad_to(full));
        }
        index.push((p_row, opt_rows));
    }
    // Batch-evaluate all rows, keeping per-row sums.
    let name = format!("eval_nll_{}", cfg.name);
    let batch = rt.manifest.eval_batch;
    let mut row_nll = vec![0.0f64; rows.len()];
    let mut i = 0;
    while i < rows.len() {
        let take = (rows.len() - i).min(batch);
        let mut flat = Vec::with_capacity(batch * width);
        for k in 0..batch {
            if k < take {
                flat.extend_from_slice(&rows[i + k]);
            } else {
                flat.extend(std::iter::repeat(0).take(width));
            }
        }
        let tok = lit_i32(&flat, &[batch, width]);
        let mut inputs: Vec<&xla::Literal> = dev.lits.iter().collect();
        inputs.push(&tok);
        let out = rt.execute_refs(&name, &inputs)?;
        let nll = to_vec_f32(&out[0]);
        for k in 0..take {
            row_nll[i + k] = nll[k] as f64;
        }
        i += take;
    }
    // Pick argmin normalized option NLL.
    let mut correct = 0usize;
    for (it, (p_row, opt_rows)) in items.iter().zip(index.iter()) {
        let base = row_nll[*p_row];
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for (o, &r) in opt_rows.iter().enumerate() {
            let len = it.options[o].len().max(1) as f64;
            let score = (row_nll[r] - base) / len;
            if score < best_score {
                best_score = score;
                best = o;
            }
        }
        if best == it.answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len().max(1) as f64)
}

/// Full zero-shot sweep: (task, accuracy) plus the macro average.
pub fn zero_shot(
    rt: &Runtime,
    params: &Params,
    suites: &[(Task, Vec<TaskItem>)],
) -> Result<(Vec<(Task, f64)>, f64), RuntimeError> {
    let dev = ParamsOnDevice::upload(rt, params)?;
    let mut per_task = Vec::with_capacity(suites.len());
    for (task, items) in suites {
        let acc = task_accuracy(rt, params, &dev, items)?;
        per_task.push((*task, acc));
    }
    let avg = per_task.iter().map(|(_, a)| a).sum::<f64>() / per_task.len().max(1) as f64;
    Ok((per_task, avg))
}
