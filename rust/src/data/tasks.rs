//! Zero-shot task suites — the LM-Eval-Harness substitute.
//!
//! Seven multiple-choice suites matching the formats of the paper's
//! seven tasks (§III-A3). Every item is a `prompt` plus `options`
//! (token sequences); the scorer picks the option with the lowest
//! length-normalized NLL — exactly LM-Eval's `acc` metric for
//! multiple-choice.
//!
//! | suite | format | probes |
//! |---|---|---|
//! | arc_c  | 4-way completion, *distractors share the verb class pool* | hard selection |
//! | arc_e  | 4-way completion, distractors from the wrong class | easy selection |
//! | boolq  | statement ? yes/no | size-comparative truth |
//! | hellaswag | 4-way multi-token ending | continuation modelling |
//! | piqa   | 2-way object affordance | selectional class |
//! | rte    | premise + hypothesis ? yes/no | size transitivity (entailment) |
//! | winogrande | 2-way verb after PP attachment | long-range head agreement |

use super::grammar::{Grammar, EOS, QSEP};
use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct TaskItem {
    /// Shared context tokens.
    pub prompt: Vec<i32>,
    /// Candidate continuations (each scored as prompt ⧺ option).
    pub options: Vec<Vec<i32>>,
    /// Index of the correct option.
    pub answer: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    ArcC,
    ArcE,
    BoolQ,
    HellaSwag,
    Piqa,
    Rte,
    WinoGrande,
}

pub const ALL_TASKS: [Task; 7] = [
    Task::ArcC,
    Task::ArcE,
    Task::BoolQ,
    Task::HellaSwag,
    Task::Piqa,
    Task::Rte,
    Task::WinoGrande,
];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::ArcC => "ARC-C",
            Task::ArcE => "ARC-E",
            Task::BoolQ => "BoolQ",
            Task::HellaSwag => "HellaSwag",
            Task::Piqa => "PIQA",
            Task::Rte => "RTE",
            Task::WinoGrande => "WinoGrande",
        }
    }

    pub fn chance(&self) -> f64 {
        match self {
            Task::ArcC | Task::ArcE | Task::HellaSwag => 0.25,
            _ => 0.5,
        }
    }

    /// Deterministic item set for this task.
    pub fn generate(&self, g: &Grammar, n: usize, seed: u64) -> Vec<TaskItem> {
        let mut rng = Pcg64::seed_from_u64(seed ^ fxhash(self.name()));
        (0..n).map(|_| self.generate_one(g, &mut rng)).collect()
    }

    fn generate_one(&self, g: &Grammar, rng: &mut Pcg64) -> TaskItem {
        match self {
            // ---- ARC-style verb selection -------------------------------
            Task::ArcC => {
                // Hard: all four options are verbs; 1 class-correct, 3
                // class-wrong but *mixed from both verb pools* with one
                // near-miss (same class, also correct-class verb would be
                // ambiguous — so distractors are wrong-class only, but
                // the prompt includes a PP distractor of the other class
                // to pull the model off the head noun).
                let np = g.sample_np(rng);
                let mut np2 = g.sample_np(rng);
                // Force the PP noun to the *other* class.
                let mut guard = 0;
                while np2.animate == np.animate && guard < 10 {
                    np2 = g.sample_np(rng);
                    guard += 1;
                }
                np2.animate = !np.animate;
                np2.noun %= if np2.animate {
                    g.lex.animals.len()
                } else {
                    g.lex.objects.len()
                };
                let mut prompt = g.np_tokens(&np);
                prompt.push(g.id_prep(rng.below_usize(g.lex.preps.len())));
                prompt.extend(g.np_tokens(&np2));
                let correct = g.sample_verb(&np, rng);
                let mut options = vec![vec![correct]];
                while options.len() < 4 {
                    let w = g.sample_wrong_verb(&np, rng);
                    if !options.iter().any(|o| o[0] == w) {
                        options.push(vec![w]);
                    }
                }
                shuffle_answer_with(prompt, options, rng)
            }
            Task::ArcE => {
                // Easy: bare NP + verb choice, no distractor phrase.
                let np = g.sample_np(rng);
                let prompt = g.np_tokens(&np);
                let correct = g.sample_verb(&np, rng);
                let mut options = vec![vec![correct]];
                while options.len() < 4 {
                    let w = g.sample_wrong_verb(&np, rng);
                    if !options.iter().any(|o| o[0] == w) {
                        options.push(vec![w]);
                    }
                }
                shuffle_answer_with(prompt, options, rng)
            }
            // ---- BoolQ: comparative truth --------------------------------
            Task::BoolQ => {
                let mut a = g.sample_np(rng);
                let mut b = g.sample_np(rng);
                a.size = Some(rng.below_usize(g.lex.sizes.len()));
                loop {
                    let s = rng.below_usize(g.lex.sizes.len());
                    if Some(s) != a.size {
                        b.size = Some(s);
                        break;
                    }
                }
                let truthful = rng.bernoulli(0.5);
                let larger = a.size.unwrap() > b.size.unwrap();
                // claim "larger" or "smaller" to make the statement
                // true iff `truthful`.
                let claim_larger = if truthful { larger } else { !larger };
                let mut prompt = g.np_tokens(&a);
                prompt.push(g.id_is());
                prompt.push(if claim_larger {
                    g.id_larger()
                } else {
                    g.id_smaller()
                });
                prompt.push(g.id_than());
                prompt.extend(g.np_tokens(&b));
                prompt.push(QSEP);
                let options = vec![vec![g.id_yes()], vec![g.id_no()]];
                TaskItem {
                    prompt,
                    options,
                    answer: if truthful { 0 } else { 1 },
                }
            }
            // ---- HellaSwag: multi-token ending ---------------------------
            Task::HellaSwag => {
                let np = g.sample_np(rng);
                // Correct ending: "is <consistent-comp> than <NP>" with a
                // truthful comparative; distractors flip the comparative
                // or use a wrong-class verb + EOS filler.
                let mut a = np;
                if a.size.is_none() {
                    a.size = Some(rng.below_usize(g.lex.sizes.len()));
                }
                let mut b = g.sample_np(rng);
                loop {
                    let s = rng.below_usize(g.lex.sizes.len());
                    if Some(s) != a.size {
                        b.size = Some(s);
                        break;
                    }
                }
                // Re-derive the prompt with the explicit size.
                let prompt = g.np_tokens(&a);
                let larger = a.size.unwrap() > b.size.unwrap();
                let mk = |comp: i32, g: &Grammar, b: &super::grammar::NounPhrase| {
                    let mut e = vec![g.id_is(), comp, g.id_than()];
                    e.extend(g.np_tokens(b));
                    e.push(EOS);
                    e
                };
                let correct = mk(
                    if larger { g.id_larger() } else { g.id_smaller() },
                    g,
                    &b,
                );
                let flipped = mk(
                    if larger { g.id_smaller() } else { g.id_larger() },
                    g,
                    &b,
                );
                let wrong_verb = vec![g.sample_wrong_verb(&a, rng), EOS];
                let wrong_verb2 = vec![g.sample_wrong_verb(&a, rng), g.sample_wrong_verb(&a, rng)];
                let options = vec![correct, flipped, wrong_verb, wrong_verb2];
                shuffle_answer_with(prompt, options, rng)
            }
            // ---- PIQA: 2-way affordance ----------------------------------
            Task::Piqa => {
                let np = g.sample_np(rng);
                let prompt = g.np_tokens(&np);
                let options = vec![
                    vec![g.sample_verb(&np, rng)],
                    vec![g.sample_wrong_verb(&np, rng)],
                ];
                shuffle_answer_with(prompt, options, rng)
            }
            // ---- RTE: size transitivity ----------------------------------
            Task::Rte => {
                // premise: A larger than B . B larger than C
                // hypothesis: A larger than C ? (entailed) or C larger
                // than A ? (contradicted).
                let mut sizes: Vec<usize> = (0..g.lex.sizes.len()).collect();
                rng.shuffle(&mut sizes);
                let (sa, sb, sc) = (sizes[0].max(sizes[1]).max(sizes[2]),
                                    med3(sizes[0], sizes[1], sizes[2]),
                                    sizes[0].min(sizes[1]).min(sizes[2]));
                let mk_np = |size: usize, g: &Grammar, rng: &mut Pcg64| {
                    let mut np = g.sample_np(rng);
                    np.size = Some(size);
                    np.color = None;
                    np
                };
                let a = mk_np(sa, g, rng);
                let b = mk_np(sb, g, rng);
                let c = mk_np(sc, g, rng);
                let mut prompt = Vec::new();
                prompt.extend(g.np_tokens(&a));
                prompt.push(g.id_is());
                prompt.push(g.id_larger());
                prompt.push(g.id_than());
                prompt.extend(g.np_tokens(&b));
                prompt.push(EOS);
                prompt.extend(g.np_tokens(&b));
                prompt.push(g.id_is());
                prompt.push(g.id_larger());
                prompt.push(g.id_than());
                prompt.extend(g.np_tokens(&c));
                prompt.push(EOS);
                let entailed = rng.bernoulli(0.5);
                if entailed {
                    prompt.extend(g.np_tokens(&a));
                } else {
                    prompt.extend(g.np_tokens(&c));
                }
                prompt.push(g.id_is());
                prompt.push(g.id_larger());
                prompt.push(g.id_than());
                if entailed {
                    prompt.extend(g.np_tokens(&c));
                } else {
                    prompt.extend(g.np_tokens(&a));
                }
                prompt.push(QSEP);
                TaskItem {
                    prompt,
                    options: vec![vec![g.id_yes()], vec![g.id_no()]],
                    answer: if entailed { 0 } else { 1 },
                }
            }
            // ---- WinoGrande: PP-attachment head agreement ----------------
            Task::WinoGrande => {
                let np = g.sample_np(rng);
                let mut np2 = g.sample_np(rng);
                np2.animate = !np.animate;
                np2.noun %= if np2.animate {
                    g.lex.animals.len()
                } else {
                    g.lex.objects.len()
                };
                let mut prompt = g.np_tokens(&np);
                prompt.push(g.id_prep(rng.below_usize(g.lex.preps.len())));
                prompt.extend(g.np_tokens(&np2));
                // Head-correct verb vs PP-noun-correct verb: the model
                // must attach the verb to the head noun.
                let options = vec![
                    vec![g.sample_verb(&np, rng)],
                    vec![g.sample_verb(&np2, rng)],
                ];
                shuffle_answer_with(prompt, options, rng)
            }
        }
    }
}

fn med3(a: usize, b: usize, c: usize) -> usize {
    a.max(b).min(a.max(c)).min(b.max(c))
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Shuffle options (answer is index 0 on input) and track the answer.
fn shuffle_answer_with(prompt: Vec<i32>, mut options: Vec<Vec<i32>>, rng: &mut Pcg64) -> TaskItem {
    let n = options.len();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let answer = order.iter().position(|&o| o == 0).unwrap();
    let mut shuffled = Vec::with_capacity(n);
    for &o in &order {
        shuffled.push(std::mem::take(&mut options[o]));
    }
    TaskItem {
        prompt,
        options: shuffled,
        answer,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_items() {
        let g = Grammar::standard();
        for task in ALL_TASKS {
            let items = task.generate(&g, 50, 99);
            assert_eq!(items.len(), 50);
            for it in &items {
                let n_opts = it.options.len();
                assert!(n_opts == 2 || n_opts == 4, "{}", task.name());
                assert!(it.answer < n_opts);
                assert!(it.options.iter().all(|o| !o.is_empty()));
                // Prompt+option fits the smallest model context.
                let max_opt = it.options.iter().map(|o| o.len()).max().unwrap();
                assert!(
                    it.prompt.len() + max_opt <= 48,
                    "{} item too long: {}",
                    task.name(),
                    it.prompt.len() + max_opt
                );
            }
        }
    }

    #[test]
    fn answers_are_distributed() {
        // Shuffling must not leave the answer always at index 0.
        let g = Grammar::standard();
        for task in ALL_TASKS {
            let items = task.generate(&g, 100, 7);
            let at0 = items.iter().filter(|i| i.answer == 0).count();
            assert!(at0 < 90, "{}: answer stuck at 0 ({at0}/100)", task.name());
        }
    }

    #[test]
    fn deterministic_generation() {
        let g = Grammar::standard();
        for task in ALL_TASKS {
            let a = task.generate(&g, 10, 5);
            let b = task.generate(&g, 10, 5);
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.prompt, y.prompt);
                assert_eq!(x.answer, y.answer);
            }
        }
    }

    #[test]
    fn boolq_labels_match_semantics() {
        let g = Grammar::standard();
        let items = Task::BoolQ.generate(&g, 100, 11);
        for it in &items {
            // Recover the claim and sizes from the prompt tokens.
            let lo = g.id_size(0);
            let hi = g.id_size(g.lex.sizes.len() - 1);
            let sizes: Vec<usize> = it
                .prompt
                .iter()
                .filter(|&&t| t >= lo && t <= hi)
                .map(|&t| (t - lo) as usize)
                .collect();
            assert!(sizes.len() >= 2);
            let claim_larger = it.prompt.contains(&g.id_larger());
            let truth = if claim_larger {
                sizes[0] > sizes[1]
            } else {
                sizes[0] < sizes[1]
            };
            assert_eq!(it.answer == 0, truth);
        }
    }

    #[test]
    fn distinct_tasks_have_distinct_items() {
        let g = Grammar::standard();
        let a = Task::ArcC.generate(&g, 5, 3);
        let b = Task::ArcE.generate(&g, 5, 3);
        assert_ne!(a[0].prompt, b[0].prompt);
    }
}
