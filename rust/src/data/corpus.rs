//! Corpus construction: train / validation / calibration token
//! streams built from the grammar.
//!
//! * **Train stream** — sentences packed back-to-back (EOS-separated)
//!   into fixed-length rows of `seq_len + 1` tokens (input+target
//!   overlap), the standard LM packing. Deterministic from a seed.
//! * **Validation shard** — a held-out stream (different seed space)
//!   used for the WikiText-2-style perplexity number.
//! * **Calibration set** — `n_calib` rows sampled like SparseGPT's
//!   128 × 2048 C4 sample (paper §III-A2), seed-disjoint from both.

use super::grammar::Grammar;
use crate::util::rng::Pcg64;

/// A packed token dataset: `rows × (seq_len + 1)` i32 matrix.
#[derive(Debug, Clone)]
pub struct TokenSet {
    pub seq_len: usize,
    /// rows × (seq_len+1), row-major.
    pub data: Vec<i32>,
    pub rows: usize,
}

impl TokenSet {
    pub fn row(&self, i: usize) -> &[i32] {
        let w = self.seq_len + 1;
        &self.data[i * w..(i + 1) * w]
    }

    /// Gather a batch of rows (wrapping) into a contiguous buffer.
    pub fn batch(&self, start: usize, bsz: usize) -> Vec<i32> {
        let w = self.seq_len + 1;
        let mut out = Vec::with_capacity(bsz * w);
        for k in 0..bsz {
            out.extend_from_slice(self.row((start + k) % self.rows));
        }
        out
    }

    pub fn token_count(&self) -> usize {
        self.rows * self.seq_len
    }

    /// All rows as owned vectors — the shape the eval harness and the
    /// benches consume (`eval::native::batched_nll` scores `&[Vec<i32>]`).
    pub fn to_rows(&self) -> Vec<Vec<i32>> {
        (0..self.rows).map(|i| self.row(i).to_vec()).collect()
    }

    /// Deterministic synthetic rows cycling through the non-special
    /// token range `[4, vocab)` — grammar-free calibration input for
    /// tests, benches, and examples (the compression pipeline only
    /// needs *some* in-vocab activations, not fluent text).
    pub fn synthetic(rows: usize, seq_len: usize, vocab: usize) -> TokenSet {
        assert!(vocab > 4, "vocab {vocab} must exceed the 4 special tokens");
        let w = seq_len + 1;
        TokenSet {
            seq_len,
            rows,
            data: (0..rows * w).map(|i| 4 + (i * 7 % (vocab - 4)) as i32).collect(),
        }
    }
}

/// Pack grammar sentences into fixed rows.
pub fn pack_stream(g: &Grammar, rng: &mut Pcg64, rows: usize, seq_len: usize) -> TokenSet {
    let w = seq_len + 1;
    let mut data = Vec::with_capacity(rows * w);
    let mut buf: Vec<i32> = Vec::with_capacity(w * 2);
    while data.len() < rows * w {
        while buf.len() < w {
            buf.extend(g.sample_sentence(rng));
        }
        data.extend_from_slice(&buf[..w]);
        // Overlap-free packing: drop what we consumed, keep remainder.
        buf.drain(..w);
    }
    TokenSet {
        seq_len,
        data,
        rows,
    }
}

/// The three standard splits with disjoint seed streams.
pub struct CorpusBundle {
    pub train: TokenSet,
    pub valid: TokenSet,
    pub calib: TokenSet,
}

/// Seeds are derived from `seed` with fixed tags so splits never
/// overlap even if the grammar evolves.
pub fn build_corpus(
    g: &Grammar,
    seed: u64,
    train_rows: usize,
    valid_rows: usize,
    calib_rows: usize,
    seq_len: usize,
) -> CorpusBundle {
    let mut root = Pcg64::seed_from_u64(seed);
    let mut r_train = root.fork(1);
    let mut r_valid = root.fork(2);
    let mut r_calib = root.fork(3);
    CorpusBundle {
        train: pack_stream(g, &mut r_train, train_rows, seq_len),
        valid: pack_stream(g, &mut r_valid, valid_rows, seq_len),
        calib: pack_stream(g, &mut r_calib, calib_rows, seq_len),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::grammar::{EOS, PAD};

    #[test]
    fn rows_have_exact_width_and_no_pad() {
        let g = Grammar::standard();
        let mut rng = Pcg64::seed_from_u64(300);
        let ts = pack_stream(&g, &mut rng, 10, 32);
        assert_eq!(ts.rows, 10);
        assert_eq!(ts.data.len(), 10 * 33);
        assert!(ts.data.iter().all(|&t| t != PAD));
        assert!(ts.data.contains(&EOS));
    }

    #[test]
    fn batch_wraps_around() {
        let g = Grammar::standard();
        let mut rng = Pcg64::seed_from_u64(301);
        let ts = pack_stream(&g, &mut rng, 3, 8);
        let b = ts.batch(2, 2);
        assert_eq!(&b[..9], ts.row(2));
        assert_eq!(&b[9..], ts.row(0));
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let g = Grammar::standard();
        let c = build_corpus(&g, 42, 5, 5, 5, 24);
        assert_ne!(c.train.data, c.valid.data);
        assert_ne!(c.valid.data, c.calib.data);
        // Determinism.
        let c2 = build_corpus(&g, 42, 5, 5, 5, 24);
        assert_eq!(c.train.data, c2.train.data);
    }

    #[test]
    fn token_count() {
        let g = Grammar::standard();
        let mut rng = Pcg64::seed_from_u64(302);
        let ts = pack_stream(&g, &mut rng, 7, 16);
        assert_eq!(ts.token_count(), 7 * 16);
    }

    #[test]
    fn to_rows_matches_row_views() {
        let ts = TokenSet::synthetic(3, 8, 16);
        let rows = ts.to_rows();
        assert_eq!(rows.len(), 3);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.as_slice(), ts.row(i));
        }
    }
}
