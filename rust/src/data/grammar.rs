//! The synthetic language ("Synthia") — the C4/WikiText-2 substitute.
//!
//! A probabilistic grammar with *learnable*, *probeable* regularities:
//!
//! * **Selectional classes**: animate nouns take animate verbs
//!   (`sleeps`, `runs`, …); inanimate nouns take object verbs
//!   (`falls`, `shines`, …). A small transformer learns this quickly;
//!   pruning damage shows up as class confusions — exactly what the
//!   zero-shot suites probe.
//! * **Size hierarchy**: size adjectives are totally ordered
//!   (`tiny < small < big < huge`); generated comparatives are always
//!   consistent with the order (`the huge cat is larger than the tiny
//!   ball`), giving BoolQ/RTE-style truth labels for free.
//! * **Zipf lexicon**: content words are drawn Zipf(1.1) like natural
//!   text, so calibration activations have realistic skew.
//!
//! Sentences (token-id sequences) are emitted directly — the
//! word-level tokenizer is the grammar's own lexicon
//! ([`crate::data::tokenizer`]).

use crate::util::rng::{Pcg64, Zipf};

/// Special token ids (match `python/compile/model.py::PAD_ID`).
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const QSEP: i32 = 3; // the "?" separator used by yes/no tasks

/// Word classes of the lexicon (ids are assigned contiguously).
#[derive(Debug, Clone)]
pub struct Lexicon {
    pub determiners: Vec<String>,
    /// Size adjectives in *ascending* size order.
    pub sizes: Vec<String>,
    pub colors: Vec<String>,
    pub animals: Vec<String>,
    pub objects: Vec<String>,
    pub animate_verbs: Vec<String>,
    pub object_verbs: Vec<String>,
    pub preps: Vec<String>,
    pub comp_larger: String,
    pub comp_smaller: String,
    pub than: String,
    pub is: String,
    pub yes: String,
    pub no: String,
}

impl Lexicon {
    pub fn standard() -> Lexicon {
        let w = |s: &[&str]| s.iter().map(|x| x.to_string()).collect::<Vec<_>>();
        Lexicon {
            determiners: w(&["the", "a"]),
            sizes: w(&["tiny", "small", "big", "huge"]),
            colors: w(&[
                "red", "blue", "green", "gray", "black", "white", "amber", "violet",
            ]),
            animals: w(&[
                "cat", "dog", "fox", "owl", "hen", "bat", "ant", "bee", "elk", "eel",
                "ram", "sow", "colt", "crow", "dove", "frog", "goat", "hare", "lark",
                "lynx", "mole", "moth", "mule", "newt", "pike", "pony", "seal", "swan",
                "toad", "wolf", "wren", "yak",
            ]),
            objects: w(&[
                "cube", "ball", "lamp", "door", "gear", "coin", "ring", "vase", "bell",
                "drum", "flag", "fork", "harp", "hook", "kite", "knob", "lens", "mast",
                "nail", "oar", "pipe", "plow", "pump", "rail", "rope", "sail", "shed",
                "sled", "tile", "vane", "wick", "zinc",
            ]),
            animate_verbs: w(&[
                "sleeps", "runs", "jumps", "hides", "waits", "barks", "hunts", "rests",
            ]),
            object_verbs: w(&[
                "falls", "shines", "rolls", "cracks", "rattles", "spins", "rusts",
                "gleams",
            ]),
            preps: w(&["near", "under", "beside"]),
            comp_larger: "larger".into(),
            comp_smaller: "smaller".into(),
            than: "than".into(),
            is: "is".into(),
            yes: "yes".into(),
            no: "no".into(),
        }
    }

    /// All words in id order (first id = 4, after the specials).
    pub fn words(&self) -> Vec<String> {
        let mut out = Vec::new();
        out.extend(self.determiners.clone());
        out.extend(self.sizes.clone());
        out.extend(self.colors.clone());
        out.extend(self.animals.clone());
        out.extend(self.objects.clone());
        out.extend(self.animate_verbs.clone());
        out.extend(self.object_verbs.clone());
        out.extend(self.preps.clone());
        out.push(self.comp_larger.clone());
        out.push(self.comp_smaller.clone());
        out.push(self.than.clone());
        out.push(self.is.clone());
        out.push(self.yes.clone());
        out.push(self.no.clone());
        out
    }
}

/// A noun phrase with its semantic attributes (used by task labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NounPhrase {
    pub det: usize,
    pub size: Option<usize>,
    pub color: Option<usize>,
    pub noun: usize,
    pub animate: bool,
}

/// The grammar: holds the lexicon, token-id mapping, and samplers.
#[derive(Debug, Clone)]
pub struct Grammar {
    pub lex: Lexicon,
    zipf_animal: Zipf,
    zipf_object: Zipf,
    zipf_color: Zipf,
}

impl Grammar {
    pub fn standard() -> Grammar {
        let lex = Lexicon::standard();
        Grammar {
            zipf_animal: Zipf::new(lex.animals.len(), 1.1),
            zipf_object: Zipf::new(lex.objects.len(), 1.1),
            zipf_color: Zipf::new(lex.colors.len(), 1.1),
            lex,
        }
    }

    // --- token-id helpers (ids are positions in Lexicon::words + 4) ----

    fn base(&self) -> [usize; 9] {
        // offsets of each class within words()
        let l = &self.lex;
        let det = 0;
        let size = det + l.determiners.len();
        let color = size + l.sizes.len();
        let animal = color + l.colors.len();
        let object = animal + l.animals.len();
        let averb = object + l.objects.len();
        let overb = averb + l.animate_verbs.len();
        let prep = overb + l.object_verbs.len();
        let misc = prep + l.preps.len();
        [det, size, color, animal, object, averb, overb, prep, misc]
    }

    pub fn id_det(&self, i: usize) -> i32 {
        (4 + self.base()[0] + i) as i32
    }
    pub fn id_size(&self, i: usize) -> i32 {
        (4 + self.base()[1] + i) as i32
    }
    pub fn id_color(&self, i: usize) -> i32 {
        (4 + self.base()[2] + i) as i32
    }
    pub fn id_animal(&self, i: usize) -> i32 {
        (4 + self.base()[3] + i) as i32
    }
    pub fn id_object(&self, i: usize) -> i32 {
        (4 + self.base()[4] + i) as i32
    }
    pub fn id_averb(&self, i: usize) -> i32 {
        (4 + self.base()[5] + i) as i32
    }
    pub fn id_overb(&self, i: usize) -> i32 {
        (4 + self.base()[6] + i) as i32
    }
    pub fn id_prep(&self, i: usize) -> i32 {
        (4 + self.base()[7] + i) as i32
    }
    pub fn id_larger(&self) -> i32 {
        (4 + self.base()[8]) as i32
    }
    pub fn id_smaller(&self) -> i32 {
        (4 + self.base()[8] + 1) as i32
    }
    pub fn id_than(&self) -> i32 {
        (4 + self.base()[8] + 2) as i32
    }
    pub fn id_is(&self) -> i32 {
        (4 + self.base()[8] + 3) as i32
    }
    pub fn id_yes(&self) -> i32 {
        (4 + self.base()[8] + 4) as i32
    }
    pub fn id_no(&self) -> i32 {
        (4 + self.base()[8] + 5) as i32
    }

    /// Total vocabulary size including specials.
    pub fn vocab(&self) -> usize {
        4 + self.lex.words().len()
    }

    // --- sampling -------------------------------------------------------

    pub fn sample_np(&self, rng: &mut Pcg64) -> NounPhrase {
        let animate = rng.bernoulli(0.5);
        let noun = if animate {
            self.zipf_animal.sample(rng)
        } else {
            self.zipf_object.sample(rng)
        };
        NounPhrase {
            det: rng.below_usize(self.lex.determiners.len()),
            size: if rng.bernoulli(0.55) {
                Some(rng.below_usize(self.lex.sizes.len()))
            } else {
                None
            },
            color: if rng.bernoulli(0.45) {
                Some(self.zipf_color.sample(rng))
            } else {
                None
            },
            noun,
            animate,
        }
    }

    pub fn np_tokens(&self, np: &NounPhrase) -> Vec<i32> {
        let mut t = vec![self.id_det(np.det)];
        if let Some(s) = np.size {
            t.push(self.id_size(s));
        }
        if let Some(c) = np.color {
            t.push(self.id_color(c));
        }
        t.push(if np.animate {
            self.id_animal(np.noun)
        } else {
            self.id_object(np.noun)
        });
        t
    }

    /// The class-correct verb for a noun phrase.
    pub fn sample_verb(&self, np: &NounPhrase, rng: &mut Pcg64) -> i32 {
        if np.animate {
            self.id_averb(rng.below_usize(self.lex.animate_verbs.len()))
        } else {
            self.id_overb(rng.below_usize(self.lex.object_verbs.len()))
        }
    }

    /// A *wrong-class* verb (task distractors).
    pub fn sample_wrong_verb(&self, np: &NounPhrase, rng: &mut Pcg64) -> i32 {
        if np.animate {
            self.id_overb(rng.below_usize(self.lex.object_verbs.len()))
        } else {
            self.id_averb(rng.below_usize(self.lex.animate_verbs.len()))
        }
    }

    /// One declarative sentence; comparatives are always truth-
    /// consistent with the size hierarchy.
    pub fn sample_sentence(&self, rng: &mut Pcg64) -> Vec<i32> {
        let mut toks = Vec::with_capacity(12);
        let np = self.sample_np(rng);
        toks.extend(self.np_tokens(&np));
        match rng.below(10) {
            // 40%: simple intransitive with class agreement.
            0..=3 => toks.push(self.sample_verb(&np, rng)),
            // 20%: PP attachment then *head-noun* agreement (the
            // Winogrande-style long-range dependency).
            4..=5 => {
                toks.push(self.id_prep(rng.below_usize(self.lex.preps.len())));
                let np2 = self.sample_np(rng);
                toks.extend(self.np_tokens(&np2));
                toks.push(self.sample_verb(&np, rng));
            }
            // 40%: size comparative, always truthful.
            _ => {
                // Force both sides to carry explicit sizes.
                let mut a = np;
                if a.size.is_none() {
                    a.size = Some(rng.below_usize(self.lex.sizes.len()));
                    toks.clear();
                    toks.extend(self.np_tokens(&a));
                }
                let mut b = self.sample_np(rng);
                loop {
                    b.size = Some(rng.below_usize(self.lex.sizes.len()));
                    if b.size != a.size {
                        break;
                    }
                }
                toks.push(self.id_is());
                let (sa, sb) = (a.size.unwrap(), b.size.unwrap());
                toks.push(if sa > sb {
                    self.id_larger()
                } else {
                    self.id_smaller()
                });
                toks.push(self.id_than());
                toks.extend(self.np_tokens(&b));
            }
        }
        toks.push(EOS);
        toks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_fits_model_configs() {
        let g = Grammar::standard();
        assert!(g.vocab() <= 512, "vocab {} must fit the smallest model", g.vocab());
        assert!(g.vocab() > 100);
    }

    #[test]
    fn token_ids_are_disjoint_and_in_range() {
        let g = Grammar::standard();
        let words = g.lex.words();
        let mut ids = vec![
            g.id_det(0),
            g.id_size(0),
            g.id_color(0),
            g.id_animal(0),
            g.id_object(0),
            g.id_averb(0),
            g.id_overb(0),
            g.id_prep(0),
            g.id_larger(),
            g.id_smaller(),
            g.id_than(),
            g.id_is(),
            g.id_yes(),
            g.id_no(),
        ];
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 14);
        assert!(ids.iter().all(|&i| i >= 4 && (i as usize) < 4 + words.len()));
        // id→word mapping round-trips via position.
        assert_eq!(words[(g.id_yes() - 4) as usize], "yes");
        assert_eq!(words[(g.id_larger() - 4) as usize], "larger");
    }

    #[test]
    fn sentences_never_contain_pad_and_end_with_eos() {
        let g = Grammar::standard();
        let mut rng = Pcg64::seed_from_u64(200);
        for _ in 0..500 {
            let s = g.sample_sentence(&mut rng);
            assert!(!s.is_empty());
            assert_eq!(*s.last().unwrap(), EOS);
            assert!(s.iter().all(|&t| t != PAD && t != BOS));
            assert!(s.iter().all(|&t| (t as usize) < g.vocab()));
        }
    }

    #[test]
    fn comparatives_are_truthful() {
        let g = Grammar::standard();
        let mut rng = Pcg64::seed_from_u64(201);
        let mut seen = 0;
        for _ in 0..2000 {
            let s = g.sample_sentence(&mut rng);
            if let Some(pos) = s.iter().position(|&t| t == g.id_larger() || t == g.id_smaller()) {
                seen += 1;
                // Extract the size adjectives on both sides.
                let size_ids: Vec<(usize, usize)> = s
                    .iter()
                    .enumerate()
                    .filter_map(|(i, &t)| {
                        let lo = g.id_size(0);
                        let hi = g.id_size(g.lex.sizes.len() - 1);
                        if t >= lo && t <= hi {
                            Some((i, (t - lo) as usize))
                        } else {
                            None
                        }
                    })
                    .collect();
                assert!(size_ids.len() >= 2, "comparative without two sizes: {s:?}");
                let left = size_ids.iter().filter(|(i, _)| *i < pos).last().unwrap().1;
                let right = size_ids.iter().find(|(i, _)| *i > pos).unwrap().1;
                if s[pos] == g.id_larger() {
                    assert!(left > right, "untruthful larger: {s:?}");
                } else {
                    assert!(left < right, "untruthful smaller: {s:?}");
                }
            }
        }
        assert!(seen > 300, "comparatives should be common, saw {seen}");
    }

    #[test]
    fn verb_agreement_holds() {
        let g = Grammar::standard();
        let mut rng = Pcg64::seed_from_u64(202);
        for _ in 0..200 {
            let np = g.sample_np(&mut rng);
            let v = g.sample_verb(&np, &mut rng);
            let averb_range = g.id_averb(0)..=g.id_averb(g.lex.animate_verbs.len() - 1);
            if np.animate {
                assert!(averb_range.contains(&v));
            } else {
                assert!(!averb_range.contains(&v));
            }
            let wrong = g.sample_wrong_verb(&np, &mut rng);
            assert_ne!(averb_range.contains(&v), averb_range.contains(&wrong));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = Grammar::standard();
        let mut a = Pcg64::seed_from_u64(7);
        let mut b = Pcg64::seed_from_u64(7);
        for _ in 0..50 {
            assert_eq!(g.sample_sentence(&mut a), g.sample_sentence(&mut b));
        }
    }
}
