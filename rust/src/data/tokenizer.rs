//! Word-level tokenizer over the grammar lexicon.
//!
//! The synthetic language is closed-vocabulary, so the tokenizer is a
//! deterministic bijection word ↔ id (specials at 0..4). Used by the
//! serving example to decode generations and by debug logging; the
//! data pipeline works in ids end-to-end.

use super::grammar::Grammar;
#[cfg(test)]
use super::grammar::{BOS, EOS, PAD, QSEP};
use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    id_to_word: Vec<String>,
    word_to_id: HashMap<String, i32>,
}

impl Tokenizer {
    pub fn from_grammar(g: &Grammar) -> Tokenizer {
        let mut id_to_word = vec![
            "<pad>".to_string(),
            "<bos>".to_string(),
            "<eos>".to_string(),
            "?".to_string(),
        ];
        id_to_word.extend(g.lex.words());
        let word_to_id = id_to_word
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Tokenizer {
            id_to_word,
            word_to_id,
        }
    }

    pub fn vocab_size(&self) -> usize {
        self.id_to_word.len()
    }

    pub fn decode_one(&self, id: i32) -> &str {
        self.id_to_word
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<unk>")
    }

    /// Space-joined decode, specials rendered symbolically.
    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&i| self.decode_one(i))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Encode a space-separated string; unknown words error.
    pub fn encode(&self, text: &str) -> Result<Vec<i32>, String> {
        text.split_whitespace()
            .map(|w| {
                self.word_to_id
                    .get(w)
                    .copied()
                    .ok_or_else(|| format!("unknown word '{w}'"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_sentences() {
        let g = Grammar::standard();
        let tok = Tokenizer::from_grammar(&g);
        let mut rng = Pcg64::seed_from_u64(400);
        for _ in 0..100 {
            let s = g.sample_sentence(&mut rng);
            let text = tok.decode(&s);
            let back = tok.encode(&text).unwrap();
            assert_eq!(back, s, "{text}");
        }
    }

    #[test]
    fn specials_have_fixed_ids() {
        let g = Grammar::standard();
        let tok = Tokenizer::from_grammar(&g);
        assert_eq!(tok.decode_one(PAD), "<pad>");
        assert_eq!(tok.decode_one(BOS), "<bos>");
        assert_eq!(tok.decode_one(EOS), "<eos>");
        assert_eq!(tok.decode_one(QSEP), "?");
        assert_eq!(tok.vocab_size(), g.vocab());
    }

    #[test]
    fn unknown_word_errors() {
        let g = Grammar::standard();
        let tok = Tokenizer::from_grammar(&g);
        assert!(tok.encode("the frobnicator").is_err());
    }
}
