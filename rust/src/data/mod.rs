//! Data substrate: the synthetic grammar language, word tokenizer,
//! corpus packing (train/valid/calibration splits), and the seven
//! zero-shot task suites. Substitutes for C4 / WikiText-2 /
//! LM-Eval-Harness in this offline reproduction (DESIGN.md §2).

pub mod corpus;
pub mod grammar;
pub mod tasks;
pub mod tokenizer;

pub use corpus::{build_corpus, pack_stream, CorpusBundle, TokenSet};
pub use grammar::{Grammar, Lexicon, NounPhrase, BOS, EOS, PAD, QSEP};
pub use tasks::{Task, TaskItem, ALL_TASKS};
pub use tokenizer::Tokenizer;
