//! # SLaB — Sparse-Lowrank-Binary decomposition for efficient LLMs
//!
//! Rust implementation of *SLaB: Sparse-Lowrank-Binary Decomposition
//! for Efficient Large Language Models* (Li, Ma & Kang, 2026): every
//! linear-layer weight is replaced, one-shot and training-free, by
//! `W ≈ W_S + W_L ⊙ W_B` — a sparse matrix, a rank-1 low-rank matrix,
//! and a 1-bit sign matrix.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//! Pallas kernels (L1) and the JAX model (L2) are AOT-compiled to HLO
//! text by `python/compile/` and executed from Rust via the PJRT C API
//! (`runtime`). Python never runs at request time.
//!
//! Module map (see `DESIGN.md` for the full inventory):
//!
//! * [`util`] — RNG / JSON / CLI / bench / property-test / thread-pool
//!   substrates (the offline crate set has none of these).
//! * [`tensor`] — dense f32 matrices, matmul, Cholesky, truncated SVD,
//!   checkpoint I/O.
//! * [`sparse`] — CSR and 2:4 / 4:8 semi-structured formats for `W_S`.
//! * [`binary`] — bitpacked ±1 matrices for `W_B`.
//! * [`slab`] — the decomposition itself: scores, group thresholding,
//!   Algorithm 1, compression-ratio accounting, packed layers.
//! * [`baselines`] — magnitude, Wanda, SparseGPT (OBS), naive
//!   sparse+low-rank.
//! * [`model`] — Llama-architecture configs, parameters, and the
//!   native packed-serving model (`SlabModel`).
//! * [`runtime`] — PJRT client / artifact registry / typed execution.
//! * [`data`] — synthetic grammar corpus, tokenizer, calibration sets.
//! * [`train`] — drives the AOT train-step artifact.
//! * [`eval`] — perplexity + zero-shot suites.
//! * [`coordinator`] — layer-wise pruning pipeline + serving router
//!   with two engines (AOT artifacts / native packed).
//! * [`report`] — paper-style table rendering.

pub mod baselines;
pub mod binary;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod model;
pub mod report;
pub mod runtime;
pub mod train;
pub mod slab;
pub mod sparse;
pub mod tensor;
pub mod util;
