//! # SLaB — Sparse-Lowrank-Binary decomposition for efficient LLMs
//!
//! Rust implementation of *SLaB: Sparse-Lowrank-Binary Decomposition
//! for Efficient Large Language Models* (Li, Ma & Kang, 2026): every
//! linear-layer weight is replaced, one-shot and training-free, by
//! `W ≈ W_S + W_L ⊙ W_B` — a sparse matrix, a rank-1 low-rank matrix,
//! and a 1-bit sign matrix.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//! Pallas kernels (L1) and the JAX model (L2) are AOT-compiled to HLO
//! text by `python/compile/` and executed from Rust via the PJRT C API
//! (`runtime`). Python never runs at request time.
//!
//! Module map (see `DESIGN.md` for the full inventory):
//!
//! * [`util`] — RNG / JSON / CLI / bench / property-test / thread-pool
//!   substrates (the offline crate set has none of these).
//! * [`tensor`] — dense f32 matrices, matmul, Cholesky, truncated SVD,
//!   checkpoint I/O.
//! * [`sparse`] — CSR and 2:4 / 4:8 semi-structured formats for `W_S`.
//! * [`binary`] — bitpacked ±1 matrices for `W_B`.
//! * [`slab`] — the decomposition itself: scores, group thresholding,
//!   Algorithm 1, compression-ratio accounting, packed layers.
//! * [`baselines`] — magnitude, Wanda, SparseGPT (OBS), naive
//!   sparse+low-rank.
//! * [`model`] — Llama-architecture configs, parameters, and the
//!   native packed-serving model (`SlabModel`).
//! * [`runtime`] — PJRT client / artifact registry / typed execution.
//! * [`data`] — synthetic grammar corpus, tokenizer, calibration sets.
//! * [`train`] — drives the AOT train-step artifact.
//! * [`eval`] — perplexity + zero-shot suites behind two engines:
//!   the XLA `eval_nll` artifact path and the artifact-free
//!   `eval::native` harness over `SlabModel` (row-parallel,
//!   bit-identical to serial).
//! * [`coordinator`] — staged compression pipeline (capture →
//!   decompose → emit behind one `CompressJob`) + serving router
//!   with three engines (AOT artifacts / native packed / native
//!   packed behind the continuous-batching scheduler).
//! * [`report`] — paper-style table rendering.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

pub mod baselines;
pub mod binary;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod model;
pub mod report;
pub mod runtime;
pub mod train;
pub mod slab;
pub mod sparse;
pub mod tensor;
pub mod util;
