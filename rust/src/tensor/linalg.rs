//! Dense linear algebra: Cholesky factorization + triangular solves
//! (SparseGPT's OBS updates need `H⁻¹` via Cholesky), and truncated
//! SVD via block power iteration (SLaB's rank-1/rank-r low-rank term,
//! Fig 1/Fig 3 sweeps).
//!
//! Everything accumulates in f64 internally; matrices stay f32 at the
//! interface to match the rest of the stack.

use super::mat::Mat;
use super::ops::{matmul_bt, matvec, matvec_t, norm2};
use crate::util::rng::Pcg64;

#[derive(Debug, thiserror::Error)]
pub enum LinalgError {
    #[error("matrix not positive definite at pivot {0} (value {1})")]
    NotPositiveDefinite(usize, f64),
    #[error("dimension mismatch: {0}")]
    Dim(String),
}

/// Lower-triangular Cholesky factor L with A = L·Lᵀ.
pub fn cholesky(a: &Mat) -> Result<Mat, LinalgError> {
    if a.rows != a.cols {
        return Err(LinalgError::Dim(format!("{}x{} not square", a.rows, a.cols)));
    }
    let n = a.rows;
    // f64 working copy.
    let mut l = vec![0.0f64; n * n];
    for j in 0..n {
        // Diagonal.
        let mut d = a.at(j, j) as f64;
        for k in 0..j {
            d -= l[j * n + k] * l[j * n + k];
        }
        if d <= 0.0 {
            return Err(LinalgError::NotPositiveDefinite(j, d));
        }
        let dj = d.sqrt();
        l[j * n + j] = dj;
        // Column below diagonal.
        for i in (j + 1)..n {
            let mut s = a.at(i, j) as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            l[i * n + j] = s / dj;
        }
    }
    Ok(Mat::from_vec(n, n, l.into_iter().map(|v| v as f32).collect()))
}

/// Solve L·y = b for lower-triangular L.
pub fn solve_lower(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        let row = l.row(i);
        for k in 0..i {
            s -= row[k] as f64 * y[k];
        }
        y[i] = s / row[i] as f64;
    }
    y.into_iter().map(|v| v as f32).collect()
}

/// Solve Lᵀ·x = y for lower-triangular L.
pub fn solve_lower_t(l: &Mat, y: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i] as f64;
        for k in (i + 1)..n {
            s -= l.at(k, i) as f64 * x[k];
        }
        x[i] = s / l.at(i, i) as f64;
    }
    x.into_iter().map(|v| v as f32).collect()
}

/// Full inverse via Cholesky: A⁻¹ for SPD A.
pub fn spd_inverse(a: &Mat) -> Result<Mat, LinalgError> {
    let n = a.rows;
    let l = cholesky(a)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for j in 0..n {
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for i in 0..n {
            inv.set(i, j, x[i]);
        }
        e[j] = 0.0;
    }
    Ok(inv)
}

/// Upper-triangular Cholesky of the *inverse*: SparseGPT works with
/// `Hinv = (XᵀX + λI)⁻¹` and consumes `chol(Hinv)ᵀ` (upper). Returns U
/// with Hinv = Uᵀ·U... we return `chol(Hinv)` transposed, i.e. the
/// upper factor whose diagonal SparseGPT's pruning metric divides by.
pub fn cholesky_inverse_upper(h: &Mat) -> Result<Mat, LinalgError> {
    let hinv = spd_inverse(h)?;
    let l = cholesky(&hinv)?;
    Ok(l.transpose())
}

/// Result of a truncated SVD: `a ≈ U · diag(s) · Vᵀ`.
#[derive(Debug, Clone)]
pub struct Svd {
    /// (rows, r) left singular vectors (columns orthonormal).
    pub u: Mat,
    /// Singular values, descending.
    pub s: Vec<f32>,
    /// (cols, r) right singular vectors (columns orthonormal).
    pub v: Mat,
}

impl Svd {
    /// Reconstruct U·diag(s)·Vᵀ.
    pub fn reconstruct(&self) -> Mat {
        let r = self.s.len();
        let mut us = Mat::zeros(self.u.rows, r);
        for i in 0..self.u.rows {
            for k in 0..r {
                us.set(i, k, self.u.at(i, k) * self.s[k]);
            }
        }
        matmul_bt(&us, &self.v)
    }

    /// The paper's √σ-split factors: `U' = u√σ`, `V' = v√σ` so that
    /// W_L = U'·V'ᵀ (rank-1 case returns the two vectors).
    pub fn sqrt_split(&self, k: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(k < self.s.len());
        let sq = self.s[k].max(0.0).sqrt();
        let u: Vec<f32> = (0..self.u.rows).map(|i| self.u.at(i, k) * sq).collect();
        let v: Vec<f32> = (0..self.v.rows).map(|j| self.v.at(j, k) * sq).collect();
        (u, v)
    }
}

/// Rank-1 truncated SVD by power iteration on AᵀA implicit products.
/// Deterministic given the seed. Converges fast for the |W − W_S|
/// matrices SLaB feeds it (large spectral gap: they are near
/// rank-1-positive by construction, cf. Prop. 2).
pub fn svd_rank1(a: &Mat, iters: usize, seed: u64) -> Svd {
    svd_truncated(a, 1, iters, seed)
}

/// Rank-r truncated SVD via block power iteration (subspace iteration
/// with Gram–Schmidt re-orthonormalization each step).
pub fn svd_truncated(a: &Mat, r: usize, iters: usize, seed: u64) -> Svd {
    let (m, n) = a.shape();
    let r = r.min(m.min(n));
    let mut rng = Pcg64::seed_from_u64(seed ^ SVD_SEED_SALT);
    // V block: (n, r) random init, orthonormalized.
    let mut v: Vec<Vec<f32>> = (0..r)
        .map(|_| {
            let mut col = vec![0.0f32; n];
            rng.fill_normal(&mut col, 1.0);
            col
        })
        .collect();
    gram_schmidt(&mut v);
    let mut u: Vec<Vec<f32>> = vec![vec![0.0f32; m]; r];
    let mut sigma = vec![0.0f32; r];
    for _ in 0..iters.max(1) {
        // U = A·V, orthonormalize.
        for k in 0..r {
            u[k] = matvec(a, &v[k]);
        }
        gram_schmidt(&mut u);
        // V = Aᵀ·U; sigma from the norms before normalization.
        for k in 0..r {
            v[k] = matvec_t(a, &u[k]);
        }
        for (k, col) in v.iter().enumerate() {
            sigma[k] = norm2(col) as f32;
        }
        gram_schmidt(&mut v);
    }
    // Order by sigma descending (block iteration usually yields this
    // already; enforce it).
    let mut order: Vec<usize> = (0..r).collect();
    order.sort_by(|&i, &j| sigma[j].partial_cmp(&sigma[i]).unwrap());
    let mut um = Mat::zeros(m, r);
    let mut vm = Mat::zeros(n, r);
    let mut s = vec![0.0f32; r];
    for (slot, &k) in order.iter().enumerate() {
        s[slot] = sigma[k];
        for i in 0..m {
            um.set(i, slot, u[k][i]);
        }
        for j in 0..n {
            vm.set(j, slot, v[k][j]);
        }
    }
    // Fix signs so u·A·v ≥ 0 per component (canonical form).
    for k in 0..r {
        let av = matvec(a, &vm.col(k));
        let d: f64 = av
            .iter()
            .zip((0..m).map(|i| um.at(i, k)))
            .map(|(&x, y)| x as f64 * y as f64)
            .sum();
        if d < 0.0 {
            for i in 0..m {
                *um.at_mut(i, k) *= -1.0;
            }
            s[k] = -s[k];
        }
        s[k] = s[k].abs();
    }
    Svd { u: um, s, v: vm }
}

fn gram_schmidt(cols: &mut [Vec<f32>]) {
    for k in 0..cols.len() {
        for prev in 0..k {
            let d: f64 = cols[k]
                .iter()
                .zip(cols[prev].iter())
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            let (head, tail) = cols.split_at_mut(k);
            for (x, &p) in tail[0].iter_mut().zip(head[prev].iter()) {
                *x -= (d as f32) * p;
            }
        }
        let nrm = norm2(&cols[k]) as f32;
        if nrm > 1e-20 {
            for x in cols[k].iter_mut() {
                *x /= nrm;
            }
        }
    }
}

/// Seed salt so SVD streams never collide with other consumers of a seed.
const SVD_SEED_SALT: u64 = 0x51ab_5fd0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul;

    fn random_spd(n: usize, rng: &mut Pcg64) -> Mat {
        let x = Mat::randn(n * 2, n, 1.0, rng);
        let mut h = crate::tensor::ops::gram(&x);
        for i in 0..n {
            *h.at_mut(i, i) += 0.5;
        }
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg64::seed_from_u64(20);
        let a = random_spd(12, &mut rng);
        let l = cholesky(&a).unwrap();
        let rec = matmul(&l, &l.transpose());
        assert!(rec.allclose(&a, 1e-2, 1e-3));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Pcg64::seed_from_u64(21);
        let a = random_spd(10, &mut rng);
        let l = cholesky(&a).unwrap();
        let b: Vec<f32> = (0..10).map(|i| (i as f32).cos()).collect();
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // L·Lᵀ·x should equal b.
        let ax = matvec(&a, &x);
        for i in 0..10 {
            assert!((ax[i] - b[i]).abs() < 1e-3, "i={i}: {} vs {}", ax[i], b[i]);
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let mut rng = Pcg64::seed_from_u64(22);
        let a = random_spd(8, &mut rng);
        let inv = spd_inverse(&a).unwrap();
        let prod = matmul(&a, &inv);
        assert!(prod.allclose(&Mat::eye(8), 5e-2, 1e-3));
    }

    #[test]
    fn rank1_svd_exact_on_rank1_matrix() {
        let u = vec![1.0, -2.0, 3.0];
        let v = vec![0.5, 1.5, -1.0, 2.0];
        let a = Mat::outer(&u, &v);
        let svd = svd_rank1(&a, 30, 1);
        let rec = svd.reconstruct();
        assert!(rec.allclose(&a, 1e-4, 1e-4));
        // sigma = |u|·|v|
        let expect = (norm2(&u) * norm2(&v)) as f32;
        assert!((svd.s[0] - expect).abs() < 1e-3);
    }

    #[test]
    fn truncated_svd_captures_dominant_subspace() {
        let mut rng = Pcg64::seed_from_u64(23);
        // Construct a matrix with known decaying spectrum.
        let m = 20;
        let n = 16;
        let mut a = Mat::zeros(m, n);
        for k in 0..4 {
            let mut u = vec![0.0f32; m];
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut u, 1.0);
            rng.fill_normal(&mut v, 1.0);
            let sigma = 10.0 / (k as f32 + 1.0).powi(2);
            let uo = Mat::outer(&u, &v).scale(sigma / (norm2(&u) * norm2(&v)) as f32);
            a.add_assign(&uo);
        }
        let svd = svd_truncated(&a, 4, 60, 7);
        let rec = svd.reconstruct();
        // Rank-4 reconstruction should capture essentially everything.
        assert!(rec.frob_dist(&a) / a.frob_norm() < 0.05);
        // Singular values descending.
        for k in 1..svd.s.len() {
            assert!(svd.s[k - 1] >= svd.s[k] - 1e-4);
        }
    }

    #[test]
    fn svd_orthonormal_columns() {
        let mut rng = Pcg64::seed_from_u64(24);
        let a = Mat::randn(15, 11, 1.0, &mut rng);
        let svd = svd_truncated(&a, 3, 50, 9);
        for i in 0..3 {
            for j in 0..3 {
                let d: f32 = (0..15).map(|r| svd.u.at(r, i) * svd.u.at(r, j)).sum();
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((d - expect).abs() < 1e-3, "u col {i}·{j} = {d}");
            }
        }
    }

    #[test]
    fn sqrt_split_reconstructs_rank1() {
        let u = vec![2.0, 1.0];
        let v = vec![1.0, 3.0];
        let a = Mat::outer(&u, &v);
        let svd = svd_rank1(&a, 30, 3);
        let (su, sv) = svd.sqrt_split(0);
        let rec = Mat::outer(&su, &sv);
        assert!(rec.allclose(&a, 1e-3, 1e-3));
    }
}
