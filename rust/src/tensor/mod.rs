//! Dense tensor substrate: row-major f32 matrices, matmul kernels,
//! Cholesky / triangular solves / truncated SVD, and checkpoint I/O.
//!
//! Built from scratch because the offline crate set has no
//! ndarray/nalgebra/BLAS. See `DESIGN.md` §4 (system inventory).

pub mod io;
pub mod linalg;
pub mod mat;
pub mod ops;

pub use io::{Checkpoint, CheckpointWriter, Entry, TensorData};
pub use linalg::{cholesky, spd_inverse, svd_rank1, svd_truncated, Svd};
pub use mat::Mat;
pub use ops::{
    gram, gram_par, matmul, matmul_bt, matmul_bt_par, matmul_bt_par_into, matvec, matvec_t,
};
