//! Binary tensor checkpoint format (`.slabckpt`).
//!
//! No serde offline, so checkpoints use a simple self-describing
//! little-endian container:
//!
//! ```text
//! magic   8  b"SLABCKP1"
//! count   u32
//! repeat count times:
//!   name_len u32, name bytes (utf-8)
//!   ndim u32, dims u64 × ndim
//!   dtype u8 (0 = f32, 1 = i32, 2 = u8)
//!   payload (numel × dtype size, little-endian)
//! crc32? no — integrity via length checks + magic; checkpoints are
//! produced and consumed by this binary only.
//! ```
//!
//! Entries preserve insertion order (the artifact manifest's parameter
//! order) — ordering is load-bearing for the PJRT call ABI.

use super::mat::Mat;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"SLABCKP1";

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

impl TensorData {
    pub fn numel(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U8(v) => v.len(),
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            TensorData::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            TensorData::I32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_u8(&self) -> Option<&[u8]> {
        match self {
            TensorData::U8(v) => Some(v),
            _ => None,
        }
    }
}

/// A named tensor entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl Entry {
    pub fn from_mat(name: &str, m: &Mat) -> Entry {
        Entry {
            name: name.to_string(),
            dims: vec![m.rows, m.cols],
            data: TensorData::F32(m.data.clone()),
        }
    }

    pub fn f32(name: &str, dims: Vec<usize>, data: Vec<f32>) -> Entry {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Entry {
            name: name.to_string(),
            dims,
            data: TensorData::F32(data),
        }
    }

    pub fn to_mat(&self) -> Option<Mat> {
        if self.dims.len() != 2 {
            return None;
        }
        self.data
            .as_f32()
            .map(|d| Mat::from_vec(self.dims[0], self.dims[1], d.to_vec()))
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// An ordered collection of named tensors.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    pub entries: Vec<Entry>,
}

impl Checkpoint {
    pub fn new() -> Checkpoint {
        Checkpoint::default()
    }

    pub fn push(&mut self, e: Entry) {
        self.entries.push(e);
    }

    pub fn get(&self, name: &str) -> Option<&Entry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        for e in &self.entries {
            write_entry(&mut w, e)?;
        }
        w.flush()
    }

    pub fn load(path: &Path) -> std::io::Result<Checkpoint> {
        let mut r = BufReader::new(File::open(path)?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad checkpoint magic in {}", path.display()),
            ));
        }
        let count = read_u32(&mut r)? as usize;
        let mut entries = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = read_u32(&mut r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name)
                .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
            let ndim = read_u32(&mut r)? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u64(&mut r)? as usize);
            }
            let numel: usize = dims.iter().product();
            let mut dtype = [0u8; 1];
            r.read_exact(&mut dtype)?;
            let data = match dtype[0] {
                0 => {
                    let mut buf = vec![0u8; numel * 4];
                    r.read_exact(&mut buf)?;
                    TensorData::F32(
                        buf.chunks_exact(4)
                            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect(),
                    )
                }
                1 => {
                    let mut buf = vec![0u8; numel * 4];
                    r.read_exact(&mut buf)?;
                    TensorData::I32(
                        buf.chunks_exact(4)
                            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect(),
                    )
                }
                2 => {
                    let mut buf = vec![0u8; numel];
                    r.read_exact(&mut buf)?;
                    TensorData::U8(buf)
                }
                d => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("unknown dtype tag {d}"),
                    ))
                }
            };
            entries.push(Entry { name, dims, data });
        }
        Ok(Checkpoint { entries })
    }
}

/// One entry in the container encoding shared by [`Checkpoint::save`]
/// and [`CheckpointWriter::append`].
fn write_entry<W: Write>(w: &mut W, e: &Entry) -> std::io::Result<()> {
    let name = e.name.as_bytes();
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name)?;
    w.write_all(&(e.dims.len() as u32).to_le_bytes())?;
    for &d in &e.dims {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    match &e.data {
        TensorData::F32(v) => {
            w.write_all(&[0u8])?;
            for &x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        TensorData::I32(v) => {
            w.write_all(&[1u8])?;
            for &x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        TensorData::U8(v) => {
            w.write_all(&[2u8])?;
            w.write_all(v)?;
        }
    }
    Ok(())
}

/// Incremental checkpoint writer: append entries one at a time and
/// never hold more than one entry's tensors in memory — the streaming
/// half of the compression pipeline's emit stage (a block's packed
/// layers go to disk the moment the block finishes; peak memory is one
/// block, not one model).
///
/// The on-disk bytes are identical to a batch [`Checkpoint::save`] of
/// the same entries in the same order (pinned by a test): the header's
/// entry count starts at zero and is patched in by
/// [`finalize`](CheckpointWriter::finalize). A writer dropped without
/// `finalize` therefore leaves a valid-but-empty checkpoint, never a
/// torn one.
pub struct CheckpointWriter {
    w: BufWriter<File>,
    count: u32,
}

impl CheckpointWriter {
    /// Create the file (parents included) and write the header with a
    /// zero entry count.
    pub fn create(path: &Path) -> std::io::Result<CheckpointWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&0u32.to_le_bytes())?;
        Ok(CheckpointWriter { w, count: 0 })
    }

    /// Append one entry; it can be dropped by the caller immediately.
    pub fn append(&mut self, e: &Entry) -> std::io::Result<()> {
        write_entry(&mut self.w, e)?;
        self.count += 1;
        Ok(())
    }

    /// Entries appended so far.
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Flush, patch the header's entry count, and close; returns the
    /// entry count.
    pub fn finalize(mut self) -> std::io::Result<usize> {
        self.w.flush()?;
        let f = self.w.get_mut();
        f.seek(SeekFrom::Start(MAGIC.len() as u64))?;
        f.write_all(&self.count.to_le_bytes())?;
        f.flush()?;
        Ok(self.count as usize)
    }
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("slab-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_mixed_dtypes() {
        let mut rng = Pcg64::seed_from_u64(30);
        let m = Mat::randn(7, 5, 1.0, &mut rng);
        let mut ck = Checkpoint::new();
        ck.push(Entry::from_mat("w", &m));
        ck.push(Entry {
            name: "ids".into(),
            dims: vec![3],
            data: TensorData::I32(vec![-1, 0, 7]),
        });
        ck.push(Entry {
            name: "bits".into(),
            dims: vec![4],
            data: TensorData::U8(vec![0xde, 0xad, 0xbe, 0xef]),
        });
        let path = tmpfile("roundtrip.slabckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.get("w").unwrap().to_mat().unwrap(), m);
    }

    #[test]
    fn preserves_order() {
        let mut ck = Checkpoint::new();
        for name in ["z", "a", "m"] {
            ck.push(Entry::f32(name, vec![1], vec![1.0]));
        }
        let path = tmpfile("order.slabckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let names: Vec<&str> = back.entries.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, vec!["z", "a", "m"]);
    }

    #[test]
    fn streaming_writer_matches_batch_save_byte_for_byte() {
        let mut rng = Pcg64::seed_from_u64(31);
        let mut ck = Checkpoint::new();
        ck.push(Entry::from_mat("w", &Mat::randn(5, 9, 1.0, &mut rng)));
        ck.push(Entry {
            name: "ids".into(),
            dims: vec![2],
            data: TensorData::I32(vec![3, -4]),
        });
        ck.push(Entry {
            name: "bits".into(),
            dims: vec![3],
            data: TensorData::U8(vec![1, 2, 3]),
        });
        let batch = tmpfile("batch.slabckpt");
        ck.save(&batch).unwrap();
        let streamed = tmpfile("streamed.slabckpt");
        let mut w = CheckpointWriter::create(&streamed).unwrap();
        assert!(w.is_empty());
        for e in &ck.entries {
            w.append(e).unwrap();
        }
        assert_eq!(w.len(), 3);
        assert_eq!(w.finalize().unwrap(), 3);
        assert_eq!(
            std::fs::read(&streamed).unwrap(),
            std::fs::read(&batch).unwrap(),
            "streamed bytes must equal batch save"
        );
        assert_eq!(Checkpoint::load(&streamed).unwrap(), ck);
    }

    #[test]
    fn unfinalized_writer_leaves_an_empty_but_valid_checkpoint() {
        let path = tmpfile("unfinalized.slabckpt");
        {
            let mut w = CheckpointWriter::create(&path).unwrap();
            w.append(&Entry::f32("x", vec![1], vec![1.0])).unwrap();
            // dropped without finalize
        }
        let back = Checkpoint::load(&path).unwrap();
        assert!(back.is_empty(), "count was never patched in");
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("bad.slabckpt");
        std::fs::write(&path, b"NOTMAGIC____").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn empty_checkpoint_roundtrips() {
        let ck = Checkpoint::new();
        let path = tmpfile("empty.slabckpt");
        ck.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap().len(), 0);
    }
}
