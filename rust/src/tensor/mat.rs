//! Dense row-major f32 matrix — the core numeric container.
//!
//! No ndarray/BLAS in the offline crate set, so this module carries the
//! dense representation used everywhere: weights, activations,
//! calibration batches. The layout is always row-major `(rows, cols)`;
//! `Mat` is cheap to clone only when you mean it (no implicit views —
//! explicitness beats accidental aliasing in a compression pipeline
//! that mutates weights in place).

use crate::util::rng::Pcg64;

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    // ------------------------------------------------------------------
    // Construction
    // ------------------------------------------------------------------

    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![v; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(
            data.len(),
            rows * cols,
            "from_vec: {}x{} != data len {}",
            rows,
            cols,
            data.len()
        );
        Mat { rows, cols, data }
    }

    pub fn from_fn<F: FnMut(usize, usize) -> f32>(rows: usize, cols: usize, mut f: F) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Mat {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// I.i.d. N(0, std).
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Pcg64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    /// Uniform in [lo, hi).
    pub fn rand_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Pcg64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_uniform(&mut m.data, lo, hi);
        m
    }

    // ------------------------------------------------------------------
    // Access
    // ------------------------------------------------------------------

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    // ------------------------------------------------------------------
    // Elementwise / reductions
    // ------------------------------------------------------------------

    pub fn map<F: Fn(f32) -> f32>(&self, f: F) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn map_inplace<F: Fn(f32) -> f32>(&mut self, f: F) {
        for v in self.data.iter_mut() {
            *v = f(*v);
        }
    }

    pub fn abs(&self) -> Mat {
        self.map(f32::abs)
    }

    /// sign with sign(0) = +1, matching the paper's `sign` ("non-negative
    /// numbers are denoted as 1 while negative numbers are denoted as 0",
    /// i.e. ±1 after the {0,1}→{−1,+1} mapping).
    pub fn sign_pm1(&self) -> Mat {
        self.map(|x| if x >= 0.0 { 1.0 } else { -1.0 })
    }

    pub fn zip<F: Fn(f32, f32) -> f32>(&self, other: &Mat, f: F) -> Mat {
        assert_eq!(self.shape(), other.shape(), "zip shape mismatch");
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a - b)
    }

    /// Hadamard (elementwise) product — the paper's ⊙.
    pub fn hadamard(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a * b)
    }

    /// Elementwise division — the paper's ⊘. Caller guarantees no zeros.
    pub fn eldiv(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a / b)
    }

    pub fn scale(&self, s: f32) -> Mat {
        self.map(|x| x * s)
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    pub fn sub_assign(&mut self, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a -= b;
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    pub fn frob_dist(&self, other: &Mat) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt() as f32
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn count_nonzero(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    /// Column L2 norms: `||X_j||₂` — the Wanda activation statistic.
    pub fn col_norms(&self) -> Vec<f32> {
        let mut acc = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for (j, &v) in row.iter().enumerate() {
                acc[j] += (v as f64) * (v as f64);
            }
        }
        acc.into_iter().map(|v| v.sqrt() as f32).collect()
    }

    // ------------------------------------------------------------------
    // Structure ops
    // ------------------------------------------------------------------

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large weights.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Select a row range [r0, r1) as a new matrix.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Stack matrices with identical `cols` vertically.
    pub fn vstack(mats: &[&Mat]) -> Mat {
        assert!(!mats.is_empty());
        let cols = mats[0].cols;
        let rows: usize = mats.iter().map(|m| m.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for m in mats {
            assert_eq!(m.cols, cols, "vstack col mismatch");
            data.extend_from_slice(&m.data);
        }
        Mat { rows, cols, data }
    }

    /// Outer product u vᵀ (u: len rows, v: len cols).
    pub fn outer(u: &[f32], v: &[f32]) -> Mat {
        let mut m = Mat::zeros(u.len(), v.len());
        for (i, &ui) in u.iter().enumerate() {
            let row = m.row_mut(i);
            for (j, &vj) in v.iter().enumerate() {
                row[j] = ui * vj;
            }
        }
        m
    }

    /// Approximate equality for tests.
    pub fn allclose(&self, other: &Mat, atol: f32, rtol: f32) -> bool {
        if self.shape() != other.shape() {
            return false;
        }
        self.data.iter().zip(other.data.iter()).all(|(&a, &b)| {
            let tol = atol + rtol * b.abs();
            (a - b).abs() <= tol
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.at(1, 2), 12.0);
        assert_eq!(m.row(0), &[0.0, 1.0, 2.0]);
        assert_eq!(m.col(1), vec![1.0, 11.0]);
        assert_eq!(m.shape(), (2, 3));
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seed_from_u64(1);
        let m = Mat::randn(37, 53, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().at(5, 7), m.at(7, 5));
    }

    #[test]
    fn hadamard_and_eldiv_inverse() {
        let mut rng = Pcg64::seed_from_u64(2);
        let a = Mat::randn(8, 8, 1.0, &mut rng);
        let b = Mat::rand_uniform(8, 8, 0.5, 2.0, &mut rng);
        let back = a.hadamard(&b).eldiv(&b);
        assert!(back.allclose(&a, 1e-6, 1e-5));
    }

    #[test]
    fn sign_pm1_values() {
        let m = Mat::from_vec(1, 4, vec![-2.0, 0.0, 3.0, -0.0]);
        let s = m.sign_pm1();
        // sign(0) = +1 per the paper ("non-negative → 1"); note -0.0 >= 0.0.
        assert_eq!(s.data, vec![-1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn col_norms_match_manual() {
        let m = Mat::from_vec(2, 2, vec![3.0, 1.0, 4.0, 2.0]);
        let n = m.col_norms();
        assert!((n[0] - 5.0).abs() < 1e-6);
        assert!((n[1] - (5.0f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn outer_product() {
        let m = Mat::outer(&[1.0, 2.0], &[3.0, 4.0, 5.0]);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.at(1, 2), 10.0);
    }

    #[test]
    fn frob_norm_and_dist() {
        let a = Mat::from_vec(1, 3, vec![1.0, 2.0, 2.0]);
        assert!((a.frob_norm() - 3.0).abs() < 1e-6);
        let b = Mat::zeros(1, 3);
        assert!((a.frob_dist(&b) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn vstack_and_slice_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(3);
        let m = Mat::randn(10, 4, 1.0, &mut rng);
        let a = m.slice_rows(0, 4);
        let b = m.slice_rows(4, 10);
        assert_eq!(Mat::vstack(&[&a, &b]), m);
    }

    #[test]
    #[should_panic]
    fn from_vec_len_mismatch_panics() {
        let _ = Mat::from_vec(2, 2, vec![1.0]);
    }
}
