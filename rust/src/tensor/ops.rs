//! Dense matrix multiply and friends, tuned for the single-core CPU
//! testbed: blocked ikj loops with an explicitly transposed-B variant
//! (`matmul_bt`) because the compression pipeline almost always holds
//! weights as `(Dout, Din)` and computes `X·Wᵀ`.

use super::mat::Mat;
use crate::util::pool::{chunk_ranges, ThreadPool};

/// C = A·B. Blocked ikj with row-major accumulation (auto-vectorizes).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    const KB: usize = 64;
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for i in 0..m {
            let arow = a.row(i);
            let crow = &mut c.data[i * n..(i + 1) * n];
            for kk in kb..kend {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
    }
    c
}

/// C = A·Bᵀ with B given as `(n, k)` — dot-product kernel over rows,
/// the layout both activations and weights already use.
pub fn matmul_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_bt inner dim {} vs {}", a.cols, b.cols);
    let mut c = Mat::zeros(a.rows, b.rows);
    matmul_bt_rows(a, b, 0, a.rows, &mut c.data);
    c
}

/// The shared row kernel: output rows `[r0, r1)` of A·Bᵀ into `out`
/// (`(r1 − r0) × b.rows`, row-major). Both [`matmul_bt`] and the
/// pool-parallel variants go through here, so per-element accumulation
/// order — and therefore the result — is identical across all of them.
fn matmul_bt_rows(a: &Mat, b: &Mat, r0: usize, r1: usize, out: &mut [f32]) {
    let (k, n) = (a.cols, b.rows);
    debug_assert_eq!(out.len(), (r1 - r0) * n);
    for i in r0..r1 {
        let arow = a.row(i);
        let crow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
        for j in 0..n {
            let brow = b.row(j);
            let mut acc0 = 0.0f32;
            let mut acc1 = 0.0f32;
            let mut acc2 = 0.0f32;
            let mut acc3 = 0.0f32;
            let chunks = k / 4;
            for t in 0..chunks {
                let idx = 4 * t;
                acc0 += arow[idx] * brow[idx];
                acc1 += arow[idx + 1] * brow[idx + 1];
                acc2 += arow[idx + 2] * brow[idx + 2];
                acc3 += arow[idx + 3] * brow[idx + 3];
            }
            let mut acc = acc0 + acc1 + acc2 + acc3;
            for idx in 4 * chunks..k {
                acc += arow[idx] * brow[idx];
            }
            crow[j] = acc;
        }
    }
}

/// [`matmul_bt`] with the rows of `a` chunked across `pool` — the
/// dense twin of `Csr::spmm_bt_par`/`BitMat::matmul_bt_par`. Each
/// output row is produced by exactly one worker running the shared
/// row kernel, so the result is **bit-identical** to the serial call
/// (pinned by a property test below).
pub fn matmul_bt_par(a: &Mat, b: &Mat, pool: &ThreadPool) -> Mat {
    let mut c = Mat::zeros(a.rows, b.rows);
    matmul_bt_par_into(a, b, pool, &mut c);
    c
}

/// [`matmul_bt_par`] writing into a caller-owned output (overwritten
/// entirely). `c` must be `(a.rows, b.rows)`.
pub fn matmul_bt_par_into(a: &Mat, b: &Mat, pool: &ThreadPool, c: &mut Mat) {
    assert_eq!(a.cols, b.cols, "matmul_bt inner dim {} vs {}", a.cols, b.cols);
    assert_eq!(
        (c.rows, c.cols),
        (a.rows, b.rows),
        "matmul_bt_par_into: bad output shape"
    );
    let n = b.rows;
    let ranges = chunk_ranges(a.rows, pool.size());
    if ranges.len() <= 1 {
        matmul_bt_rows(a, b, 0, a.rows, &mut c.data);
        return;
    }
    let mut jobs = Vec::with_capacity(ranges.len());
    let mut rest: &mut [f32] = &mut c.data;
    for &(r0, r1) in &ranges {
        let (head, tail) = rest.split_at_mut((r1 - r0) * n);
        rest = tail;
        jobs.push(move || matmul_bt_rows(a, b, r0, r1, head));
    }
    pool.scoped(jobs);
}

/// y = A·x (matrix-vector).
pub fn matvec(a: &Mat, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    (0..a.rows)
        .map(|i| {
            a.row(i)
                .iter()
                .zip(x.iter())
                .map(|(&w, &v)| w * v)
                .sum::<f32>()
        })
        .collect()
}

/// y = Aᵀ·x without materializing the transpose.
pub fn matvec_t(a: &Mat, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.rows, x.len());
    let mut y = vec![0.0f32; a.cols];
    for i in 0..a.rows {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = a.row(i);
        for (j, &v) in row.iter().enumerate() {
            y[j] += xi * v;
        }
    }
    y
}

/// Gram matrix H = XᵀX for X of shape (N, D) — SparseGPT's Hessian
/// (up to the damping term). Accumulates in f64 for stability, exploits
/// symmetry.
pub fn gram(x: &Mat) -> Mat {
    let d = x.cols;
    let mut acc = vec![0.0f64; d * d];
    for i in 0..x.rows {
        let row = x.row(i);
        for a in 0..d {
            let ra = row[a] as f64;
            if ra == 0.0 {
                continue;
            }
            let base = a * d;
            for b in a..d {
                acc[base + b] += ra * row[b] as f64;
            }
        }
    }
    let mut h = Mat::zeros(d, d);
    for a in 0..d {
        for b in a..d {
            let v = acc[a * d + b] as f32;
            h.set(a, b, v);
            h.set(b, a, v);
        }
    }
    h
}

/// [`gram`] with the output rows chunked across `pool` — each worker
/// owns a disjoint band of H's upper triangle and accumulates over the
/// sample rows in the same order as the serial kernel, so the result
/// is **bit-identical** to [`gram`] (the mirror pass is an exact
/// copy). This is the Din³-scale cost of Hessian methods' calibration
/// capture; everything else in that path is already row-parallel.
pub fn gram_par(x: &Mat, pool: &ThreadPool) -> Mat {
    let d = x.cols;
    let ranges = chunk_ranges(d, pool.size());
    if ranges.len() <= 1 {
        return gram(x);
    }
    let mut acc = vec![0.0f64; d * d];
    {
        let mut jobs = Vec::with_capacity(ranges.len());
        let mut rest: &mut [f64] = &mut acc;
        for &(a0, a1) in &ranges {
            let (band, tail) = rest.split_at_mut((a1 - a0) * d);
            rest = tail;
            jobs.push(move || gram_rows(x, a0, a1, band));
        }
        pool.scoped(jobs);
    }
    let mut h = Mat::zeros(d, d);
    for a in 0..d {
        for b in a..d {
            let v = acc[a * d + b] as f32;
            h.set(a, b, v);
            h.set(b, a, v);
        }
    }
    h
}

/// Upper-triangle rows `[a0, a1)` of `XᵀX` accumulated into `band`
/// (`(a1 − a0) × d`, row-major) — the shared kernel of [`gram`]'s
/// per-element arithmetic: samples accumulate in row order, f64.
fn gram_rows(x: &Mat, a0: usize, a1: usize, band: &mut [f64]) {
    let d = x.cols;
    for i in 0..x.rows {
        let row = x.row(i);
        for a in a0..a1 {
            let ra = row[a] as f64;
            if ra == 0.0 {
                continue;
            }
            let base = (a - a0) * d;
            for b in a..d {
                band[base + b] += ra * row[b] as f64;
            }
        }
    }
}

/// Dot product in f64.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// L2 norm of a vector (f64 accumulate).
pub fn norm2(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Softmax over a slice, in place, numerically stable.
pub fn softmax_inplace(v: &mut [f32]) {
    let max = v.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let mut sum = 0.0f32;
    for x in v.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in v.iter_mut() {
        *x /= sum;
    }
}

/// log-sum-exp of a slice.
pub fn logsumexp(v: &[f32]) -> f32 {
    let max = v.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    if !max.is_finite() {
        return max;
    }
    let s: f32 = v.iter().map(|&x| (x - max).exp()).sum();
    max + s.ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::seed_from_u64(10);
        for (m, k, n) in [(3, 4, 5), (17, 33, 9), (1, 7, 1), (32, 64, 16)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.allclose(&naive_matmul(&a, &b), 1e-4, 1e-4), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_bt_matches_matmul() {
        let mut rng = Pcg64::seed_from_u64(11);
        let a = Mat::randn(13, 29, 1.0, &mut rng);
        let b = Mat::randn(7, 29, 1.0, &mut rng); // (n, k)
        let c1 = matmul_bt(&a, &b);
        let c2 = matmul(&a, &b.transpose());
        assert!(c1.allclose(&c2, 1e-4, 1e-4));
    }

    #[test]
    fn matmul_bt_par_is_bit_identical_to_serial() {
        // Same contract as the packed kernels: chunking rows across
        // the pool must not change a single bit, across adversarial
        // shapes (fewer rows than workers, odd inner dims, batch 1).
        let pool = ThreadPool::new(4);
        crate::util::prop::check(
            "matmul-bt-par-vs-serial",
            25,
            |rng| crate::util::prop::gens::dims(rng, 1, 40),
            |&(m, k)| {
                let mut rng = Pcg64::seed_from_u64((m * 1000 + k) as u64);
                let a = Mat::randn(m, k, 1.0, &mut rng);
                let b = Mat::randn((k % 7) + 1, k, 1.0, &mut rng);
                let serial = matmul_bt(&a, &b);
                let par = matmul_bt_par(&a, &b, &pool);
                if par != serial {
                    return Err(format!("par != serial at {m}x{k}"));
                }
                let mut into = Mat::filled(m, b.rows, f32::NAN);
                matmul_bt_par_into(&a, &b, &pool, &mut into);
                if into != serial {
                    return Err(format!("par_into != serial at {m}x{k}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn matvec_consistency() {
        let mut rng = Pcg64::seed_from_u64(12);
        let a = Mat::randn(9, 14, 1.0, &mut rng);
        let x: Vec<f32> = (0..14).map(|i| i as f32 * 0.1).collect();
        let y = matvec(&a, &x);
        let xm = Mat::from_vec(14, 1, x.clone());
        let ym = matmul(&a, &xm);
        for i in 0..9 {
            assert!((y[i] - ym.at(i, 0)).abs() < 1e-4);
        }
        // matvec_t vs explicit transpose
        let z: Vec<f32> = (0..9).map(|i| (i as f32).sin()).collect();
        let t1 = matvec_t(&a, &z);
        let t2 = matvec(&a.transpose(), &z);
        for j in 0..14 {
            assert!((t1[j] - t2[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn gram_is_xtx() {
        let mut rng = Pcg64::seed_from_u64(13);
        let x = Mat::randn(25, 8, 1.0, &mut rng);
        let h = gram(&x);
        let href = matmul(&x.transpose(), &x);
        assert!(h.allclose(&href, 1e-3, 1e-4));
        // Symmetry exact by construction.
        for a in 0..8 {
            for b in 0..8 {
                assert_eq!(h.at(a, b), h.at(b, a));
            }
        }
    }

    #[test]
    fn gram_par_is_bit_identical_to_serial() {
        let pool = ThreadPool::new(4);
        let mut rng = Pcg64::seed_from_u64(14);
        for (rows, d) in [(1usize, 1usize), (7, 3), (25, 8), (13, 33)] {
            let mut x = Mat::randn(rows, d, 1.0, &mut rng);
            x.set(0, 0, 0.0); // exercise the zero-skip branch
            assert_eq!(gram_par(&x, &pool).data, gram(&x).data, "{rows}x{d}");
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut v = vec![1.0, 2.0, 3.0, 1000.0];
        softmax_inplace(&mut v);
        let s: f32 = v.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(v[3] > 0.99);
    }

    #[test]
    fn logsumexp_stable() {
        let v = vec![1000.0f32, 1000.0];
        let l = logsumexp(&v);
        assert!((l - (1000.0 + (2.0f32).ln())).abs() < 1e-3);
    }
}
