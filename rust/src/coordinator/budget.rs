//! Activation-aware per-layer budget allocation (ROADMAP item 2; the
//! nested activation-aware-allocation direction of PAPERS.md).
//!
//! The uniform pipeline gives every linear the same Eq.-10 keep
//! fraction, but layers differ wildly in how much activation-weighted
//! error each kept element buys back — `wq` in block 0 and `w_down`
//! in the last block are not equally sensitive. The allocator probes
//! each linear from its captured [`ActStats`] and redistributes the
//! **global** sparse budget by water-filling, holding the total
//! parameter count exactly fixed:
//!
//! 1. *Probe*: a dense-weights capture pass yields per-linear Wanda
//!    scores; sorted descending, their squared prefix sums form the
//!    kept-energy curve `E_l(k)` ([`kept_energy_curve`]) — for
//!    pruning-only selection, exactly the squared weighted error
//!    bought back by budget `k`. The recorded per-layer sensitivity
//!    is the finite-difference marginal
//!    `(E_l(k·(1+δ)) − E_l(k·(1−δ))) / 2δk` around the uniform
//!    budget.
//! 2. *Water-fill*: keep every score above one global waterline `τ` —
//!    the continuous optimum of "maximize kept energy subject to
//!    Σ k_l = K" — found by binary search, with per-layer clamps
//!    `k_l ∈ [min_scale·k_u, max_scale·k_u]` so no layer is starved
//!    or flooded, then an exact greedy fix-up of the residual few
//!    elements (ties at the waterline, clamp spill) so
//!    `Σ k_l = Σ k_u` holds *exactly* — "equal global parameter
//!    budget" is an invariant, not an approximation.
//! 3. The resulting [`BudgetPlan`] hands each layer a
//!    [`SlabConfig::with_keep`] override; `CompressJob` consumes it
//!    in place of the uniform config and records it (and its
//!    [`Table`] rendering) in the `CompressReport`.
//!
//! The plan is deterministic: scores are a deterministic function of
//! the capture, the binary search is on fixed arithmetic, and every
//! tie in the fix-up breaks by layer index.

use crate::report::Table;
use crate::slab::config::ConfigError;
use crate::slab::threshold::kept_energy_curve;
use crate::slab::SlabConfig;

/// Allocator knobs (defaults are the shipped policy).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BudgetConfig {
    /// Sensitivity-probe half-width as a fraction of the uniform
    /// budget: the recorded sensitivity is the marginal energy between
    /// `k·(1−delta)` and `k·(1+delta)`.
    pub delta: f64,
    /// Per-layer keep clamp, relative to the uniform budget: no layer
    /// drops below `min_scale · k_u` …
    pub min_scale: f64,
    /// … or rises above `max_scale · k_u` (both further clamped to
    /// `[1, numel − 1]` so every per-layer config stays feasible).
    pub max_scale: f64,
}

impl Default for BudgetConfig {
    fn default() -> Self {
        BudgetConfig { delta: 0.25, min_scale: 0.5, max_scale: 2.0 }
    }
}

/// Probe input for one linear: its Wanda scores against the dense
/// weights, sorted descending ([`crate::slab::threshold::sorted_scores_desc`]).
#[derive(Debug, Clone)]
pub struct LayerProbe {
    pub name: String,
    pub dout: usize,
    pub din: usize,
    /// Wanda scores `|W_ij|·s_j`, sorted descending.
    pub scores: Vec<f32>,
}

/// One layer's allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerBudget {
    pub name: String,
    pub dout: usize,
    pub din: usize,
    /// Eq.-10 keep count at the uniform config.
    pub uniform_keep: usize,
    /// Allocated keep count (Σ over layers equals Σ uniform exactly).
    pub keep: usize,
    /// Marginal kept energy per element around the uniform budget —
    /// the ±delta sensitivity probe's reading (diagnostic; the
    /// water-line is what actually allocates).
    pub sensitivity: f64,
}

impl LayerBudget {
    pub fn numel(&self) -> usize {
        self.dout * self.din
    }

    /// The allocated keep fraction this layer's config override pins.
    pub fn keep_frac(&self) -> f64 {
        self.keep as f64 / self.numel() as f64
    }
}

/// The allocator's output: per-layer keep budgets under the fixed
/// global parameter count, plus the waterline that produced them.
#[derive(Debug, Clone, PartialEq)]
pub struct BudgetPlan {
    /// The uniform base config the overrides modify (rank, group,
    /// structure, iteration counts stay uniform — the allocator spends
    /// the *sparse* budget; rank redistribution is a policy hook, not
    /// implemented).
    pub base: SlabConfig,
    pub layers: Vec<LayerBudget>,
    /// The global score waterline τ the water-filling pass settled on.
    pub waterline: f64,
}

impl BudgetPlan {
    /// Water-fill `probes` under the global budget implied by `base`'s
    /// Eq. 10 across all layers. Errors if any layer is infeasible at
    /// the uniform config (the caller renders that as an infeasible
    /// row, same as the uniform pipeline would).
    pub fn plan(
        probes: &[LayerProbe],
        base: &SlabConfig,
        bcfg: &BudgetConfig,
    ) -> Result<BudgetPlan, ConfigError> {
        assert!(!probes.is_empty(), "no layers to plan");
        assert!(bcfg.delta > 0.0 && bcfg.min_scale > 0.0 && bcfg.max_scale >= 1.0);
        let n = probes.len();
        let mut uniform = Vec::with_capacity(n);
        for p in probes {
            debug_assert_eq!(p.scores.len(), p.dout * p.din, "probe score count");
            debug_assert!(p.scores.windows(2).all(|w| w[0] >= w[1]), "probe scores must be sorted descending");
            uniform.push(base.keep_count(p.dout, p.din)?);
        }
        let total: usize = uniform.iter().sum();

        // Feasible clamp window per layer.
        let bounds: Vec<(usize, usize)> = probes
            .iter()
            .zip(uniform.iter())
            .map(|(p, &ku)| {
                let numel = p.dout * p.din;
                let lo = ((bcfg.min_scale * ku as f64).floor() as usize).clamp(1, numel - 1);
                let hi = ((bcfg.max_scale * ku as f64).ceil() as usize).clamp(lo, numel - 1);
                (lo, hi)
            })
            .collect();

        // keep_l(τ) = clamp(#scores > τ, lo, hi); Σ is monotone
        // non-increasing in τ, so bisect τ down to the step where the
        // budget is met. `count > τ` on a descending array is a
        // partition point.
        let count_above = |p: &LayerProbe, tau: f64| -> usize {
            p.scores.partition_point(|&s| s as f64 > tau)
        };
        let keeps_at = |tau: f64| -> Vec<usize> {
            probes
                .iter()
                .zip(bounds.iter())
                .map(|(p, &(lo, hi))| count_above(p, tau).clamp(lo, hi))
                .collect()
        };
        let mut tau_lo = 0.0f64; // keeps everything feasible → Σ ≥ K (clamped)
        let mut tau_hi = probes
            .iter()
            .filter_map(|p| p.scores.first())
            .fold(0.0f64, |m, &s| m.max(s as f64));
        for _ in 0..64 {
            let mid = 0.5 * (tau_lo + tau_hi);
            if keeps_at(mid).iter().sum::<usize>() > total {
                tau_lo = mid;
            } else {
                tau_hi = mid;
            }
        }
        // Conservative side (Σ ≤ K), then grow greedily: each step
        // adds the globally largest next marginal score among layers
        // with clamp headroom — exactly the water-filling order. The
        // shrink direction handles the all-clamped corner where even
        // τ_hi overshoots.
        let waterline = tau_hi;
        let mut keeps = keeps_at(waterline);
        let mut sum: usize = keeps.iter().sum();
        while sum < total {
            let mut best: Option<(f64, usize)> = None;
            for (l, p) in probes.iter().enumerate() {
                if keeps[l] >= bounds[l].1 {
                    continue;
                }
                let next = p.scores[keeps[l]] as f64;
                let better = match best {
                    Some((b, _)) => next > b,
                    None => true,
                };
                if better {
                    best = Some((next, l));
                }
            }
            match best {
                Some((_, l)) => keeps[l] += 1,
                None => break, // every layer at its cap: budget unreachable
            }
            sum += 1;
        }
        while sum > total {
            // Drop the globally smallest kept marginal score.
            let mut worst: Option<(f64, usize)> = None;
            for (l, p) in probes.iter().enumerate() {
                if keeps[l] <= bounds[l].0 {
                    continue;
                }
                let last = p.scores[keeps[l] - 1] as f64;
                let smaller = match worst {
                    Some((w, _)) => last < w,
                    None => true,
                };
                if smaller {
                    worst = Some((last, l));
                }
            }
            match worst {
                Some((_, l)) => keeps[l] -= 1,
                None => break,
            }
            sum -= 1;
        }

        let layers = probes
            .iter()
            .zip(uniform.iter())
            .zip(keeps.iter())
            .map(|((p, &ku), &k)| {
                let curve = kept_energy_curve(&p.scores);
                let numel = p.dout * p.din;
                let klo = ((ku as f64 * (1.0 - bcfg.delta)) as usize).clamp(0, numel);
                let khi = ((ku as f64 * (1.0 + bcfg.delta)) as usize).clamp(klo, numel);
                let sensitivity = if khi > klo {
                    (curve[khi] - curve[klo]) / (khi - klo) as f64
                } else {
                    0.0
                };
                LayerBudget {
                    name: p.name.clone(),
                    dout: p.dout,
                    din: p.din,
                    uniform_keep: ku,
                    keep: k,
                    sensitivity,
                }
            })
            .collect();
        Ok(BudgetPlan { base: *base, layers, waterline })
    }

    /// Σ allocated keep across layers.
    pub fn total_keep(&self) -> usize {
        self.layers.iter().map(|l| l.keep).sum()
    }

    /// Σ uniform (Eq. 10) keep across layers — equals
    /// [`total_keep`](BudgetPlan::total_keep) by the allocator's
    /// budget-conservation invariant.
    pub fn total_uniform_keep(&self) -> usize {
        self.layers.iter().map(|l| l.uniform_keep).sum()
    }

    /// The per-layer config the decompose stage uses: the uniform base
    /// with this layer's keep fraction pinned. Unknown names fall back
    /// to the base config (defensive; the pipeline only asks for
    /// planned layers).
    pub fn config_for(&self, name: &str) -> SlabConfig {
        match self.layers.iter().find(|l| l.name == name) {
            Some(l) => self.base.with_keep(l.keep_frac()),
            None => self.base,
        }
    }

    /// Serialize allocator decisions per layer (text + CSV via the
    /// shared [`Table`] renderer) — the auditability surface.
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Budget allocation — water-filled keep per layer (τ = {:.5}, Σ keep {} = Σ uniform {})",
                self.waterline,
                self.total_keep(),
                self.total_uniform_keep()
            ),
            &["layer", "shape", "uniform keep", "alloc keep", "Δ%", "sensitivity"],
        );
        for l in &self.layers {
            let delta_pct = if l.uniform_keep > 0 {
                100.0 * (l.keep as f64 - l.uniform_keep as f64) / l.uniform_keep as f64
            } else {
                0.0
            };
            t.push_row(vec![
                l.name.clone(),
                format!("{}x{}", l.dout, l.din),
                l.uniform_keep.to_string(),
                l.keep.to_string(),
                format!("{delta_pct:+.1}"),
                format!("{:.3e}", l.sensitivity),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::threshold::sorted_scores_desc;
    use crate::tensor::Mat;
    use crate::util::rng::Pcg64;

    fn probe(name: &str, dout: usize, din: usize, scale: f32, seed: u64) -> LayerProbe {
        let mut rng = Pcg64::seed_from_u64(seed);
        let m = Mat::rand_uniform(dout, din, 0.0, scale, &mut rng);
        LayerProbe {
            name: name.into(),
            dout,
            din,
            scores: sorted_scores_desc(&m),
        }
    }

    fn base() -> SlabConfig {
        SlabConfig { cr: 0.5, ..Default::default() }
    }

    #[test]
    fn budget_is_conserved_exactly() {
        let probes = vec![
            probe("a", 24, 48, 1.0, 1),
            probe("b", 24, 48, 0.1, 2),
            probe("c", 32, 32, 0.5, 3),
        ];
        let plan = BudgetPlan::plan(&probes, &base(), &BudgetConfig::default()).unwrap();
        assert_eq!(plan.total_keep(), plan.total_uniform_keep(), "exact conservation");
        assert_eq!(plan.layers.len(), 3);
    }

    #[test]
    fn hot_layers_win_budget_from_cold_layers() {
        // Two same-shape layers, one with 10x the score scale: the hot
        // layer must end with more keep than uniform, the cold one
        // with less — and the clamps must hold.
        let bcfg = BudgetConfig::default();
        let probes = vec![probe("hot", 24, 48, 1.0, 4), probe("cold", 24, 48, 0.05, 5)];
        let plan = BudgetPlan::plan(&probes, &base(), &bcfg).unwrap();
        let hot = &plan.layers[0];
        let cold = &plan.layers[1];
        assert!(hot.keep > hot.uniform_keep, "hot {} !> {}", hot.keep, hot.uniform_keep);
        assert!(cold.keep < cold.uniform_keep, "cold {} !< {}", cold.keep, cold.uniform_keep);
        assert!(hot.sensitivity > cold.sensitivity);
        for l in &plan.layers {
            let lo = (bcfg.min_scale * l.uniform_keep as f64).floor() as usize;
            let hi = (bcfg.max_scale * l.uniform_keep as f64).ceil() as usize;
            assert!(l.keep >= lo.max(1) && l.keep <= hi.min(l.numel() - 1), "{}: {}", l.name, l.keep);
        }
        assert_eq!(plan.total_keep(), plan.total_uniform_keep());
    }

    #[test]
    fn allocation_improves_kept_energy_over_uniform() {
        // The point of the exercise, at the proxy level: kept score
        // energy under the plan ≥ kept energy under uniform, at equal
        // total budget.
        let probes = vec![
            probe("a", 16, 64, 1.0, 6),
            probe("b", 16, 64, 0.2, 7),
            probe("c", 16, 64, 0.01, 8),
        ];
        let plan = BudgetPlan::plan(&probes, &base(), &BudgetConfig::default()).unwrap();
        let energy = |keeps: Vec<usize>| -> f64 {
            probes
                .iter()
                .zip(keeps)
                .map(|(p, k)| kept_energy_curve(&p.scores)[k])
                .sum()
        };
        let e_alloc = energy(plan.layers.iter().map(|l| l.keep).collect());
        let e_uniform = energy(plan.layers.iter().map(|l| l.uniform_keep).collect());
        assert!(
            e_alloc >= e_uniform,
            "alloc energy {e_alloc} < uniform {e_uniform}"
        );
        assert!(e_alloc > e_uniform, "scale spread this wide must strictly improve");
    }

    #[test]
    fn plan_is_deterministic_and_configs_are_feasible() {
        let probes = vec![probe("x", 20, 40, 1.0, 9), probe("y", 40, 20, 0.3, 10)];
        let a = BudgetPlan::plan(&probes, &base(), &BudgetConfig::default()).unwrap();
        let b = BudgetPlan::plan(&probes, &base(), &BudgetConfig::default()).unwrap();
        assert_eq!(a, b);
        for l in &a.layers {
            let cfg = a.config_for(&l.name);
            let f = cfg.keep_fraction(l.dout, l.din).expect("planned config feasible");
            assert!((f - l.keep_frac()).abs() < 1e-12);
        }
        // Unknown layers fall back to the base config.
        assert_eq!(a.config_for("nope"), a.base);
    }

    #[test]
    fn infeasible_uniform_base_propagates() {
        let probes = vec![probe("tiny", 2, 2, 1.0, 11)];
        assert!(BudgetPlan::plan(&probes, &base(), &BudgetConfig::default()).is_err());
    }

    #[test]
    fn table_renders_every_layer_and_csv() {
        let probes = vec![probe("l0.wq", 16, 32, 1.0, 12), probe("l0.wo", 16, 16, 0.2, 13)];
        let plan = BudgetPlan::plan(&probes, &base(), &BudgetConfig::default()).unwrap();
        let t = plan.to_table();
        let md = t.render();
        assert!(md.contains("l0.wq") && md.contains("l0.wo"));
        assert!(md.contains("τ ="));
        let csv = t.render_csv();
        assert!(csv.starts_with("layer,shape,uniform keep,alloc keep,"));
        assert_eq!(csv.lines().count(), 1 + 2);
    }
}
