//! Serving coordinator: request router + dynamic batcher over the
//! AOT prefill/decode artifacts.
//!
//! vLLM-router-shaped, scaled to this testbed: client threads submit
//! [`Request`]s into an mpsc queue; the router thread drains up to
//! `serve_batch` requests (waiting at most `batch_window` for
//! stragglers — classic dynamic batching), runs one `prefill_{cfg}`
//! and then `decode_step_{cfg}` until every sequence in the batch hit
//! its token budget or EOS, and completes the callers' response
//! channels. Greedy decoding; deterministic.
//!
//! The compressed model serves through the same artifacts with the
//! reconstructed `Ŵ` swapped in — identical code path, smaller
//! deployed weights (the packed-format byte savings are measured in
//! `bench_kernels`; end-to-end latency/throughput in
//! `examples/serve_compressed.rs`).

use crate::data::EOS;
use crate::model::Params;
use crate::runtime::client::RuntimeError;
use crate::runtime::{lit_i32, lit_scalar_i32, to_vec_f32, Runtime};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub tokens: Vec<i32>,
    /// Queue + batch wait before prefill started.
    pub queue_ms: f64,
    /// Total request latency.
    pub latency_ms: f64,
}

struct Job {
    req: Request,
    submitted: Instant,
    reply: Sender<Response>,
}

/// Server handle: submit requests, then `shutdown()`.
pub struct Server {
    tx: Sender<Job>,
    handle: Option<std::thread::JoinHandle<Result<ServeStats, RuntimeError>>>,
}

#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    pub generated_tokens: usize,
    pub wall_secs: f64,
}

impl ServeStats {
    pub fn tokens_per_sec(&self) -> f64 {
        self.generated_tokens as f64 / self.wall_secs.max(1e-9)
    }

    /// Mean batch occupancy (1.0 = always full batches).
    pub fn occupancy(&self, batch_cap: usize) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / (self.batches * batch_cap) as f64
    }
}

pub struct ServerConfig {
    /// Max time the router waits to fill a batch.
    pub batch_window: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_window: Duration::from_millis(5),
        }
    }
}

impl Server {
    /// Start the router thread. The PJRT client is *not* `Send`
    /// (Rc-based FFI handles), so the router thread owns its own
    /// [`Runtime`] over `artifacts_dir` — the natural shape anyway:
    /// the engine owns the device, clients own channels. `params` is
    /// the model to serve (dense or compressed — same ABI).
    pub fn start(artifacts_dir: PathBuf, params: Params, scfg: ServerConfig) -> Server {
        let (tx, rx) = channel::<Job>();
        let handle = std::thread::Builder::new()
            .name("slab-router".into())
            .spawn(move || {
                let rt = Runtime::new(&artifacts_dir)?;
                router_loop(&rt, params, scfg, rx)
            })
            .expect("spawn router");
        Server {
            tx,
            handle: Some(handle),
        }
    }

    /// Submit a request; returns the response receiver immediately.
    pub fn submit(&self, req: Request) -> Receiver<Response> {
        let (reply, rx) = channel();
        self.tx
            .send(Job {
                req,
                submitted: Instant::now(),
                reply,
            })
            .expect("router alive");
        rx
    }

    /// Blocking convenience call.
    pub fn generate(&self, req: Request) -> Response {
        self.submit(req).recv().expect("router response")
    }

    /// Stop accepting requests, drain, and return aggregate stats.
    pub fn shutdown(mut self) -> Result<ServeStats, RuntimeError> {
        drop(self.tx);
        self.handle
            .take()
            .unwrap()
            .join()
            .expect("router join")
    }
}

fn router_loop(
    rt: &Runtime,
    params: Params,
    scfg: ServerConfig,
    rx: Receiver<Job>,
) -> Result<ServeStats, RuntimeError> {
    let cfg = params.cfg.clone();
    let cap = rt.manifest.serve_batch;
    let prompt_len = cfg.prompt_len;
    let prefill_name = format!("prefill_{}", cfg.name);
    let decode_name = format!("decode_step_{}", cfg.name);
    // Build param literals once; borrowed by every call.
    let dev = params.to_literals();
    let mut stats = ServeStats::default();
    let t_start = Instant::now();

    'outer: loop {
        // --- gather a batch (dynamic batching) -------------------------
        let mut jobs: Vec<Job> = Vec::with_capacity(cap);
        match rx.recv() {
            Ok(j) => jobs.push(j),
            Err(_) => break 'outer, // all senders dropped
        }
        let window_end = Instant::now() + scfg.batch_window;
        while jobs.len() < cap {
            match rx.try_recv() {
                Ok(j) => jobs.push(j),
                Err(TryRecvError::Empty) => {
                    if Instant::now() >= window_end {
                        break;
                    }
                    std::thread::yield_now();
                }
                Err(TryRecvError::Disconnected) => break,
            }
        }
        let t_batch = Instant::now();
        stats.batches += 1;
        stats.requests += jobs.len();

        // --- prefill -----------------------------------------------------
        // Left-aligned prompts, right-padded to prompt_len, PAD keys are
        // attention-masked inside the artifact.
        let mut flat = vec![0i32; cap * prompt_len];
        for (s, job) in jobs.iter().enumerate() {
            let p = &job.req.prompt;
            let n = p.len().min(prompt_len);
            flat[s * prompt_len..s * prompt_len + n].copy_from_slice(&p[..n]);
        }
        let tok_lit = lit_i32(&flat, &[cap, prompt_len]);
        let mut inputs: Vec<&xla::Literal> = dev.iter().collect();
        inputs.push(&tok_lit);
        let outs = rt.execute_refs(&prefill_name, &inputs)?;
        let (mut logits, mut kc, mut vc) = take3(outs);

        // --- decode loop ---------------------------------------------------
        let max_new: usize = jobs
            .iter()
            .map(|j| j.req.max_new)
            .max()
            .unwrap_or(0)
            .min(cfg.max_seq - prompt_len);
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); jobs.len()];
        let mut done = vec![false; jobs.len()];
        for step in 0..max_new {
            // Greedy sample from the last logits.
            let l = to_vec_f32(&logits);
            let mut next = vec![EOS; cap];
            for (s, job) in jobs.iter().enumerate() {
                if done[s] || step >= job.req.max_new {
                    done[s] = true;
                    continue;
                }
                let row = &l[s * cfg.vocab..(s + 1) * cfg.vocab];
                let mut best = 4usize; // never emit specials by argmax ties
                let mut best_v = f32::NEG_INFINITY;
                for (tid, &v) in row.iter().enumerate() {
                    if v > best_v {
                        best_v = v;
                        best = tid;
                    }
                }
                next[s] = best as i32;
                if best as i32 == EOS {
                    done[s] = true;
                } else {
                    generated[s].push(best as i32);
                    stats.generated_tokens += 1;
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
            let pos = (prompt_len + step) as i32;
            let tok = lit_i32(&next, &[cap]);
            let pb = lit_scalar_i32(pos);
            let mut inputs: Vec<&xla::Literal> = dev.iter().collect();
            inputs.push(&kc);
            inputs.push(&vc);
            inputs.push(&tok);
            inputs.push(&pb);
            let outs = rt.execute_refs(&decode_name, &inputs)?;
            let (l2, k2, v2) = take3(outs);
            logits = l2;
            kc = k2;
            vc = v2;
        }

        // --- respond -------------------------------------------------------
        for (s, job) in jobs.into_iter().enumerate() {
            let _ = job.reply.send(Response {
                tokens: std::mem::take(&mut generated[s]),
                queue_ms: (t_batch - job.submitted).as_secs_f64() * 1e3,
                latency_ms: job.submitted.elapsed().as_secs_f64() * 1e3,
            });
        }
    }
    stats.wall_secs = t_start.elapsed().as_secs_f64();
    Ok(stats)
}

fn take3(mut outs: Vec<xla::Literal>) -> (xla::Literal, xla::Literal, xla::Literal) {
    assert!(outs.len() >= 3);
    let c = outs.pop().unwrap();
    let b = outs.pop().unwrap();
    let a = outs.pop().unwrap();
    (a, b, c)
}
