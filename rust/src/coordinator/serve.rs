//! Serving coordinator: request router over three interchangeable
//! engines — two batch-at-a-time backends and a continuous-batching
//! scheduler.
//!
//! vLLM-router-shaped, scaled to this testbed: client threads submit
//! [`Request`]s into an mpsc queue; the router thread owns the engine
//! and completes the callers' response channels. Greedy decoding;
//! deterministic.
//!
//! The engine behind the queue is a [`Backend`]:
//!
//! * [`Backend::Artifact`] — the AOT `prefill_{cfg}` /
//!   `decode_step_{cfg}` XLA executables over dense weights. A
//!   compressed model serves here with the reconstructed `Ŵ` swapped
//!   in — identical code path, smaller *checkpoint*, but dense
//!   request-time compute. Dynamic batching: drain up to the batch
//!   cap, wait at most `batch_window` for stragglers, decode the
//!   whole batch to budget/EOS.
//! * [`Backend::NativePacked`] — the pure-Rust
//!   [`SlabModel`](crate::model::SlabModel) forward that consumes the
//!   packed `W_S + u vᵀ ⊙ W_B` format directly through the parallel
//!   blocked kernels; the byte savings become request-time memory
//!   traffic savings (DESIGN.md §3, §6). Same dynamic batching as the
//!   artifact backend.
//! * [`Backend::NativeBatched`] — the same native engine behind the
//!   continuous-batching [`Scheduler`]: requests prefill individually
//!   and *join the running decode batch* (prefill-then-join), finished
//!   sessions leave it immediately, and a bounded admission queue
//!   rejects overflow with an explicit backpressure [`Response`]
//!   (DESIGN.md §6a).
//!
//! All backends sit behind the same [`Request`]/[`Response`] API, so
//! the batcher, clients, and stats are engine-agnostic
//! (`examples/serve_compressed.rs` races all four configurations),
//! and the native pair is pinned token-identical by tests here and in
//! `rust/tests/integration.rs`.

use crate::data::{EOS, PAD};
use crate::model::{greedy_token, DecodeSlot, KvCachePool, Params, SlabModel};
use crate::runtime::client::RuntimeError;
use crate::runtime::{lit_i32, lit_scalar_i32, to_vec_f32, Runtime};
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub tokens: Vec<i32>,
    /// Queue + batch wait before prefill started.
    pub queue_ms: f64,
    /// Total request latency.
    pub latency_ms: f64,
    /// Backpressure: the admission queue was full and the request was
    /// never scheduled (`tokens` is empty). Only the continuous
    /// batcher ([`Backend::NativeBatched`]) rejects; the dynamic
    /// batchers queue without bound.
    pub rejected: bool,
}

struct Job {
    req: Request,
    submitted: Instant,
    reply: Sender<Response>,
}

/// Server handle: submit requests, then `shutdown()`.
pub struct Server {
    tx: Sender<Job>,
    handle: Option<std::thread::JoinHandle<Result<ServeStats, RuntimeError>>>,
}

#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Requests that received a generated (non-rejected) response.
    pub requests: usize,
    /// Dynamic batchers: batches executed. Continuous batcher: decode
    /// ticks executed.
    pub batches: usize,
    pub generated_tokens: usize,
    /// Requests rejected by admission-queue backpressure.
    pub rejected: usize,
    /// Sessions terminated by the sequence cap (`max_seq_len`) before
    /// reaching their own token budget or EOS.
    pub evicted: usize,
    pub wall_secs: f64,
}

impl ServeStats {
    pub fn tokens_per_sec(&self) -> f64 {
        self.generated_tokens as f64 / self.wall_secs.max(1e-9)
    }

    /// Mean batch occupancy (1.0 = always full batches).
    pub fn occupancy(&self, batch_cap: usize) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / (self.batches * batch_cap) as f64
    }
}

pub struct ServerConfig {
    /// Max time the router waits to fill a batch.
    pub batch_window: Duration,
    /// Batch cap for [`Backend::NativePacked`] (the artifact backend's
    /// cap is baked into its static-shaped executables, so it comes
    /// from the manifest instead).
    pub serve_batch: usize,
    /// Continuous-batching knobs for [`Backend::NativeBatched`];
    /// ignored by the dynamic batchers.
    pub sched: SchedulerConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_window: Duration::from_millis(5),
            serve_batch: 4,
            sched: SchedulerConfig::default(),
        }
    }
}

/// Knobs for the continuous-batching [`Scheduler`].
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Hard cap on concurrently decoding sessions (≥ 1 enforced) —
    /// also the [`KvCachePool`] capacity.
    pub max_batch: usize,
    /// Per-session sequence cap (prompt plus generated positions),
    /// clamped to the model's `max_seq`; `0` means the model's
    /// `max_seq`. A session that reaches it is evicted mid-batch with
    /// the tokens it has.
    pub max_seq_len: usize,
    /// Admission-queue bound (≥ 1 enforced); submissions past it get
    /// an immediate `Response { rejected: true, .. }` instead of
    /// unbounded queue growth.
    pub queue_cap: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 8,
            max_seq_len: 0,
            queue_cap: 64,
        }
    }
}

/// The engine a [`Server`] routes requests to. Every variant serves
/// the same [`Request`]/[`Response`] API with identical
/// greedy-decoding semantics; they differ in *what executes a batch*
/// and *how requests become batches*:
///
/// * `Artifact` — XLA prefill/decode executables over an artifact
///   directory, fed dense parameter literals (a compressed model
///   serves its reconstructed `Ŵ`). The router thread owns the PJRT
///   client (it is not `Send`). Dynamic batching.
/// * `NativePacked` — a [`SlabModel`]: pure-Rust forward straight
///   from the packed SLaB format, parallel blocked kernels, no
///   artifacts or Python toolchain anywhere near the request path.
///   Dynamic batching.
/// * `NativeBatched` — the same [`SlabModel`] engine behind the
///   continuous-batching [`Scheduler`].
pub enum Backend {
    /// AOT artifact engine: `(artifacts_dir, params)`.
    Artifact {
        artifacts_dir: PathBuf,
        params: Params,
    },
    /// Native packed engine (boxed: a whole model lives inside).
    NativePacked(Box<SlabModel>),
    /// Native packed engine behind the continuous-batching
    /// [`Scheduler`]: per-request prefill-then-join admission,
    /// per-session termination/eviction, bounded-queue backpressure.
    /// Token-identical to `NativePacked` for any request mix (pinned
    /// by tests); strictly higher decode throughput under load, since
    /// every weight pass is shared by all live sessions.
    NativeBatched(Box<SlabModel>),
}

impl Server {
    /// Start the router thread over the artifact backend — the
    /// historical entry point, kept as a convenience wrapper around
    /// [`Server::start_with`]. `params` is the model to serve (dense
    /// or compressed — same ABI).
    pub fn start(artifacts_dir: PathBuf, params: Params, scfg: ServerConfig) -> Server {
        Server::start_with(
            Backend::Artifact {
                artifacts_dir,
                params,
            },
            scfg,
        )
    }

    /// Start the router thread over an explicit [`Backend`]. The
    /// engine is owned by the router thread (for `Artifact` that is
    /// where the PJRT client must live; for `NativePacked` the model
    /// and its thread pool move in) — the natural shape anyway: the
    /// engine owns the device, clients own channels.
    pub fn start_with(backend: Backend, scfg: ServerConfig) -> Server {
        let (tx, rx) = channel::<Job>();
        let handle = std::thread::Builder::new()
            .name("slab-router".into())
            .spawn(move || match backend {
                Backend::Artifact {
                    artifacts_dir,
                    params,
                } => {
                    let rt = Runtime::new(&artifacts_dir)?;
                    router_loop(&rt, params, scfg, rx)
                }
                Backend::NativePacked(model) => native_router_loop(&model, scfg, rx),
                Backend::NativeBatched(model) => batched_router_loop(model, scfg, rx),
            })
            .expect("spawn router");
        Server {
            tx,
            handle: Some(handle),
        }
    }

    /// Submit a request; returns the response receiver immediately.
    pub fn submit(&self, req: Request) -> Receiver<Response> {
        let (reply, rx) = channel();
        self.tx
            .send(Job {
                req,
                submitted: Instant::now(),
                reply,
            })
            .expect("router alive");
        rx
    }

    /// Blocking convenience call.
    pub fn generate(&self, req: Request) -> Response {
        self.submit(req).recv().expect("router response")
    }

    /// Stop accepting requests, drain, and return aggregate stats.
    pub fn shutdown(mut self) -> Result<ServeStats, RuntimeError> {
        drop(self.tx);
        self.handle
            .take()
            .unwrap()
            .join()
            .expect("router join")
    }
}

fn router_loop(
    rt: &Runtime,
    params: Params,
    scfg: ServerConfig,
    rx: Receiver<Job>,
) -> Result<ServeStats, RuntimeError> {
    let cfg = params.cfg.clone();
    let cap = rt.manifest.serve_batch;
    let prompt_len = cfg.prompt_len;
    let prefill_name = format!("prefill_{}", cfg.name);
    let decode_name = format!("decode_step_{}", cfg.name);
    // Build param literals once; borrowed by every call.
    let dev = params.to_literals();
    let mut stats = ServeStats::default();
    let t_start = Instant::now();

    'outer: loop {
        // --- gather a batch (dynamic batching) -------------------------
        let Some(jobs) = gather_batch(&rx, cap, scfg.batch_window) else {
            break 'outer; // all senders dropped
        };
        let t_batch = Instant::now();
        stats.batches += 1;
        stats.requests += jobs.len();

        // --- prefill -----------------------------------------------------
        // Left-aligned prompts, right-padded to prompt_len, PAD keys are
        // attention-masked inside the artifact.
        let mut flat = vec![0i32; cap * prompt_len];
        for (s, job) in jobs.iter().enumerate() {
            let p = &job.req.prompt;
            let n = p.len().min(prompt_len);
            flat[s * prompt_len..s * prompt_len + n].copy_from_slice(&p[..n]);
        }
        let tok_lit = lit_i32(&flat, &[cap, prompt_len]);
        let mut inputs: Vec<&xla::Literal> = dev.iter().collect();
        inputs.push(&tok_lit);
        let outs = rt.execute_refs(&prefill_name, &inputs)?;
        let (mut logits, mut kc, mut vc) = take3(outs);

        // --- decode loop ---------------------------------------------------
        let max_new: usize = jobs
            .iter()
            .map(|j| j.req.max_new)
            .max()
            .unwrap_or(0)
            .min(cfg.max_seq - prompt_len);
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); jobs.len()];
        let mut done = vec![false; jobs.len()];
        for step in 0..max_new {
            // Greedy sample from the last logits.
            let l = to_vec_f32(&logits);
            let mut next = vec![EOS; cap];
            for (s, job) in jobs.iter().enumerate() {
                if done[s] || step >= job.req.max_new {
                    done[s] = true;
                    continue;
                }
                let tok = greedy_token(&l[s * cfg.vocab..(s + 1) * cfg.vocab]);
                next[s] = tok;
                if tok == EOS {
                    done[s] = true;
                } else {
                    generated[s].push(tok);
                    stats.generated_tokens += 1;
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
            let pos = (prompt_len + step) as i32;
            let tok = lit_i32(&next, &[cap]);
            let pb = lit_scalar_i32(pos);
            let mut inputs: Vec<&xla::Literal> = dev.iter().collect();
            inputs.push(&kc);
            inputs.push(&vc);
            inputs.push(&tok);
            inputs.push(&pb);
            let outs = rt.execute_refs(&decode_name, &inputs)?;
            let (l2, k2, v2) = take3(outs);
            logits = l2;
            kc = k2;
            vc = v2;
        }

        // --- respond -------------------------------------------------------
        for (s, job) in jobs.into_iter().enumerate() {
            let _ = job.reply.send(Response {
                tokens: std::mem::take(&mut generated[s]),
                queue_ms: (t_batch - job.submitted).as_secs_f64() * 1e3,
                latency_ms: job.submitted.elapsed().as_secs_f64() * 1e3,
                rejected: false,
            });
        }
    }
    stats.wall_secs = t_start.elapsed().as_secs_f64();
    Ok(stats)
}

/// Drain up to `cap` jobs: block for the first, then poll for
/// stragglers until the batch window closes. `None` once all senders
/// dropped and the queue is empty (shutdown).
fn gather_batch(rx: &Receiver<Job>, cap: usize, window: Duration) -> Option<Vec<Job>> {
    let mut jobs: Vec<Job> = Vec::with_capacity(cap);
    match rx.recv() {
        Ok(j) => jobs.push(j),
        Err(_) => return None,
    }
    let window_end = Instant::now() + window;
    while jobs.len() < cap {
        match rx.try_recv() {
            Ok(j) => jobs.push(j),
            Err(TryRecvError::Empty) => {
                if Instant::now() >= window_end {
                    break;
                }
                std::thread::yield_now();
            }
            Err(TryRecvError::Disconnected) => break,
        }
    }
    Some(jobs)
}

/// The [`Backend::NativePacked`] router: same dynamic batching,
/// greedy policy, and accounting as [`router_loop`], but prefill and
/// decode run through [`SlabModel`] — no PJRT, no padding the batch
/// up to an artifact's static shape (the native engine takes the
/// actual batch size).
fn native_router_loop(
    model: &SlabModel,
    scfg: ServerConfig,
    rx: Receiver<Job>,
) -> Result<ServeStats, RuntimeError> {
    let cap = scfg.serve_batch.max(1);
    let prompt_len = model.cfg.prompt_len;
    let mut stats = ServeStats::default();
    let t_start = Instant::now();

    loop {
        let Some(jobs) = gather_batch(&rx, cap, scfg.batch_window) else {
            break;
        };
        let t_batch = Instant::now();
        stats.batches += 1;
        stats.requests += jobs.len();
        let bsz = jobs.len();

        // --- prefill: left-aligned prompts, PAD-padded ------------------
        let vmax = model.cfg.vocab.saturating_sub(1) as i32;
        let mut flat = vec![PAD; bsz * prompt_len];
        for (s, job) in jobs.iter().enumerate() {
            let p = &job.req.prompt;
            let n = p.len().min(prompt_len);
            for (j, &tok) in p[..n].iter().enumerate() {
                // Clamp malformed ids like the artifact backend does
                // (XLA gather clamps OOB indices): one bad request
                // must not panic the router thread for everyone.
                flat[s * prompt_len + j] = tok.clamp(0, vmax);
            }
        }
        let (mut logits, mut cache) = model.prefill(&flat, bsz);

        // --- decode loop -------------------------------------------------
        let max_new: usize = jobs
            .iter()
            .map(|j| j.req.max_new)
            .max()
            .unwrap_or(0)
            .min(model.cfg.max_seq.saturating_sub(prompt_len));
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); bsz];
        let mut done = vec![false; bsz];
        for step in 0..max_new {
            let mut next = vec![EOS; bsz];
            for (s, job) in jobs.iter().enumerate() {
                if done[s] || step >= job.req.max_new {
                    done[s] = true;
                    continue;
                }
                let tok = greedy_token(logits.row(s));
                next[s] = tok;
                if tok == EOS {
                    done[s] = true;
                } else {
                    generated[s].push(tok);
                    stats.generated_tokens += 1;
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
            logits = model.decode_step(&mut cache, &next, prompt_len + step);
        }

        // --- respond -------------------------------------------------------
        for (s, job) in jobs.into_iter().enumerate() {
            let _ = job.reply.send(Response {
                tokens: std::mem::take(&mut generated[s]),
                queue_ms: (t_batch - job.submitted).as_secs_f64() * 1e3,
                latency_ms: job.submitted.elapsed().as_secs_f64() * 1e3,
                rejected: false,
            });
        }
    }
    stats.wall_secs = t_start.elapsed().as_secs_f64();
    Ok(stats)
}

/// One live request inside the continuous batcher.
struct Session {
    job: Job,
    /// [`KvCachePool`] handle once the session joined the decode
    /// batch; `None` for sessions that finished at prefill.
    slot: Option<usize>,
    /// Next cache write position (`prompt_len + generated so far`).
    pos: usize,
    /// Token to feed at the next decode tick.
    next_tok: i32,
    /// Effective token budget: `min(max_new, seq_cap − prompt_len)` —
    /// exactly the serial router's clamp, so the two paths stay
    /// token-identical.
    budget: usize,
    generated: Vec<i32>,
    /// True when `budget` was cut down by the sequence cap — reaching
    /// it then counts as an eviction, not a normal completion.
    capped: bool,
    /// When the session left the queue (prefill start).
    t_admit: Instant,
}

/// Continuous-batching scheduler over the native packed engine — the
/// state machine behind [`Backend::NativeBatched`] (DESIGN.md §6a).
///
/// Request lifecycle: bounded admission queue → individual prefill
/// (prefill-then-join) → member of the shared decode batch until EOS
/// / token budget / sequence-cap eviction → response. One
/// [`tick`](Scheduler::tick) = admit up to `max_batch` live sessions,
/// then one [`SlabModel::decode_batch`] step for all of them; new
/// requests join the running batch between ticks without stalling
/// in-flight decodes, and finished sessions free their
/// [`KvCachePool`] slot immediately. Submissions past `queue_cap`
/// receive an explicit rejected [`Response`] (backpressure) instead
/// of growing the queue without bound.
///
/// Per session the sampling semantics are exactly the serial native
/// router's (same prompt padding, same greedy policy, same budget
/// clamp), and [`SlabModel::decode_batch`] is bit-identical row-wise
/// to serial decode — so a `NativeBatched` server answers every
/// request with the same tokens a `NativePacked` server would.
pub struct Scheduler {
    model: Box<SlabModel>,
    cfg: SchedulerConfig,
    /// `min(model.max_seq, max_seq_len)` — the hard position cap.
    seq_cap: usize,
    kv: KvCachePool,
    queue: VecDeque<Job>,
    active: Vec<Session>,
    stats: ServeStats,
}

impl Scheduler {
    pub fn new(model: Box<SlabModel>, cfg: SchedulerConfig) -> Scheduler {
        let mut cfg = cfg;
        cfg.max_batch = cfg.max_batch.max(1);
        cfg.queue_cap = cfg.queue_cap.max(1);
        let seq_cap = if cfg.max_seq_len == 0 {
            model.cfg.max_seq
        } else {
            cfg.max_seq_len.min(model.cfg.max_seq)
        };
        let kv = KvCachePool::for_model(&model, cfg.max_batch);
        Scheduler {
            model,
            cfg,
            seq_cap,
            kv,
            queue: VecDeque::new(),
            active: Vec::new(),
            stats: ServeStats::default(),
        }
    }

    /// Submit a request. Returns `false` (after sending an immediate
    /// rejected [`Response`]) when the admission queue is full.
    pub fn enqueue(&mut self, req: Request, reply: Sender<Response>) -> bool {
        self.enqueue_job(Job {
            req,
            submitted: Instant::now(),
            reply,
        })
    }

    fn enqueue_job(&mut self, job: Job) -> bool {
        if self.queue.len() >= self.cfg.queue_cap {
            self.stats.rejected += 1;
            let waited_ms = job.submitted.elapsed().as_secs_f64() * 1e3;
            let _ = job.reply.send(Response {
                tokens: Vec::new(),
                queue_ms: waited_ms,
                latency_ms: waited_ms,
                rejected: true,
            });
            return false;
        }
        self.queue.push_back(job);
        true
    }

    /// Anything queued or decoding?
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    /// Sessions currently in the decode batch.
    pub fn active_sessions(&self) -> usize {
        self.active.len()
    }

    /// Requests waiting for admission.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Tear down, returning the accumulated stats (`wall_secs` is the
    /// router's to fill — the scheduler does not own the clock).
    pub fn into_stats(self) -> ServeStats {
        self.stats
    }

    /// One continuous-batching step: admit up to the batch cap, then
    /// run one shared decode step for every active session. Returns
    /// the number of sessions decoded; an empty tick (nothing queued,
    /// nothing active) is a no-op returning 0.
    pub fn tick(&mut self) -> usize {
        self.admit();
        self.decode_tick()
    }

    /// Prefill-then-join admission: each queued request prefills
    /// alone (batch 1), samples its first token, and either finishes
    /// on the spot (zero budget / immediate EOS / budget of one) or
    /// adopts its KV cache into the pool and joins the decode batch.
    fn admit(&mut self) {
        while self.active.len() < self.cfg.max_batch && !self.kv.is_full() {
            let Some(job) = self.queue.pop_front() else {
                break;
            };
            let t_admit = Instant::now();
            let (logits, cache) = self.model.prefill_session(&job.req.prompt);
            let prompt_len = self.model.cfg.prompt_len;
            let headroom = self.seq_cap.saturating_sub(prompt_len);
            // The serial router's exact clamp, so the two native paths
            // stay token-identical; `capped` remembers whether the
            // sequence cap (not the caller) set the budget.
            let capped = headroom < job.req.max_new;
            let budget = job.req.max_new.min(headroom);
            let mut sess = Session {
                job,
                slot: None,
                pos: prompt_len,
                next_tok: EOS,
                budget,
                generated: Vec::new(),
                capped,
                t_admit,
            };
            if sess.budget == 0 {
                self.finish(sess, capped);
                continue;
            }
            let first = greedy_token(logits.row(0));
            if first == EOS {
                self.finish(sess, false);
                continue;
            }
            sess.generated.push(first);
            self.stats.generated_tokens += 1;
            if sess.generated.len() >= sess.budget {
                self.finish(sess, capped);
                continue;
            }
            sess.next_tok = first;
            sess.slot = Some(self.kv.adopt(cache).expect("kv pool sized to max_batch"));
            self.active.push(sess);
        }
    }

    /// One shared decode step for the active batch; terminating
    /// sessions (EOS / budget / cap eviction) leave it immediately.
    fn decode_tick(&mut self) -> usize {
        // Hard guard: never let a session write past the cap. The
        // budget clamp at admission finishes capped sessions one step
        // earlier, so this only fires if the bookkeeping drifts.
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].pos >= self.seq_cap {
                let sess = self.active.remove(i);
                self.finish(sess, true);
            } else {
                i += 1;
            }
        }
        if self.active.is_empty() {
            return 0;
        }
        let steps: Vec<DecodeSlot> = self
            .active
            .iter()
            .map(|s| DecodeSlot {
                session: s.slot.expect("active session owns a kv slot"),
                token: s.next_tok,
                pos: s.pos,
            })
            .collect();
        let logits = self.model.decode_batch(&mut self.kv, &steps);
        self.stats.batches += 1;
        let n = steps.len();
        let mut new_tokens = 0usize;
        // (row, evicted) of sessions that terminate this tick.
        let mut done: Vec<(usize, bool)> = Vec::new();
        for (r, sess) in self.active.iter_mut().enumerate() {
            sess.pos += 1;
            let tok = greedy_token(logits.row(r));
            if tok == EOS {
                done.push((r, false)); // EOS, not the cap, ended it
                continue;
            }
            sess.generated.push(tok);
            new_tokens += 1;
            if sess.generated.len() >= sess.budget {
                done.push((r, sess.capped));
            } else {
                sess.next_tok = tok;
            }
        }
        self.stats.generated_tokens += new_tokens;
        for &(r, evicted) in done.iter().rev() {
            let sess = self.active.remove(r);
            self.finish(sess, evicted);
        }
        n
    }

    /// Complete a session: free its KV slot, account it, reply.
    fn finish(&mut self, sess: Session, evicted: bool) {
        if let Some(slot) = sess.slot {
            self.kv.release(slot);
        }
        if evicted {
            self.stats.evicted += 1;
        }
        self.stats.requests += 1;
        let _ = sess.job.reply.send(Response {
            tokens: sess.generated,
            queue_ms: (sess.t_admit - sess.job.submitted).as_secs_f64() * 1e3,
            latency_ms: sess.job.submitted.elapsed().as_secs_f64() * 1e3,
            rejected: false,
        });
    }
}

/// The [`Backend::NativeBatched`] router: a [`Scheduler`] driven off
/// the mpsc queue. Unlike the dynamic batchers there is no batch
/// window — arrivals are drained non-blockingly before every tick and
/// join the running batch at their first admission opportunity; the
/// router only blocks when fully idle.
fn batched_router_loop(
    model: Box<SlabModel>,
    scfg: ServerConfig,
    rx: Receiver<Job>,
) -> Result<ServeStats, RuntimeError> {
    let mut sched = Scheduler::new(model, scfg.sched.clone());
    let t_start = Instant::now();
    let mut open = true;
    loop {
        if open && !sched.has_work() {
            // Idle: block for the next request (or shutdown).
            match rx.recv() {
                Ok(job) => {
                    sched.enqueue_job(job);
                }
                Err(_) => open = false,
            }
        }
        while open {
            match rx.try_recv() {
                Ok(job) => {
                    sched.enqueue_job(job);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => open = false,
            }
        }
        if !sched.has_work() {
            if !open {
                break; // drained and no more senders: shutdown
            }
            continue;
        }
        sched.tick();
    }
    let mut stats = sched.into_stats();
    stats.wall_secs = t_start.elapsed().as_secs_f64();
    Ok(stats)
}

fn take3(mut outs: Vec<xla::Literal>) -> (xla::Literal, xla::Literal, xla::Literal) {
    assert!(outs.len() >= 3);
    let c = outs.pop().unwrap();
    let b = outs.pop().unwrap();
    let a = outs.pop().unwrap();
    (a, b, c)
}

#[cfg(test)]
mod tests {
    //! The native backend needs no artifacts, so the router/batcher
    //! invariants get exercised on every `cargo test`, not only when
    //! `make artifacts` has run.

    use super::*;
    use crate::runtime::ModelCfg;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg::llama("tiny-serve", 32, 8, 1, 2, 16, 12, 4)
    }

    #[test]
    fn native_backend_serves_every_request_exactly_once() {
        let cfg = tiny_cfg();
        let model = SlabModel::from_dense(&Params::init(&cfg, 51), 2);
        let scfg = ServerConfig {
            serve_batch: 3,
            ..Default::default()
        };
        let server = Server::start_with(Backend::NativePacked(Box::new(model)), scfg);
        let n = 10;
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                server.submit(Request {
                    prompt: vec![5 + i as i32, 6, 7],
                    max_new: 1 + (i % 4),
                })
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().expect("response");
            assert!(r.tokens.len() <= 1 + (i % 4), "token budget violated");
            assert!(r.latency_ms >= r.queue_ms);
            assert!(r.tokens.iter().all(|&t| t != EOS && t != PAD));
        }
        let stats = server.shutdown().expect("stats");
        assert_eq!(stats.requests, n);
        assert!(stats.batches >= n.div_ceil(3));
        assert!(stats.requests <= stats.batches * 3);
        assert!(stats.wall_secs > 0.0);
    }

    #[test]
    fn native_backend_survives_out_of_vocab_prompts() {
        // Malformed token ids are clamped (like XLA gather in the
        // artifact backend), not allowed to panic the router thread.
        let cfg = tiny_cfg();
        let model = SlabModel::from_dense(&Params::init(&cfg, 53), 1);
        let server = Server::start_with(
            Backend::NativePacked(Box::new(model)),
            ServerConfig::default(),
        );
        let bad = server.generate(Request {
            prompt: vec![-7, i32::MAX, 9999, 5],
            max_new: 3,
        });
        assert!(bad.tokens.len() <= 3);
        // The server is still alive and serves well-formed requests.
        let ok = server.generate(Request {
            prompt: vec![5, 6],
            max_new: 3,
        });
        assert!(ok.tokens.len() <= 3);
        let stats = server.shutdown().expect("stats");
        assert_eq!(stats.requests, 2);
    }

    /// Drive a server over `prompts`/`budgets`, returning each
    /// request's tokens (order-stable).
    fn serve_all(
        backend: Backend,
        scfg: ServerConfig,
        prompts: &[Vec<i32>],
        budgets: &[usize],
    ) -> Vec<Response> {
        let server = Server::start_with(backend, scfg);
        let rxs: Vec<_> = prompts
            .iter()
            .zip(budgets)
            .map(|(p, &b)| {
                server.submit(Request {
                    prompt: p.clone(),
                    max_new: b,
                })
            })
            .collect();
        let out = rxs.into_iter().map(|rx| rx.recv().expect("response")).collect();
        server.shutdown().expect("stats");
        out
    }

    #[test]
    fn batched_backend_is_token_identical_to_serial_native() {
        // The tentpole acceptance test: for a mixed-length request set
        // (short, long, single-token, empty, over-length prompts; mixed
        // budgets), the continuous batcher must answer every request
        // with exactly the tokens the serial NativePacked router
        // produces.
        let cfg = tiny_cfg();
        let mk = || Box::new(SlabModel::from_dense(&Params::init(&cfg, 55), 2));
        let prompts: Vec<Vec<i32>> = vec![
            vec![5, 6, 7],
            vec![9, 10, 11, 12, 13],
            vec![21],
            vec![],
            vec![8; 20], // longer than prompt_len: truncated by both paths
            vec![17, 4, 29, 3],
        ];
        let budgets = [6usize, 3, 8, 2, 5, 7];
        let serial: Vec<Vec<i32>> = serve_all(
            Backend::NativePacked(mk()),
            ServerConfig::default(),
            &prompts,
            &budgets,
        )
        .into_iter()
        .map(|r| r.tokens)
        .collect();
        let batched = serve_all(
            Backend::NativeBatched(mk()),
            ServerConfig {
                sched: SchedulerConfig {
                    max_batch: 3, // force joins/leaves mid-stream
                    ..Default::default()
                },
                ..Default::default()
            },
            &prompts,
            &budgets,
        );
        for (r, b) in batched.iter().zip(&budgets) {
            assert!(!r.rejected);
            assert!(r.tokens.len() <= *b);
            assert!(r.latency_ms >= r.queue_ms);
        }
        let batched: Vec<Vec<i32>> = batched.into_iter().map(|r| r.tokens).collect();
        assert_eq!(serial, batched, "continuous batcher diverged from serial router");
    }

    #[test]
    fn scheduler_empty_tick_is_noop() {
        let cfg = tiny_cfg();
        let model = Box::new(SlabModel::from_dense(&Params::init(&cfg, 56), 1));
        let mut s = Scheduler::new(model, SchedulerConfig::default());
        assert!(!s.has_work());
        assert_eq!(s.tick(), 0);
        assert_eq!(s.tick(), 0);
        assert_eq!(s.active_sessions(), 0);
        assert_eq!(s.queued(), 0);
        let st = s.into_stats();
        assert_eq!((st.requests, st.batches, st.generated_tokens), (0, 0, 0));
    }

    #[test]
    fn scheduler_single_session_matches_generate_batch() {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 57);
        let reference = SlabModel::from_dense(&params, 1)
            .generate_batch(&[vec![5, 6, 7]], 6)
            .remove(0);
        let model = Box::new(SlabModel::from_dense(&params, 1));
        let mut s = Scheduler::new(model, SchedulerConfig::default());
        let (tx, rx) = channel();
        assert!(s.enqueue(Request { prompt: vec![5, 6, 7], max_new: 6 }, tx));
        while s.has_work() {
            s.tick();
        }
        let r = rx.recv().expect("response");
        assert!(!r.rejected);
        assert_eq!(r.tokens, reference);
        assert_eq!(s.stats().requests, 1);
        assert_eq!(s.active_sessions(), 0);
        assert_eq!(s.kv.active(), 0, "kv slot must be released");
    }

    #[test]
    fn scheduler_rejects_when_queue_is_full() {
        let cfg = tiny_cfg();
        let model = Box::new(SlabModel::from_dense(&Params::init(&cfg, 58), 1));
        let mut s = Scheduler::new(
            model,
            SchedulerConfig {
                max_batch: 1,
                max_seq_len: 0,
                queue_cap: 2,
            },
        );
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (tx, rx) = channel();
            let admitted = s.enqueue(Request { prompt: vec![5 + i], max_new: 3 }, tx);
            assert_eq!(admitted, i < 2, "queue_cap 2 admits exactly the first two");
            rxs.push(rx);
        }
        assert_eq!(s.stats().rejected, 3);
        // Rejections reply immediately, before any tick.
        for rx in &rxs[2..] {
            let r = rx.recv().expect("rejected response");
            assert!(r.rejected);
            assert!(r.tokens.is_empty());
        }
        while s.has_work() {
            s.tick();
        }
        for rx in &rxs[..2] {
            let r = rx.recv().expect("served response");
            assert!(!r.rejected);
            assert!(r.tokens.len() <= 3);
        }
        assert_eq!(s.stats().requests, 2);
    }

    #[test]
    fn scheduler_evicts_capped_session_mid_batch() {
        // One session whose budget exceeds the sequence cap joins a
        // batch with one that finishes by its own budget: the capped
        // one must be evicted exactly at the cap, the other must be
        // untouched, and the batch must shrink mid-flight.
        let cfg = tiny_cfg();
        let mut params = Params::init(&cfg, 59);
        // Make EOS unreachable: its lm_head row duplicates PAD's, so
        // their logits tie bit-exactly and first-max tie-breaking
        // (PAD = 0 scans before EOS = 2) always picks PAD — sessions
        // deterministically run to budget/cap.
        let mut head = params.mat("lm_head");
        let pad_row = head.row(PAD as usize).to_vec();
        head.row_mut(EOS as usize).copy_from_slice(&pad_row);
        params.set_mat("lm_head", &head);

        let t = cfg.prompt_len;
        let cap_headroom = 3usize;
        let model = Box::new(SlabModel::from_dense(&params, 1));
        let mut s = Scheduler::new(
            model,
            SchedulerConfig {
                max_batch: 4,
                max_seq_len: t + cap_headroom,
                queue_cap: 8,
            },
        );
        let (tx_a, rx_a) = channel();
        s.enqueue(Request { prompt: vec![5, 6], max_new: 10 }, tx_a); // capped at 3
        assert_eq!(s.tick(), 1, "A admitted and decoding alone");
        let (tx_b, rx_b) = channel();
        s.enqueue(Request { prompt: vec![9, 8, 7], max_new: 2 }, tx_b); // own budget 2
        assert_eq!(s.tick(), 2, "B joined A mid-stream");
        while s.has_work() {
            s.tick();
        }
        let ra = rx_a.recv().expect("A");
        let rb = rx_b.recv().expect("B");
        assert_eq!(ra.tokens.len(), cap_headroom, "A evicted at the cap");
        assert_eq!(rb.tokens.len(), 2, "B unaffected by A's eviction");
        assert!(ra.tokens.iter().chain(rb.tokens.iter()).all(|&tk| tk != EOS));
        let st = s.stats();
        assert_eq!(st.evicted, 1, "exactly A hit the cap");
        assert_eq!(st.requests, 2);
        assert_eq!(s.kv.active(), 0, "both kv slots released");
    }

    #[test]
    fn batched_server_applies_backpressure_end_to_end() {
        // Through the full Server API: a tiny queue with a burst of
        // submissions yields some rejected responses, and every
        // accepted request still completes.
        let cfg = tiny_cfg();
        let model = Box::new(SlabModel::from_dense(&Params::init(&cfg, 60), 1));
        let scfg = ServerConfig {
            sched: SchedulerConfig {
                max_batch: 1,
                max_seq_len: 0,
                queue_cap: 1,
            },
            ..Default::default()
        };
        let server = Server::start_with(Backend::NativeBatched(model), scfg);
        let n = 12;
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                server.submit(Request {
                    prompt: vec![5 + (i % 20) as i32],
                    max_new: 2,
                })
            })
            .collect();
        let responses: Vec<Response> = rxs
            .into_iter()
            .map(|rx| rx.recv().expect("response"))
            .collect();
        let stats = server.shutdown().expect("stats");
        let served = responses.iter().filter(|r| !r.rejected).count();
        let rejected = responses.iter().filter(|r| r.rejected).count();
        assert_eq!(served + rejected, n);
        assert_eq!(stats.requests, served);
        assert_eq!(stats.rejected, rejected);
        assert!(served >= 1, "at least the first request is served");
        for r in &responses {
            if r.rejected {
                assert!(r.tokens.is_empty());
            } else {
                assert!(r.tokens.len() <= 2);
            }
        }
    }

    #[test]
    fn native_backend_is_deterministic_across_servers() {
        let cfg = tiny_cfg();
        let run = || {
            let model = SlabModel::from_dense(&Params::init(&cfg, 52), 1);
            let server = Server::start_with(
                Backend::NativePacked(Box::new(model)),
                ServerConfig::default(),
            );
            let out = server
                .generate(Request {
                    prompt: vec![9, 10, 11],
                    max_new: 6,
                })
                .tokens;
            server.shutdown().expect("stats");
            out
        };
        assert_eq!(run(), run());
    }
}
