//! Serving coordinator: request router + dynamic batcher over two
//! interchangeable engines.
//!
//! vLLM-router-shaped, scaled to this testbed: client threads submit
//! [`Request`]s into an mpsc queue; the router thread drains up to
//! the batch cap (waiting at most `batch_window` for stragglers —
//! classic dynamic batching), runs one prefill and then decode steps
//! until every sequence in the batch hit its token budget or EOS, and
//! completes the callers' response channels. Greedy decoding;
//! deterministic.
//!
//! The engine behind the queue is a [`Backend`]:
//!
//! * [`Backend::Artifact`] — the AOT `prefill_{cfg}` /
//!   `decode_step_{cfg}` XLA executables over dense weights. A
//!   compressed model serves here with the reconstructed `Ŵ` swapped
//!   in — identical code path, smaller *checkpoint*, but dense
//!   request-time compute.
//! * [`Backend::NativePacked`] — the pure-Rust
//!   [`SlabModel`](crate::model::SlabModel) forward that consumes the
//!   packed `W_S + u vᵀ ⊙ W_B` format directly through the parallel
//!   blocked kernels; the byte savings become request-time memory
//!   traffic savings (DESIGN.md §3, §6).
//!
//! Both backends sit behind the same [`Request`]/[`Response`] API, so
//! the batcher, clients, and stats are engine-agnostic
//! (`examples/serve_compressed.rs` races all three configurations).

use crate::data::{EOS, PAD};
use crate::model::{greedy_token, Params, SlabModel};
use crate::runtime::client::RuntimeError;
use crate::runtime::{lit_i32, lit_scalar_i32, to_vec_f32, Runtime};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub tokens: Vec<i32>,
    /// Queue + batch wait before prefill started.
    pub queue_ms: f64,
    /// Total request latency.
    pub latency_ms: f64,
}

struct Job {
    req: Request,
    submitted: Instant,
    reply: Sender<Response>,
}

/// Server handle: submit requests, then `shutdown()`.
pub struct Server {
    tx: Sender<Job>,
    handle: Option<std::thread::JoinHandle<Result<ServeStats, RuntimeError>>>,
}

#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    pub generated_tokens: usize,
    pub wall_secs: f64,
}

impl ServeStats {
    pub fn tokens_per_sec(&self) -> f64 {
        self.generated_tokens as f64 / self.wall_secs.max(1e-9)
    }

    /// Mean batch occupancy (1.0 = always full batches).
    pub fn occupancy(&self, batch_cap: usize) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / (self.batches * batch_cap) as f64
    }
}

pub struct ServerConfig {
    /// Max time the router waits to fill a batch.
    pub batch_window: Duration,
    /// Batch cap for [`Backend::NativePacked`] (the artifact backend's
    /// cap is baked into its static-shaped executables, so it comes
    /// from the manifest instead).
    pub serve_batch: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_window: Duration::from_millis(5),
            serve_batch: 4,
        }
    }
}

/// The engine a [`Server`] routes batches to. Both variants serve the
/// same [`Request`]/[`Response`] API with identical greedy-decoding
/// semantics; they differ in *what executes a batch*:
///
/// * `Artifact` — XLA prefill/decode executables over an artifact
///   directory, fed dense parameter literals (a compressed model
///   serves its reconstructed `Ŵ`). The router thread owns the PJRT
///   client (it is not `Send`).
/// * `NativePacked` — a [`SlabModel`]: pure-Rust forward straight
///   from the packed SLaB format, parallel blocked kernels, no
///   artifacts or Python toolchain anywhere near the request path.
pub enum Backend {
    /// AOT artifact engine: `(artifacts_dir, params)`.
    Artifact {
        artifacts_dir: PathBuf,
        params: Params,
    },
    /// Native packed engine (boxed: a whole model lives inside).
    NativePacked(Box<SlabModel>),
}

impl Server {
    /// Start the router thread over the artifact backend — the
    /// historical entry point, kept as a convenience wrapper around
    /// [`Server::start_with`]. `params` is the model to serve (dense
    /// or compressed — same ABI).
    pub fn start(artifacts_dir: PathBuf, params: Params, scfg: ServerConfig) -> Server {
        Server::start_with(
            Backend::Artifact {
                artifacts_dir,
                params,
            },
            scfg,
        )
    }

    /// Start the router thread over an explicit [`Backend`]. The
    /// engine is owned by the router thread (for `Artifact` that is
    /// where the PJRT client must live; for `NativePacked` the model
    /// and its thread pool move in) — the natural shape anyway: the
    /// engine owns the device, clients own channels.
    pub fn start_with(backend: Backend, scfg: ServerConfig) -> Server {
        let (tx, rx) = channel::<Job>();
        let handle = std::thread::Builder::new()
            .name("slab-router".into())
            .spawn(move || match backend {
                Backend::Artifact {
                    artifacts_dir,
                    params,
                } => {
                    let rt = Runtime::new(&artifacts_dir)?;
                    router_loop(&rt, params, scfg, rx)
                }
                Backend::NativePacked(model) => native_router_loop(&model, scfg, rx),
            })
            .expect("spawn router");
        Server {
            tx,
            handle: Some(handle),
        }
    }

    /// Submit a request; returns the response receiver immediately.
    pub fn submit(&self, req: Request) -> Receiver<Response> {
        let (reply, rx) = channel();
        self.tx
            .send(Job {
                req,
                submitted: Instant::now(),
                reply,
            })
            .expect("router alive");
        rx
    }

    /// Blocking convenience call.
    pub fn generate(&self, req: Request) -> Response {
        self.submit(req).recv().expect("router response")
    }

    /// Stop accepting requests, drain, and return aggregate stats.
    pub fn shutdown(mut self) -> Result<ServeStats, RuntimeError> {
        drop(self.tx);
        self.handle
            .take()
            .unwrap()
            .join()
            .expect("router join")
    }
}

fn router_loop(
    rt: &Runtime,
    params: Params,
    scfg: ServerConfig,
    rx: Receiver<Job>,
) -> Result<ServeStats, RuntimeError> {
    let cfg = params.cfg.clone();
    let cap = rt.manifest.serve_batch;
    let prompt_len = cfg.prompt_len;
    let prefill_name = format!("prefill_{}", cfg.name);
    let decode_name = format!("decode_step_{}", cfg.name);
    // Build param literals once; borrowed by every call.
    let dev = params.to_literals();
    let mut stats = ServeStats::default();
    let t_start = Instant::now();

    'outer: loop {
        // --- gather a batch (dynamic batching) -------------------------
        let Some(jobs) = gather_batch(&rx, cap, scfg.batch_window) else {
            break 'outer; // all senders dropped
        };
        let t_batch = Instant::now();
        stats.batches += 1;
        stats.requests += jobs.len();

        // --- prefill -----------------------------------------------------
        // Left-aligned prompts, right-padded to prompt_len, PAD keys are
        // attention-masked inside the artifact.
        let mut flat = vec![0i32; cap * prompt_len];
        for (s, job) in jobs.iter().enumerate() {
            let p = &job.req.prompt;
            let n = p.len().min(prompt_len);
            flat[s * prompt_len..s * prompt_len + n].copy_from_slice(&p[..n]);
        }
        let tok_lit = lit_i32(&flat, &[cap, prompt_len]);
        let mut inputs: Vec<&xla::Literal> = dev.iter().collect();
        inputs.push(&tok_lit);
        let outs = rt.execute_refs(&prefill_name, &inputs)?;
        let (mut logits, mut kc, mut vc) = take3(outs);

        // --- decode loop ---------------------------------------------------
        let max_new: usize = jobs
            .iter()
            .map(|j| j.req.max_new)
            .max()
            .unwrap_or(0)
            .min(cfg.max_seq - prompt_len);
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); jobs.len()];
        let mut done = vec![false; jobs.len()];
        for step in 0..max_new {
            // Greedy sample from the last logits.
            let l = to_vec_f32(&logits);
            let mut next = vec![EOS; cap];
            for (s, job) in jobs.iter().enumerate() {
                if done[s] || step >= job.req.max_new {
                    done[s] = true;
                    continue;
                }
                let tok = greedy_token(&l[s * cfg.vocab..(s + 1) * cfg.vocab]);
                next[s] = tok;
                if tok == EOS {
                    done[s] = true;
                } else {
                    generated[s].push(tok);
                    stats.generated_tokens += 1;
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
            let pos = (prompt_len + step) as i32;
            let tok = lit_i32(&next, &[cap]);
            let pb = lit_scalar_i32(pos);
            let mut inputs: Vec<&xla::Literal> = dev.iter().collect();
            inputs.push(&kc);
            inputs.push(&vc);
            inputs.push(&tok);
            inputs.push(&pb);
            let outs = rt.execute_refs(&decode_name, &inputs)?;
            let (l2, k2, v2) = take3(outs);
            logits = l2;
            kc = k2;
            vc = v2;
        }

        // --- respond -------------------------------------------------------
        for (s, job) in jobs.into_iter().enumerate() {
            let _ = job.reply.send(Response {
                tokens: std::mem::take(&mut generated[s]),
                queue_ms: (t_batch - job.submitted).as_secs_f64() * 1e3,
                latency_ms: job.submitted.elapsed().as_secs_f64() * 1e3,
            });
        }
    }
    stats.wall_secs = t_start.elapsed().as_secs_f64();
    Ok(stats)
}

/// Drain up to `cap` jobs: block for the first, then poll for
/// stragglers until the batch window closes. `None` once all senders
/// dropped and the queue is empty (shutdown).
fn gather_batch(rx: &Receiver<Job>, cap: usize, window: Duration) -> Option<Vec<Job>> {
    let mut jobs: Vec<Job> = Vec::with_capacity(cap);
    match rx.recv() {
        Ok(j) => jobs.push(j),
        Err(_) => return None,
    }
    let window_end = Instant::now() + window;
    while jobs.len() < cap {
        match rx.try_recv() {
            Ok(j) => jobs.push(j),
            Err(TryRecvError::Empty) => {
                if Instant::now() >= window_end {
                    break;
                }
                std::thread::yield_now();
            }
            Err(TryRecvError::Disconnected) => break,
        }
    }
    Some(jobs)
}

/// The [`Backend::NativePacked`] router: same dynamic batching,
/// greedy policy, and accounting as [`router_loop`], but prefill and
/// decode run through [`SlabModel`] — no PJRT, no padding the batch
/// up to an artifact's static shape (the native engine takes the
/// actual batch size).
fn native_router_loop(
    model: &SlabModel,
    scfg: ServerConfig,
    rx: Receiver<Job>,
) -> Result<ServeStats, RuntimeError> {
    let cap = scfg.serve_batch.max(1);
    let prompt_len = model.cfg.prompt_len;
    let mut stats = ServeStats::default();
    let t_start = Instant::now();

    loop {
        let Some(jobs) = gather_batch(&rx, cap, scfg.batch_window) else {
            break;
        };
        let t_batch = Instant::now();
        stats.batches += 1;
        stats.requests += jobs.len();
        let bsz = jobs.len();

        // --- prefill: left-aligned prompts, PAD-padded ------------------
        let vmax = model.cfg.vocab.saturating_sub(1) as i32;
        let mut flat = vec![PAD; bsz * prompt_len];
        for (s, job) in jobs.iter().enumerate() {
            let p = &job.req.prompt;
            let n = p.len().min(prompt_len);
            for (j, &tok) in p[..n].iter().enumerate() {
                // Clamp malformed ids like the artifact backend does
                // (XLA gather clamps OOB indices): one bad request
                // must not panic the router thread for everyone.
                flat[s * prompt_len + j] = tok.clamp(0, vmax);
            }
        }
        let (mut logits, mut cache) = model.prefill(&flat, bsz);

        // --- decode loop -------------------------------------------------
        let max_new: usize = jobs
            .iter()
            .map(|j| j.req.max_new)
            .max()
            .unwrap_or(0)
            .min(model.cfg.max_seq.saturating_sub(prompt_len));
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); bsz];
        let mut done = vec![false; bsz];
        for step in 0..max_new {
            let mut next = vec![EOS; bsz];
            for (s, job) in jobs.iter().enumerate() {
                if done[s] || step >= job.req.max_new {
                    done[s] = true;
                    continue;
                }
                let tok = greedy_token(logits.row(s));
                next[s] = tok;
                if tok == EOS {
                    done[s] = true;
                } else {
                    generated[s].push(tok);
                    stats.generated_tokens += 1;
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
            logits = model.decode_step(&mut cache, &next, prompt_len + step);
        }

        // --- respond -------------------------------------------------------
        for (s, job) in jobs.into_iter().enumerate() {
            let _ = job.reply.send(Response {
                tokens: std::mem::take(&mut generated[s]),
                queue_ms: (t_batch - job.submitted).as_secs_f64() * 1e3,
                latency_ms: job.submitted.elapsed().as_secs_f64() * 1e3,
            });
        }
    }
    stats.wall_secs = t_start.elapsed().as_secs_f64();
    Ok(stats)
}

fn take3(mut outs: Vec<xla::Literal>) -> (xla::Literal, xla::Literal, xla::Literal) {
    assert!(outs.len() >= 3);
    let c = outs.pop().unwrap();
    let b = outs.pop().unwrap();
    let a = outs.pop().unwrap();
    (a, b, c)
}

#[cfg(test)]
mod tests {
    //! The native backend needs no artifacts, so the router/batcher
    //! invariants get exercised on every `cargo test`, not only when
    //! `make artifacts` has run.

    use super::*;
    use crate::runtime::ModelCfg;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg::llama("tiny-serve", 32, 8, 1, 2, 16, 12, 4)
    }

    #[test]
    fn native_backend_serves_every_request_exactly_once() {
        let cfg = tiny_cfg();
        let model = SlabModel::from_dense(&Params::init(&cfg, 51), 2);
        let scfg = ServerConfig {
            serve_batch: 3,
            ..Default::default()
        };
        let server = Server::start_with(Backend::NativePacked(Box::new(model)), scfg);
        let n = 10;
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                server.submit(Request {
                    prompt: vec![5 + i as i32, 6, 7],
                    max_new: 1 + (i % 4),
                })
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().expect("response");
            assert!(r.tokens.len() <= 1 + (i % 4), "token budget violated");
            assert!(r.latency_ms >= r.queue_ms);
            assert!(r.tokens.iter().all(|&t| t != EOS && t != PAD));
        }
        let stats = server.shutdown().expect("stats");
        assert_eq!(stats.requests, n);
        assert!(stats.batches >= n.div_ceil(3));
        assert!(stats.requests <= stats.batches * 3);
        assert!(stats.wall_secs > 0.0);
    }

    #[test]
    fn native_backend_survives_out_of_vocab_prompts() {
        // Malformed token ids are clamped (like XLA gather in the
        // artifact backend), not allowed to panic the router thread.
        let cfg = tiny_cfg();
        let model = SlabModel::from_dense(&Params::init(&cfg, 53), 1);
        let server = Server::start_with(
            Backend::NativePacked(Box::new(model)),
            ServerConfig::default(),
        );
        let bad = server.generate(Request {
            prompt: vec![-7, i32::MAX, 9999, 5],
            max_new: 3,
        });
        assert!(bad.tokens.len() <= 3);
        // The server is still alive and serves well-formed requests.
        let ok = server.generate(Request {
            prompt: vec![5, 6],
            max_new: 3,
        });
        assert!(ok.tokens.len() <= 3);
        let stats = server.shutdown().expect("stats");
        assert_eq!(stats.requests, 2);
    }

    #[test]
    fn native_backend_is_deterministic_across_servers() {
        let cfg = tiny_cfg();
        let run = || {
            let model = SlabModel::from_dense(&Params::init(&cfg, 52), 1);
            let server = Server::start_with(
                Backend::NativePacked(Box::new(model)),
                ServerConfig::default(),
            );
            let out = server
                .generate(Request {
                    prompt: vec![9, 10, 11],
                    max_new: 6,
                })
                .tokens;
            server.shutdown().expect("stats");
            out
        };
        assert_eq!(run(), run());
    }
}
