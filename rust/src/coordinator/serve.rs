//! Serving coordinator: a streaming session router over three
//! interchangeable engines — two dynamic batchers and a
//! continuous-batching scheduler.
//!
//! vLLM-router-shaped, scaled to this testbed: client threads
//! [`Server::submit`] a [`Request`] and get a [`Session`] handle back
//! *immediately*; the router thread owns the engine and streams an
//! ordered [`Event`] sequence into the session — `Token` per generated
//! token as it is sampled, then exactly one terminal event
//! (`Done` / `Evicted` / `Rejected`). [`Session::cancel`] (or dropping
//! the handle) stops generation mid-decode and frees the session's
//! resources; [`Session::collect`] reproduces the historical blocking
//! whole-completion call token-identically (DESIGN.md §12). Greedy
//! decoding; deterministic.
//!
//! The engine behind the queue is a [`Backend`]:
//!
//! * [`Backend::Artifact`] — the AOT `prefill_{cfg}` /
//!   `decode_step_{cfg}` XLA executables over dense weights. A
//!   compressed model serves here with the reconstructed `Ŵ` swapped
//!   in — identical code path, smaller *checkpoint*, but dense
//!   request-time compute. Dynamic batching: drain up to the batch
//!   cap, wait at most `batch_window` for stragglers, decode the
//!   whole batch to budget/EOS.
//! * [`Backend::NativePacked`] — the pure-Rust
//!   [`SlabModel`](crate::model::SlabModel) forward that consumes the
//!   packed `W_S + u vᵀ ⊙ W_B` format directly through the parallel
//!   blocked kernels; the byte savings become request-time memory
//!   traffic savings (DESIGN.md §3, §6). Same dynamic batching as the
//!   artifact backend.
//! * [`Backend::NativeBatched`] — the same native engine behind the
//!   continuous-batching [`Scheduler`]: requests prefill individually
//!   and *join the running decode batch* (prefill-then-join), finished
//!   sessions leave it immediately (DESIGN.md §6a).
//!
//! Every backend applies the same admission policy: a bounded queue
//! ([`ServerConfig::queue_cap`]) whose overflow terminates the session
//! with an immediate [`Event::Rejected`] instead of unbounded growth,
//! plus optional per-request deadlines
//! ([`Request::deadline`] / [`SchedulerConfig::deadline`]) that evict
//! a session — queued or decoding — once its wall-clock budget is
//! spent. All backends sit behind the same [`Request`]/[`Session`]
//! API, so the batcher, clients, and stats are engine-agnostic, and
//! the native pair is pinned token-identical by tests here and in
//! `rust/tests/integration.rs`. The `coordinator::http` front-end
//! exposes exactly this API over HTTP/1.1 (DESIGN.md §12).

use crate::data::{EOS, PAD};
use crate::model::{
    greedy_token, DecodeSlot, KvCachePool, PagedKvConfig, PagedKvPool, Params, SlabModel,
    VerifySlot,
};
use crate::report::Table;
use crate::runtime::client::RuntimeError;
use crate::runtime::{lit_i32, lit_scalar_i32, to_vec_f32, Runtime};
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Default)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Wall-clock deadline measured from submission. `Some(d)` always
    /// applies (even `Some(ZERO)`, which expires immediately); `None`
    /// falls back to [`SchedulerConfig::deadline`] (where `ZERO`
    /// means *no* deadline). A session past its deadline is evicted —
    /// from the queue or mid-decode — with the tokens streamed so far.
    pub deadline: Option<Duration>,
}

/// One step of a [`Session`]'s ordered event stream: zero or more
/// `Token`s followed by exactly one terminal event. The stream is the
/// serving contract — `collect()` and the HTTP front-end are both
/// pure folds over it (DESIGN.md §12).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// One generated token, emitted the tick it was sampled.
    Token(i32),
    /// Terminal: the session completed (EOS, token budget, or
    /// cancellation — see [`SessionStats::cancelled`]).
    Done(SessionStats),
    /// Terminal: admission backpressure — the bounded queue was full
    /// and the request was never scheduled. No tokens were streamed.
    Rejected,
    /// Terminal: evicted by the sequence cap or a deadline, with the
    /// tokens streamed so far.
    Evicted(SessionStats),
}

/// Per-session accounting carried by a terminal [`Event`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SessionStats {
    /// Tokens streamed before the terminal event.
    pub tokens: usize,
    /// Queue + batch wait before prefill started.
    pub queue_ms: f64,
    /// Total session latency (submission → terminal event).
    pub latency_ms: f64,
    /// Submission → first streamed token; `0.0` when none was.
    pub ttft_ms: f64,
    /// The session was cancelled (explicitly or by the client
    /// dropping its [`Session`]) before it finished on its own.
    pub cancelled: bool,
}

/// Shared cancellation flag for one session. Cloneable so a registry
/// (e.g. the HTTP front-end's session table) can cancel a stream it
/// does not own; setting it is idempotent and safe from any thread.
#[derive(Debug, Clone, Default)]
pub struct CancelHandle(Arc<AtomicBool>);

impl CancelHandle {
    /// Request cancellation: the routers observe the flag at the next
    /// decode tick, stop streaming, free the session's KV slot, and
    /// emit the terminal event with `cancelled: true`.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// Client half of one submitted request: consume the ordered
/// [`Event`] stream, cancel mid-stream, or [`collect`](Session::collect)
/// into the historical blocking [`Response`]. Dropping an unconsumed
/// session counts as cancellation — the router stops decoding for a
/// client that hung up.
pub struct Session {
    id: u64,
    events: Receiver<Event>,
    cancel: CancelHandle,
}

impl Session {
    /// Server-unique session id (the HTTP `DELETE /v1/sessions/{id}`
    /// key).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Cancel this session; already-streamed tokens stay valid and the
    /// terminal event still arrives (with `cancelled: true`).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A cloneable cancel handle (for registries / other threads).
    pub fn cancel_handle(&self) -> CancelHandle {
        self.cancel.clone()
    }

    /// Blocking: the next event, or `None` once the stream is over.
    pub fn recv(&self) -> Option<Event> {
        self.events.recv().ok()
    }

    /// Non-blocking poll.
    pub fn try_recv(&self) -> Option<Event> {
        self.events.try_recv().ok()
    }

    /// Blocking with a timeout (`None` on timeout or closed stream).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Event> {
        self.events.recv_timeout(timeout).ok()
    }

    /// Blocking iterator over the remaining events (ends after the
    /// terminal event).
    pub fn iter(&self) -> std::sync::mpsc::Iter<'_, Event> {
        self.events.iter()
    }

    /// Drain the stream to completion — the blocking convenience that
    /// reproduces the historical whole-completion call
    /// token-identically (pinned by `streaming_matches_collect_*`).
    pub fn collect(self) -> Response {
        collect_events(&self.events)
    }
}

impl Drop for Session {
    /// Dropping the handle IS cancellation: nobody can consume the
    /// stream anymore, so the router must not keep decoding for it.
    /// Setting the flag (not just closing the channel) also lets the
    /// scheduler's reap sweep drop an abandoned job from the *wait
    /// queue* — a closed channel alone is invisible until a send is
    /// attempted. Harmless after a terminal event: cancelling a
    /// finished session is a no-op.
    fn drop(&mut self) {
        self.cancel.cancel();
    }
}

/// Fold an event stream into a [`Response`] — the blocking
/// whole-completion view. Public so direct [`Scheduler`] users and
/// tests can drain a raw event channel the same way
/// [`Session::collect`] does.
pub fn collect_events(events: &Receiver<Event>) -> Response {
    let mut r = Response::default();
    let mut terminal = false;
    for ev in events.iter() {
        match ev {
            Event::Token(t) => r.tokens.push(t),
            Event::Rejected => {
                r.rejected = true;
                terminal = true;
                break;
            }
            Event::Done(s) => {
                r.finish_from(&s);
                terminal = true;
                break;
            }
            Event::Evicted(s) => {
                r.evicted = true;
                r.finish_from(&s);
                terminal = true;
                break;
            }
        }
    }
    // The stream closed without a terminal event: the router died
    // mid-session (engine error / panic). Mark it so callers cannot
    // mistake a truncated stream for a normal completion.
    r.incomplete = !terminal;
    r
}

/// Whole-completion view of a finished session (what
/// [`Session::collect`] returns) — the pre-streaming `Response`
/// contract, token-identical to consuming the event stream directly.
#[derive(Debug, Clone, Default)]
pub struct Response {
    pub tokens: Vec<i32>,
    /// Queue + batch wait before prefill started.
    pub queue_ms: f64,
    /// Total request latency.
    pub latency_ms: f64,
    /// Submission → first token (`0.0` when nothing was generated).
    pub ttft_ms: f64,
    /// Backpressure: the admission queue was full and the request was
    /// never scheduled (`tokens` is empty). Every backend applies the
    /// same bounded-queue policy ([`ServerConfig::queue_cap`]).
    pub rejected: bool,
    /// Terminated by the sequence cap or a deadline.
    pub evicted: bool,
    /// Terminated by [`Session::cancel`] / client hang-up.
    pub cancelled: bool,
    /// The event stream closed **without** a terminal event — the
    /// router thread died mid-session (engine error / panic), so
    /// `tokens` is a truncated stream, not a completion. Every
    /// healthy outcome (including rejection and cancellation) leaves
    /// this `false`.
    pub incomplete: bool,
}

impl Response {
    fn finish_from(&mut self, s: &SessionStats) {
        self.queue_ms = s.queue_ms;
        self.latency_ms = s.latency_ms;
        self.ttft_ms = s.ttft_ms;
        self.cancelled = s.cancelled;
    }
}

/// One submitted request inside the router: the request plus its
/// session-side channel and cancellation flag.
struct Job {
    req: Request,
    submitted: Instant,
    events: Sender<Event>,
    cancel: CancelHandle,
}

impl Job {
    /// Absolute deadline, if any: the request's own wins; otherwise
    /// the scheduler default (`ZERO` = none). `checked_add`: an
    /// astronomically large (but type-valid) deadline saturates to
    /// "no deadline" — one request must never panic the router
    /// thread with `Instant` overflow.
    fn deadline_at(&self, default: Duration) -> Option<Instant> {
        let d = match self.req.deadline {
            Some(d) => d,
            None if default > Duration::ZERO => default,
            None => return None,
        };
        self.submitted.checked_add(d)
    }
}

/// Submit-side state shared between a [`Server`] handle and its
/// router thread: the admission gate and the live stats snapshot.
#[derive(Default)]
struct Gate {
    /// Jobs submitted but not yet decoding (mpsc + scheduler queue).
    pending: AtomicUsize,
    /// Rejections applied at the submit gate (callers' threads) —
    /// folded into [`ServeStats::rejected`] by `stats`/`shutdown`.
    gate_rejected: AtomicUsize,
    /// Router's latest stats snapshot — what `GET /metrics` renders.
    live: Mutex<ServeStats>,
}

impl Gate {
    /// `n` jobs left the waiting state (entered a batch / the decode
    /// set, or were terminated while queued).
    fn depart(&self, n: usize) {
        if n > 0 {
            self.pending.fetch_sub(n, Ordering::AcqRel);
        }
    }
}

/// Publish the router's current stats to the live snapshot.
fn sync_live(gate: &Gate, stats: &ServeStats, t_start: Instant) {
    let mut snap = stats.clone();
    snap.wall_secs = t_start.elapsed().as_secs_f64();
    *gate.live.lock().unwrap_or_else(|p| p.into_inner()) = snap;
}

/// Server handle: submit requests (each returns a streaming
/// [`Session`]), read live [`stats`](Server::stats), then
/// [`shutdown`](Server::shutdown).
pub struct Server {
    tx: Sender<Job>,
    handle: Option<std::thread::JoinHandle<Result<ServeStats, RuntimeError>>>,
    next_id: AtomicU64,
    queue_cap: usize,
    gate: Arc<Gate>,
    started: Instant,
}

#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Requests that received a terminal `Done`/`Evicted` event
    /// (everything submitted except rejections).
    pub requests: usize,
    /// Dynamic batchers: batches executed. Continuous batcher: decode
    /// ticks executed.
    pub batches: usize,
    pub generated_tokens: usize,
    /// Requests rejected by admission-queue backpressure.
    pub rejected: usize,
    /// Sessions terminated by the sequence cap (`max_seq_len`) before
    /// reaching their own token budget or EOS.
    pub evicted: usize,
    /// Sessions evicted because their deadline passed first.
    pub deadline_evicted: usize,
    /// Sessions cancelled ([`Session::cancel`] or client hang-up).
    pub cancelled: usize,
    /// Sessions whose client dropped the [`Session`] before the
    /// terminal event could be delivered — never a router panic.
    pub dropped_clients: usize,
    /// Sum of per-request time-to-first-token over `ttft_samples`.
    pub ttft_ms_total: f64,
    /// Requests that streamed at least one token.
    pub ttft_samples: usize,
    /// Paged-KV admissions that joined an already-prefilled shared
    /// prefix (no prefill forward ran). Zero under the contiguous
    /// fallback or with sharing disabled.
    pub prefix_hits: usize,
    /// Paged-KV admissions that prefilled fresh pages.
    pub prefix_misses: usize,
    /// Copy-on-write page splits (first divergent write to a shared
    /// page).
    pub cow_splits: usize,
    /// Sessions evicted because no KV page could be secured for their
    /// next token (page exhaustion after the prefix index was already
    /// drained).
    pub page_evictions: usize,
    /// KV pages currently allocated (gauge; `0` under the contiguous
    /// fallback).
    pub kv_pages: usize,
    /// High-water mark of allocated KV pages.
    pub kv_pages_peak: usize,
    /// Self-speculative decoding (DESIGN.md §14): draft→verify rounds
    /// executed (one per non-empty speculative tick).
    pub spec_rounds: usize,
    /// Draft tokens proposed by the cheap sparse+low-rank path.
    pub spec_drafted: usize,
    /// Draft tokens the full-model verify pass accepted.
    pub spec_accepted: usize,
    /// Verify passes that rejected at least one draft token, rolling
    /// the session's KV state back past the divergence point.
    pub spec_rollbacks: usize,
    pub wall_secs: f64,
}

impl ServeStats {
    pub fn tokens_per_sec(&self) -> f64 {
        self.generated_tokens as f64 / self.wall_secs.max(1e-9)
    }

    /// Mean batch occupancy (1.0 = always full batches).
    pub fn occupancy(&self, batch_cap: usize) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / (self.batches * batch_cap) as f64
    }

    /// Mean time-to-first-token across requests that produced one.
    pub fn mean_ttft_ms(&self) -> f64 {
        self.ttft_ms_total / self.ttft_samples.max(1) as f64
    }

    /// Fraction of paged admissions that shared an existing prefix
    /// (`0.0` when none were attempted).
    pub fn prefix_hit_rate(&self) -> f64 {
        self.prefix_hits as f64 / (self.prefix_hits + self.prefix_misses).max(1) as f64
    }

    /// Fraction of draft tokens the verify pass accepted (`0.0` when
    /// speculation never ran) — the observability headline of
    /// DESIGN.md §14: speedup ≈ acceptance, losslessness regardless.
    pub fn acceptance_rate(&self) -> f64 {
        self.spec_accepted as f64 / self.spec_drafted.max(1) as f64
    }

    /// Render as a metric/value [`Table`] — the `/metrics` body and
    /// the CLI's summary form.
    pub fn table(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["metric", "value"]);
        let rows: Vec<(&str, String)> = vec![
            ("requests", self.requests.to_string()),
            ("batches", self.batches.to_string()),
            ("generated_tokens", self.generated_tokens.to_string()),
            ("tokens_per_sec", format!("{:.1}", self.tokens_per_sec())),
            ("rejected", self.rejected.to_string()),
            ("evicted", self.evicted.to_string()),
            ("deadline_evicted", self.deadline_evicted.to_string()),
            ("cancelled", self.cancelled.to_string()),
            ("dropped_clients", self.dropped_clients.to_string()),
            ("prefix_hits", self.prefix_hits.to_string()),
            ("prefix_misses", self.prefix_misses.to_string()),
            ("prefix_hit_rate", format!("{:.3}", self.prefix_hit_rate())),
            ("cow_splits", self.cow_splits.to_string()),
            ("page_evictions", self.page_evictions.to_string()),
            ("kv_pages", self.kv_pages.to_string()),
            ("kv_pages_peak", self.kv_pages_peak.to_string()),
            ("spec_rounds", self.spec_rounds.to_string()),
            ("spec_drafted", self.spec_drafted.to_string()),
            ("spec_accepted", self.spec_accepted.to_string()),
            ("spec_acceptance_rate", format!("{:.3}", self.acceptance_rate())),
            ("spec_rollbacks", self.spec_rollbacks.to_string()),
            ("mean_ttft_ms", format!("{:.3}", self.mean_ttft_ms())),
            ("wall_secs", format!("{:.3}", self.wall_secs)),
        ];
        for (k, v) in rows {
            t.push_row(vec![k.to_string(), v]);
        }
        t
    }
}

pub struct ServerConfig {
    /// Max time the router waits to fill a batch.
    pub batch_window: Duration,
    /// Batch cap for [`Backend::NativePacked`] (the artifact backend's
    /// cap is baked into its static-shaped executables, so it comes
    /// from the manifest instead).
    pub serve_batch: usize,
    /// Uniform admission cap, enforced at [`Server::submit`] for
    /// *every* backend: while `queue_cap` submissions are already
    /// waiting (not yet decoding), new submissions terminate
    /// immediately with [`Event::Rejected`]. `0` rejects everything —
    /// a drain/maintenance mode. The continuous batcher additionally
    /// bounds its internal queue with [`SchedulerConfig::queue_cap`];
    /// keep the two equal (the defaults are) unless you want the
    /// stricter of the two to win.
    pub queue_cap: usize,
    /// Continuous-batching knobs for [`Backend::NativeBatched`]; the
    /// dynamic batchers honor only [`SchedulerConfig::deadline`].
    pub sched: SchedulerConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_window: Duration::from_millis(5),
            serve_batch: 4,
            queue_cap: 64,
            sched: SchedulerConfig::default(),
        }
    }
}

/// Knobs for the continuous-batching [`Scheduler`].
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Hard cap on concurrently decoding sessions (≥ 1 enforced) —
    /// also the [`KvCachePool`] capacity.
    pub max_batch: usize,
    /// Per-session sequence cap (prompt plus generated positions),
    /// clamped to the model's `max_seq`; `0` means the model's
    /// `max_seq`. A session that reaches it is evicted mid-batch with
    /// the tokens it has.
    pub max_seq_len: usize,
    /// Admission-queue bound (≥ 1 enforced); submissions past it get
    /// an immediate [`Event::Rejected`] instead of unbounded queue
    /// growth.
    pub queue_cap: usize,
    /// Default per-request deadline from submission, applied when a
    /// [`Request`] carries none; `ZERO` (the default) disables it. An
    /// expired session is evicted with the tokens streamed so far and
    /// counted in [`ServeStats::deadline_evicted`].
    pub deadline: Duration,
    /// KV page size in tokens for the block-paged pool (DESIGN.md
    /// §13). `0` falls back to the legacy contiguous
    /// [`KvCachePool`] — kept as the conformance reference.
    pub kv_page: usize,
    /// Hard KV page budget for the paged pool; `0` (the default) is
    /// the worst-case-safe budget
    /// `max_batch · ⌈max_seq / kv_page⌉`. Tighter budgets trade
    /// worst-case admission for memory: sessions are admitted against
    /// *real* page availability and evicted (terminal
    /// [`Event::Evicted`], counted in [`ServeStats::page_evictions`])
    /// if a decode write cannot secure a page even after the prefix
    /// index is drained.
    pub page_budget: usize,
    /// Share prefilled pages between sessions with identical padded
    /// prompts (copy-on-write; paged pool only).
    pub prefix_sharing: bool,
    /// Self-speculative decoding (DESIGN.md §14): each tick drafts up
    /// to [`draft_len`](SchedulerConfig::draft_len) tokens per session
    /// through the cheap sparse+low-rank view, verifies them in one
    /// full-model multi-token pass, and emits the longest accepted
    /// prefix plus the verify's own next token. **Lossless**: streams
    /// are token-identical to plain greedy decode (pinned by the
    /// parity and fuzz suites); only throughput and the
    /// `spec_*`/acceptance-rate counters change.
    pub speculate: bool,
    /// Draft tokens proposed per session per speculative round
    /// (clamped ≥ 1 at use; windows shrink near the sequence cap,
    /// token budgets, and KV page exhaustion).
    pub draft_len: usize,
    /// Truncate the draft view to the top-`r` Hadamard rank-1 terms
    /// (`None` = full rank). `Some(0)` drafts through the sparse
    /// component alone — the cheapest, lowest-acceptance draft.
    pub draft_rank: Option<usize>,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 8,
            max_seq_len: 0,
            queue_cap: 64,
            deadline: Duration::ZERO,
            kv_page: 8,
            page_budget: 0,
            prefix_sharing: true,
            speculate: false,
            draft_len: 4,
            draft_rank: None,
        }
    }
}

/// The engine a [`Server`] routes requests to. Every variant serves
/// the same [`Request`]/[`Session`] API with identical
/// greedy-decoding semantics; they differ in *what executes a batch*
/// and *how requests become batches*:
///
/// * `Artifact` — XLA prefill/decode executables over an artifact
///   directory, fed dense parameter literals (a compressed model
///   serves its reconstructed `Ŵ`). The router thread owns the PJRT
///   client (it is not `Send`). Dynamic batching.
/// * `NativePacked` — a [`SlabModel`]: pure-Rust forward straight
///   from the packed SLaB format, parallel blocked kernels, no
///   artifacts or Python toolchain anywhere near the request path.
///   Dynamic batching.
/// * `NativeBatched` — the same [`SlabModel`] engine behind the
///   continuous-batching [`Scheduler`].
pub enum Backend {
    /// AOT artifact engine: `(artifacts_dir, params)`.
    Artifact {
        artifacts_dir: PathBuf,
        params: Params,
    },
    /// Native packed engine (boxed: a whole model lives inside).
    NativePacked(Box<SlabModel>),
    /// Native packed engine behind the continuous-batching
    /// [`Scheduler`]: per-request prefill-then-join admission,
    /// per-session termination/eviction, bounded-queue backpressure.
    /// Token-identical to `NativePacked` for any request mix (pinned
    /// by tests); strictly higher decode throughput under load, since
    /// every weight pass is shared by all live sessions.
    NativeBatched(Box<SlabModel>),
}

impl Server {
    /// Start the router thread over the artifact backend — the
    /// historical entry point, kept as a convenience wrapper around
    /// [`Server::start_with`]. `params` is the model to serve (dense
    /// or compressed — same ABI).
    pub fn start(artifacts_dir: PathBuf, params: Params, scfg: ServerConfig) -> Server {
        Server::start_with(
            Backend::Artifact {
                artifacts_dir,
                params,
            },
            scfg,
        )
    }

    /// Start the router thread over an explicit [`Backend`]. The
    /// engine is owned by the router thread (for `Artifact` that is
    /// where the PJRT client must live; for `NativePacked` the model
    /// and its thread pool move in) — the natural shape anyway: the
    /// engine owns the device, clients own channels.
    pub fn start_with(backend: Backend, scfg: ServerConfig) -> Server {
        let (tx, rx) = channel::<Job>();
        let gate = Arc::new(Gate::default());
        let queue_cap = scfg.queue_cap;
        let routed = gate.clone();
        let handle = std::thread::Builder::new()
            .name("slab-router".into())
            .spawn(move || match backend {
                Backend::Artifact {
                    artifacts_dir,
                    params,
                } => {
                    let rt = Runtime::new(&artifacts_dir)?;
                    router_loop(&rt, params, scfg, rx, &routed)
                }
                Backend::NativePacked(model) => native_router_loop(&model, scfg, rx, &routed),
                Backend::NativeBatched(model) => batched_router_loop(model, scfg, rx, &routed),
            })
            .expect("spawn router");
        Server {
            tx,
            handle: Some(handle),
            next_id: AtomicU64::new(1),
            queue_cap,
            gate,
            started: Instant::now(),
        }
    }

    /// Submit a request; returns its streaming [`Session`]
    /// immediately. Never blocks and never panics: a full admission
    /// queue (or a dead router) terminates the session with
    /// [`Event::Rejected`].
    pub fn submit(&self, req: Request) -> Session {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let cancel = CancelHandle::default();
        let session = Session {
            id,
            events: rx,
            cancel: cancel.clone(),
        };
        // The uniform bounded-queue gate (DESIGN.md §12): admit only
        // while fewer than `queue_cap` submissions are waiting.
        let admitted = self
            .gate
            .pending
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |p| {
                if p >= self.queue_cap {
                    None
                } else {
                    Some(p + 1)
                }
            })
            .is_ok();
        if !admitted {
            self.gate.gate_rejected.fetch_add(1, Ordering::AcqRel);
            let _ = tx.send(Event::Rejected);
            return session;
        }
        let job = Job {
            req,
            submitted: Instant::now(),
            events: tx,
            cancel,
        };
        if let Err(failed) = self.tx.send(job) {
            // Router thread already exited (shutdown race / engine
            // error): reject instead of panicking the caller.
            self.gate.depart(1);
            self.gate.gate_rejected.fetch_add(1, Ordering::AcqRel);
            let _ = failed.0.events.send(Event::Rejected);
        }
        session
    }

    /// Blocking convenience call (submit + collect).
    pub fn generate(&self, req: Request) -> Response {
        self.submit(req).collect()
    }

    /// Live stats snapshot (what `GET /metrics` serves): the router's
    /// latest per-batch/per-tick publication plus gate-side
    /// rejections, with `wall_secs` measured from server start.
    pub fn stats(&self) -> ServeStats {
        let mut s = self
            .gate
            .live
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .clone();
        s.rejected += self.gate.gate_rejected.load(Ordering::Acquire);
        s.wall_secs = self.started.elapsed().as_secs_f64();
        s
    }

    /// Submissions currently waiting at the admission gate (submitted
    /// but not yet decoding). The HTTP front-end derives its
    /// `Retry-After` hint from this depth (DESIGN.md §15).
    pub fn queue_depth(&self) -> usize {
        self.gate.pending.load(Ordering::Acquire)
    }

    /// The admission-gate capacity ([`ServerConfig::queue_cap`]).
    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Stop accepting requests, drain, and return aggregate stats.
    /// Typed errors instead of panics: a vanished or panicked router
    /// thread surfaces as [`RuntimeError::Router`].
    pub fn shutdown(mut self) -> Result<ServeStats, RuntimeError> {
        drop(self.tx);
        let handle = self
            .handle
            .take()
            .ok_or_else(|| RuntimeError::Router("server already shut down".into()))?;
        let joined = handle
            .join()
            .map_err(|_| RuntimeError::Router("router thread panicked".into()))?;
        let mut stats = joined?;
        stats.rejected += self.gate.gate_rejected.load(Ordering::Acquire);
        Ok(stats)
    }
}

/// Terminal classification of one session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Outcome {
    /// EOS, token budget, or empty budget — a normal completion.
    Done,
    /// Cancelled via [`CancelHandle`] or client hang-up.
    Cancelled,
    /// Hit the sequence cap (`max_seq_len`).
    Evicted,
    /// Deadline expired first.
    DeadlineEvicted,
}

/// Streaming bookkeeping for one live session: emits `Token` events
/// the tick they are sampled, tracks TTFT, and carries the terminal
/// outcome. Shared by the dynamic batchers (directly) and the
/// continuous batcher (embedded in its per-session state).
struct BatchSession {
    job: Job,
    /// When the session left the queue (prefill start).
    t_admit: Instant,
    deadline: Option<Instant>,
    /// Effective token budget: `min(max_new, headroom)` — the
    /// sequence cap's clamp, identical across all backends.
    budget: usize,
    /// True when the sequence cap (not the caller) set `budget` —
    /// running to it then classifies as [`Outcome::Evicted`], the
    /// same terminal every backend reports for a capped request.
    capped: bool,
    /// Tokens streamed so far.
    streamed: usize,
    /// TTFT once known; `0.0` until the first token.
    first_ms: f64,
    done: bool,
    outcome: Outcome,
    /// The client dropped its [`Session`]; treated as cancellation.
    client_gone: bool,
}

impl BatchSession {
    fn new(job: Job, default_deadline: Duration, t_admit: Instant, headroom: usize) -> BatchSession {
        let deadline = job.deadline_at(default_deadline);
        let capped = headroom < job.req.max_new;
        let budget = job.req.max_new.min(headroom);
        BatchSession {
            job,
            t_admit,
            deadline,
            budget,
            capped,
            streamed: 0,
            first_ms: 0.0,
            done: false,
            outcome: Outcome::Done,
            client_gone: false,
        }
    }

    /// Pre-step liveness gate: cancellation, client hang-up, deadline,
    /// then the clamped token budget — in that order, so a cancelled
    /// session never costs another decode row.
    fn wants_token(&mut self, step: usize, now: Instant) -> bool {
        if self.done {
            return false;
        }
        if self.job.cancel.is_cancelled() || self.client_gone {
            self.done = true;
            self.outcome = Outcome::Cancelled;
            return false;
        }
        if self.deadline.is_some_and(|d| now >= d) {
            self.done = true;
            self.outcome = Outcome::DeadlineEvicted;
            return false;
        }
        if step >= self.budget {
            self.done = true;
            return false;
        }
        true
    }

    /// Stream one sampled token (EOS terminates the session instead).
    fn push(&mut self, tok: i32, stats: &mut ServeStats) {
        if tok == EOS {
            self.done = true;
            return;
        }
        if self.streamed == 0 {
            self.first_ms = self.job.submitted.elapsed().as_secs_f64() * 1e3;
            stats.ttft_ms_total += self.first_ms;
            stats.ttft_samples += 1;
        }
        self.streamed += 1;
        stats.generated_tokens += 1;
        if self.job.events.send(Event::Token(tok)).is_err() {
            self.client_gone = true;
        }
    }

    /// Terminal event + accounting. A failed send (client hung up) is
    /// counted, never propagated — the router thread must outlive any
    /// client.
    fn finish(mut self, stats: &mut ServeStats) {
        // A capped session that ran to its clamped budget was ended
        // by the sequence cap, not the caller: classify it Evicted —
        // uniformly, on every backend.
        if self.outcome == Outcome::Done && self.capped && self.streamed >= self.budget {
            self.outcome = Outcome::Evicted;
        }
        stats.requests += 1;
        match self.outcome {
            Outcome::Done => {}
            Outcome::Cancelled => stats.cancelled += 1,
            Outcome::Evicted => stats.evicted += 1,
            Outcome::DeadlineEvicted => stats.deadline_evicted += 1,
        }
        let s = SessionStats {
            tokens: self.streamed,
            queue_ms: (self.t_admit - self.job.submitted).as_secs_f64() * 1e3,
            latency_ms: self.job.submitted.elapsed().as_secs_f64() * 1e3,
            ttft_ms: self.first_ms,
            cancelled: matches!(self.outcome, Outcome::Cancelled),
        };
        let ev = match self.outcome {
            Outcome::Evicted | Outcome::DeadlineEvicted => Event::Evicted(s),
            _ => Event::Done(s),
        };
        let hung_up = self.job.events.send(ev).is_err();
        if self.client_gone || hung_up {
            stats.dropped_clients += 1;
        }
    }
}

/// Queued-state admission gate shared by the dynamic batchers:
/// terminate dead jobs (cancelled / expired / zero-budget) without
/// touching the engine, return the sessions that will decode.
fn admit_batch(
    jobs: Vec<Job>,
    default_deadline: Duration,
    t_batch: Instant,
    headroom: usize,
    stats: &mut ServeStats,
) -> Vec<BatchSession> {
    let mut admitted = Vec::with_capacity(jobs.len());
    for job in jobs {
        let mut bs = BatchSession::new(job, default_deadline, t_batch, headroom);
        if !bs.wants_token(0, t_batch) {
            bs.finish(stats);
        } else {
            admitted.push(bs);
        }
    }
    admitted
}

/// One dynamic-batch decode step's bookkeeping, shared by both
/// dynamic batchers so their admission/termination semantics cannot
/// diverge: gate each live session, sample its row via `sample`,
/// stream the token, and emit each terminal the step it is known — a
/// deadline or cancellation must not wait for the batch's slowest
/// member. Returns `true` once every session has finished.
fn step_batch(
    live: &mut [Option<BatchSession>],
    step: usize,
    next: &mut [i32],
    stats: &mut ServeStats,
    mut sample: impl FnMut(usize) -> i32,
) -> bool {
    let now = Instant::now();
    let mut all_done = true;
    for (s, slot) in live.iter_mut().enumerate() {
        let Some(bs) = slot.as_mut() else { continue };
        if bs.wants_token(step, now) {
            let tok = sample(s);
            next[s] = tok;
            bs.push(tok, stats);
        }
        if bs.done {
            let bs = slot.take().expect("session present");
            bs.finish(stats);
        } else {
            all_done = false;
        }
    }
    all_done
}

fn router_loop(
    rt: &Runtime,
    params: Params,
    scfg: ServerConfig,
    rx: Receiver<Job>,
    gate: &Gate,
) -> Result<ServeStats, RuntimeError> {
    let cfg = params.cfg.clone();
    let cap = rt.manifest.serve_batch;
    let prompt_len = cfg.prompt_len;
    let prefill_name = format!("prefill_{}", cfg.name);
    let decode_name = format!("decode_step_{}", cfg.name);
    // Build param literals once; borrowed by every call.
    let dev = params.to_literals();
    let mut stats = ServeStats::default();
    let t_start = Instant::now();

    let headroom = cfg.max_seq.saturating_sub(prompt_len);
    'outer: loop {
        // --- gather a batch (dynamic batching) -------------------------
        let Some(jobs) = gather_batch(&rx, cap, scfg.batch_window) else {
            break 'outer; // all senders dropped
        };
        gate.depart(jobs.len());
        let t_batch = Instant::now();
        let admitted = admit_batch(jobs, scfg.sched.deadline, t_batch, headroom, &mut stats);
        if admitted.is_empty() {
            sync_live(gate, &stats, t_start);
            continue;
        }
        stats.batches += 1;

        // --- prefill -----------------------------------------------------
        // Left-aligned prompts, right-padded to prompt_len, PAD keys are
        // attention-masked inside the artifact.
        let mut flat = vec![0i32; cap * prompt_len];
        for (s, bs) in admitted.iter().enumerate() {
            let p = &bs.job.req.prompt;
            let n = p.len().min(prompt_len);
            flat[s * prompt_len..s * prompt_len + n].copy_from_slice(&p[..n]);
        }
        let tok_lit = lit_i32(&flat, &[cap, prompt_len]);
        let mut inputs: Vec<&xla::Literal> = dev.iter().collect();
        inputs.push(&tok_lit);
        let outs = rt.execute_refs(&prefill_name, &inputs)?;
        let (mut logits, mut kc, mut vc) = take3(&prefill_name, outs)?;

        // --- decode loop: stream tokens and terminals as they happen ----
        let max_new: usize = admitted.iter().map(|b| b.budget).max().unwrap_or(0);
        let mut live: Vec<Option<BatchSession>> = admitted.into_iter().map(Some).collect();
        for step in 0..max_new {
            let l = to_vec_f32(&logits);
            let mut next = vec![EOS; cap];
            let done = step_batch(&mut live, step, &mut next, &mut stats, |s| {
                greedy_token(&l[s * cfg.vocab..(s + 1) * cfg.vocab])
            });
            if done {
                break;
            }
            let pos = (prompt_len + step) as i32;
            let tok = lit_i32(&next, &[cap]);
            let pb = lit_scalar_i32(pos);
            let mut inputs: Vec<&xla::Literal> = dev.iter().collect();
            inputs.push(&kc);
            inputs.push(&vc);
            inputs.push(&tok);
            inputs.push(&pb);
            let outs = rt.execute_refs(&decode_name, &inputs)?;
            let (l2, k2, v2) = take3(&decode_name, outs)?;
            logits = l2;
            kc = k2;
            vc = v2;
        }

        // --- terminal events ---------------------------------------------
        for bs in live.into_iter().flatten() {
            bs.finish(&mut stats);
        }
        sync_live(gate, &stats, t_start);
    }
    stats.wall_secs = t_start.elapsed().as_secs_f64();
    sync_live(gate, &stats, t_start);
    Ok(stats)
}

/// Drain up to `cap` jobs: block for the first, then poll for
/// stragglers until the batch window closes. `None` once all senders
/// dropped and the queue is empty (shutdown).
fn gather_batch(rx: &Receiver<Job>, cap: usize, window: Duration) -> Option<Vec<Job>> {
    let mut jobs: Vec<Job> = Vec::with_capacity(cap);
    match rx.recv() {
        Ok(j) => jobs.push(j),
        Err(_) => return None,
    }
    let window_end = Instant::now() + window;
    while jobs.len() < cap {
        match rx.try_recv() {
            Ok(j) => jobs.push(j),
            Err(TryRecvError::Empty) => {
                if Instant::now() >= window_end {
                    break;
                }
                std::thread::yield_now();
            }
            Err(TryRecvError::Disconnected) => break,
        }
    }
    Some(jobs)
}

/// The [`Backend::NativePacked`] router: same dynamic batching,
/// greedy policy, streaming, and accounting as [`router_loop`], but
/// prefill and decode run through [`SlabModel`] — no PJRT, no padding
/// the batch up to an artifact's static shape (the native engine
/// takes the actual batch size).
fn native_router_loop(
    model: &SlabModel,
    scfg: ServerConfig,
    rx: Receiver<Job>,
    gate: &Gate,
) -> Result<ServeStats, RuntimeError> {
    let cap = scfg.serve_batch.max(1);
    let prompt_len = model.cfg.prompt_len;
    let mut stats = ServeStats::default();
    let t_start = Instant::now();

    let headroom = model.cfg.max_seq.saturating_sub(prompt_len);
    loop {
        let Some(jobs) = gather_batch(&rx, cap, scfg.batch_window) else {
            break;
        };
        gate.depart(jobs.len());
        let t_batch = Instant::now();
        let admitted = admit_batch(jobs, scfg.sched.deadline, t_batch, headroom, &mut stats);
        if admitted.is_empty() {
            sync_live(gate, &stats, t_start);
            continue;
        }
        stats.batches += 1;
        let bsz = admitted.len();

        // --- prefill: left-aligned prompts, PAD-padded ------------------
        let vmax = model.cfg.vocab.saturating_sub(1) as i32;
        let mut flat = vec![PAD; bsz * prompt_len];
        for (s, bs) in admitted.iter().enumerate() {
            let p = &bs.job.req.prompt;
            let n = p.len().min(prompt_len);
            for (j, &tok) in p[..n].iter().enumerate() {
                // Clamp malformed ids like the artifact backend does
                // (XLA gather clamps OOB indices): one bad request
                // must not panic the router thread for everyone.
                flat[s * prompt_len + j] = tok.clamp(0, vmax);
            }
        }
        let (mut logits, mut cache) = model.prefill(&flat, bsz);

        // --- decode loop: stream tokens and terminals as they happen ----
        let max_new: usize = admitted.iter().map(|b| b.budget).max().unwrap_or(0);
        let mut live: Vec<Option<BatchSession>> = admitted.into_iter().map(Some).collect();
        for step in 0..max_new {
            let mut next = vec![EOS; bsz];
            let done = step_batch(&mut live, step, &mut next, &mut stats, |s| {
                greedy_token(logits.row(s))
            });
            if done {
                break;
            }
            logits = model.decode_step(&mut cache, &next, prompt_len + step);
        }

        for bs in live.into_iter().flatten() {
            bs.finish(&mut stats);
        }
        sync_live(gate, &stats, t_start);
    }
    stats.wall_secs = t_start.elapsed().as_secs_f64();
    sync_live(gate, &stats, t_start);
    Ok(stats)
}

/// One live request inside the continuous batcher: the shared
/// streaming core (which owns the budget/cap clamp) plus the
/// decode-batch bookkeeping.
struct ActiveSession {
    core: BatchSession,
    /// [`KvCachePool`] handle once the session joined the decode
    /// batch; `None` for sessions that finished at prefill.
    slot: Option<usize>,
    /// Next cache write position (`prompt_len + generated so far`).
    pos: usize,
    /// Token to feed at the next decode tick.
    next_tok: i32,
}

/// Continuous-batching scheduler over the native packed engine — the
/// state machine behind [`Backend::NativeBatched`] (DESIGN.md §6a,
/// §12).
///
/// Request lifecycle: bounded admission queue → individual prefill
/// (prefill-then-join) → member of the shared decode batch until EOS
/// / token budget / sequence-cap or deadline eviction / cancellation
/// → terminal event. One [`tick`](Scheduler::tick) = reap terminated
/// sessions (cancelled, expired, capped — freeing their
/// [`KvCachePool`] slots *before* admission, so a cancellation makes
/// room in the same tick), admit up to `max_batch` live sessions,
/// then one [`SlabModel::decode_batch_greedy`] step for all of them;
/// each session's sampled token is streamed as [`Event::Token`] the
/// tick it is produced — nothing is buffered. Submissions past
/// `queue_cap` receive an immediate [`Event::Rejected`]
/// (backpressure) instead of growing the queue without bound.
///
/// Per session the sampling semantics are exactly the serial native
/// router's (same prompt padding, same greedy policy, same budget
/// clamp), and [`SlabModel::decode_batch`] is bit-identical row-wise
/// to serial decode — so a `NativeBatched` server answers every
/// request with the same tokens a `NativePacked` server would.
pub struct Scheduler {
    model: Box<SlabModel>,
    cfg: SchedulerConfig,
    /// `min(model.max_seq, max_seq_len)` — the hard position cap.
    seq_cap: usize,
    kv: KvBacking,
    queue: VecDeque<Job>,
    active: Vec<ActiveSession>,
    stats: ServeStats,
}

/// The scheduler's KV storage: the block-paged pool (default, with
/// copy-on-write prefix sharing and real-page admission) or the
/// legacy contiguous pool (`kv_page: 0`) kept as the conformance
/// reference. Decode is bit-identical across the two (DESIGN.md §13).
enum KvBacking {
    Contiguous(KvCachePool),
    Paged(PagedKvPool),
}

impl Scheduler {
    pub fn new(model: Box<SlabModel>, cfg: SchedulerConfig) -> Scheduler {
        let mut cfg = cfg;
        cfg.max_batch = cfg.max_batch.max(1);
        cfg.queue_cap = cfg.queue_cap.max(1);
        let seq_cap = if cfg.max_seq_len == 0 {
            model.cfg.max_seq
        } else {
            cfg.max_seq_len.min(model.cfg.max_seq)
        };
        let kv = if cfg.kv_page == 0 {
            KvBacking::Contiguous(KvCachePool::for_model(&model, cfg.max_batch))
        } else {
            KvBacking::Paged(PagedKvPool::for_model(
                &model,
                cfg.max_batch,
                PagedKvConfig {
                    page_size: cfg.kv_page,
                    n_pages: cfg.page_budget,
                    prefix_sharing: cfg.prefix_sharing,
                },
            ))
        };
        Scheduler {
            model,
            cfg,
            seq_cap,
            kv,
            queue: VecDeque::new(),
            active: Vec::new(),
            stats: ServeStats::default(),
        }
    }

    /// Submit a request directly (no [`Server`] in front), streaming
    /// its events into `events`. Returns the session's
    /// [`CancelHandle`], or `None` when the bounded queue rejected it
    /// (an [`Event::Rejected`] is already in the channel).
    pub fn enqueue(&mut self, req: Request, events: Sender<Event>) -> Option<CancelHandle> {
        let cancel = CancelHandle::default();
        let job = Job {
            req,
            submitted: Instant::now(),
            events,
            cancel: cancel.clone(),
        };
        if self.enqueue_job(job) {
            Some(cancel)
        } else {
            None
        }
    }

    fn enqueue_job(&mut self, job: Job) -> bool {
        if self.queue.len() >= self.cfg.queue_cap {
            self.stats.rejected += 1;
            if job.events.send(Event::Rejected).is_err() {
                self.stats.dropped_clients += 1;
            }
            return false;
        }
        self.queue.push_back(job);
        true
    }

    /// Anything queued or decoding?
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    /// Sessions currently in the decode batch.
    pub fn active_sessions(&self) -> usize {
        self.active.len()
    }

    /// Requests waiting for admission.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Tear down, returning the accumulated stats (`wall_secs` is the
    /// router's to fill — the scheduler does not own the clock).
    pub fn into_stats(mut self) -> ServeStats {
        self.sync_kv_stats();
        self.stats
    }

    /// One continuous-batching step: reap terminated sessions (their
    /// KV slots free up *before* admission), admit up to the batch
    /// cap, then run one shared decode step for every active session.
    /// Returns the number of sessions decoded; an empty tick (nothing
    /// queued, nothing active) is a no-op returning 0.
    pub fn tick(&mut self) -> usize {
        self.reap();
        self.admit();
        let n = if self.cfg.speculate {
            self.speculative_tick()
        } else {
            self.decode_tick()
        };
        self.sync_kv_stats();
        n
    }

    /// Mirror the paged pool's counters into [`ServeStats`] so
    /// `/metrics` and the stats table see live values each tick.
    /// `page_evictions` stays scheduler-owned — the pool does not
    /// know *why* a session was removed.
    fn sync_kv_stats(&mut self) {
        if let KvBacking::Paged(pool) = &mut self.kv {
            let c = pool.counters();
            self.stats.prefix_hits = c.prefix_hits;
            self.stats.prefix_misses = c.prefix_misses;
            self.stats.cow_splits = c.cow_splits;
            self.stats.kv_pages = c.pages_in_use;
            self.stats.kv_pages_peak = c.pages_peak;
        }
    }

    /// Remove sessions that terminated outside the decode path —
    /// cancelled, client-gone, deadline-expired, or at the hard
    /// sequence cap — and emit their terminal events. Freed KV slots
    /// are immediately reusable by [`admit`](Scheduler::admit). The
    /// *wait queue* is swept too: a cancelled or expired entry must
    /// not sit behind a full batch holding its bounded-queue place
    /// (and the caller's gate slot) until a KV slot happens to free.
    fn reap(&mut self) {
        let now = Instant::now();
        let mut i = 0;
        while i < self.queue.len() {
            let job = &self.queue[i];
            let dead_cancel = job.cancel.is_cancelled();
            let dead_deadline = job
                .deadline_at(self.cfg.deadline)
                .is_some_and(|d| now >= d);
            if dead_cancel || dead_deadline {
                let job = self.queue.remove(i).expect("indexed queue entry");
                let headroom = self.seq_cap.saturating_sub(self.model.cfg.prompt_len);
                let mut core = BatchSession::new(job, self.cfg.deadline, now, headroom);
                core.outcome = if dead_cancel {
                    Outcome::Cancelled
                } else {
                    Outcome::DeadlineEvicted
                };
                core.finish(&mut self.stats);
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.active.len() {
            let s = &self.active[i];
            let gone = s.core.job.cancel.is_cancelled() || s.core.client_gone;
            let expired = s.core.deadline.is_some_and(|d| now >= d);
            if gone || expired || s.pos >= self.seq_cap {
                let sess = self.active.remove(i);
                let outcome = if gone {
                    Outcome::Cancelled
                } else if expired {
                    Outcome::DeadlineEvicted
                } else {
                    Outcome::Evicted
                };
                self.finish(sess, outcome);
            } else {
                i += 1;
            }
        }
    }

    /// Prefill-then-join admission: each queued request prefills
    /// alone (batch 1), samples and streams its first token, and
    /// either finishes on the spot (zero budget / immediate EOS /
    /// budget of one) or adopts its KV cache into the pool and joins
    /// the decode batch. Cancelled or expired queue entries terminate
    /// here without touching the engine.
    ///
    /// On the paged pool admission is gated on *real* page
    /// availability for the queue head: zero pages when its padded
    /// prompt is already in the prefix index (the cached prefill is
    /// joined copy-on-write and the memoized logits replay its first
    /// token), a full prompt's worth otherwise — draining the prefix
    /// index first when short, and stalling admission (not rejecting)
    /// when pages are held by live sessions.
    fn admit(&mut self) {
        loop {
            if self.active.len() >= self.cfg.max_batch || self.queue.is_empty() {
                break;
            }
            match &mut self.kv {
                KvBacking::Contiguous(pool) => {
                    if pool.is_full() {
                        break;
                    }
                }
                KvBacking::Paged(pool) => {
                    if pool.is_full() {
                        break;
                    }
                    let front = self.queue.front().expect("checked non-empty");
                    let padded = self.model.pad_prompt(&front.req.prompt);
                    let need = if pool.has_prefix(&padded) {
                        0
                    } else {
                        pool.prompt_pages()
                    };
                    if pool.free_pages() < need {
                        pool.evict_prefixes(need);
                    }
                    if pool.free_pages() < need {
                        break;
                    }
                }
            }
            let job = self.queue.pop_front().expect("checked non-empty");
            let t_admit = Instant::now();
            let prompt_len = self.model.cfg.prompt_len;
            // The serial router's exact clamp (inside BatchSession),
            // so the two native paths stay token-identical.
            let headroom = self.seq_cap.saturating_sub(prompt_len);
            let mut core = BatchSession::new(job, self.cfg.deadline, t_admit, headroom);
            // The queued-state gate: cancellation / deadline / empty
            // budget end the session before prefill (`wants_token`
            // leaves `core` untouched when it returns true; a capped
            // zero-budget session classifies Evicted in finish).
            if !core.wants_token(0, t_admit) {
                core.finish(&mut self.stats);
                continue;
            }
            let (slot, first_row) = self.admit_prefill(&core.job.req.prompt);
            let mut sess = ActiveSession {
                core,
                slot: Some(slot),
                pos: prompt_len,
                next_tok: EOS,
            };
            let first = greedy_token(&first_row);
            if first == EOS {
                self.finish(sess, Outcome::Done);
                continue;
            }
            sess.core.push(first, &mut self.stats);
            if sess.core.streamed >= sess.core.budget {
                self.finish(sess, Outcome::Done); // finish caps→Evicted
                continue;
            }
            sess.next_tok = first;
            self.active.push(sess);
        }
    }

    /// Prefill-and-adopt for one admitted request — the **single**
    /// `prefill_session` call site shared by both KV backings (and
    /// thereby the one integration point the speculative path rides
    /// on): paged admission first tries to join a cached shared
    /// prefix (replaying its memoized logits), falling back to a
    /// fresh prefill adopted into whichever pool is live. Capacity
    /// was pre-checked by [`admit`](Scheduler::admit).
    fn admit_prefill(&mut self, prompt: &[i32]) -> (usize, Vec<f32>) {
        let padded = self.model.pad_prompt(prompt);
        if let KvBacking::Paged(pool) = &mut self.kv {
            if let Some((sid, row)) = pool.admit_shared(&padded) {
                return (sid, row);
            }
        }
        let (logits, cache) = self.model.prefill_session(prompt);
        let first_row = logits.row(0).to_vec();
        let slot = match &mut self.kv {
            KvBacking::Contiguous(pool) => {
                pool.adopt(cache).expect("kv pool sized to max_batch")
            }
            KvBacking::Paged(pool) => pool
                .adopt_prefill(&padded, logits.row(0), &cache)
                .expect("admission pre-checked page availability"),
        };
        (slot, first_row)
    }

    /// One shared decode step for the active batch; terminating
    /// sessions (EOS / budget / cap eviction) leave it immediately.
    /// Sessions cancelled or expired since the tick's reap pass are
    /// caught by the same gates one tick later — never decoded past
    /// their budget either way.
    fn decode_tick(&mut self) -> usize {
        // Paged pool: every active session secures its write page
        // *before* the shared step — decode itself never allocates.
        // When a session cannot (page budget exhausted even after
        // draining the prefix index) the *newest* session is
        // preempted — evicted with the tokens streamed so far, its
        // pages freed on the spot — and the starved session retries.
        // Oldest-first page securing plus newest-first preemption
        // plus the one-worst-case-session budget floor guarantee the
        // oldest session always progresses (no eviction livelock).
        let mut page_evicted: Vec<ActiveSession> = Vec::new();
        if let KvBacking::Paged(pool) = &mut self.kv {
            let mut i = 0;
            while i < self.active.len() {
                let sid = self.active[i].slot.expect("active session owns a kv slot");
                let pos = self.active[i].pos;
                if !pool.can_write(sid, pos) {
                    pool.evict_prefixes(1);
                }
                if pool.prepare_write(sid, pos) {
                    i += 1;
                    continue;
                }
                let victim = self.active.len() - 1;
                let mut sess = self.active.remove(victim);
                if let Some(slot) = sess.slot.take() {
                    pool.release(slot); // freed *now*, so the retry can win
                }
                page_evicted.push(sess);
            }
        }
        for sess in page_evicted {
            self.stats.page_evictions += 1;
            self.finish(sess, Outcome::Evicted);
        }
        if self.active.is_empty() {
            return 0;
        }
        let steps: Vec<DecodeSlot> = self
            .active
            .iter()
            .map(|s| DecodeSlot {
                session: s.slot.expect("active session owns a kv slot"),
                token: s.next_tok,
                pos: s.pos,
            })
            .collect();
        // The per-tick emit hook: one shared weight pass, then the
        // serving argmax per row (bit-identical to serial decode —
        // paged or contiguous, the compute body is the same code).
        let next = match &mut self.kv {
            KvBacking::Contiguous(pool) => self.model.decode_batch_greedy(pool, &steps),
            KvBacking::Paged(pool) => self.model.decode_batch_greedy_paged(pool, &steps),
        };
        self.stats.batches += 1;
        let n = steps.len();
        // (row, outcome) of sessions that terminate this tick.
        let mut done: Vec<(usize, Outcome)> = Vec::new();
        for (r, sess) in self.active.iter_mut().enumerate() {
            sess.pos += 1;
            let tok = next[r];
            if tok == EOS {
                done.push((r, Outcome::Done));
                continue;
            }
            sess.core.push(tok, &mut self.stats);
            if sess.core.streamed >= sess.core.budget {
                done.push((r, Outcome::Done)); // finish caps→Evicted
            } else {
                sess.next_tok = tok;
            }
        }
        for &(r, outcome) in done.iter().rev() {
            let sess = self.active.remove(r);
            self.finish(sess, outcome);
        }
        n
    }

    /// One self-speculative round for the active batch (DESIGN.md
    /// §14), replacing [`decode_tick`](Scheduler::decode_tick) when
    /// [`SchedulerConfig::speculate`] is on:
    ///
    /// 1. **window** — per session, `k = min(draft_len, cap headroom,
    ///    budget headroom)` tokens may be speculated past the
    ///    mandatory verify token (paged: each extra position must also
    ///    secure a page, shrinking `k` instead of evicting — only the
    ///    verify token's page preempts, exactly like `decode_tick`);
    /// 2. **draft** — `k` greedy tokens through the cheap
    ///    sparse+low-rank [`SlabModel::draft`] view, writing
    ///    draft-quality K/V into the session's own cache;
    /// 3. **verify** — one full-model multi-token pass re-feeds the
    ///    last emitted token plus the draft run, overwriting every fed
    ///    K/V row with full-model rows;
    /// 4. **accept/emit** — the longest draft prefix matching the
    ///    verify argmaxes is emitted plus the verify's own next token,
    ///    through the *same* per-token EOS/budget gates as
    ///    `decode_tick` — streams are token-identical to plain greedy
    ///    decode, speculation only changes how many arrive per tick;
    /// 5. **rollback** — paged sessions truncate to their new length,
    ///    releasing pages past the divergence point (contiguous
    ///    rollback is a no-op: stale rows are overwritten before any
    ///    later read — `KvCache`'s lazy-growth contract).
    ///
    /// `k = 0` (cap/budget/page-starved) degrades to a single-token
    /// verify with plain-decode semantics, so every session always
    /// progresses. Cancellations and deadlines land at tick
    /// boundaries, as in plain decode — a speculative tick may stream
    /// up to `k` extra tokens first, all of them still exact.
    fn speculative_tick(&mut self) -> usize {
        let draft_len = self.cfg.draft_len.max(1);
        // Per-session speculation window past the mandatory verify
        // token. The window never reaches seq_cap (the fed run ends
        // at pos + k ≤ seq_cap - 1) and never drafts past the token
        // budget (at most budget-streamed tokens can still be
        // emitted, consuming at most that many fed positions).
        let window = |sess: &ActiveSession, cap: usize| -> usize {
            draft_len
                .min(cap.saturating_sub(sess.pos + 1))
                .min(sess.core.budget.saturating_sub(sess.core.streamed + 1))
        };
        // Page securing, mirroring decode_tick's pre-pass: the verify
        // token's page is mandatory (oldest-first securing,
        // newest-first preemption, same livelock-freedom argument);
        // draft positions just shrink the window when starved.
        let mut ks: Vec<usize> = Vec::new();
        let mut page_evicted: Vec<ActiveSession> = Vec::new();
        match &mut self.kv {
            KvBacking::Contiguous(_) => {
                let cap = self.seq_cap;
                ks = self.active.iter().map(|s| window(s, cap)).collect();
            }
            KvBacking::Paged(pool) => {
                let mut i = 0;
                while i < self.active.len() {
                    let sid = self.active[i].slot.expect("active session owns a kv slot");
                    let pos = self.active[i].pos;
                    if !pool.can_write(sid, pos) {
                        pool.evict_prefixes(1);
                    }
                    if !pool.prepare_write(sid, pos) {
                        let victim = self.active.len() - 1;
                        let mut sess = self.active.remove(victim);
                        if let Some(slot) = sess.slot.take() {
                            pool.release(slot);
                        }
                        page_evicted.push(sess);
                        continue;
                    }
                    let want = window(&self.active[i], self.seq_cap);
                    let mut k = 0;
                    for j in 1..=want {
                        if !pool.can_write(sid, pos + j) {
                            pool.evict_prefixes(1);
                        }
                        if !pool.prepare_write(sid, pos + j) {
                            break;
                        }
                        k = j;
                    }
                    ks.push(k);
                    i += 1;
                }
            }
        }
        for sess in page_evicted {
            self.stats.page_evictions += 1;
            self.finish(sess, Outcome::Evicted);
        }
        if self.active.is_empty() {
            return 0;
        }
        debug_assert_eq!(ks.len(), self.active.len());

        // Draft phase: k greedy tokens per session through the cheap
        // view. fed[i] = [next_tok, d_1, .., d_k] — the verify input.
        let mut fed: Vec<Vec<i32>> = self.active.iter().map(|s| vec![s.next_tok]).collect();
        let max_k = ks.iter().copied().max().unwrap_or(0);
        let draft = self.model.draft(self.cfg.draft_rank);
        for j in 0..max_k {
            let mut idx: Vec<usize> = Vec::new();
            let mut steps: Vec<DecodeSlot> = Vec::new();
            for (i, sess) in self.active.iter().enumerate() {
                if ks[i] > j {
                    idx.push(i);
                    steps.push(DecodeSlot {
                        session: sess.slot.expect("active session owns a kv slot"),
                        token: fed[i][j],
                        pos: sess.pos + j,
                    });
                }
            }
            if steps.is_empty() {
                break;
            }
            let toks = match &mut self.kv {
                KvBacking::Contiguous(pool) => draft.decode_batch_greedy(pool, &steps),
                KvBacking::Paged(pool) => draft.decode_batch_greedy_paged(pool, &steps),
            };
            for (&i, &t) in idx.iter().zip(&toks) {
                fed[i].push(t);
            }
        }

        // Verify: one full-model pass over every fed run. Row j of a
        // session's run is bit-identical to what sequential decode of
        // fed[..=j] would produce — the losslessness anchor.
        let slots: Vec<VerifySlot> = self
            .active
            .iter()
            .enumerate()
            .map(|(i, s)| VerifySlot {
                session: s.slot.expect("active session owns a kv slot"),
                pos: s.pos,
                tokens: fed[i].clone(),
            })
            .collect();
        let logits = match &mut self.kv {
            KvBacking::Contiguous(pool) => self.model.decode_batch_multi(pool, &slots),
            KvBacking::Paged(pool) => self.model.decode_batch_multi_paged(pool, &slots),
        };
        self.stats.batches += 1;
        self.stats.spec_rounds += 1;

        // Accept & emit: per session, verify row j answers "what
        // follows fed[..=j]?" — accept drafts while they agree, then
        // the verify's own token, each through the exact per-token
        // gates (EOS, then budget) of the plain decode path.
        let n = self.active.len();
        let mut done: Vec<(usize, Outcome)> = Vec::new();
        let mut row = 0usize;
        for (i, sess) in self.active.iter_mut().enumerate() {
            let k = ks[i];
            let f = &fed[i];
            let mut accepted = 0;
            while accepted < k && greedy_token(logits.row(row + accepted)) == f[accepted + 1] {
                accepted += 1;
            }
            self.stats.spec_drafted += k;
            self.stats.spec_accepted += accepted;
            if accepted < k {
                self.stats.spec_rollbacks += 1;
            }
            for j in 0..=accepted {
                let tok = greedy_token(logits.row(row + j));
                sess.pos += 1;
                if tok == EOS {
                    done.push((i, Outcome::Done));
                    break;
                }
                sess.core.push(tok, &mut self.stats);
                if sess.core.streamed >= sess.core.budget {
                    done.push((i, Outcome::Done)); // finish caps→Evicted
                    break;
                }
                sess.next_tok = tok;
            }
            row += f.len();
        }
        debug_assert_eq!(row, logits.rows);

        // Rollback: drop KV state past each session's new length.
        // Terminating sessions release everything in finish() anyway;
        // live ones must not keep rejected-suffix pages pinned.
        if let KvBacking::Paged(pool) = &mut self.kv {
            for sess in &self.active {
                let sid = sess.slot.expect("active session owns a kv slot");
                pool.truncate(sid, sess.pos);
            }
        }
        for &(r, outcome) in done.iter().rev() {
            let sess = self.active.remove(r);
            self.finish(sess, outcome);
        }
        n
    }

    /// Complete a session: free its KV slot, account it, emit the
    /// terminal event.
    fn finish(&mut self, mut sess: ActiveSession, outcome: Outcome) {
        if let Some(slot) = sess.slot {
            match &mut self.kv {
                KvBacking::Contiguous(pool) => {
                    pool.release(slot);
                }
                KvBacking::Paged(pool) => {
                    pool.release(slot);
                }
            }
        }
        sess.core.outcome = outcome;
        sess.core.finish(&mut self.stats);
    }

    #[cfg(test)]
    fn kv_active(&self) -> usize {
        match &self.kv {
            KvBacking::Contiguous(pool) => pool.active(),
            KvBacking::Paged(pool) => pool.active(),
        }
    }
}

/// The [`Backend::NativeBatched`] router: a [`Scheduler`] driven off
/// the mpsc queue. Unlike the dynamic batchers there is no batch
/// window — arrivals are drained non-blockingly before every tick and
/// join the running batch at their first admission opportunity; the
/// router only blocks when fully idle.
fn batched_router_loop(
    model: Box<SlabModel>,
    scfg: ServerConfig,
    rx: Receiver<Job>,
    gate: &Gate,
) -> Result<ServeStats, RuntimeError> {
    let mut sched = Scheduler::new(model, scfg.sched.clone());
    let t_start = Instant::now();
    let mut open = true;
    loop {
        if open && !sched.has_work() {
            // Idle: block for the next request (or shutdown).
            match rx.recv() {
                Ok(job) => {
                    if !sched.enqueue_job(job) {
                        gate.depart(1);
                    }
                }
                Err(_) => open = false,
            }
        }
        while open {
            match rx.try_recv() {
                Ok(job) => {
                    if !sched.enqueue_job(job) {
                        gate.depart(1);
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => open = false,
            }
        }
        if !sched.has_work() {
            sync_live(gate, sched.stats(), t_start);
            if !open {
                break; // drained and no more senders: shutdown
            }
            continue;
        }
        let waiting = sched.queued();
        sched.tick();
        // Jobs that left the wait queue this tick (admitted or
        // terminated while queued) are no longer pending at the gate.
        gate.depart(waiting.saturating_sub(sched.queued()));
        sync_live(gate, sched.stats(), t_start);
    }
    let mut stats = sched.into_stats();
    stats.wall_secs = t_start.elapsed().as_secs_f64();
    sync_live(gate, &stats, t_start);
    Ok(stats)
}

/// Pop the three outputs of a prefill/decode artifact call — typed
/// error instead of a panicking unwrap when an artifact returns a
/// malformed tuple (the router thread must never die on bad data).
fn take3(
    name: &str,
    mut outs: Vec<xla::Literal>,
) -> Result<(xla::Literal, xla::Literal, xla::Literal), RuntimeError> {
    let got = outs.len();
    let pop = |outs: &mut Vec<xla::Literal>| {
        outs.pop()
            .ok_or_else(|| RuntimeError::Outputs(name.to_string(), 3, got))
    };
    let c = pop(&mut outs)?;
    let b = pop(&mut outs)?;
    let a = pop(&mut outs)?;
    Ok((a, b, c))
}

/// In-crate test fixtures shared by the serving and HTTP test suites
/// (the integration binaries carry their own copy in
/// `rust/tests/common/mod.rs` — `cfg(test)` items are invisible to
/// them).
#[cfg(test)]
pub(crate) mod test_support {
    use crate::data::{EOS, PAD};
    use crate::model::Params;
    use crate::runtime::ModelCfg;
    use crate::slab::{decompose, ActStats, SlabConfig, SlabLayer};
    use crate::tensor::Mat;
    use crate::util::rng::Pcg64;

    /// Params whose EOS logit row duplicates PAD's, so first-max
    /// tie-breaking (PAD = 0 scans before EOS = 2) can never emit EOS
    /// — sessions deterministically run to budget/cap. Used wherever
    /// a test needs sessions of known length.
    pub(crate) fn eos_free_params(cfg: &ModelCfg, seed: u64) -> Params {
        let mut params = Params::init(cfg, seed);
        let mut head = params.mat("lm_head");
        let pad_row = head.row(PAD as usize).to_vec();
        head.row_mut(EOS as usize).copy_from_slice(&pad_row);
        params.set_mat("lm_head", &head);
        params
    }

    /// Decompose every pruned linear of `params` natively →
    /// (packed layers, params with `Ŵ` swapped in), ready for
    /// [`SlabModel::from_packed`](crate::model::SlabModel). The
    /// speculative-decoding tests need a genuinely packed model: on a
    /// dense one the draft view falls through to the full path and
    /// every draft is accepted, so rejection/rollback never fires.
    pub(crate) fn packed_params(params: &Params, seed: u64) -> (Vec<(String, SlabLayer)>, Params) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let scfg = SlabConfig {
            iters: 3,
            svd_iters: 6,
            ..Default::default()
        };
        let mut packed = Vec::new();
        let mut swapped = params.clone();
        for (name, (_, din)) in params.cfg.pruned.clone() {
            let w = params.mat(&name);
            let stats = ActStats::from_activations(&Mat::randn(48, din, 1.0, &mut rng));
            let d = decompose(&w, &stats, &scfg).expect("decompose");
            let layer = SlabLayer::from_decomposition(&d);
            swapped.set_mat(&name, &layer.reconstruct());
            packed.push((name, layer));
        }
        (packed, swapped)
    }
}

#[cfg(test)]
mod tests {
    //! The native backend needs no artifacts, so the router/batcher/
    //! streaming invariants get exercised on every `cargo test`, not
    //! only when `make artifacts` has run.

    use super::test_support::{eos_free_params, packed_params};
    use super::*;
    use crate::runtime::ModelCfg;
    use crate::util::prop::{check, Shrink};
    use crate::util::rng::Pcg64;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg::llama("tiny-serve", 32, 8, 1, 2, 16, 12, 4)
    }

    fn req(prompt: Vec<i32>, max_new: usize) -> Request {
        Request {
            prompt,
            max_new,
            deadline: None,
        }
    }

    #[test]
    fn native_backend_serves_every_request_exactly_once() {
        let cfg = tiny_cfg();
        let model = SlabModel::from_dense(&Params::init(&cfg, 51), 2);
        let scfg = ServerConfig {
            serve_batch: 3,
            ..Default::default()
        };
        let server = Server::start_with(Backend::NativePacked(Box::new(model)), scfg);
        let n = 10;
        let sessions: Vec<Session> = (0..n)
            .map(|i| server.submit(req(vec![5 + i as i32, 6, 7], 1 + (i % 4))))
            .collect();
        // Session ids are unique and monotone.
        for w in sessions.windows(2) {
            assert!(w[0].id() < w[1].id());
        }
        for (i, s) in sessions.into_iter().enumerate() {
            let r = s.collect();
            assert!(r.tokens.len() <= 1 + (i % 4), "token budget violated");
            assert!(r.latency_ms >= r.queue_ms);
            assert!(r.tokens.iter().all(|&t| t != EOS && t != PAD));
            if !r.tokens.is_empty() {
                assert!(r.ttft_ms > 0.0, "ttft must be set when tokens streamed");
            }
        }
        let stats = server.shutdown().expect("stats");
        assert_eq!(stats.requests, n);
        assert!(stats.batches >= n.div_ceil(3));
        assert!(stats.requests <= stats.batches * 3);
        assert!(stats.wall_secs > 0.0);
        if stats.generated_tokens > 0 {
            assert!(stats.ttft_samples > 0);
            assert!(stats.mean_ttft_ms() > 0.0);
        }
    }

    #[test]
    fn native_backend_survives_out_of_vocab_prompts() {
        // Malformed token ids are clamped (like XLA gather in the
        // artifact backend), not allowed to panic the router thread.
        let cfg = tiny_cfg();
        let model = SlabModel::from_dense(&Params::init(&cfg, 53), 1);
        let server = Server::start_with(
            Backend::NativePacked(Box::new(model)),
            ServerConfig::default(),
        );
        let bad = server.generate(req(vec![-7, i32::MAX, 9999, 5], 3));
        assert!(bad.tokens.len() <= 3);
        // The server is still alive and serves well-formed requests.
        let ok = server.generate(req(vec![5, 6], 3));
        assert!(ok.tokens.len() <= 3);
        let stats = server.shutdown().expect("stats");
        assert_eq!(stats.requests, 2);
    }

    /// Drive a server over `prompts`/`budgets`, returning each
    /// request's blocking response (order-stable).
    fn serve_all(
        backend: Backend,
        scfg: ServerConfig,
        prompts: &[Vec<i32>],
        budgets: &[usize],
    ) -> Vec<Response> {
        let server = Server::start_with(backend, scfg);
        let sessions: Vec<Session> = prompts
            .iter()
            .zip(budgets)
            .map(|(p, &b)| server.submit(req(p.clone(), b)))
            .collect();
        let out = sessions.into_iter().map(|s| s.collect()).collect();
        server.shutdown().expect("stats");
        out
    }

    /// Consume a session's raw event stream: (tokens, terminal).
    fn stream_all(session: Session) -> (Vec<i32>, Event) {
        let mut tokens = Vec::new();
        for ev in session.iter() {
            match ev {
                Event::Token(t) => tokens.push(t),
                terminal => return (tokens, terminal),
            }
        }
        panic!("stream ended without a terminal event");
    }

    #[test]
    fn batched_backend_is_token_identical_to_serial_native() {
        // The tentpole acceptance test: for a mixed-length request set
        // (short, long, single-token, empty, over-length prompts; mixed
        // budgets), the continuous batcher must answer every request
        // with exactly the tokens the serial NativePacked router
        // produces.
        let cfg = tiny_cfg();
        let mk = || Box::new(SlabModel::from_dense(&Params::init(&cfg, 55), 2));
        let prompts: Vec<Vec<i32>> = vec![
            vec![5, 6, 7],
            vec![9, 10, 11, 12, 13],
            vec![21],
            vec![],
            vec![8; 20], // longer than prompt_len: truncated by both paths
            vec![17, 4, 29, 3],
        ];
        let budgets = [6usize, 3, 8, 2, 5, 7];
        let serial: Vec<Vec<i32>> = serve_all(
            Backend::NativePacked(mk()),
            ServerConfig::default(),
            &prompts,
            &budgets,
        )
        .into_iter()
        .map(|r| r.tokens)
        .collect();
        let batched = serve_all(
            Backend::NativeBatched(mk()),
            ServerConfig {
                sched: SchedulerConfig {
                    max_batch: 3, // force joins/leaves mid-stream
                    ..Default::default()
                },
                ..Default::default()
            },
            &prompts,
            &budgets,
        );
        for (r, b) in batched.iter().zip(&budgets) {
            assert!(!r.rejected);
            assert!(r.tokens.len() <= *b);
            assert!(r.latency_ms >= r.queue_ms);
        }
        let batched: Vec<Vec<i32>> = batched.into_iter().map(|r| r.tokens).collect();
        assert_eq!(serial, batched, "continuous batcher diverged from serial router");
    }

    /// A request mix for the streaming property test; shrinks by
    /// dropping requests.
    #[derive(Debug, Clone)]
    struct ReqMix(Vec<(Vec<i32>, usize)>);

    impl Shrink for ReqMix {
        fn shrinks(&self) -> Vec<Self> {
            let mut out = Vec::new();
            if self.0.len() > 1 {
                out.push(ReqMix(self.0[..self.0.len() / 2].to_vec()));
                out.push(ReqMix(self.0[self.0.len() / 2..].to_vec()));
            }
            out
        }
    }

    #[test]
    fn streaming_matches_collect_for_every_native_backend() {
        // The streaming contract: for random request mixes, the Token
        // events of a session concatenate bit-identically to the
        // blocking collect() response, on both native backends, and
        // both equal the engine-level generate_batch reference.
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 61);
        let reference_model = SlabModel::from_dense(&params, 1);
        check(
            "stream==collect per backend",
            4,
            |rng: &mut Pcg64| {
                let n = 2 + rng.below_usize(4);
                ReqMix(
                    (0..n)
                        .map(|_| {
                            let len = rng.below_usize(6);
                            let p: Vec<i32> =
                                (0..len).map(|_| 5 + rng.below(20) as i32).collect();
                            (p, rng.below_usize(7))
                        })
                        .collect(),
                )
            },
            |mix: &ReqMix| {
                let prompts: Vec<Vec<i32>> = mix.0.iter().map(|(p, _)| p.clone()).collect();
                let budgets: Vec<usize> = mix.0.iter().map(|(_, b)| *b).collect();
                let reference: Vec<Vec<i32>> = mix
                    .0
                    .iter()
                    .map(|(p, b)| reference_model.generate_batch(&[p.clone()], *b).remove(0))
                    .collect();
                let backends: [fn(Params) -> Backend; 2] = [
                    |p| Backend::NativePacked(Box::new(SlabModel::from_dense(&p, 1))),
                    |p| Backend::NativeBatched(Box::new(SlabModel::from_dense(&p, 1))),
                ];
                for mk in backends {
                    // Streamed consumption.
                    let server = Server::start_with(mk(params.clone()), ServerConfig::default());
                    let sessions: Vec<Session> = prompts
                        .iter()
                        .zip(&budgets)
                        .map(|(p, &b)| server.submit(req(p.clone(), b)))
                        .collect();
                    let streamed: Vec<(Vec<i32>, Event)> =
                        sessions.into_iter().map(stream_all).collect();
                    server.shutdown().expect("stats");
                    for (i, (tokens, terminal)) in streamed.iter().enumerate() {
                        if tokens != &reference[i] {
                            return Err(format!(
                                "streamed req {i}: {tokens:?} != reference {:?}",
                                reference[i]
                            ));
                        }
                        match terminal {
                            Event::Done(s) | Event::Evicted(s) => {
                                if s.tokens != tokens.len() {
                                    return Err(format!(
                                        "terminal stats.tokens {} != streamed {}",
                                        s.tokens,
                                        tokens.len()
                                    ));
                                }
                            }
                            other => return Err(format!("unexpected terminal {other:?}")),
                        }
                    }
                    // Blocking collect() over a fresh identical server.
                    let collected: Vec<Vec<i32>> = serve_all(
                        mk(params.clone()),
                        ServerConfig::default(),
                        &prompts,
                        &budgets,
                    )
                    .into_iter()
                    .map(|r| r.tokens)
                    .collect();
                    let streamed_tokens: Vec<Vec<i32>> =
                        streamed.into_iter().map(|(t, _)| t).collect();
                    if collected != streamed_tokens {
                        return Err("collect() diverged from streamed tokens".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn scheduler_empty_tick_is_noop() {
        let cfg = tiny_cfg();
        let model = Box::new(SlabModel::from_dense(&Params::init(&cfg, 56), 1));
        let mut s = Scheduler::new(model, SchedulerConfig::default());
        assert!(!s.has_work());
        assert_eq!(s.tick(), 0);
        assert_eq!(s.tick(), 0);
        assert_eq!(s.active_sessions(), 0);
        assert_eq!(s.queued(), 0);
        let st = s.into_stats();
        assert_eq!((st.requests, st.batches, st.generated_tokens), (0, 0, 0));
    }

    #[test]
    fn scheduler_single_session_matches_generate_batch() {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 57);
        let reference = SlabModel::from_dense(&params, 1)
            .generate_batch(&[vec![5, 6, 7]], 6)
            .remove(0);
        let model = Box::new(SlabModel::from_dense(&params, 1));
        let mut s = Scheduler::new(model, SchedulerConfig::default());
        let (tx, rx) = channel();
        assert!(s.enqueue(req(vec![5, 6, 7], 6), tx).is_some());
        while s.has_work() {
            s.tick();
        }
        let r = collect_events(&rx);
        assert!(!r.rejected && !r.cancelled && !r.evicted);
        assert_eq!(r.tokens, reference);
        assert_eq!(s.stats().requests, 1);
        assert_eq!(s.active_sessions(), 0);
        assert_eq!(s.kv_active(), 0, "kv slot must be released");
    }

    #[test]
    fn scheduler_rejects_when_queue_is_full() {
        let cfg = tiny_cfg();
        let model = Box::new(SlabModel::from_dense(&Params::init(&cfg, 58), 1));
        let mut s = Scheduler::new(
            model,
            SchedulerConfig {
                max_batch: 1,
                queue_cap: 2,
                ..Default::default()
            },
        );
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (tx, rx) = channel();
            let admitted = s.enqueue(req(vec![5 + i], 3), tx).is_some();
            assert_eq!(admitted, i < 2, "queue_cap 2 admits exactly the first two");
            rxs.push(rx);
        }
        assert_eq!(s.stats().rejected, 3);
        // Rejections terminate immediately, before any tick.
        for rx in &rxs[2..] {
            let r = collect_events(rx);
            assert!(r.rejected);
            assert!(r.tokens.is_empty());
        }
        while s.has_work() {
            s.tick();
        }
        for rx in &rxs[..2] {
            let r = collect_events(rx);
            assert!(!r.rejected);
            assert!(r.tokens.len() <= 3);
        }
        assert_eq!(s.stats().requests, 2);
    }

    #[test]
    fn scheduler_evicts_capped_session_mid_batch() {
        // One session whose budget exceeds the sequence cap joins a
        // batch with one that finishes by its own budget: the capped
        // one must be evicted exactly at the cap, the other must be
        // untouched, and the batch must shrink mid-flight.
        let cfg = tiny_cfg();
        let params = eos_free_params(&cfg, 59);
        let t = cfg.prompt_len;
        let cap_headroom = 3usize;
        let model = Box::new(SlabModel::from_dense(&params, 1));
        let mut s = Scheduler::new(
            model,
            SchedulerConfig {
                max_batch: 4,
                max_seq_len: t + cap_headroom,
                queue_cap: 8,
                ..Default::default()
            },
        );
        let (tx_a, rx_a) = channel();
        s.enqueue(req(vec![5, 6], 10), tx_a); // capped at 3
        assert_eq!(s.tick(), 1, "A admitted and decoding alone");
        let (tx_b, rx_b) = channel();
        s.enqueue(req(vec![9, 8, 7], 2), tx_b); // own budget 2
        assert_eq!(s.tick(), 2, "B joined A mid-stream");
        while s.has_work() {
            s.tick();
        }
        let ra = collect_events(&rx_a);
        let rb = collect_events(&rx_b);
        assert_eq!(ra.tokens.len(), cap_headroom, "A evicted at the cap");
        assert!(ra.evicted, "A's terminal event is Evicted");
        assert_eq!(rb.tokens.len(), 2, "B unaffected by A's eviction");
        assert!(!rb.evicted);
        assert!(ra.tokens.iter().chain(rb.tokens.iter()).all(|&tk| tk != EOS));
        let st = s.stats();
        assert_eq!(st.evicted, 1, "exactly A hit the cap");
        assert_eq!(st.requests, 2);
        assert_eq!(s.kv_active(), 0, "both kv slots released");
    }

    #[test]
    fn cancellation_frees_kv_slot_for_waiting_request() {
        // The cancellation acceptance path: with max_batch 1, a
        // long-running session blocks a queued one; cancelling the
        // first frees its KV slot (reap runs before admit inside the
        // same tick) and the waiting session completes normally with
        // exactly its serial-reference tokens.
        let cfg = tiny_cfg();
        let params = eos_free_params(&cfg, 62);
        let reference_b = SlabModel::from_dense(&params, 1)
            .generate_batch(&[vec![9, 8]], 3)
            .remove(0);
        let reference_a = SlabModel::from_dense(&params, 1)
            .generate_batch(&[vec![5, 6]], 8)
            .remove(0);
        let model = Box::new(SlabModel::from_dense(&params, 1));
        let mut s = Scheduler::new(
            model,
            SchedulerConfig {
                max_batch: 1,
                ..Default::default()
            },
        );
        let (tx_a, rx_a) = channel();
        let cancel_a = s.enqueue(req(vec![5, 6], 8), tx_a).expect("admitted");
        let (tx_b, rx_b) = channel();
        s.enqueue(req(vec![9, 8], 3), tx_b).expect("queued");
        s.tick(); // A admitted (streams first token), decodes once
        s.tick();
        assert_eq!(s.active_sessions(), 1, "batch full: B still queued");
        assert_eq!(s.queued(), 1);
        cancel_a.cancel();
        let decoded = s.tick(); // reap A → admit B → decode B
        assert_eq!(decoded, 1, "B decoding the tick A was reaped");
        assert_eq!(s.queued(), 0);
        while s.has_work() {
            s.tick();
        }
        let ra = collect_events(&rx_a);
        assert!(ra.cancelled, "A's terminal is cancelled");
        assert!(!ra.tokens.is_empty(), "A streamed before cancellation");
        assert_eq!(
            ra.tokens[..],
            reference_a[..ra.tokens.len()],
            "cancelled stream is a prefix of the serial reference"
        );
        let rb = collect_events(&rx_b);
        assert!(!rb.cancelled);
        assert_eq!(rb.tokens, reference_b, "B unaffected by A's cancellation");
        let st = s.stats();
        assert_eq!(st.cancelled, 1);
        assert_eq!(st.requests, 2);
        assert_eq!(s.kv_active(), 0, "all kv slots released");
    }

    #[test]
    fn dropping_a_session_cancels_it() {
        // Dropping the handle IS cancellation (Session::drop sets the
        // flag): the router stops decoding for the abandoned session
        // and its capacity serves the follow-up request.
        let cfg = ModelCfg::llama("slow-drop", 32, 64, 2, 2, 128, 1024, 4);
        let params = eos_free_params(&cfg, 70);
        let budget = cfg.max_seq - cfg.prompt_len;
        let model = Box::new(SlabModel::from_dense(&params, 1));
        let server = Server::start_with(
            Backend::NativeBatched(model),
            ServerConfig {
                sched: SchedulerConfig {
                    max_batch: 1,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        drop(server.submit(req(vec![5, 6], budget)));
        let follow = server.generate(req(vec![9, 8], 3));
        assert!(!follow.rejected && !follow.cancelled && !follow.incomplete);
        assert_eq!(follow.tokens.len(), 3, "EOS-free follow-up runs to budget");
        let stats = server.shutdown().expect("stats");
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.cancelled, 1, "dropped handle counts as cancellation");
    }

    #[test]
    fn capped_requests_classify_evicted_on_every_backend() {
        // A request whose budget exceeds the sequence headroom must
        // terminate as Evicted — with identical tokens — on the
        // dynamic and continuous backends alike: one Event contract,
        // not per-backend classification.
        let cfg = tiny_cfg();
        let params = eos_free_params(&cfg, 68);
        let headroom = cfg.max_seq - cfg.prompt_len;
        let run = |backend: Backend| {
            let server = Server::start_with(backend, ServerConfig::default());
            let r = server.generate(req(vec![5, 6], headroom + 5));
            let stats = server.shutdown().expect("stats");
            (r, stats)
        };
        let (rp, sp) = run(Backend::NativePacked(Box::new(SlabModel::from_dense(&params, 1))));
        let (rb, sb) = run(Backend::NativeBatched(Box::new(SlabModel::from_dense(&params, 1))));
        for (r, s) in [(&rp, &sp), (&rb, &sb)] {
            assert!(r.evicted, "capped request must classify Evicted");
            assert!(!r.cancelled && !r.rejected && !r.incomplete);
            assert_eq!(r.tokens.len(), headroom, "EOS-free: runs to the cap");
            assert_eq!(s.evicted, 1);
        }
        assert_eq!(rp.tokens, rb.tokens, "token-identical across backends");
    }

    #[test]
    fn dynamic_batcher_emits_terminals_mid_batch() {
        // A session's terminal event must leave the dynamic batcher
        // the step it is known, not when the whole batch finishes.
        // Proof without wall-clock asserts: cancel A mid-batch; once
        // A's terminal arrives, B must *still* be decoding — so
        // cancelling B at that moment yields a truncated, cancelled
        // B stream (were the batch already over, B would have
        // completed untouched).
        let cfg = ModelCfg::llama("slow-dyn", 32, 64, 2, 2, 128, 1024, 4);
        let params = eos_free_params(&cfg, 69);
        let budget = cfg.max_seq - cfg.prompt_len;
        let model = SlabModel::from_dense(&params, 1);
        let server = Server::start_with(
            Backend::NativePacked(Box::new(model)),
            ServerConfig {
                serve_batch: 2,
                ..Default::default()
            },
        );
        let a = server.submit(req(vec![5, 6], budget));
        let b = server.submit(req(vec![9, 8], budget));
        let mut a_tokens = 0usize;
        while a_tokens < 2 {
            match a.recv().expect("A streaming") {
                Event::Token(_) => a_tokens += 1,
                ev => panic!("early terminal {ev:?}"),
            }
        }
        a.cancel();
        let ra = a.collect();
        assert!(ra.cancelled, "A terminates cancelled");
        assert!(ra.tokens.len() < budget, "A cut short mid-batch");
        b.cancel();
        let rb = b.collect();
        assert!(
            rb.cancelled,
            "B was still decoding when A's terminal arrived — terminals must not wait for the batch"
        );
        assert!(rb.tokens.len() < budget);
        let stats = server.shutdown().expect("stats");
        assert_eq!(stats.cancelled, 2);
        assert_eq!(stats.requests, 2);
    }

    #[test]
    fn queued_session_cancel_is_reaped_behind_a_full_batch() {
        // A cancelled (or expired) entry must not sit in the wait
        // queue holding its bounded-queue place until a KV slot
        // frees: reap sweeps the queue every tick, so its terminal
        // event arrives while the batch is still fully occupied.
        let cfg = tiny_cfg();
        let params = eos_free_params(&cfg, 67);
        let model = Box::new(SlabModel::from_dense(&params, 1));
        let mut s = Scheduler::new(
            model,
            SchedulerConfig {
                max_batch: 1,
                ..Default::default()
            },
        );
        let (tx_a, rx_a) = channel();
        let headroom = cfg.max_seq - cfg.prompt_len;
        s.enqueue(req(vec![5, 6], headroom), tx_a).expect("admitted");
        let (tx_b, rx_b) = channel();
        let cancel_b = s.enqueue(req(vec![9, 8], 3), tx_b).expect("queued");
        s.tick(); // A occupies the only slot; B waits
        assert_eq!((s.active_sessions(), s.queued()), (1, 1));
        cancel_b.cancel();
        s.tick(); // reap sweeps the queue: B terminates *now*
        assert_eq!(s.queued(), 0, "cancelled queue entry reaped");
        assert_eq!(s.active_sessions(), 1, "A still decoding");
        let rb = collect_events(&rx_b);
        assert!(rb.cancelled && rb.tokens.is_empty() && !rb.incomplete);
        assert_eq!(s.stats().cancelled, 1);
        while s.has_work() {
            s.tick();
        }
        let ra = collect_events(&rx_a);
        assert!(!ra.cancelled && ra.tokens.len() == headroom);
        assert_eq!(s.stats().requests, 2);
        assert_eq!(s.kv_active(), 0);
    }

    #[test]
    fn deadline_evicts_queued_and_running_sessions() {
        let cfg = tiny_cfg();
        let params = eos_free_params(&cfg, 63);
        // (a) Already-expired deadline: evicted at admission, before
        // the engine runs — zero tokens, Evicted terminal.
        let model = Box::new(SlabModel::from_dense(&params, 1));
        let mut s = Scheduler::new(model, SchedulerConfig::default());
        let (tx, rx) = channel();
        s.enqueue(
            Request {
                prompt: vec![5, 6],
                max_new: 4,
                deadline: Some(Duration::ZERO),
            },
            tx,
        )
        .expect("queued");
        while s.has_work() {
            s.tick();
        }
        let r = collect_events(&rx);
        assert!(r.evicted && !r.cancelled);
        assert!(r.tokens.is_empty());
        assert_eq!(s.stats().deadline_evicted, 1);
        assert_eq!(s.stats().generated_tokens, 0);
        assert_eq!(s.kv_active(), 0);

        // (b) Config-default deadline applies to requests without one.
        let model = Box::new(SlabModel::from_dense(&params, 1));
        let mut s = Scheduler::new(
            model,
            SchedulerConfig {
                deadline: Duration::from_nanos(1),
                ..Default::default()
            },
        );
        let (tx, rx) = channel();
        s.enqueue(req(vec![5, 6], 4), tx).expect("queued");
        std::thread::sleep(Duration::from_millis(1));
        while s.has_work() {
            s.tick();
        }
        let r = collect_events(&rx);
        assert!(r.evicted);
        assert_eq!(s.stats().deadline_evicted, 1);
    }

    #[test]
    fn cancellation_fuzz_slot_accounting_stays_consistent() {
        // Random interleavings of enqueue / tick / cancel must never
        // corrupt the scheduler's slot accounting: every session gets
        // exactly one terminal event, every KV slot is released, and
        // every stream — cancelled or not — is a prefix of (or equal
        // to) its serial reference.
        let cfg = tiny_cfg();
        let params = eos_free_params(&cfg, 64);
        let reference_model = SlabModel::from_dense(&params, 1);
        let seq_headroom = cfg.max_seq - cfg.prompt_len;
        let mut rng = Pcg64::seed_from_u64(0xfu64 ^ 0x5e55);
        for round in 0..6 {
            let model = Box::new(SlabModel::from_dense(&params, 1));
            let mut s = Scheduler::new(
                model,
                SchedulerConfig {
                    max_batch: 1 + rng.below_usize(3),
                    queue_cap: 16,
                    ..Default::default()
                },
            );
            let n = 3 + rng.below_usize(5);
            let mut rxs = Vec::new();
            let mut handles = Vec::new();
            let mut specs = Vec::new();
            let mut enqueued = 0usize;
            while enqueued < n || s.has_work() {
                let op = rng.below(3);
                if op == 0 && enqueued < n {
                    let len = rng.below_usize(5);
                    let prompt: Vec<i32> = (0..len).map(|_| 5 + rng.below(20) as i32).collect();
                    let budget = 1 + rng.below_usize(6);
                    let (tx, rx) = channel();
                    let handle = s.enqueue(req(prompt.clone(), budget), tx);
                    assert!(handle.is_some(), "queue_cap 16 never overflows here");
                    rxs.push(rx);
                    handles.push(handle.unwrap());
                    specs.push((prompt, budget));
                    enqueued += 1;
                } else if op == 1 && !handles.is_empty() {
                    // Cancel a random session (possibly already done —
                    // cancelling a finished session must be harmless).
                    handles[rng.below_usize(handles.len())].cancel();
                } else {
                    s.tick();
                }
            }
            assert_eq!(s.active_sessions(), 0, "round {round}: drained");
            assert_eq!(s.kv_active(), 0, "round {round}: every kv slot released");
            let st = s.stats();
            assert_eq!(st.requests, n, "round {round}: one terminal per session");
            assert_eq!(st.rejected, 0);
            let mut cancelled_seen = 0usize;
            for (i, rx) in rxs.iter().enumerate() {
                let r = collect_events(rx);
                let (prompt, budget) = &specs[i];
                let reference = reference_model
                    .generate_batch(&[prompt.clone()], *budget)
                    .remove(0);
                assert_eq!(reference.len(), (*budget).min(seq_headroom), "EOS-free");
                if r.cancelled {
                    cancelled_seen += 1;
                    assert!(
                        r.tokens.len() <= reference.len(),
                        "round {round} req {i}: cancelled stream within budget"
                    );
                } else {
                    assert_eq!(
                        r.tokens, reference,
                        "round {round} req {i}: uncancelled stream must be bit-identical"
                    );
                }
                assert_eq!(
                    r.tokens[..],
                    reference[..r.tokens.len()],
                    "round {round} req {i}: stream is a prefix of the serial reference"
                );
            }
            assert_eq!(cancelled_seen, st.cancelled, "round {round}: cancel accounting");
        }
    }

    #[test]
    fn batched_server_applies_backpressure_end_to_end() {
        // Through the full Server API: a tiny queue with a burst of
        // submissions yields some rejected responses, and every
        // accepted request still completes.
        let cfg = tiny_cfg();
        let model = Box::new(SlabModel::from_dense(&Params::init(&cfg, 60), 1));
        let scfg = ServerConfig {
            sched: SchedulerConfig {
                max_batch: 1,
                queue_cap: 1,
                ..Default::default()
            },
            ..Default::default()
        };
        let server = Server::start_with(Backend::NativeBatched(model), scfg);
        let n = 12;
        let sessions: Vec<Session> = (0..n)
            .map(|i| server.submit(req(vec![5 + (i % 20) as i32], 2)))
            .collect();
        let responses: Vec<Response> = sessions.into_iter().map(|s| s.collect()).collect();
        let stats = server.shutdown().expect("stats");
        let served = responses.iter().filter(|r| !r.rejected).count();
        let rejected = responses.iter().filter(|r| r.rejected).count();
        assert_eq!(served + rejected, n);
        assert_eq!(stats.requests, served);
        assert_eq!(stats.rejected, rejected);
        assert!(served >= 1, "at least the first request is served");
        for r in &responses {
            if r.rejected {
                assert!(r.tokens.is_empty());
            } else {
                assert!(r.tokens.len() <= 2);
            }
        }
    }

    #[test]
    fn submit_gate_rejects_uniformly_across_backends() {
        // queue_cap 0 is the deterministic drain mode: every
        // submission is rejected at the gate, for dynamic and
        // continuous backends alike — the uniform backpressure path.
        let cfg = tiny_cfg();
        let backends: [fn(&ModelCfg) -> Backend; 2] = [
            |c| Backend::NativePacked(Box::new(SlabModel::from_dense(&Params::init(c, 65), 1))),
            |c| Backend::NativeBatched(Box::new(SlabModel::from_dense(&Params::init(c, 65), 1))),
        ];
        for mk in backends {
            let server = Server::start_with(
                mk(&cfg),
                ServerConfig {
                    queue_cap: 0,
                    ..Default::default()
                },
            );
            let responses: Vec<Response> =
                (0..3).map(|i| server.generate(req(vec![5 + i], 2))).collect();
            for r in &responses {
                assert!(r.rejected);
                assert!(r.tokens.is_empty());
            }
            assert_eq!(server.stats().rejected, 3, "live stats see gate rejections");
            let stats = server.shutdown().expect("stats");
            assert_eq!(stats.rejected, 3);
            assert_eq!(stats.requests, 0);
        }
    }

    #[test]
    fn server_cancel_stops_stream_mid_decode() {
        // End-to-end over the Server API: cancel after the second
        // streamed token; the stream terminates with cancelled=true
        // well before the budget, and the router survives to serve
        // the next request. The config makes the full completion take
        // ~1k decode ticks on a dim-64 model, so the client's cancel
        // (issued microseconds after the first tokens) lands
        // mid-stream with enormous margin.
        let cfg = ModelCfg::llama("slow-serve", 32, 64, 2, 2, 128, 1024, 4);
        let params = eos_free_params(&cfg, 66);
        let budget = cfg.max_seq - cfg.prompt_len; // long-running
        let model = Box::new(SlabModel::from_dense(&params, 1));
        let server = Server::start_with(Backend::NativeBatched(model), ServerConfig::default());
        let session = server.submit(req(vec![5, 6, 7], budget));
        let mut tokens = Vec::new();
        let mut terminal = None;
        while tokens.len() < 2 {
            match session.recv().expect("stream open") {
                Event::Token(t) => tokens.push(t),
                ev => {
                    terminal = Some(ev);
                    break;
                }
            }
        }
        assert!(terminal.is_none(), "budget {budget} outlives two tokens");
        session.cancel();
        let mut saw_terminal = false;
        for ev in session.iter() {
            match ev {
                Event::Token(t) => tokens.push(t),
                Event::Done(s) => {
                    assert!(s.cancelled);
                    assert_eq!(s.tokens, tokens.len());
                    saw_terminal = true;
                }
                other => panic!("unexpected terminal {other:?}"),
            }
        }
        assert!(saw_terminal);
        assert!(
            tokens.len() < budget,
            "cancellation must stop the stream early ({} of {budget})",
            tokens.len()
        );
        // Router alive and the KV slot free: a fresh request serves.
        let follow_up = server.generate(req(vec![9, 10], 3));
        assert!(!follow_up.rejected && !follow_up.cancelled);
        let stats = server.shutdown().expect("stats");
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.requests, 2);
    }

    #[test]
    fn native_backend_is_deterministic_across_servers() {
        let cfg = tiny_cfg();
        let run = || {
            let model = SlabModel::from_dense(&Params::init(&cfg, 52), 1);
            let server = Server::start_with(
                Backend::NativePacked(Box::new(model)),
                ServerConfig::default(),
            );
            let out = server.generate(req(vec![9, 10, 11], 6)).tokens;
            server.shutdown().expect("stats");
            out
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn serve_stats_table_renders_every_counter() {
        let stats = ServeStats {
            requests: 7,
            batches: 3,
            generated_tokens: 21,
            rejected: 2,
            evicted: 1,
            deadline_evicted: 1,
            cancelled: 2,
            dropped_clients: 1,
            prefix_hits: 3,
            prefix_misses: 1,
            cow_splits: 2,
            page_evictions: 1,
            kv_pages: 5,
            kv_pages_peak: 9,
            spec_rounds: 4,
            spec_drafted: 12,
            spec_accepted: 9,
            spec_rollbacks: 2,
            ttft_ms_total: 14.0,
            ttft_samples: 7,
            wall_secs: 2.0,
        };
        assert!((stats.mean_ttft_ms() - 2.0).abs() < 1e-12);
        assert!((stats.prefix_hit_rate() - 0.75).abs() < 1e-12);
        assert!((stats.acceptance_rate() - 0.75).abs() < 1e-12);
        let rendered = stats.table("serve").render();
        for key in [
            "requests",
            "batches",
            "generated_tokens",
            "tokens_per_sec",
            "rejected",
            "evicted",
            "deadline_evicted",
            "cancelled",
            "dropped_clients",
            "prefix_hits",
            "prefix_misses",
            "prefix_hit_rate",
            "cow_splits",
            "page_evictions",
            "kv_pages",
            "kv_pages_peak",
            "spec_rounds",
            "spec_drafted",
            "spec_accepted",
            "spec_acceptance_rate",
            "spec_rollbacks",
            "mean_ttft_ms",
            "wall_secs",
        ] {
            assert!(rendered.contains(key), "missing {key} in:\n{rendered}");
        }
    }

    /// Drive a scheduler directly to completion over a request set,
    /// returning per-request responses (submission order) and the
    /// final stats. Direct [`Scheduler`] access so the paged-pool
    /// conformance tests can pick the KV backing per run.
    fn sched_all(
        params: &Params,
        scfg: SchedulerConfig,
        prompts: &[Vec<i32>],
        budgets: &[usize],
    ) -> (Vec<Response>, ServeStats) {
        sched_all_with(
            || Box::new(SlabModel::from_dense(params, 1)),
            scfg,
            prompts,
            budgets,
        )
    }

    /// [`sched_all`] over an arbitrary engine factory — the
    /// speculative tests serve genuinely *packed* models, where the
    /// draft view really is a different (cheaper) forward.
    fn sched_all_with(
        mk: impl Fn() -> Box<SlabModel>,
        scfg: SchedulerConfig,
        prompts: &[Vec<i32>],
        budgets: &[usize],
    ) -> (Vec<Response>, ServeStats) {
        let mut s = Scheduler::new(mk(), scfg);
        let rxs: Vec<_> = prompts
            .iter()
            .zip(budgets)
            .map(|(p, &b)| {
                let (tx, rx) = channel();
                s.enqueue(req(p.clone(), b), tx).expect("queued");
                rx
            })
            .collect();
        while s.has_work() {
            s.tick();
        }
        assert_eq!(s.kv_active(), 0, "every kv session released");
        let out = rxs.iter().map(collect_events).collect();
        (out, s.into_stats())
    }

    #[test]
    fn shared_prefix_decode_is_bit_identical_across_kv_backings() {
        // The prefix-sharing conformance contract (DESIGN.md §13):
        // N sessions with an identical padded prompt served off
        // copy-on-write shared pages must stream token streams
        // bit-identical to (a) the same N with sharing disabled,
        // (b) the legacy contiguous pool, and (c) the serial
        // NativePacked reference — sharing is invisible everywhere
        // except the hit counters.
        let cfg = tiny_cfg();
        let params = eos_free_params(&cfg, 71);
        let prompts: Vec<Vec<i32>> = vec![vec![5, 6, 7]; 4];
        let budgets = [6usize, 4, 2, 5];
        let serial = SlabModel::from_dense(&params, 1);
        let reference: Vec<Vec<i32>> = budgets
            .iter()
            .map(|&b| serial.generate_batch(&[prompts[0].clone()], b).remove(0))
            .collect();
        let shared_cfg = SchedulerConfig::default(); // paged + sharing
        let unshared_cfg = SchedulerConfig {
            prefix_sharing: false,
            ..Default::default()
        };
        let contiguous_cfg = SchedulerConfig {
            kv_page: 0,
            ..Default::default()
        };
        let (shared, st_shared) = sched_all(&params, shared_cfg, &prompts, &budgets);
        let (unshared, st_unshared) = sched_all(&params, unshared_cfg, &prompts, &budgets);
        let (contig, _) = sched_all(&params, contiguous_cfg, &prompts, &budgets);
        for i in 0..prompts.len() {
            assert!(!shared[i].rejected && !shared[i].cancelled);
            assert_eq!(shared[i].tokens, reference[i], "shared vs serial, req {i}");
            assert_eq!(unshared[i].tokens, reference[i], "unshared vs serial, req {i}");
            assert_eq!(contig[i].tokens, reference[i], "contiguous vs serial, req {i}");
        }
        // One prefill for four sessions; each diverges by COW-split
        // of the half-filled prompt page on its first decode write.
        assert_eq!(st_shared.prefix_misses, 1, "exactly one cold prefill");
        assert_eq!(st_shared.prefix_hits, 3, "three sessions joined the cached prefill");
        assert_eq!(st_shared.cow_splits, 4);
        assert_eq!(st_shared.page_evictions, 0);
        assert!(st_shared.kv_pages_peak > 0);
        assert_eq!(st_unshared.prefix_hits, 0, "sharing off: every prompt prefills");
        assert_eq!(st_unshared.prefix_misses, 4);
        assert_eq!(st_unshared.cow_splits, 0);
    }

    #[test]
    fn cancelling_a_prefix_sharer_mid_decode_leaves_the_rest_intact() {
        // One of three sessions holding COW-shared prompt pages is
        // cancelled mid-decode; the survivors must still stream their
        // exact serial-reference tokens (released shared pages only
        // drop a refcount — never data out from under a sharer), and
        // the cancelled stream is a prefix of its own reference.
        let cfg = tiny_cfg();
        let params = eos_free_params(&cfg, 72);
        let prompt = vec![9, 10, 11];
        let budget = 7usize;
        let reference = SlabModel::from_dense(&params, 1)
            .generate_batch(&[prompt.clone()], budget)
            .remove(0);
        let model = Box::new(SlabModel::from_dense(&params, 1));
        let mut s = Scheduler::new(model, SchedulerConfig::default());
        let mut rxs = Vec::new();
        let mut cancels = Vec::new();
        for _ in 0..3 {
            let (tx, rx) = channel();
            cancels.push(s.enqueue(req(prompt.clone(), budget), tx).expect("queued"));
            rxs.push(rx);
        }
        s.tick(); // all admitted (first token), one shared decode step
        s.tick();
        assert_eq!(s.active_sessions(), 3);
        cancels[1].cancel();
        while s.has_work() {
            s.tick();
        }
        let r0 = collect_events(&rxs[0]);
        let r1 = collect_events(&rxs[1]);
        let r2 = collect_events(&rxs[2]);
        assert_eq!(r0.tokens, reference, "sharer 0 unaffected by the cancellation");
        assert_eq!(r2.tokens, reference, "sharer 2 unaffected by the cancellation");
        assert!(r1.cancelled);
        assert!(!r1.tokens.is_empty() && r1.tokens.len() < budget);
        assert_eq!(r1.tokens[..], reference[..r1.tokens.len()]);
        assert_eq!(s.kv_active(), 0);
        let st = s.into_stats();
        assert_eq!((st.prefix_misses, st.prefix_hits), (1, 2));
        assert_eq!(st.cancelled, 1);
        // Sessions are gone but the prefix index keeps the cached
        // prompt page (one page at the default page size) warm for
        // future hits — the only allocation left standing.
        assert_eq!(st.kv_pages, 1);
    }

    #[test]
    fn page_exhaustion_preempts_newest_session_and_frees_pages() {
        // Two EOS-free sessions on a page budget too small for both
        // to reach their budgets: the *newest* is preempted the tick
        // pages run out (terminal Evicted, counted in
        // page_evictions), its pages free on the spot, and the oldest
        // runs to its full budget with bit-exact serial tokens.
        let cfg = tiny_cfg();
        let params = eos_free_params(&cfg, 73);
        let serial = SlabModel::from_dense(&params, 1);
        let ref_a = serial.generate_batch(&[vec![5, 6]], 8).remove(0);
        let ref_b = serial.generate_batch(&[vec![9, 8]], 8).remove(0);
        let model = Box::new(SlabModel::from_dense(&params, 1));
        let mut s = Scheduler::new(
            model,
            SchedulerConfig {
                max_batch: 2,
                kv_page: 2,
                page_budget: 8, // worst case for one session is 6
                prefix_sharing: false,
                ..Default::default()
            },
        );
        let (tx_a, rx_a) = channel();
        s.enqueue(req(vec![5, 6], 8), tx_a).expect("queued");
        let (tx_b, rx_b) = channel();
        s.enqueue(req(vec![9, 8], 8), tx_b).expect("queued");
        while s.has_work() {
            s.tick();
        }
        let ra = collect_events(&rx_a);
        let rb = collect_events(&rx_b);
        assert!(!ra.evicted && !ra.cancelled, "oldest session never preempted");
        assert_eq!(ra.tokens, ref_a, "oldest runs to budget, bit-exact");
        assert!(rb.evicted, "newest preempted on page exhaustion");
        assert!(!rb.tokens.is_empty() && rb.tokens.len() < 8);
        assert_eq!(rb.tokens[..], ref_b[..rb.tokens.len()]);
        assert_eq!(s.kv_active(), 0);
        let st = s.into_stats();
        assert_eq!(st.page_evictions, 1);
        assert_eq!(st.evicted, 1, "page preemption classifies Evicted");
        assert!(st.kv_pages_peak <= 8, "budget is a hard ceiling");
        assert_eq!(st.kv_pages, 0);
    }

    #[test]
    fn speculative_decode_is_token_identical_to_plain_greedy() {
        // The losslessness contract (DESIGN.md §14): speculation may
        // only change *when* tokens arrive and the spec_* counters —
        // never the tokens. Packed model (the draft view really is a
        // different, sometimes-wrong forward), across contiguous and
        // paged KV, draft_len 1..=6, full-rank / truncated /
        // sparse-only drafts.
        let cfg = tiny_cfg();
        let params = eos_free_params(&cfg, 74);
        let (packed, swapped) = packed_params(&params, 74);
        let mk = || Box::new(SlabModel::from_packed(&swapped, &packed, 1));
        let prompts: Vec<Vec<i32>> = vec![vec![5, 6, 7], vec![9], vec![11, 4, 13], vec![5, 6, 7]];
        let budgets = [8usize, 3, 6, 5];
        let plain_cfg = SchedulerConfig {
            max_batch: 3,
            ..Default::default()
        };
        let (plain, plain_stats) = sched_all_with(&mk, plain_cfg, &prompts, &budgets);
        assert_eq!(plain_stats.spec_rounds, 0, "plain path never speculates");
        for (draft_len, kv_page, draft_rank) in [
            (1, 8, None),
            (4, 8, None),
            (6, 0, None),
            (3, 2, Some(0)),
            (4, 0, Some(0)),
            (2, 8, Some(1)),
        ] {
            let scfg = SchedulerConfig {
                max_batch: 3,
                kv_page,
                speculate: true,
                draft_len,
                draft_rank,
                ..Default::default()
            };
            let (spec, st) = sched_all_with(&mk, scfg, &prompts, &budgets);
            let label = format!("draft_len {draft_len} kv_page {kv_page} rank {draft_rank:?}");
            for i in 0..prompts.len() {
                assert!(!spec[i].rejected && !spec[i].cancelled, "{label}, req {i}");
                assert_eq!(spec[i].tokens, plain[i].tokens, "{label}, req {i}");
            }
            assert_eq!(st.generated_tokens, plain_stats.generated_tokens, "{label}");
            assert!(st.spec_rounds > 0 && st.spec_drafted > 0, "{label}");
            assert!(st.spec_accepted <= st.spec_drafted, "{label}");
            assert!(st.acceptance_rate() <= 1.0, "{label}");
        }
    }

    #[test]
    fn dense_draft_accepts_every_token_and_counts_it() {
        // On a dense model the draft view falls through to the full
        // forward, so every draft must be accepted: acceptance_rate
        // exactly 1.0, zero rollbacks, and strictly fewer verify
        // rounds than emitted decode tokens — speculation really
        // batches multi-token emission. (This is also the HTTP e2e
        // anchor: a served dense model reports acceptance 1.0.)
        let cfg = tiny_cfg();
        let params = eos_free_params(&cfg, 75);
        let prompts: Vec<Vec<i32>> = vec![vec![5, 6], vec![9, 8, 7]];
        let budgets = [6usize, 4];
        let (plain, _) = sched_all(&params, SchedulerConfig::default(), &prompts, &budgets);
        let spec_cfg = SchedulerConfig {
            speculate: true,
            draft_len: 3,
            ..Default::default()
        };
        let (spec, st) = sched_all(&params, spec_cfg, &prompts, &budgets);
        for i in 0..prompts.len() {
            assert_eq!(spec[i].tokens, plain[i].tokens, "req {i}");
        }
        assert!(st.spec_rounds > 0 && st.spec_drafted > 0);
        assert_eq!(st.spec_accepted, st.spec_drafted, "dense draft == full model");
        assert_eq!(st.spec_rollbacks, 0);
        assert!((st.acceptance_rate() - 1.0).abs() < 1e-12);
        // 10 tokens total, 2 from prefill: 8 decode-emitted tokens in
        // well under 8 verify rounds.
        let decode_emitted = st.generated_tokens - prompts.len();
        assert!(
            st.spec_rounds < decode_emitted,
            "{} rounds for {decode_emitted} decode tokens",
            st.spec_rounds
        );
    }

    #[test]
    fn speculation_fuzz_streams_bit_exact_and_pages_balance() {
        // Satellite: random prompts × draft_len 1..8 × cancellation
        // and deadline injection × paged and contiguous KV, on a
        // packed model. Undisturbed streams must be bit-exact to the
        // serial plain-greedy reference, interrupted ones a prefix of
        // it, and KV slot/page accounting must balance after every
        // round's rollbacks.
        let cfg = tiny_cfg();
        let params = eos_free_params(&cfg, 76);
        let (packed, swapped) = packed_params(&params, 76);
        let reference_model = SlabModel::from_packed(&swapped, &packed, 1);
        let seq_headroom = cfg.max_seq - cfg.prompt_len;
        let mut rng = Pcg64::seed_from_u64(0x5bec ^ 0xf0);
        for round in 0..8 {
            let paged = round % 2 == 0;
            let sharing = rng.below(2) == 0;
            let model = Box::new(SlabModel::from_packed(&swapped, &packed, 1));
            let mut s = Scheduler::new(
                model,
                SchedulerConfig {
                    max_batch: 1 + rng.below_usize(3),
                    queue_cap: 16,
                    kv_page: if paged { 1 + rng.below_usize(4) } else { 0 },
                    prefix_sharing: sharing,
                    speculate: true,
                    draft_len: 1 + rng.below_usize(8),
                    draft_rank: match rng.below(3) {
                        0 => None,
                        1 => Some(0),
                        _ => Some(1),
                    },
                    ..Default::default()
                },
            );
            let n = 3 + rng.below_usize(5);
            let mut rxs = Vec::new();
            let mut handles = Vec::new();
            let mut specs = Vec::new();
            let mut enqueued = 0usize;
            while enqueued < n || s.has_work() {
                let op = rng.below(4);
                if op == 0 && enqueued < n {
                    let len = rng.below_usize(5);
                    let prompt: Vec<i32> = (0..len).map(|_| 5 + rng.below(20) as i32).collect();
                    let budget = 1 + rng.below_usize(6);
                    // Occasional already-expired deadline: the session
                    // is evicted at (or just after) admission with
                    // whatever prefix it managed to stream.
                    let deadline = (rng.below(5) == 0).then_some(Duration::ZERO);
                    let (tx, rx) = channel();
                    let handle = s.enqueue(
                        Request {
                            prompt: prompt.clone(),
                            max_new: budget,
                            deadline,
                        },
                        tx,
                    );
                    assert!(handle.is_some(), "queue_cap 16 never overflows here");
                    rxs.push(rx);
                    handles.push(handle.unwrap());
                    specs.push((prompt, budget));
                    enqueued += 1;
                } else if op == 1 && !handles.is_empty() {
                    handles[rng.below_usize(handles.len())].cancel();
                } else {
                    s.tick();
                }
            }
            assert_eq!(s.active_sessions(), 0, "round {round}: drained");
            assert_eq!(s.kv_active(), 0, "round {round}: every kv slot released");
            for (i, rx) in rxs.iter().enumerate() {
                let r = collect_events(rx);
                let (prompt, budget) = &specs[i];
                let reference = reference_model
                    .generate_batch(&[prompt.clone()], *budget)
                    .remove(0);
                assert_eq!(reference.len(), (*budget).min(seq_headroom), "EOS-free");
                if !r.cancelled && !r.evicted {
                    assert_eq!(
                        r.tokens, reference,
                        "round {round} req {i}: undisturbed stream must be bit-identical"
                    );
                }
                assert_eq!(
                    r.tokens[..],
                    reference[..r.tokens.len()],
                    "round {round} req {i}: stream is a prefix of the serial reference"
                );
            }
            let st = s.into_stats();
            assert_eq!(st.requests, n, "round {round}: one terminal per session");
            assert_eq!(st.rejected, 0, "round {round}");
            assert!(st.spec_accepted <= st.spec_drafted, "round {round}");
            if paged && !sharing {
                // Sharing keeps cached prefill pages warm in the
                // prefix index; without it every page must be back.
                assert_eq!(st.kv_pages, 0, "round {round}: all pages released");
            }
        }
    }
}
