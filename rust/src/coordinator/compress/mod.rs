//! The staged layer-wise one-shot compression pipeline (paper
//! §II-A.1) — three decoupled stages behind one [`CompressJob`] API
//! (DESIGN.md §10), the offline mirror of the serving refactor:
//!
//! 1. [`capture`] — forward the calibration batches through the
//!    *current* (already partially pruned) weights block by block,
//!    accumulating [`crate::slab::ActStats`] for the four activation
//!    sources. Runs natively on the `model::native` block machinery
//!    ([`CaptureEngine::Native`]) or through the
//!    `embed_{cfg}`/`block_capture_{cfg}` XLA artifacts
//!    ([`CaptureEngine::Artifact`], the cross-check engine).
//! 2. [`decompose`] — prune the seven linears of the block. They
//!    share only read-only stats, so they fan out across
//!    `ThreadPool::scoped` workers with a slot-ordered reduction:
//!    reports and packed layers are bit-identical to the serial path.
//! 3. [`emit`] — stream the block's packed [`SlabLayer`]s to a
//!    checkpoint as the block finishes; with `keep_dense(false)` and
//!    `keep_packed(false)` peak memory is one block, not one model —
//!    the configuration that compresses models too large for the old
//!    all-in-memory loop.
//!
//! The historical single-call API ([`compress_model`]) survives as a
//! thin wrapper: artifact capture, serial decompose, everything
//! retained in memory.

pub mod capture;
pub mod decompose;
pub mod emit;

pub use capture::{BlockWeights, CaptureEngine};
pub use emit::load_packed_checkpoint;

use super::budget::{BudgetConfig, BudgetPlan, LayerProbe};
use crate::baselines::{Method, MethodError};
use crate::data::TokenSet;
use crate::model::{Params, SlabModel};
use crate::runtime::client::RuntimeError;
use crate::runtime::Runtime;
use crate::slab::threshold::sorted_scores_desc;
use crate::slab::{wanda_scores_par, RefineConfig, RefineReport, SlabLayer};
use crate::util::pool::ThreadPool;
use std::path::PathBuf;

/// Where the SLaB decomposition itself runs (the capture engine is a
/// separate, orthogonal choice — [`CompressJob::capture`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Pure-rust decomposition (used by all baselines; SLaB optional).
    Native,
    /// SLaB through the AOT Pallas `decompose_{shape}` artifact.
    /// Requires [`CaptureEngine::Artifact`] (it needs the runtime).
    Artifact,
}

#[derive(Debug, Clone, PartialEq)]
pub struct LayerReport {
    pub name: String,
    pub kept: usize,
    pub numel: usize,
    pub frob_err: f32,
}

#[derive(Debug, Clone)]
pub struct CompressReport {
    pub method: String,
    pub layers: Vec<LayerReport>,
    pub wall_secs: f64,
    /// Mean ‖W − Ŵ‖_F across layers (the Fig. 3 metric).
    pub mean_frob: f64,
    /// Peak resident tensor bytes — an accounting proxy (inputs +
    /// calibration stream + retained outputs + the largest per-block
    /// transient, including the budget probe's score arrays and the
    /// refinement loop's per-linear scratch when those stages run),
    /// not an RSS measurement; comparable across job configurations,
    /// which is what the streaming-emit story needs.
    pub peak_bytes: usize,
    /// The activation-aware per-layer budget plan, when the job ran
    /// with [`CompressJob::budget`] (render with
    /// [`BudgetPlan::to_table`]).
    pub budget: Option<BudgetPlan>,
    /// Per-layer refinement diagnostics, when the job ran with
    /// [`CompressJob::refine`] (render with
    /// [`crate::slab::refine_table`]). Emission order.
    pub refine: Vec<(String, RefineReport)>,
}

#[derive(Debug, thiserror::Error)]
pub enum PipelineError {
    #[error("runtime: {0}")]
    Runtime(#[from] RuntimeError),
    #[error("method: {0}")]
    Method(#[from] MethodError),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("pipeline: {0}")]
    Other(String),
}

/// Result of the legacy [`compress_model`] call: swapped-in dense
/// reconstructions plus (for SLaB) the packed deployable layers.
pub struct CompressedModel {
    pub params: Params,
    pub slab_layers: Vec<(String, SlabLayer)>,
    pub report: CompressReport,
}

/// Everything a [`CompressJob`] run produces. `params`/`slab_layers`
/// are present only when the job was asked to retain them — a
/// streaming job's packed layers live in its checkpoint instead.
pub struct CompressOut {
    /// Dense params with `Ŵ` swapped in (`None` on `keep_dense(false)`
    /// jobs).
    pub params: Option<Params>,
    /// Packed layers in emission order (empty on `keep_packed(false)`
    /// jobs).
    pub slab_layers: Vec<(String, SlabLayer)>,
    pub report: CompressReport,
}

impl CompressOut {
    /// The native serving/eval engine for this run's output: a method
    /// that emitted packed layers (SLaB) is served straight out of the
    /// compressed format ([`SlabModel::from_packed`]; the untouched
    /// dense tensors — embeddings, norms, head — come from
    /// `original`), while pure-pruning baselines serve their dense
    /// reconstruction `Ŵ`. This is the hand-off the evaluation sweep
    /// uses: compress → serve → score, all artifact-free. Errors when
    /// the job retained neither representation (a
    /// `keep_dense(false) + keep_packed(false)` streaming run —
    /// reload its checkpoint via [`load_packed_checkpoint`] instead).
    pub fn serving_model(
        &self,
        original: &Params,
        threads: usize,
    ) -> Result<SlabModel, PipelineError> {
        if !self.slab_layers.is_empty() {
            return Ok(SlabModel::from_packed(original, &self.slab_layers, threads));
        }
        match &self.params {
            Some(p) => Ok(SlabModel::from_dense(p, threads)),
            None => Err(PipelineError::Other(
                "job retained neither packed layers nor dense params — \
                 reload the streamed checkpoint via load_packed_checkpoint"
                    .into(),
            )),
        }
    }
}

/// One compression run, configured then [`run`](CompressJob::run):
///
/// ```text
/// CompressJob::new(&params, &calib, &method)
///     .threads(0)                      // decompose fan-out + capture matmuls
///     .keep_dense(false)               // don't clone the model
///     .keep_packed(false)
///     .stream_to("runs/m.slabckpt".into())  // emit per block
///     .run()?
/// ```
///
/// Defaults reproduce the historical pipeline: native capture with
/// batch 8, native decompose, serial (`threads = 1`), everything
/// retained, nothing streamed.
pub struct CompressJob<'a> {
    params: &'a Params,
    calib: &'a TokenSet,
    method: &'a Method,
    capture: CaptureEngine<'a>,
    engine: Engine,
    threads: usize,
    batch: usize,
    keep_dense: bool,
    keep_packed: bool,
    stream_to: Option<PathBuf>,
    refine: Option<RefineConfig>,
    budget: Option<BudgetConfig>,
}

impl<'a> CompressJob<'a> {
    pub fn new(params: &'a Params, calib: &'a TokenSet, method: &'a Method) -> CompressJob<'a> {
        CompressJob {
            params,
            calib,
            method,
            capture: CaptureEngine::Native,
            engine: Engine::Native,
            threads: 1,
            batch: 8,
            keep_dense: true,
            keep_packed: true,
            stream_to: None,
            refine: None,
            budget: None,
        }
    }

    /// Which engine runs the calibration forward (default: native).
    pub fn capture(mut self, engine: CaptureEngine<'a>) -> Self {
        self.capture = engine;
        self
    }

    /// Which engine runs the SLaB decomposition (default: native).
    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Worker threads for the decompose fan-out and the native capture
    /// matmuls: `1` = serial (the reference path), `0` = available
    /// parallelism, `n` = exactly `n`. Any setting is bit-identical to
    /// serial (pinned by tests).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Calibration rows per native-capture forward; the final batch
    /// may be partial, so every row counts exactly once regardless of
    /// the setting (the artifact engine's batch is instead baked into
    /// its executables, which truncates a trailing remainder).
    /// Default 8.
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Retain a full dense model with `Ŵ` swapped in (default true).
    /// `false` skips the model clone entirely — each block's dense
    /// reconstructions die right after output propagation.
    pub fn keep_dense(mut self, keep: bool) -> Self {
        self.keep_dense = keep;
        self
    }

    /// Retain the packed layers in memory (default true).
    pub fn keep_packed(mut self, keep: bool) -> Self {
        self.keep_packed = keep;
        self
    }

    /// Stream packed layers to this checkpoint as blocks finish.
    pub fn stream_to(mut self, path: PathBuf) -> Self {
        self.stream_to = Some(path);
        self
    }

    /// Run [`crate::slab::refine`] after each linear's one-shot
    /// decomposition (SLaB + native engine only — validated at
    /// [`run`](CompressJob::run)). Rounds execute inside the same
    /// per-linear fan-out unit, so any thread setting stays
    /// bit-identical to serial.
    pub fn refine(mut self, rcfg: RefineConfig) -> Self {
        self.refine = Some(rcfg);
        self
    }

    /// Replace the uniform Eq.-10 keep fraction with an
    /// activation-aware per-layer allocation ([`super::budget`]): a
    /// dense-weights probe pass scores every linear, then water-fills
    /// the *global* sparse budget across layers (SLaB + native engine
    /// only — validated at [`run`](CompressJob::run)). The resulting
    /// plan is recorded in [`CompressReport::budget`].
    pub fn budget(mut self, bcfg: BudgetConfig) -> Self {
        self.budget = Some(bcfg);
        self
    }

    /// Run capture → decompose → emit over every block.
    pub fn run(self) -> Result<CompressOut, PipelineError> {
        let t0 = std::time::Instant::now();
        let cfg = self.params.cfg.clone();
        let pool_owned = (self.threads != 1).then(|| ThreadPool::new(self.threads));
        let pool = pool_owned.as_ref();
        let rt: Option<&Runtime> = match self.capture {
            CaptureEngine::Artifact(rt) => Some(rt),
            CaptureEngine::Native => None,
        };
        if self.engine == Engine::Artifact && rt.is_none() {
            return Err(PipelineError::Other(
                "artifact decompose engine requires the artifact capture engine".into(),
            ));
        }
        // Only SLaB produces packed layers; streaming any other method
        // would quietly write a valid-but-empty checkpoint that later
        // loads as "no packed linears" — reject the misconfiguration
        // up front instead.
        if self.stream_to.is_some() && !matches!(self.method, Method::Slab(_)) {
            return Err(PipelineError::Other(format!(
                "stream_to set but method '{}' emits no packed layers (SLaB only)",
                self.method.name()
            )));
        }
        // Refinement and budget allocation are SLaB concepts (they
        // re-fit/re-budget a decomposition) and run natively — same
        // up-front rejection policy as stream_to.
        if self.refine.is_some() || self.budget.is_some() {
            let what = if self.refine.is_some() { "refine" } else { "budget" };
            if !matches!(self.method, Method::Slab(_)) {
                return Err(PipelineError::Other(format!(
                    "{what} set but method '{}' has no decomposition to {what} (SLaB only)",
                    self.method.name()
                )));
            }
            if self.engine == Engine::Artifact {
                return Err(PipelineError::Other(format!(
                    "{what} is not supported by the artifact decompose engine (use Engine::Native)"
                )));
            }
        }

        // Budget probe pre-pass: one extra capture pass over the
        // *dense* weights (no reconstruction swap-in, so later blocks
        // see unpruned activations — the allocator scores layers
        // before any budget is spent), folding each linear's Wanda
        // scores into a sorted probe. The probes and the plan they
        // produce are all the pass retains.
        let mut probe_peak = 0usize;
        let params_bytes = cfg.n_params() * 4;
        let plan: Option<BudgetPlan> = match (&self.budget, self.method) {
            (Some(bcfg), Method::Slab(scfg)) => {
                let mut probe_cap = capture::Capture::start(
                    self.capture,
                    self.params,
                    self.calib,
                    self.batch,
                    pool,
                )?;
                let mut probes: Vec<LayerProbe> = Vec::new();
                let mut probe_bytes = 0usize;
                for layer in 0..cfg.n_layers {
                    let blockw = BlockWeights::from_params(self.params, layer)?;
                    let stats = probe_cap.capture_block(&blockw, false)?;
                    for (name, src, w) in &blockw.linears {
                        let scores = wanda_scores_par(w, &stats[*src], pool);
                        probes.push(LayerProbe {
                            name: name.clone(),
                            dout: w.rows,
                            din: w.cols,
                            scores: sorted_scores_desc(&scores),
                        });
                        probe_bytes += w.numel() * 4;
                    }
                    // Retained probes + this block's weights, stats and
                    // in-flight score matrix.
                    probe_peak = probe_peak.max(
                        params_bytes
                            + probe_cap.resident_bytes()
                            + probe_bytes
                            + 2 * blockw.nbytes()
                            + stats.iter().map(|s| s.nbytes()).sum::<usize>(),
                    );
                    if layer + 1 < cfg.n_layers {
                        probe_cap.advance(&blockw)?;
                    }
                }
                let plan = BudgetPlan::plan(&probes, scfg, bcfg)
                    .map_err(|e| PipelineError::Method(MethodError::Config(e)))?;
                eprintln!(
                    "[compress] budget plan: {} layers water-filled at τ = {:.5}",
                    plan.layers.len(),
                    plan.waterline
                );
                Some(plan)
            }
            _ => None,
        };

        let mut cap = capture::Capture::start(self.capture, self.params, self.calib, self.batch, pool)?;
        let needs_gram = self.method.needs_gram();
        let mut out_params = if self.keep_dense { Some(self.params.clone()) } else { None };
        let mut sink = emit::Sink::new(self.stream_to.as_deref())?;
        let mut slab_layers: Vec<(String, SlabLayer)> = Vec::new();
        let mut reports: Vec<LayerReport> = Vec::new();
        let mut refine_reports: Vec<(String, RefineReport)> = Vec::new();

        // Peak-resident accounting (a proxy, not an RSS reading):
        // inputs + calibration stream (+ the keep_dense clone) are
        // always live; retained packed layers accumulate; per-block
        // transients add the current weights, their reconstructions,
        // the packed triples, and the stats. A refining job adds the
        // loop's per-linear scratch (residual, |residual|, low-rank
        // product, score matrix, mask — ≈ 5 dense copies of each
        // in-flight linear, i.e. 5× the block on a full fan-out); the
        // budget probe's peak was tracked by the pre-pass above.
        let base = params_bytes * (1 + self.keep_dense as usize) + cap.resident_bytes();
        let mut retained = 0usize;
        let mut peak = base.max(probe_peak);

        for layer in 0..cfg.n_layers {
            let mut blockw = BlockWeights::from_params(self.params, layer)?;
            let stats = cap.capture_block(&blockw, needs_gram)?;
            let outs = decompose::decompose_block(
                self.method,
                self.engine,
                rt,
                &blockw,
                &stats,
                plan.as_ref(),
                self.refine.as_ref(),
                pool,
            )?;
            let refine_scratch = if self.refine.is_some() { 5 * blockw.nbytes() } else { 0 };
            let transient = 2 * blockw.nbytes()
                + refine_scratch
                + stats.iter().map(|s| s.nbytes()).sum::<usize>()
                + outs
                    .iter()
                    .map(|o| o.packed.as_ref().map_or(0, |p| p.nbytes_deploy()))
                    .sum::<usize>();
            peak = peak.max(base + retained + transient);
            for (slot, out) in outs.into_iter().enumerate() {
                let decompose::LinearOut { report, w_hat, packed, refine } = out;
                if let Some(p) = &mut out_params {
                    p.set_mat(&report.name, &w_hat);
                }
                if let Some(r) = refine {
                    refine_reports.push((report.name.clone(), r));
                }
                if let Some(packed) = packed {
                    sink.emit(&report.name, &packed)?;
                    if self.keep_packed {
                        retained += packed.nbytes_deploy();
                        slab_layers.push((report.name.clone(), packed));
                    }
                }
                // Swap the reconstruction in for output propagation;
                // on !keep_dense jobs it dies with `blockw` below.
                blockw.linears[slot].2 = w_hat;
                reports.push(report);
            }
            // The last block's output feeds nothing — skip the
            // propagation forward (one full calibration pass saved).
            if layer + 1 < cfg.n_layers {
                cap.advance(&blockw)?;
            }
            eprintln!(
                "[compress] {} block {}/{} done",
                self.method.name(),
                layer + 1,
                cfg.n_layers
            );
        }
        let streamed = sink.finish()?;
        if let Some(path) = &self.stream_to {
            eprintln!("[compress] streamed {streamed} entries → {}", path.display());
        }

        let mean_frob = reports.iter().map(|l| l.frob_err as f64).sum::<f64>()
            / reports.len().max(1) as f64;
        Ok(CompressOut {
            params: out_params,
            slab_layers,
            report: CompressReport {
                method: self.method.name(),
                layers: reports,
                wall_secs: t0.elapsed().as_secs_f64(),
                mean_frob,
                peak_bytes: peak,
                budget: plan,
                refine: refine_reports,
            },
        })
    }
}

/// Compress every pruned linear of `params` with `method` — the
/// historical single-call API: artifact capture, serial decompose,
/// dense and packed outputs retained in memory. Callers that want
/// native capture, a parallel decompose stage, or streaming emission
/// use [`CompressJob`] directly.
pub fn compress_model(
    rt: &Runtime,
    params: &Params,
    calib: &TokenSet,
    method: &Method,
    engine: Engine,
) -> Result<CompressedModel, PipelineError> {
    let out = CompressJob::new(params, calib, method)
        .capture(CaptureEngine::Artifact(rt))
        .engine(engine)
        .run()?;
    Ok(CompressedModel {
        params: out
            .params
            .ok_or_else(|| PipelineError::Other("keep_dense run returned no params".into()))?,
        slab_layers: out.slab_layers,
        report: out.report,
    })
}

#[cfg(test)]
mod tests {
    //! The native capture engine needs no artifacts, so the staged
    //! pipeline's invariants run on every `cargo test`; the
    //! native-vs-artifact cross-checks live in
    //! `rust/tests/integration.rs` (artifact-gated).

    use super::*;
    use crate::model::SlabModel;
    use crate::runtime::ModelCfg;
    use crate::slab::SlabConfig;

    fn tiny_cfg(n_layers: usize) -> ModelCfg {
        ModelCfg::llama("tiny-compress", 32, 8, n_layers, 2, 16, 10, 4)
    }

    /// Deterministic in-vocab calibration rows — no grammar needed.
    fn calib(cfg: &ModelCfg, rows: usize) -> TokenSet {
        TokenSet::synthetic(rows, cfg.max_seq, cfg.vocab)
    }

    fn slab_method() -> Method {
        Method::Slab(SlabConfig {
            iters: 2,
            svd_iters: 4,
            ..Default::default()
        })
    }

    #[test]
    fn native_capture_wanda_matches_paper_semantics() {
        // The native twin of the artifact-gated pipeline test: exact
        // per-row sparsity on every pruned linear, untouched params
        // bit-identical, full report coverage.
        let cfg = tiny_cfg(2);
        let params = Params::init(&cfg, 400);
        let method = Method::Wanda { sparsity: 0.5, pattern: None };
        let out = CompressJob::new(&params, &calib(&cfg, 4), &method).run().expect("compress job");
        let p = out.params.as_ref().expect("keep_dense default retains params");
        for (name, (dout, din)) in &cfg.pruned {
            let m = p.mat(name);
            for i in 0..*dout {
                let nnz = m.row(i).iter().filter(|&&v| v != 0.0).count();
                assert_eq!(nnz, din / 2, "{name} row {i}");
            }
        }
        for (i, name) in cfg.param_names.iter().enumerate() {
            if !cfg.pruned.iter().any(|(pn, _)| pn == name) {
                assert_eq!(p.tensors[i], params.tensors[i], "{name} must be untouched");
            }
        }
        assert_eq!(out.report.layers.len(), cfg.pruned.len());
        assert!(out.slab_layers.is_empty(), "wanda emits no packed layers");
        assert!(out.report.peak_bytes > 0);
    }

    #[test]
    fn parallel_job_is_bit_identical_to_serial() {
        // The tentpole determinism contract, end to end: fanning the
        // decompose stage (and the capture matmuls) across workers
        // must not change one bit of any packed layer, parameter, or
        // report.
        let cfg = tiny_cfg(2);
        let params = Params::init(&cfg, 401);
        let cal = calib(&cfg, 4);
        let method = slab_method();
        let serial = CompressJob::new(&params, &cal, &method).run().expect("compress job");
        let par = CompressJob::new(&params, &cal, &method).threads(4).run().expect("compress job");
        assert_eq!(serial.slab_layers, par.slab_layers, "packed layers");
        assert_eq!(
            serial.params.as_ref().expect("serial params").tensors,
            par.params.as_ref().expect("parallel params").tensors,
            "dense reconstructions"
        );
        assert_eq!(serial.report.layers, par.report.layers, "reports");
        assert_eq!(serial.slab_layers.len(), cfg.pruned.len());
        // Canonical emission order: block-major, block_linears-minor.
        let names: Vec<&str> = serial.slab_layers.iter().map(|(n, _)| n.as_str()).collect();
        let expect: Vec<String> = (0..cfg.n_layers)
            .flat_map(|l| cfg.block_linears(l).map(|(n, _)| n))
            .collect();
        assert_eq!(names, expect.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    }

    #[test]
    fn streaming_lean_job_matches_in_memory_and_shrinks_peak() {
        // keep nothing + stream: the checkpoint must reload to exactly
        // the in-memory packed layers, serve token-identically, and
        // the peak-bytes proxy must come in under the keep-everything
        // run.
        let cfg = tiny_cfg(2);
        let params = Params::init(&cfg, 402);
        let cal = calib(&cfg, 4);
        let method = slab_method();
        let keep = CompressJob::new(&params, &cal, &method).run().expect("compress job");
        let path = std::env::temp_dir().join("slab-tests/compress-stream.slabckpt");
        let lean = CompressJob::new(&params, &cal, &method)
            .threads(2)
            .keep_dense(false)
            .keep_packed(false)
            .stream_to(path.clone())
            .run()
            .expect("streaming job");
        assert!(lean.params.is_none());
        assert!(lean.slab_layers.is_empty());
        assert!(
            lean.report.peak_bytes < keep.report.peak_bytes,
            "stream {} vs keep {}",
            lean.report.peak_bytes,
            keep.report.peak_bytes
        );
        assert_eq!(lean.report.layers, keep.report.layers, "reports still complete");

        let loaded = load_packed_checkpoint(&path).expect("reload checkpoint");
        assert_eq!(loaded, keep.slab_layers, "streamed layers reload bit-identically");

        // And the streamed checkpoint serves: packed engine over the
        // reloaded layers vs dense engine over the kept Ŵ.
        let packed_model = SlabModel::from_packed(&params, &loaded, 1);
        let dense_model = SlabModel::from_dense(keep.params.as_ref().expect("kept params"), 1);
        let prompts = vec![vec![5, 6, 7], vec![9, 10]];
        assert_eq!(
            packed_model.generate_batch(&prompts, 4),
            dense_model.generate_batch(&prompts, 4),
            "streamed checkpoint must serve token-identically"
        );
    }

    #[test]
    fn batch_size_only_regroups_the_same_rows() {
        // Any batch size — dividing or not — feeds every calibration
        // row exactly once through identical weights; the
        // sample-weighted ActStats merge pools the (possibly partial)
        // batches to the same statistic up to rounding, so per-layer
        // error stays put to float tolerance.
        let cfg = tiny_cfg(1);
        let params = Params::init(&cfg, 403);
        let cal = calib(&cfg, 4);
        let method = Method::Wanda { sparsity: 0.5, pattern: None };
        let a = CompressJob::new(&params, &cal, &method).batch(4).run().expect("compress job");
        // batch 3 → batches of 3 and 1 rows; batch 7 → one short batch.
        for batch in [2usize, 3, 7] {
            let b = CompressJob::new(&params, &cal, &method).batch(batch).run().expect("compress job");
            for (la, lb) in a.report.layers.iter().zip(b.report.layers.iter()) {
                assert_eq!(la.kept, lb.kept, "batch {batch}");
                assert!(
                    (la.frob_err - lb.frob_err).abs() <= 1e-3 * (1.0 + la.frob_err.abs()),
                    "batch {batch} {}: {} vs {}",
                    la.name,
                    la.frob_err,
                    lb.frob_err
                );
            }
        }
    }

    #[test]
    fn streaming_a_non_packed_method_is_rejected() {
        // Wanda emits no packed layers; streaming it would produce a
        // valid-but-empty checkpoint — the job must refuse up front.
        let cfg = tiny_cfg(1);
        let params = Params::init(&cfg, 405);
        let cal = calib(&cfg, 2);
        let method = Method::Wanda { sparsity: 0.5, pattern: None };
        let err = CompressJob::new(&params, &cal, &method)
            .stream_to(std::env::temp_dir().join("slab-tests/never-written.slabckpt"))
            .run();
        assert!(matches!(err, Err(PipelineError::Other(_))));
    }

    #[test]
    fn serving_model_picks_packed_else_dense() {
        let cfg = tiny_cfg(1);
        let params = Params::init(&cfg, 406);
        let cal = calib(&cfg, 2);
        // SLaB retained packed layers: the packed engine, token-identical
        // to serving the dense reconstruction of the same decomposition.
        let slab_out = CompressJob::new(&params, &cal, &slab_method()).run().expect("compress job");
        let packed = slab_out.serving_model(&params, 1).expect("packed serving model");
        assert_eq!(packed.packed_linear_count(), cfg.pruned.len());
        let dense_ref = SlabModel::from_dense(slab_out.params.as_ref().expect("slab dense params"), 1);
        let prompts = vec![vec![5, 6], vec![7]];
        assert_eq!(
            packed.generate_batch(&prompts, 3),
            dense_ref.generate_batch(&prompts, 3),
            "packed vs dense-reconstruction tokens"
        );
        // Wanda emits no packed layers → the dense-reconstruction engine.
        let wanda = Method::Wanda { sparsity: 0.5, pattern: None };
        let wout = CompressJob::new(&params, &cal, &wanda).run().expect("compress job");
        assert_eq!(wout.serving_model(&params, 1).expect("dense serving model").packed_linear_count(), 0);
        // A streaming-lean job retains neither → explicit error, not a panic.
        let path = std::env::temp_dir().join("slab-tests/serving-model-lean.slabckpt");
        let lean = CompressJob::new(&params, &cal, &slab_method())
            .keep_dense(false)
            .keep_packed(false)
            .stream_to(path)
            .run()
            .expect("streaming job");
        assert!(matches!(lean.serving_model(&params, 1), Err(PipelineError::Other(_))));
    }

    #[test]
    fn refined_alloc_job_is_bit_identical_parallel_vs_serial() {
        // The tentpole determinism contract extended to the new
        // stages: budget probe + plan + per-layer refinement rounds
        // under a 4-worker fan-out must match the serial run bit for
        // bit — packed layers, dense reconstructions, reports, refine
        // traces, and the plan itself.
        let cfg = tiny_cfg(2);
        let params = Params::init(&cfg, 408);
        let cal = calib(&cfg, 4);
        let method = slab_method();
        let rc = crate::slab::RefineConfig { rounds: 2, tol: 0.0 };
        let serial = CompressJob::new(&params, &cal, &method)
            .refine(rc)
            .budget(BudgetConfig::default())
            .run()
            .expect("serial refined job");
        let par = CompressJob::new(&params, &cal, &method)
            .refine(rc)
            .budget(BudgetConfig::default())
            .threads(4)
            .run()
            .expect("parallel refined job");
        assert_eq!(serial.slab_layers, par.slab_layers, "packed layers");
        assert_eq!(
            serial.params.as_ref().expect("serial params").tensors,
            par.params.as_ref().expect("parallel params").tensors,
            "dense reconstructions"
        );
        assert_eq!(serial.report.layers, par.report.layers, "reports");
        assert_eq!(serial.report.refine, par.report.refine, "refine traces");
        assert_eq!(serial.report.budget, par.report.budget, "budget plan");
        // Every pruned linear got a refine report, in emission order.
        assert_eq!(serial.report.refine.len(), cfg.pruned.len());
        let names: Vec<&str> = serial.report.refine.iter().map(|(n, _)| n.as_str()).collect();
        let expect: Vec<String> = (0..cfg.n_layers)
            .flat_map(|l| cfg.block_linears(l).map(|(n, _)| n))
            .collect();
        assert_eq!(names, expect.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    }

    #[test]
    fn budget_plan_conserves_global_keep_and_refine_never_regresses() {
        let cfg = tiny_cfg(2);
        let params = Params::init(&cfg, 409);
        let cal = calib(&cfg, 4);
        let method = slab_method();
        let out = CompressJob::new(&params, &cal, &method)
            .refine(crate::slab::RefineConfig { rounds: 2, tol: 0.0 })
            .budget(BudgetConfig::default())
            .run()
            .expect("refined alloc job");
        let plan = out.report.budget.as_ref().expect("plan recorded");
        assert_eq!(
            plan.total_keep(),
            plan.total_uniform_keep(),
            "equal global parameter budget is an invariant"
        );
        assert_eq!(plan.layers.len(), cfg.pruned.len());
        // The accept guard makes per-layer non-regression structural.
        for (name, r) in &out.report.refine {
            assert!(
                r.err_after() <= r.err_before(),
                "{name}: {} > {}",
                r.err_after(),
                r.err_before()
            );
        }
        // The plan's table renders every layer.
        let t = plan.to_table();
        assert_eq!(t.rows.len(), cfg.pruned.len());
    }

    #[test]
    fn refine_and_budget_reject_non_slab_and_artifact_engine() {
        let cfg = tiny_cfg(1);
        let params = Params::init(&cfg, 410);
        let cal = calib(&cfg, 2);
        let wanda = Method::Wanda { sparsity: 0.5, pattern: None };
        let err = CompressJob::new(&params, &cal, &wanda)
            .refine(crate::slab::RefineConfig::default())
            .run();
        assert!(matches!(err, Err(PipelineError::Other(_))), "refine on wanda");
        let err = CompressJob::new(&params, &cal, &wanda)
            .budget(BudgetConfig::default())
            .run();
        assert!(matches!(err, Err(PipelineError::Other(_))), "budget on wanda");
        let slab = slab_method();
        let err = CompressJob::new(&params, &cal, &slab)
            .engine(Engine::Artifact)
            .refine(crate::slab::RefineConfig::default())
            .run();
        assert!(matches!(err, Err(PipelineError::Other(_))), "refine on artifact engine");
    }

    #[test]
    fn artifact_decompose_requires_artifact_capture() {
        let cfg = tiny_cfg(1);
        let params = Params::init(&cfg, 404);
        let cal = calib(&cfg, 2);
        let method = slab_method();
        let err = CompressJob::new(&params, &cal, &method).engine(Engine::Artifact).run();
        assert!(matches!(err, Err(PipelineError::Other(_))));
    }

    #[test]
    fn missing_block_params_are_a_typed_error_not_a_panic() {
        // Asking for a block the config doesn't have (the shape a
        // config/checkpoint mismatch takes) must surface as a typed
        // RuntimeError::MissingParam naming the parameter — the
        // serve-side error policy, applied to compression inputs.
        let cfg = tiny_cfg(1);
        let params = Params::init(&cfg, 407);
        let err = match BlockWeights::from_params(&params, 1) {
            Err(e) => e,
            Ok(_) => panic!("layer 1 of a 1-layer model must fail"),
        };
        assert!(
            matches!(err, PipelineError::Runtime(RuntimeError::MissingParam(_))),
            "unexpected error shape: {err}"
        );
        assert!(err.to_string().contains("l1."), "{err}");
    }
}
