//! Stage 2 — **decompose**: prune/decompose the seven linears of a
//! captured block.
//!
//! After capture the linears share only the read-only per-source
//! [`ActStats`] (HASSLE-free's framing: sparse+low-rank compression
//! is a set of independent per-layer local-loss problems), so they
//! fan out across [`ThreadPool::scoped_map`] workers. The reduction
//! is slot-ordered — reports and packed layers come back in the
//! canonical [`crate::runtime::ModelCfg::block_linears`] order — so a
//! parallel run is **bit-identical** to the serial one (pinned by
//! tests at the job level and end-to-end).
//!
//! For SLaB the dense reconstruction `Ŵ` and the packed layer now come
//! from a *single* Algorithm-1 run; the old pipeline ran the
//! decomposition twice per linear (once inside
//! `Method::compress_layer`, once for the packed output).

use super::capture::BlockWeights;
use super::{Engine, LayerReport, PipelineError};
use crate::baselines::{Method, MethodError};
use crate::coordinator::budget::BudgetPlan;
use crate::runtime::{lit_mat, lit_scalar_i32, to_vec_f32, Runtime};
use crate::slab::{ActStats, RefineConfig, RefineReport, SlabConfig, SlabLayer};
use crate::tensor::Mat;
use crate::util::pool::ThreadPool;

/// One linear's stage output, in canonical block order.
pub(crate) struct LinearOut {
    pub report: LayerReport,
    /// Dense reconstruction — always materialized (the capture stage
    /// needs it to propagate pruned outputs); retained past the block
    /// only on `keep_dense` jobs.
    pub w_hat: Mat,
    pub packed: Option<SlabLayer>,
    /// Refinement diagnostics when the job opted into `refine`.
    pub refine: Option<RefineReport>,
}

/// Decompose every linear of `blockw` against its activation source.
/// `plan` (per-layer keep budgets) and `rcfg` (joint refinement) are
/// SLaB-native-only extras; the job validates that up front, so here
/// they are simply unused on the other paths.
pub(crate) fn decompose_block(
    method: &Method,
    engine: Engine,
    rt: Option<&Runtime>,
    blockw: &BlockWeights,
    stats: &[ActStats; 4],
    plan: Option<&BudgetPlan>,
    rcfg: Option<&RefineConfig>,
    pool: Option<&ThreadPool>,
) -> Result<Vec<LinearOut>, PipelineError> {
    // SLaB through the AOT `decompose_{shape}` artifact stays serial:
    // the PJRT client is not a fan-out target, and the artifact path
    // exists as the paper-faithful cross-check, not the fast path.
    if let (Method::Slab(scfg), Engine::Artifact) = (method, engine) {
        debug_assert!(
            plan.is_none() && rcfg.is_none(),
            "job validation rejects refine/budget on the artifact engine"
        );
        let rt = rt.ok_or_else(|| {
            PipelineError::Other(
                "artifact decompose engine requires the artifact capture engine".into(),
            )
        })?;
        return blockw
            .linears
            .iter()
            .map(|(name, src, w)| decompose_one_artifact(rt, name, w, &stats[*src], scfg))
            .collect();
    }
    let items: Vec<(&str, &Mat, &ActStats)> = blockw
        .linears
        .iter()
        .map(|(name, src, w)| (name.as_str(), w, &stats[*src]))
        .collect();
    match pool {
        Some(p) if p.size() > 1 => p
            .scoped_map(items, |(name, w, st)| {
                decompose_one(method, name, w, st, plan, rcfg)
            })
            .into_iter()
            .collect(),
        _ => items
            .into_iter()
            .map(|(name, w, st)| decompose_one(method, name, w, st, plan, rcfg))
            .collect(),
    }
}

/// Compress one linear natively. This is the unit of work a pool
/// worker runs, so it must not touch the pool itself (no nested
/// fork-join); the per-row inner parallelism of
/// [`crate::slab::decompose_par`] is for single-layer callers. The
/// optional refinement rounds run serially *inside* the worker, so the
/// fan-out's bit-identical-to-serial contract extends to them for
/// free.
fn decompose_one(
    method: &Method,
    name: &str,
    w: &Mat,
    stats: &ActStats,
    plan: Option<&BudgetPlan>,
    rcfg: Option<&RefineConfig>,
) -> Result<LinearOut, PipelineError> {
    let (w_hat, kept, frob, packed, refine) = match method {
        Method::Slab(scfg) => {
            // The budget plan swaps the uniform config for this
            // layer's keep-override variant; everything else (rank,
            // group, structure, seeds) stays uniform.
            let eff = plan.map_or(*scfg, |p| p.config_for(name));
            let mut d = crate::slab::decompose(w, stats, &eff).map_err(MethodError::Config)?;
            let mut rep = None;
            if let Some(rc) = rcfg {
                let (refined, r) =
                    crate::slab::refine(w, &d, stats, &eff, rc).map_err(MethodError::Config)?;
                d = refined;
                rep = Some(r);
            }
            let packed = SlabLayer::from_decomposition(&d);
            let frob = *d.frob_trace.last().unwrap_or(&0.0);
            (d.reconstruct(), d.kept, frob, Some(packed), rep)
        }
        _ => {
            let c = method.compress_layer(w, stats)?;
            (c.w_hat, c.kept, c.frob_err, None, None)
        }
    };
    Ok(LinearOut {
        report: LayerReport {
            name: name.to_string(),
            kept,
            numel: w.numel(),
            frob_err: frob,
        },
        w_hat,
        packed,
        refine,
    })
}

/// Execute `decompose_{dout}x{din}` and rebuild both the dense `Ŵ`
/// and the packed layer from its outputs.
fn decompose_one_artifact(
    rt: &Runtime,
    name: &str,
    w: &Mat,
    stats: &ActStats,
    scfg: &SlabConfig,
) -> Result<LinearOut, PipelineError> {
    let (dout, din) = w.shape();
    let keep = scfg
        .keep_fraction(dout, din)
        .map_err(|e| PipelineError::Other(e.to_string()))?;
    let art_name = format!("decompose_{dout}x{din}");
    let outs = rt.execute(
        &art_name,
        &[
            lit_mat(w),
            crate::runtime::lit_f32(&stats.col_norms, &[din]),
            crate::runtime::literal::lit_scalar_f32(keep as f32),
            lit_scalar_i32(scfg.iters as i32),
        ],
    )?;
    if outs.len() < 4 {
        return Err(PipelineError::Other(format!(
            "{art_name} returned {} outputs, expected 4",
            outs.len()
        )));
    }
    let w_s = Mat::from_vec(dout, din, to_vec_f32(&outs[0]));
    let u = to_vec_f32(&outs[1]);
    let v = to_vec_f32(&outs[2]);
    let w_b = Mat::from_vec(dout, din, to_vec_f32(&outs[3]));
    let w_hat = w_s.add(&Mat::outer(&u, &v).hadamard(&w_b));
    let packed = SlabLayer {
        w_s: crate::sparse::Csr::from_dense(&w_s),
        u: vec![u],
        v: vec![v],
        w_b: crate::binary::BitMat::from_sign_of(&w_b),
    };
    let frob = w.frob_dist(&w_hat);
    Ok(LinearOut {
        report: LayerReport {
            name: name.to_string(),
            kept: packed.w_s.nnz(),
            numel: w.numel(),
            frob_err: frob,
        },
        w_hat,
        packed: Some(packed),
        refine: None,
    })
}
