//! Stage 3 — **emit**: stream each block's packed layers to disk the
//! moment the block finishes.
//!
//! A [`CheckpointWriter`] appends [`SlabLayer::entries`] per linear
//! and never holds more than the current block's tensors; combined
//! with `keep_dense(false)`/`keep_packed(false)` on the job, peak
//! memory is the input model plus the calibration stream plus ~one
//! block — not a second full model (DESIGN.md §10). The resulting
//! file is a plain `.slabckpt` container, byte-identical to a batch
//! save of the same entries, loadable by [`load_packed_checkpoint`]
//! or entry-by-entry by `SlabLayer::load_from`.

use crate::slab::SlabLayer;
use crate::tensor::{Checkpoint, CheckpointWriter};
use std::io;
use std::path::Path;

/// Where packed layers go as blocks finish: a streaming checkpoint
/// writer, or nowhere (in-memory-only jobs).
pub(crate) struct Sink {
    writer: Option<CheckpointWriter>,
}

impl Sink {
    pub fn new(path: Option<&Path>) -> io::Result<Sink> {
        Ok(Sink {
            writer: path.map(CheckpointWriter::create).transpose()?,
        })
    }

    /// Append one packed linear under its parameter name.
    pub fn emit(&mut self, name: &str, layer: &SlabLayer) -> io::Result<()> {
        if let Some(w) = &mut self.writer {
            for e in layer.entries(name) {
                w.append(&e)?;
            }
        }
        Ok(())
    }

    /// Finalize the stream; returns the entry count (0 when nothing
    /// was streamed).
    pub fn finish(self) -> io::Result<usize> {
        match self.writer {
            Some(w) => w.finalize(),
            None => Ok(0),
        }
    }
}

/// Load a packed-layer checkpoint written by the emit stage (or by
/// `SlabLayer::save_into`): every `{prefix}.shape` entry marks one
/// packed linear; prefixes keep their block emission order, so the
/// result plugs straight into `SlabModel::from_packed`.
pub fn load_packed_checkpoint(path: &Path) -> io::Result<Vec<(String, SlabLayer)>> {
    let ck = Checkpoint::load(path)?;
    let mut out = Vec::new();
    for e in &ck.entries {
        if let Some(prefix) = e.name.strip_suffix(".shape") {
            let layer = SlabLayer::load_from(&ck, prefix).ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("malformed packed layer '{prefix}'"),
                )
            })?;
            out.push((prefix.to_string(), layer));
        }
    }
    Ok(out)
}
