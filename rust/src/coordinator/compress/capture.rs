//! Stage 1 — **capture**: forward the calibration batches through the
//! current (partially pruned) model block by block and accumulate
//! [`ActStats`] for the four activation sources — `x_attn` (feeds
//! wq/wk/wv), `att_out` (wo), `x_mlp` (w_gate/w_up), `mlp_inner`
//! (w_down).
//!
//! Two engines behind one stage API:
//!
//! * [`CaptureEngine::Native`] — the pure-Rust block forward
//!   ([`CaptureBlock::capture_forward`]): RoPE/MHA/SwiGLU out of
//!   `model::native`, dense matmuls row-chunked on the job's pool,
//!   activations accumulated straight into [`ActStats`] without ever
//!   leaving the process. No `embed_*`/`block_capture_*` artifacts
//!   required.
//! * [`CaptureEngine::Artifact`] — the historical XLA path
//!   (`embed_{cfg}` / `block_capture_{cfg}` / `gram_{shape}`
//!   executables), retained as a cross-check engine; integration
//!   tests pin the two against each other. Per-layer literals are
//!   built **once per block** and borrowed by every batch call
//!   (`execute_refs`) — the old pipeline re-cloned them through a
//!   host round-trip for every batch.

use super::PipelineError;
use crate::data::TokenSet;
use crate::model::{embed_rows, CaptureBlock, Params};
use crate::runtime::client::RuntimeError;
use crate::runtime::{lit_f32, lit_i32, lit_mat, to_vec_f32, Runtime};
use crate::slab::ActStats;
use crate::tensor::Mat;
use crate::util::pool::ThreadPool;

/// Which engine executes the calibration forward.
#[derive(Clone, Copy)]
pub enum CaptureEngine<'a> {
    /// Pure-Rust capture on the native block machinery — no XLA
    /// artifacts anywhere near the compression path.
    Native,
    /// The `embed_{cfg}`/`block_capture_{cfg}` executables of `rt` —
    /// the cross-check engine (and the only one that can feed the
    /// `decompose_{shape}` artifact, see [`super::Engine::Artifact`]).
    Artifact(&'a Runtime),
}

/// One block's dense weights in canonical order — the unit of work
/// handed from capture to decompose to emit. Holds the *current*
/// weights: originals at capture time; the decompose stage swaps the
/// pruned reconstructions in before output propagation.
pub struct BlockWeights {
    pub layer: usize,
    pub attn_norm: Vec<f32>,
    pub mlp_norm: Vec<f32>,
    /// The seven pruned linears in [`crate::runtime::ModelCfg::block_linears`]
    /// order: (name, activation-source index, weight).
    pub linears: Vec<(String, usize, Mat)>,
}

impl BlockWeights {
    /// Gather one block's weights by the per-block name contract.
    /// A name the params don't carry is a malformed job input (e.g. a
    /// config/checkpoint mismatch) — a typed error, not a panic, so
    /// compression jobs fail with context.
    pub fn from_params(params: &Params, layer: usize) -> Result<BlockWeights, PipelineError> {
        let missing = |name: &str| RuntimeError::MissingParam(name.to_string());
        let vec1 = |name: &str| -> Result<Vec<f32>, PipelineError> {
            let i = params.index(name).ok_or_else(|| missing(name))?;
            Ok(params.tensors[i].clone())
        };
        // Norm names come from the same per-block contract as the
        // linears (`block_param_names` is the block_capture argument
        // order: attn_norm first, mlp_norm sixth).
        let names = params.cfg.block_param_names(layer);
        let mut linears = Vec::new();
        for (name, src) in params.cfg.block_linears(layer) {
            let w = params.try_mat(&name).ok_or_else(|| missing(&name))?;
            linears.push((name, src, w));
        }
        Ok(BlockWeights {
            layer,
            attn_norm: vec1(&names[0])?,
            mlp_norm: vec1(&names[5])?,
            linears,
        })
    }

    /// Borrow as a native capture block.
    fn as_capture_block(&self, n_heads: usize) -> CaptureBlock<'_> {
        CaptureBlock {
            attn_norm: &self.attn_norm,
            wq: &self.linears[0].2,
            wk: &self.linears[1].2,
            wv: &self.linears[2].2,
            wo: &self.linears[3].2,
            mlp_norm: &self.mlp_norm,
            w_gate: &self.linears[4].2,
            w_up: &self.linears[5].2,
            w_down: &self.linears[6].2,
            n_heads,
        }
    }

    /// The nine parameter literals in `block_capture` artifact order —
    /// built once per block, borrowed by every batch call.
    fn to_literals(&self) -> Vec<xla::Literal> {
        vec![
            lit_f32(&self.attn_norm, &[self.attn_norm.len()]),
            lit_mat(&self.linears[0].2),
            lit_mat(&self.linears[1].2),
            lit_mat(&self.linears[2].2),
            lit_mat(&self.linears[3].2),
            lit_f32(&self.mlp_norm, &[self.mlp_norm.len()]),
            lit_mat(&self.linears[4].2),
            lit_mat(&self.linears[5].2),
            lit_mat(&self.linears[6].2),
        ]
    }

    /// Resident bytes of this block's weights (peak accounting).
    pub fn nbytes(&self) -> usize {
        self.linears.iter().map(|(_, _, w)| w.numel() * 4).sum::<usize>()
            + (self.attn_norm.len() + self.mlp_norm.len()) * 4
    }
}

/// The capture stage's live state: the calibration residual stream
/// for every batch, advanced block by block.
pub(crate) enum Capture<'a> {
    Native {
        /// One `(rows_b·t, dim)` residual matrix per calibration
        /// batch; the final batch may carry fewer rows — the
        /// sample-weighted [`ActStats::merge`] pools unequal batches
        /// exactly, so every calibration row counts once.
        h: Vec<Mat>,
        t: usize,
        n_heads: usize,
        pool: Option<&'a ThreadPool>,
    },
    Artifact {
        rt: &'a Runtime,
        /// One `(bsz, t, dim)` device literal per calibration batch.
        h: Vec<xla::Literal>,
        bsz: usize,
        t: usize,
        dim: usize,
        ffn: usize,
        cap_name: String,
    },
}

impl<'a> Capture<'a> {
    /// Embed every calibration batch. The native engine consumes
    /// **every row exactly once** — the final batch may be partial,
    /// and the sample-weighted [`ActStats::merge`] pools unequal
    /// batches exactly, so the `batch` setting only regroups the same
    /// rows (pinned by a test). The artifact engine's batch shape is
    /// baked into its executables: a trailing remainder is truncated
    /// (with a stderr note), and a calibration set smaller than one
    /// batch is an error rather than a silent double-count.
    pub fn start(
        engine: CaptureEngine<'a>,
        params: &Params,
        calib: &TokenSet,
        batch: usize,
        pool: Option<&'a ThreadPool>,
    ) -> Result<Capture<'a>, PipelineError> {
        let cfg = &params.cfg;
        let t = cfg.max_seq;
        if calib.rows == 0 {
            return Err(PipelineError::Other("empty calibration set".into()));
        }
        let flat_tokens = |start: usize, count: usize| {
            let mut flat = Vec::with_capacity(count * t);
            for k in 0..count {
                flat.extend_from_slice(&calib.row(start + k)[..t]);
            }
            flat
        };
        match engine {
            CaptureEngine::Native => {
                let bsz = batch.max(1);
                let n_batches = calib.rows.div_ceil(bsz);
                let tok_emb = params
                    .try_mat("tok_emb")
                    .ok_or_else(|| RuntimeError::MissingParam("tok_emb".into()))?;
                let h = (0..n_batches)
                    .map(|b| {
                        let start = b * bsz;
                        let count = bsz.min(calib.rows - start);
                        embed_rows(&tok_emb, &flat_tokens(start, count))
                    })
                    .collect();
                Ok(Capture::Native {
                    h,
                    t,
                    n_heads: cfg.n_heads,
                    pool,
                })
            }
            CaptureEngine::Artifact(rt) => {
                let bsz = rt.manifest.eval_batch;
                if calib.rows < bsz {
                    return Err(PipelineError::Other(format!(
                        "calibration set ({} rows) smaller than the artifact eval batch \
                         ({bsz}) — the capture executables are static-shaped; use \
                         CaptureEngine::Native",
                        calib.rows
                    )));
                }
                let n_batches = calib.rows / bsz;
                if calib.rows % bsz != 0 {
                    eprintln!(
                        "[compress] artifact capture truncates calibration to {} of {} rows \
                         (static batch {bsz})",
                        n_batches * bsz,
                        calib.rows
                    );
                }
                let emb_name = format!("embed_{}", cfg.name);
                // Hoisted once and borrowed per call — no per-batch
                // host round-trip of the embedding table. Resolved by
                // name (like every other parameter here), not by flat
                // position.
                let emb_idx = params
                    .index("tok_emb")
                    .ok_or_else(|| RuntimeError::MissingParam("tok_emb".into()))?;
                let tok_emb_lit =
                    lit_f32(&params.tensors[emb_idx], &cfg.param_shapes[emb_idx]);
                let mut h = Vec::with_capacity(n_batches);
                for b in 0..n_batches {
                    let tok_lit = lit_i32(&flat_tokens(b * bsz, bsz), &[bsz, t]);
                    let outs = rt.execute_refs(&emb_name, &[&tok_emb_lit, &tok_lit])?;
                    h.push(outs.into_iter().next().ok_or_else(|| {
                        PipelineError::Other("embed artifact returned no outputs".into())
                    })?);
                }
                Ok(Capture::Artifact {
                    rt,
                    h,
                    bsz,
                    t,
                    dim: cfg.dim,
                    ffn: cfg.ffn,
                    cap_name: format!("block_capture_{}", cfg.name),
                })
            }
        }
    }

    /// Forward every batch through `blockw` with its *current*
    /// weights, folding the four activation sources into per-source
    /// [`ActStats`] (sample-weighted merges — batches of unequal row
    /// counts pool exactly). The residual stream is **not** advanced.
    pub fn capture_block(
        &self,
        blockw: &BlockWeights,
        needs_gram: bool,
    ) -> Result<[ActStats; 4], PipelineError> {
        let mut stats: [Option<ActStats>; 4] = [None, None, None, None];
        let fold = |stats: &mut [Option<ActStats>; 4], slot: usize, st: ActStats| {
            match &mut stats[slot] {
                Some(acc) => acc.merge(&st),
                None => stats[slot] = Some(st),
            }
        };
        match self {
            Capture::Native { h, t, n_heads, pool } => {
                let blk = blockw.as_capture_block(*n_heads);
                for hb in h {
                    let acts = blk.capture_forward(hb, hb.rows / *t, *pool);
                    for (slot, x) in [
                        (0usize, &acts.x_attn),
                        (1, &acts.att_out),
                        (2, &acts.x_mlp),
                        (3, &acts.mlp_inner),
                    ] {
                        let st = if needs_gram {
                            ActStats::from_activations_with_gram_par(x, *pool)
                        } else {
                            ActStats::from_activations(x)
                        };
                        fold(&mut stats, slot, st);
                    }
                }
            }
            Capture::Artifact { rt, h, bsz, t, dim, ffn, cap_name } => {
                let lits = blockw.to_literals();
                for hlit in h {
                    let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
                    inputs.push(hlit);
                    let outs = rt.execute_refs(cap_name, &inputs)?;
                    // outs: h_out, x_attn, att_out, x_mlp, mlp_inner
                    if outs.len() < 5 {
                        return Err(PipelineError::Other(format!(
                            "{cap_name} returned {} outputs, expected 5",
                            outs.len()
                        )));
                    }
                    for (slot, idx) in [(0usize, 1usize), (1, 2), (2, 3), (3, 4)] {
                        let din = if slot == 3 { *ffn } else { *dim };
                        let rows = bsz * t;
                        let x = Mat::from_vec(rows, din, to_vec_f32(&outs[idx]));
                        let st = if needs_gram {
                            // Gram via the XLA kernel (Din³-scale work).
                            let gname = format!("gram_{rows}x{din}");
                            let gouts = rt.execute(&gname, &[lit_mat(&x)])?;
                            let gram = Mat::from_vec(din, din, to_vec_f32(&gouts[0]));
                            ActStats::from_raw(x.col_norms(), Some(gram), rows)
                        } else {
                            ActStats::from_activations(&x)
                        };
                        fold(&mut stats, slot, st);
                    }
                }
            }
        }
        match stats {
            [Some(a), Some(b), Some(c), Some(d)] => Ok([a, b, c, d]),
            // Unreachable through `start` (which rejects empty
            // calibration sets), but a typed error beats a panic if a
            // future engine ever yields zero batches.
            _ => Err(PipelineError::Other(
                "capture produced no calibration batches".into(),
            )),
        }
    }

    /// Propagate the residual stream through `blockw` with its
    /// (now pruned) weights — the hand-off to the next block.
    pub fn advance(&mut self, blockw: &BlockWeights) -> Result<(), PipelineError> {
        match self {
            Capture::Native { h, t, n_heads, pool } => {
                let blk = blockw.as_capture_block(*n_heads);
                for hb in h.iter_mut() {
                    *hb = blk.capture_forward(hb, hb.rows / *t, *pool).h_out;
                }
                Ok(())
            }
            Capture::Artifact { rt, h, cap_name, .. } => {
                let lits = blockw.to_literals();
                for hlit in h.iter_mut() {
                    let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
                    inputs.push(hlit);
                    let outs = rt.execute_refs(cap_name, &inputs)?;
                    *hlit = outs.into_iter().next().ok_or_else(|| {
                        PipelineError::Other("block_capture returned no outputs".into())
                    })?;
                }
                Ok(())
            }
        }
    }

    /// Resident bytes of the calibration stream (peak accounting).
    pub fn resident_bytes(&self) -> usize {
        match self {
            Capture::Native { h, .. } => h.iter().map(|m| m.numel() * 4).sum(),
            Capture::Artifact { h, bsz, t, dim, .. } => h.len() * bsz * t * dim * 4,
        }
    }
}
