//! The layer-wise one-shot pruning pipeline (paper §II-A.1).
//!
//! For every transformer block, in order:
//!
//! 1. **forward** the calibration batches through the block with the
//!    *current* (already partially pruned) weights, capturing the four
//!    activation sources — `x_attn` (feeds wq/wk/wv), `att_out` (wo),
//!    `x_mlp` (w_gate/w_up), `mlp_inner` (w_down);
//! 2. **prune** the seven linears with the configured method;
//! 3. **update** the block outputs with the pruned weights and hand
//!    them to the next block.
//!
//! All forward compute runs in the `embed_{cfg}` / `block_capture_{cfg}`
//! artifacts; SLaB decomposition can run either natively
//! ([`Engine::Native`]) or through the AOT `decompose_{shape}` Pallas
//! artifact ([`Engine::Artifact`]) — integration tests pin the two
//! paths against each other.

use crate::baselines::{Method, MethodError};
use crate::data::TokenSet;
use crate::model::Params;
use crate::runtime::client::RuntimeError;
use crate::runtime::{lit_i32, lit_scalar_i32, to_vec_f32, Runtime};
use crate::slab::{ActStats, SlabConfig, SlabLayer};
use crate::tensor::Mat;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Pure-rust decomposition (used by all baselines; SLaB optional).
    Native,
    /// SLaB through the AOT Pallas `decompose_{shape}` artifact.
    Artifact,
}

#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    pub kept: usize,
    pub numel: usize,
    pub frob_err: f32,
}

#[derive(Debug, Clone)]
pub struct CompressReport {
    pub method: String,
    pub layers: Vec<LayerReport>,
    pub wall_secs: f64,
    /// Mean ‖W − Ŵ‖_F across layers (the Fig. 3 metric).
    pub mean_frob: f64,
}

#[derive(Debug, thiserror::Error)]
pub enum PipelineError {
    #[error("runtime: {0}")]
    Runtime(#[from] RuntimeError),
    #[error("method: {0}")]
    Method(#[from] MethodError),
    #[error("pipeline: {0}")]
    Other(String),
}

/// Result of compressing a model: swapped-in dense reconstructions
/// plus (for SLaB) the packed deployable layers.
pub struct CompressedModel {
    pub params: Params,
    pub slab_layers: Vec<(String, SlabLayer)>,
    pub report: CompressReport,
}

/// Compress every pruned linear of `params` with `method`.
pub fn compress_model(
    rt: &Runtime,
    params: &Params,
    calib: &TokenSet,
    method: &Method,
    engine: Engine,
) -> Result<CompressedModel, PipelineError> {
    let t0 = std::time::Instant::now();
    let cfg = params.cfg.clone();
    let mut out = params.clone();
    let bsz = rt.manifest.eval_batch;
    let t = cfg.max_seq;
    let n_batches = (calib.rows / bsz).max(1);

    // --- embed all calibration batches ---------------------------------
    let emb_name = format!("embed_{}", cfg.name);
    let tok_emb_lit = &params.to_literals()[0];
    let mut h_batches: Vec<xla::Literal> = Vec::with_capacity(n_batches);
    for b in 0..n_batches {
        let mut flat = Vec::with_capacity(bsz * t);
        for k in 0..bsz {
            flat.extend_from_slice(&calib.row(b * bsz + k)[..t]);
        }
        let outs = rt.execute(
            &emb_name,
            &[clone_lit(tok_emb_lit), lit_i32(&flat, &[bsz, t])],
        )?;
        h_batches.push(into_single(outs));
    }

    let cap_name = format!("block_capture_{}", cfg.name);
    let mut layers = Vec::new();
    let mut slab_layers: Vec<(String, SlabLayer)> = Vec::new();

    for layer in 0..cfg.n_layers {
        // --- pass 1: capture activations with current weights ----------
        let layer_lits = layer_literals(&out, layer);
        let mut stats: [Option<ActStats>; 4] = [None, None, None, None];
        let needs_gram = method.needs_gram();
        for h in &h_batches {
            let mut inputs: Vec<xla::Literal> =
                layer_lits.iter().map(clone_lit).collect();
            inputs.push(clone_lit(h));
            let outs = rt.execute(&cap_name, &inputs)?;
            // outs: h_out, x_attn, att_out, x_mlp, mlp_inner
            for (slot, idx) in [(0usize, 1usize), (1, 2), (2, 3), (3, 4)] {
                let din = if slot == 3 { cfg.ffn } else { cfg.dim };
                let rows = bsz * t;
                let x = Mat::from_vec(rows, din, to_vec_f32(&outs[idx]));
                let st = if needs_gram {
                    // Gram via the XLA kernel (Din³-scale work).
                    let gname = format!("gram_{rows}x{din}");
                    let gouts = rt.execute(&gname, &[crate::runtime::lit_mat(&x)])?;
                    let gram = Mat::from_vec(din, din, to_vec_f32(&gouts[0]));
                    ActStats {
                        col_norms: x.col_norms(),
                        gram: Some(gram),
                        samples: rows,
                    }
                } else {
                    ActStats::from_activations(&x)
                };
                match &mut stats[slot] {
                    Some(acc) => acc.merge(&st),
                    None => stats[slot] = Some(st),
                }
            }
        }
        let stats: Vec<ActStats> = stats.into_iter().map(|s| s.unwrap()).collect();

        // --- pass 2: prune the seven linears ----------------------------
        let linears = [
            (format!("l{layer}.wq"), 0usize),
            (format!("l{layer}.wk"), 0),
            (format!("l{layer}.wv"), 0),
            (format!("l{layer}.wo"), 1),
            (format!("l{layer}.w_gate"), 2),
            (format!("l{layer}.w_up"), 2),
            (format!("l{layer}.w_down"), 3),
        ];
        for (name, src) in &linears {
            let w = out.mat(name);
            let st = &stats[*src];
            let (w_hat, kept, frob, packed) = match (method, engine) {
                (Method::Slab(scfg), Engine::Artifact) => {
                    let (d, layer_packed) = decompose_via_artifact(rt, &w, st, scfg)?;
                    let err = w.frob_dist(&d);
                    (d, layer_packed.w_s.nnz(), err, Some(layer_packed))
                }
                _ => {
                    let c = method.compress_layer(&w, st)?;
                    let packed = if let Method::Slab(scfg) = method {
                        let dec = crate::slab::decompose(&w, st, scfg)
                            .map_err(MethodError::Config)?;
                        Some(SlabLayer::from_decomposition(&dec))
                    } else {
                        None
                    };
                    (c.w_hat, c.kept, c.frob_err, packed)
                }
            };
            layers.push(LayerReport {
                name: name.clone(),
                kept,
                numel: w.numel(),
                frob_err: frob,
            });
            out.set_mat(name, &w_hat);
            if let Some(p) = packed {
                slab_layers.push((name.clone(), p));
            }
        }

        // --- pass 3: propagate pruned outputs --------------------------
        let layer_lits = layer_literals(&out, layer);
        for h in h_batches.iter_mut() {
            let mut inputs: Vec<xla::Literal> =
                layer_lits.iter().map(clone_lit).collect();
            inputs.push(clone_lit(h));
            let outs = rt.execute(&cap_name, &inputs)?;
            *h = outs.into_iter().next().unwrap();
        }
        eprintln!(
            "[pipeline] {} block {layer}/{} done",
            method.name(),
            cfg.n_layers
        );
    }

    let mean_frob =
        layers.iter().map(|l| l.frob_err as f64).sum::<f64>() / layers.len().max(1) as f64;
    Ok(CompressedModel {
        params: out,
        slab_layers,
        report: CompressReport {
            method: method.name(),
            layers,
            wall_secs: t0.elapsed().as_secs_f64(),
            mean_frob,
        },
    })
}

/// Execute `decompose_{dout}x{din}` and rebuild both the dense Ŵ and
/// the packed layer from its outputs.
fn decompose_via_artifact(
    rt: &Runtime,
    w: &Mat,
    stats: &ActStats,
    scfg: &SlabConfig,
) -> Result<(Mat, SlabLayer), PipelineError> {
    let (dout, din) = w.shape();
    let keep = scfg
        .keep_fraction(dout, din)
        .map_err(|e| PipelineError::Other(e.to_string()))?;
    let name = format!("decompose_{dout}x{din}");
    let outs = rt.execute(
        &name,
        &[
            crate::runtime::lit_mat(w),
            crate::runtime::lit_f32(&stats.col_norms, &[din]),
            crate::runtime::literal::lit_scalar_f32(keep as f32),
            lit_scalar_i32(scfg.iters as i32),
        ],
    )?;
    let w_s = Mat::from_vec(dout, din, to_vec_f32(&outs[0]));
    let u = to_vec_f32(&outs[1]);
    let v = to_vec_f32(&outs[2]);
    let w_b = Mat::from_vec(dout, din, to_vec_f32(&outs[3]));
    let w_hat = w_s.add(&Mat::outer(&u, &v).hadamard(&w_b));
    let packed = SlabLayer {
        w_s: crate::sparse::Csr::from_dense(&w_s),
        u: vec![u],
        v: vec![v],
        w_b: crate::binary::BitMat::from_sign_of(&w_b),
    };
    Ok((w_hat, packed))
}

/// The nine per-layer parameter literals in block_capture order.
fn layer_literals(params: &Params, layer: usize) -> Vec<xla::Literal> {
    let names = [
        format!("l{layer}.attn_norm"),
        format!("l{layer}.wq"),
        format!("l{layer}.wk"),
        format!("l{layer}.wv"),
        format!("l{layer}.wo"),
        format!("l{layer}.mlp_norm"),
        format!("l{layer}.w_gate"),
        format!("l{layer}.w_up"),
        format!("l{layer}.w_down"),
    ];
    names
        .iter()
        .map(|n| {
            let i = params.index(n).unwrap();
            crate::runtime::lit_f32(&params.tensors[i], &params.cfg.param_shapes[i])
        })
        .collect()
}

fn clone_lit(l: &xla::Literal) -> xla::Literal {
    let shape = l.array_shape().expect("clone shape");
    let dims: Vec<i64> = shape.dims().to_vec();
    match l.ty().expect("clone ty") {
        xla::ElementType::S32 => {
            let v = l.to_vec::<i32>().expect("clone i32");
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
        _ => {
            let v = l.to_vec::<f32>().expect("clone f32");
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
    }
}

fn into_single(mut outs: Vec<xla::Literal>) -> xla::Literal {
    assert_eq!(outs.len(), 1);
    outs.pop().unwrap()
}
