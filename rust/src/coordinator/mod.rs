//! Layer-3 coordination: the one-shot compression pipeline
//! ([`pipeline`]) and the serving router ([`serve`]) over its three
//! engines ([`serve::Backend`]) — two dynamic batchers and the
//! continuous-batching [`serve::Scheduler`].

pub mod pipeline;
pub mod serve;

pub use pipeline::{compress_model, CompressReport, CompressedModel, Engine, PipelineError};
pub use serve::{
    Backend, Request, Response, Scheduler, SchedulerConfig, ServeStats, Server, ServerConfig,
};
