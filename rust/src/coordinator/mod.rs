//! Layer-3 coordination: the one-shot compression pipeline
//! ([`pipeline`]) and the serving router/dynamic batcher ([`serve`])
//! over its two engines ([`serve::Backend`]).

pub mod pipeline;
pub mod serve;

pub use pipeline::{compress_model, CompressReport, CompressedModel, Engine, PipelineError};
pub use serve::{Backend, Request, Response, ServeStats, Server, ServerConfig};
