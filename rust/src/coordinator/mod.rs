//! Layer-3 coordination: the staged one-shot compression pipeline
//! ([`compress`] — capture → decompose → emit behind one
//! [`compress::CompressJob`]), the activation-aware per-layer budget
//! allocator ([`budget`] — water-filling the global sparse budget
//! across linears, DESIGN.md §16), the streaming serving router
//! ([`serve`]) over its three engines ([`serve::Backend`]) — two
//! dynamic batchers and the continuous-batching [`serve::Scheduler`]
//! — and the dependency-free HTTP/1.1 front-end ([`http`]) that
//! exposes the session API over a socket (DESIGN.md §12).

pub mod budget;
pub mod compress;
pub mod http;
pub mod serve;

pub use budget::{BudgetConfig, BudgetPlan, LayerBudget, LayerProbe};
pub use compress::{
    compress_model, load_packed_checkpoint, CaptureEngine, CompressJob, CompressOut,
    CompressReport, CompressedModel, Engine, LayerReport, PipelineError,
};
pub use http::{HttpConfig, HttpServer};
pub use serve::{
    collect_events, Backend, CancelHandle, Event, Request, Response, Scheduler, SchedulerConfig,
    ServeStats, Server, ServerConfig, Session, SessionStats,
};
