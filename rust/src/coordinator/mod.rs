//! Layer-3 coordination: the one-shot compression pipeline
//! ([`pipeline`]) and the serving router/dynamic batcher ([`serve`]).

pub mod pipeline;
pub mod serve;

pub use pipeline::{compress_model, CompressReport, CompressedModel, Engine, PipelineError};
pub use serve::{Request, Response, ServeStats, Server, ServerConfig};
