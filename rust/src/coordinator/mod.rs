//! Layer-3 coordination: the staged one-shot compression pipeline
//! ([`compress`] — capture → decompose → emit behind one
//! [`compress::CompressJob`]) and the serving router ([`serve`]) over
//! its three engines ([`serve::Backend`]) — two dynamic batchers and
//! the continuous-batching [`serve::Scheduler`].

pub mod compress;
pub mod serve;

pub use compress::{
    compress_model, load_packed_checkpoint, CaptureEngine, CompressJob, CompressOut,
    CompressReport, CompressedModel, Engine, LayerReport, PipelineError,
};
pub use serve::{
    Backend, Request, Response, Scheduler, SchedulerConfig, ServeStats, Server, ServerConfig,
};
