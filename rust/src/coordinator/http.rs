//! Dependency-free HTTP/1.1 front-end over the streaming session API
//! (`slab serve --http <addr>`): pure `std::net` plus the
//! [`evloop`](crate::util::evloop) readiness substrate — no async
//! runtime, no TLS, no external crates (DESIGN.md §15).
//!
//! Architecture: one nonblocking **event-loop thread** (epoll on
//! Linux, portable `poll(2)` fallback) owns the listener and every
//! connection socket — reads, request framing, and all writes happen
//! there, so ten thousand idle or slow connections cost zero threads.
//! A small **fixed worker pool** drives the blocking session API
//! (`submit`/`recv`/`collect`) and hands response bytes back to the
//! loop over a channel + self-pipe waker. Connections are keep-alive
//! by default with a per-connection request budget, a hard
//! [`HttpConfig::max_conns`] limit, and per-connection write budgets:
//! a client that stops reading its stream gets its session cancelled
//! and its socket closed instead of pinning memory forever.
//!
//! Wire surface:
//!
//! * `POST /v1/generate` — body
//!   `{"prompt": [ints], "max_new": n, "stream": bool, "deadline_ms": ms}`
//!   (`deadline_ms` of `0` or omitted = no per-request deadline, the
//!   same convention as `--deadline-ms` and
//!   [`SchedulerConfig::deadline`](super::serve::SchedulerConfig)).
//!   Non-streaming: one JSON object with the whole completion
//!   (`Session::collect` semantics). Streaming (`"stream": true`):
//!   SSE-style chunked transfer — one `data: {...}\n\n` frame per
//!   [`Event`], starting with `{"id": n}` so the client can cancel.
//!   Bodies are parsed with the lazy path-scanning
//!   [`LazyJson`](crate::util::json::LazyJson) reader — request
//!   extraction never builds a value tree on the hot path.
//! * `DELETE /v1/sessions/{id}` — cancel a live session mid-stream;
//!   its KV slot frees immediately and the stream terminates with
//!   `{"done": {..., "cancelled": true}}`.
//! * `GET /healthz` — liveness probe (query string is ignored).
//! * `GET /metrics` — the live [`ServeStats`] snapshot rendered
//!   through [`report::Table`](crate::report::Table) (text/plain), or
//!   as a JSON object with `?format=json`.
//!
//! Error contract (RFC 7807): every error response carries an
//! `application/problem+json` body — `type` is
//! `urn:slab:problem:<code>`, `title`/`status` echo the status line,
//! `detail` is human-readable, and `field` names the request field at
//! fault where one exists. `429` responses additionally carry a
//! `Retry-After` header (and a `retry_after_secs` member) derived
//! from the submit-gate depth: `1 + pending/queue_cap` seconds.
//!
//! Wire-contract hardening over the original thread-per-connection
//! front-end: methods match **case-sensitively** (RFC 9110 §9.1 —
//! `get` is 405 with an `Allow` header, not a silent alias of `GET`),
//! the query string is stripped before routing (`/healthz?probe=1`
//! works), `Transfer-Encoding` requests are refused with `411` rather
//! than silently misread as empty bodies, and oversized heads are
//! `431`.
//!
//! A client that disconnects mid-stream is treated as a cancellation
//! (the router stops decoding for it); a malformed request gets a
//! problem body and never reaches the engine. The [`client`]
//! submodule holds the minimal blocking loopback client (one-shot and
//! keep-alive) the benches and integration tests drive this server
//! with.

use super::serve::{CancelHandle, Event, Request, Server, ServeStats, SessionStats};
use crate::runtime::client::RuntimeError;
use crate::util::evloop::{self, PollEvent, Poller, WakeReader, Waker, EV_READ, EV_WRITE};
use crate::util::json::{Json, LazyJson};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Request-body cap — far above any prompt this testbed serves.
const MAX_BODY: usize = 1 << 20;
/// Per-line cap for the request line and each header: anything longer
/// is an attack or a bug, never a valid request of ours.
const MAX_LINE: usize = 8 * 1024;
const MAX_HEADERS: usize = 64;
/// Total request-head cap (request line + all headers). A client
/// streaming newline-free bytes hits this bound, not unbounded memory.
const MAX_HEAD: usize = 32 * 1024;
/// Read-buffer cap per connection: a full head plus a full body plus
/// one pipelined head. Beyond this the client is flooding.
const RBUF_CAP: usize = MAX_BODY + 2 * MAX_HEAD;
/// Event-loop tick: the poll timeout, which bounds how often the
/// timeout/budget sweep runs.
const POLL_TICK: Duration = Duration::from_millis(25);

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Front-end tuning knobs (`HttpServer::bind` uses the defaults; the
/// CLI exposes `--max-conns`, `--keep-alive`, `--http-workers`).
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// Hard cap on simultaneously open connections. New connections
    /// past the cap get a best-effort `503` + `Retry-After` and are
    /// closed immediately.
    pub max_conns: usize,
    /// Worker threads driving the blocking session API. This bounds
    /// in-flight request *handling*; open connections are bounded
    /// only by `max_conns`.
    pub workers: usize,
    /// Requests served per connection before the server closes it
    /// (`Connection: close` on the final response). `0` disables
    /// keep-alive entirely (every response closes).
    pub keep_alive_requests: usize,
    /// Idle cap. A connection idle between requests this long is
    /// closed silently; one idle *mid-request* (partial head or body)
    /// gets a `408` problem first.
    pub idle_timeout: Duration,
    /// Pending-write cap per connection. When a client stops reading
    /// and more than this many bytes are buffered for it, the
    /// connection is killed and its session cancelled.
    pub write_budget: usize,
    /// Write-stall cap: buffered bytes but zero write progress for
    /// this long also kills the connection (catches clients that stop
    /// reading before the budget fills).
    pub write_stall: Duration,
    /// `SO_SNDBUF` for accepted sockets; `0` keeps the kernel
    /// default. Tests shrink this to make the write budget bite
    /// deterministically.
    pub sndbuf: usize,
    /// Use the portable `poll(2)` backend even where epoll is
    /// available (exercised by tests so the fallback cannot rot).
    pub force_poll: bool,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            max_conns: 256,
            workers: 8,
            keep_alive_requests: 64,
            idle_timeout: Duration::from_secs(30),
            write_budget: 1 << 20,
            write_stall: Duration::from_secs(10),
            sndbuf: 0,
            force_poll: false,
        }
    }
}

// ---------------------------------------------------------------------
// RFC 7807 problem bodies
// ---------------------------------------------------------------------

/// An `application/problem+json` error response (RFC 7807): `type` is
/// `urn:slab:problem:<code>`, plus our extension members `field`
/// (request field at fault) and `retry_after_secs` (mirrors the
/// `Retry-After` header on 429/503).
struct Problem {
    status: u16,
    code: &'static str,
    title: &'static str,
    detail: String,
    field: Option<&'static str>,
    retry_after: Option<u64>,
    allow: Option<&'static str>,
    extra: Vec<(&'static str, Json)>,
}

impl Problem {
    fn new<S: Into<String>>(status: u16, code: &'static str, title: &'static str, detail: S) -> Problem {
        Problem {
            status,
            code,
            title,
            detail: detail.into(),
            field: None,
            retry_after: None,
            allow: None,
            extra: Vec::new(),
        }
    }

    /// Name the request field at fault (problem `field` member).
    fn field(mut self, f: &'static str) -> Problem {
        self.field = Some(f);
        self
    }

    /// Attach a `Retry-After` header + `retry_after_secs` member.
    fn retry_after(mut self, secs: u64) -> Problem {
        self.retry_after = Some(secs);
        self
    }

    /// Attach an `Allow` header (405 responses, RFC 9110 §10.2.2).
    fn allow(mut self, methods: &'static str) -> Problem {
        self.allow = Some(methods);
        self
    }

    /// Attach an arbitrary extension member.
    fn with(mut self, key: &'static str, value: Json) -> Problem {
        self.extra.push((key, value));
        self
    }

    fn body(&self) -> String {
        let mut pairs = vec![
            ("type", Json::str(format!("urn:slab:problem:{}", self.code))),
            ("title", Json::str(self.title)),
            ("status", Json::from_usize(self.status as usize)),
            ("detail", Json::str(self.detail.clone())),
        ];
        if let Some(f) = self.field {
            pairs.push(("field", Json::str(f)));
        }
        if let Some(r) = self.retry_after {
            pairs.push(("retry_after_secs", Json::from_usize(r as usize)));
        }
        for (k, v) in &self.extra {
            pairs.push((k, v.clone()));
        }
        Json::obj(pairs).to_string()
    }

    /// Serialize to a full HTTP/1.1 response.
    fn response(&self, reuse: bool) -> Vec<u8> {
        let mut extra = String::new();
        if let Some(a) = self.allow {
            extra.push_str(&format!("Allow: {a}\r\n"));
        }
        if let Some(r) = self.retry_after {
            extra.push_str(&format!("Retry-After: {r}\r\n"));
        }
        response_bytes(self.status, "application/problem+json", &extra, &self.body(), reuse)
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Content Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Error",
    }
}

/// Serialize one complete response; `reuse` picks the `Connection`
/// header (the loop closes the socket after flushing iff `!reuse`).
fn response_bytes(status: u16, ctype: &str, extra: &str, body: &str, reuse: bool) -> Vec<u8> {
    let conn = if reuse { "keep-alive" } else { "close" };
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {ctype}\r\nContent-Length: {len}\r\n{extra}Connection: {conn}\r\n\r\n{body}",
        reason = reason(status),
        len = body.len(),
    )
    .into_bytes()
}

// ---------------------------------------------------------------------
// Shared state + server handle
// ---------------------------------------------------------------------

/// State shared by the event loop and the worker pool.
struct HttpState {
    /// The serving router. `None` after shutdown — handlers answer
    /// `503` instead of panicking on a vanished server.
    server: Mutex<Option<Server>>,
    /// Live sessions by id — the `DELETE /v1/sessions/{id}` registry.
    sessions: Mutex<HashMap<u64, CancelHandle>>,
    running: AtomicBool,
    started: Instant,
}

impl HttpState {
    fn lock_sessions(&self) -> std::sync::MutexGuard<'_, HashMap<u64, CancelHandle>> {
        self.sessions.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn lock_server(&self) -> std::sync::MutexGuard<'_, Option<Server>> {
        self.server.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Cancel a live session through the registry (used by the loop when
/// it kills a connection whose worker is still streaming).
fn cancel_session(state: &HttpState, sid: u64) {
    if let Some(h) = state.lock_sessions().get(&sid).cloned() {
        h.cancel();
    }
}

/// The `Retry-After` convention (DESIGN.md §15): `1 + depth/cap`
/// seconds, where `depth` is the number of submissions currently
/// waiting at the admission gate. Coarse by design — the point is a
/// parseable, monotone backoff hint, not a queueing model.
fn retry_after_hint(state: &HttpState) -> u64 {
    match state.lock_server().as_ref() {
        Some(s) => 1 + (s.queue_depth() / s.queue_cap().max(1)) as u64,
        None => 1,
    }
}

/// The HTTP front-end handle: owns the event loop, the worker pool,
/// and the inner [`Server`]. Bind, then either
/// [`serve_forever`](HttpServer::serve_forever) (the CLI) or drive it
/// from tests/benches and [`shutdown`](HttpServer::shutdown).
pub struct HttpServer {
    addr: SocketAddr,
    state: Arc<HttpState>,
    waker: Waker,
    event_loop: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`, or port `0` for an
    /// ephemeral port — see [`addr`](HttpServer::addr)) with default
    /// [`HttpConfig`]. Any [`Backend`](super::serve::Backend) works —
    /// the front-end only speaks the session API.
    pub fn bind(addr: &str, server: Server) -> std::io::Result<HttpServer> {
        HttpServer::bind_with(addr, server, HttpConfig::default())
    }

    /// [`bind`](HttpServer::bind) with explicit tuning knobs.
    pub fn bind_with(addr: &str, server: Server, cfg: HttpConfig) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let poller = Poller::new(cfg.force_poll)?;
        let (waker, wake_rx) = evloop::waker()?;
        let state = Arc::new(HttpState {
            server: Mutex::new(Some(server)),
            sessions: Mutex::new(HashMap::new()),
            running: AtomicBool::new(true),
            started: Instant::now(),
        });
        let (msg_tx, msg_rx) = channel::<Msg>();
        let (work_tx, work_rx) = channel::<Work>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for i in 0..cfg.workers.max(1) {
            let rx = work_rx.clone();
            let tx = msg_tx.clone();
            let wk = waker.clone();
            let st = state.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("slab-http-worker-{i}"))
                    .spawn(move || worker_loop(&rx, &tx, &wk, &st))
                    .expect("spawn http worker"),
            );
        }
        drop(msg_tx); // the loop's msg_rx disconnects once workers exit
        let loop_state = state.clone();
        let loop_cfg = cfg;
        let event_loop = std::thread::Builder::new()
            .name("slab-http-loop".into())
            .spawn(move || {
                let mut el = EventLoop {
                    listener,
                    poller,
                    wake_rx,
                    msg_rx,
                    work_tx: Some(work_tx),
                    state: loop_state,
                    cfg: loop_cfg,
                    conns: HashMap::new(),
                    next_token: FIRST_CONN_TOKEN,
                };
                el.run();
            })
            .expect("spawn http event loop");
        Ok(HttpServer {
            addr: local,
            state,
            waker,
            event_loop: Some(event_loop),
            workers,
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block the calling thread until shutdown — the CLI's
    /// serve-until-killed mode.
    pub fn serve_forever(mut self) {
        if let Some(h) = self.event_loop.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    /// Stop accepting, cancel in-flight sessions, and shut the inner
    /// [`Server`] down, returning its aggregate stats.
    pub fn shutdown(mut self) -> Result<ServeStats, RuntimeError> {
        self.state.running.store(false, Ordering::Release);
        self.waker.wake();
        if let Some(h) = self.event_loop.take() {
            let _ = h.join();
        }
        // Take the server *before* the cancel sweep: workers that
        // race this point see `None` (503) and cannot submit past the
        // sweep; a worker that already submitted either lands in the
        // registry before the sweep (cancelled here) or observes
        // `running == false` right after registering and cancels
        // itself (see `run_generate`).
        let server = self.state.lock_server().take();
        for (_, cancel) in self.state.lock_sessions().drain() {
            cancel.cancel();
        }
        // The loop's teardown dropped the work sender, so workers
        // exit once their current session terminates.
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        match server {
            Some(s) => s.shutdown(),
            None => Err(RuntimeError::Router("http server already shut down".into())),
        }
    }
}

// ---------------------------------------------------------------------
// Event loop
// ---------------------------------------------------------------------

/// A generate request handed to the worker pool.
struct Work {
    conn: u64,
    body: String,
    /// Whether the response may keep the connection alive.
    reuse: bool,
}

/// Worker → loop messages. All socket writes flow through these; the
/// loop is the only thread that touches connection sockets.
enum Msg {
    /// A session was submitted for `conn`: register it so a client
    /// hang-up can cancel it.
    Started { conn: u64, session: u64 },
    /// Response bytes to queue on `conn`.
    Data { conn: u64, bytes: Vec<u8> },
    /// The worker is done with `conn`; hand it back to the loop.
    End { conn: u64, reuse: bool },
}

enum ConnState {
    /// Parsing the request head (also the idle keep-alive state).
    Head,
    /// Head parsed; waiting for the full `Content-Length` body.
    Body { head: ReqHead },
    /// A `Work` item is with the worker pool.
    Busy,
    /// Flush `wbuf`, then close.
    Drain,
}

struct Conn {
    /// `None` after the socket died but a worker still owns the
    /// connection token (the entry survives until its `Msg::End`).
    stream: Option<TcpStream>,
    fd: RawFd,
    state: ConnState,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    /// Flushed prefix of `wbuf`.
    woff: usize,
    /// Requests answered on this connection (keep-alive budget).
    served: usize,
    /// Live session to cancel if the client vanishes.
    session: Option<u64>,
    busy: bool,
    /// Client hung up while a worker was still running.
    gone: bool,
    last_read: Instant,
    last_write_progress: Instant,
    /// Currently registered interest bits.
    interest: u8,
}

/// Parsed request head.
struct ReqHead {
    method: String,
    path: String,
    query: String,
    content_length: usize,
    keep_alive: bool,
}

/// Offset just past the head terminator (`\r\n\r\n`, or the sloppy
/// bare `\n\n`), if the buffer holds a complete head.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    let crlf = buf.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4);
    let lf = buf.windows(2).position(|w| w == b"\n\n").map(|i| i + 2);
    match (crlf, lf) {
        (Some(a), Some(b)) => Some(a.min(b)),
        (a, b) => a.or(b),
    }
}

/// Parse a complete request head (request line + headers, terminator
/// included). Every rejection is a [`Problem`] with the exact status
/// the wire-contract tests pin.
fn parse_head(raw: &[u8]) -> Result<ReqHead, Problem> {
    let text = std::str::from_utf8(raw)
        .map_err(|_| Problem::new(400, "malformed-head", "Bad Request", "request head is not utf-8"))?;
    let mut lines = text.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));
    let request_line = lines.next().unwrap_or("");
    if request_line.len() > MAX_LINE {
        return Err(Problem::new(
            431,
            "line-too-large",
            "Request Header Fields Too Large",
            format!("request line exceeds {MAX_LINE} bytes"),
        ));
    }
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("").to_string();
    if method.is_empty() || !target.starts_with('/') {
        return Err(Problem::new(
            400,
            "malformed-request-line",
            "Bad Request",
            format!("malformed request line {request_line:?}"),
        ));
    }
    if !version.starts_with("HTTP/") {
        return Err(Problem::new(
            400,
            "malformed-request-line",
            "Bad Request",
            format!("missing HTTP version in {request_line:?}"),
        ));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(Problem::new(
            505,
            "http-version",
            "HTTP Version Not Supported",
            format!("{version} is not supported; use HTTP/1.1"),
        ));
    }
    let http11 = version == "HTTP/1.1";
    // Satellite fix: split the query string off before routing.
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let mut content_length: Option<usize> = None;
    let mut transfer_encoding = false;
    let mut conn_close = false;
    let mut conn_keep = false;
    let mut n_headers = 0usize;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        n_headers += 1;
        if n_headers > MAX_HEADERS {
            return Err(Problem::new(
                431,
                "too-many-headers",
                "Request Header Fields Too Large",
                format!("more than {MAX_HEADERS} headers"),
            ));
        }
        if line.len() > MAX_LINE {
            return Err(Problem::new(
                431,
                "line-too-large",
                "Request Header Fields Too Large",
                format!("header line exceeds {MAX_LINE} bytes"),
            ));
        }
        let Some((name, value)) = line.split_once(':') else {
            continue;
        };
        let name = name.trim();
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            let v = value.parse::<usize>().map_err(|_| {
                Problem::new(
                    400,
                    "invalid-content-length",
                    "Bad Request",
                    format!("Content-Length {value:?} is not a non-negative integer"),
                )
                .field("Content-Length")
            })?;
            content_length = Some(v);
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            transfer_encoding = true;
        } else if name.eq_ignore_ascii_case("connection") {
            for tok in value.split(',') {
                let tok = tok.trim();
                if tok.eq_ignore_ascii_case("close") {
                    conn_close = true;
                } else if tok.eq_ignore_ascii_case("keep-alive") {
                    conn_keep = true;
                }
            }
        }
    }
    if transfer_encoding {
        // Satellite fix: the old front-end ignored this header and
        // misread the chunked payload as an empty body + garbage.
        return Err(Problem::new(
            411,
            "length-required",
            "Length Required",
            "Transfer-Encoding is not supported; send a Content-Length body",
        )
        .field("Transfer-Encoding"));
    }
    let keep_alive = if conn_close {
        false
    } else if http11 {
        true
    } else {
        conn_keep
    };
    Ok(ReqHead {
        method,
        path,
        query,
        content_length: content_length.unwrap_or(0),
        keep_alive,
    })
}

/// Methods a known route answers to, for `Allow` headers on 405s.
fn allowed_methods(path: &str) -> Option<&'static str> {
    match path {
        "/healthz" | "/metrics" => Some("GET"),
        "/v1/generate" => Some("POST"),
        p if p.starts_with("/v1/sessions/") => Some("DELETE"),
        _ => None,
    }
}

/// What `advance` decided to do with a connection, computed with the
/// connection borrowed and executed after the borrow ends.
enum Step {
    Wait,
    Again,
    Dispatch(ReqHead, Vec<u8>),
    Reject(Problem),
}

enum EndAction {
    Nothing,
    Remove,
    Continue,
    Flush,
}

struct EventLoop {
    listener: TcpListener,
    poller: Poller,
    wake_rx: WakeReader,
    msg_rx: Receiver<Msg>,
    work_tx: Option<Sender<Work>>,
    state: Arc<HttpState>,
    cfg: HttpConfig,
    conns: HashMap<u64, Conn>,
    next_token: u64,
}

impl EventLoop {
    fn run(&mut self) {
        let mut events: Vec<PollEvent> = Vec::new();
        self.poller
            .register(self.listener.as_raw_fd(), TOKEN_LISTENER, EV_READ)
            .expect("register http listener");
        self.poller
            .register(self.wake_rx.fd(), TOKEN_WAKER, EV_READ)
            .expect("register http waker");
        while self.state.running.load(Ordering::Acquire) {
            if self.poller.wait(&mut events, Some(POLL_TICK)).is_err() {
                break;
            }
            for i in 0..events.len() {
                let ev = events[i];
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKER => self.wake_rx.drain(),
                    t => {
                        // On error/hang-up, read first: the kernel may
                        // still hold a final request before the EOF.
                        if ev.readable || ev.error {
                            self.read_ready(t);
                        }
                        if ev.writable {
                            self.flush(t);
                        }
                    }
                }
            }
            while let Ok(m) = self.msg_rx.try_recv() {
                self.apply_msg(m);
            }
            self.sweep();
        }
        self.teardown();
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if self.conns.len() >= self.cfg.max_conns {
                        // Hard connection limit: best-effort 503 (the
                        // fresh socket's send buffer is empty, so the
                        // nonblocking write virtually always lands).
                        let p = Problem::new(
                            503,
                            "overloaded",
                            "Service Unavailable",
                            format!("connection limit {} reached", self.cfg.max_conns),
                        )
                        .retry_after(1);
                        let mut s = stream;
                        let _ = s.set_nonblocking(true);
                        let _ = s.write_all(&p.response(false));
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    if self.cfg.sndbuf > 0 {
                        let _ = evloop::set_sndbuf(stream.as_raw_fd(), self.cfg.sndbuf);
                    }
                    let fd = stream.as_raw_fd();
                    let token = self.next_token;
                    self.next_token += 1;
                    if self.poller.register(fd, token, EV_READ).is_err() {
                        continue;
                    }
                    let now = Instant::now();
                    self.conns.insert(
                        token,
                        Conn {
                            stream: Some(stream),
                            fd,
                            state: ConnState::Head,
                            rbuf: Vec::new(),
                            wbuf: Vec::new(),
                            woff: 0,
                            served: 0,
                            session: None,
                            busy: false,
                            gone: false,
                            last_read: now,
                            last_write_progress: now,
                            interest: EV_READ,
                        },
                    );
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn read_ready(&mut self, token: u64) {
        let mut gone = false;
        let mut got_data = false;
        {
            let Some(c) = self.conns.get_mut(&token) else { return };
            let Some(stream) = c.stream.as_mut() else { return };
            let mut buf = [0u8; 4096];
            loop {
                match stream.read(&mut buf) {
                    Ok(0) => {
                        gone = true;
                        break;
                    }
                    Ok(n) => {
                        c.rbuf.extend_from_slice(&buf[..n]);
                        c.last_read = Instant::now();
                        got_data = true;
                        if c.rbuf.len() > RBUF_CAP {
                            // Flooding while a request is in flight
                            // (or an absurd pipeline backlog).
                            gone = true;
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        gone = true;
                        break;
                    }
                }
            }
        }
        if gone {
            self.hang_up(token);
            return;
        }
        if got_data {
            self.advance(token);
        }
    }

    /// Drive the per-connection state machine as far as the buffered
    /// bytes allow — possibly several pipelined requests.
    fn advance(&mut self, token: u64) {
        loop {
            let step = {
                let Some(c) = self.conns.get_mut(&token) else { return };
                match &c.state {
                    ConnState::Busy | ConnState::Drain => Step::Wait,
                    ConnState::Head => match find_head_end(&c.rbuf) {
                        None => {
                            if c.rbuf.len() > MAX_HEAD {
                                Step::Reject(Problem::new(
                                    431,
                                    "head-too-large",
                                    "Request Header Fields Too Large",
                                    format!("request head exceeds {MAX_HEAD} bytes"),
                                ))
                            } else {
                                Step::Wait
                            }
                        }
                        Some(end) => match parse_head(&c.rbuf[..end]) {
                            Err(p) => Step::Reject(p),
                            Ok(head) => {
                                c.rbuf.drain(..end);
                                if head.content_length > MAX_BODY {
                                    Step::Reject(Problem::new(
                                        413,
                                        "body-too-large",
                                        "Content Too Large",
                                        format!(
                                            "body of {} bytes exceeds cap {MAX_BODY}",
                                            head.content_length
                                        ),
                                    ))
                                } else {
                                    c.state = ConnState::Body { head };
                                    Step::Again
                                }
                            }
                        },
                    },
                    ConnState::Body { head } => {
                        let need = head.content_length;
                        if c.rbuf.len() < need {
                            Step::Wait
                        } else {
                            let ConnState::Body { head } =
                                std::mem::replace(&mut c.state, ConnState::Head)
                            else {
                                unreachable!("state checked above")
                            };
                            let body: Vec<u8> = c.rbuf.drain(..need).collect();
                            Step::Dispatch(head, body)
                        }
                    }
                }
            };
            match step {
                Step::Wait => return,
                Step::Again => continue,
                Step::Reject(p) => {
                    // Framing may be corrupt past a head-level error:
                    // always close after the problem body.
                    self.problem_close(token, p);
                    return;
                }
                Step::Dispatch(head, body) => {
                    self.dispatch(token, head, body);
                    // Keep going only if the response was inline and
                    // the connection stays in keep-alive (pipelining).
                    match self.conns.get(&token) {
                        Some(c) if matches!(c.state, ConnState::Head) && !c.busy => {}
                        _ => return,
                    }
                }
            }
        }
    }

    /// Route one complete request. Cheap routes answer inline on the
    /// loop thread; `/v1/generate` ships to the worker pool.
    fn dispatch(&mut self, token: u64, head: ReqHead, body: Vec<u8>) {
        let reuse = {
            let Some(c) = self.conns.get(&token) else { return };
            head.keep_alive
                && self.cfg.keep_alive_requests > 0
                && c.served + 1 < self.cfg.keep_alive_requests
        };
        match (head.method.as_str(), head.path.as_str()) {
            ("GET", "/healthz") => {
                let body = Json::obj(vec![
                    ("status", Json::str("ok")),
                    (
                        "uptime_secs",
                        Json::num(self.state.started.elapsed().as_secs_f64()),
                    ),
                ])
                .to_string();
                self.respond(token, 200, "application/json", &body, reuse);
            }
            ("GET", "/metrics") => {
                let stats = self.state.lock_server().as_ref().map(|s| s.stats());
                match stats {
                    None => self.respond_problem(
                        token,
                        Problem::new(503, "shutting-down", "Service Unavailable", "server is shutting down"),
                        false,
                    ),
                    Some(st) => {
                        if head.query.split('&').any(|kv| kv == "format=json") {
                            let body = stats_to_json(&st).to_string();
                            self.respond(token, 200, "application/json", &body, reuse);
                        } else {
                            let body = st.table("serve metrics").render();
                            self.respond(token, 200, "text/plain; charset=utf-8", &body, reuse);
                        }
                    }
                }
            }
            ("POST", "/v1/generate") => match String::from_utf8(body) {
                Err(_) => self.respond_problem(
                    token,
                    Problem::new(400, "invalid-body", "Bad Request", "request body is not valid utf-8"),
                    reuse,
                ),
                Ok(text) => {
                    {
                        let Some(c) = self.conns.get_mut(&token) else { return };
                        c.busy = true;
                        c.state = ConnState::Busy;
                    }
                    let sent = match &self.work_tx {
                        Some(tx) => tx
                            .send(Work {
                                conn: token,
                                body: text,
                                reuse,
                            })
                            .is_ok(),
                        None => false,
                    };
                    if !sent {
                        // Worker pool is gone (shutdown race).
                        if let Some(c) = self.conns.get_mut(&token) {
                            c.busy = false;
                            c.state = ConnState::Head;
                        }
                        self.respond_problem(
                            token,
                            Problem::new(503, "shutting-down", "Service Unavailable", "server is shutting down"),
                            false,
                        );
                    }
                }
            },
            ("DELETE", p) if p.starts_with("/v1/sessions/") => {
                let id_str = &p["/v1/sessions/".len()..];
                match id_str.parse::<u64>() {
                    Err(_) => self.respond_problem(
                        token,
                        Problem::new(
                            400,
                            "bad-session-id",
                            "Bad Request",
                            format!("session id {id_str:?} is not an unsigned integer"),
                        ),
                        reuse,
                    ),
                    Ok(id) => {
                        let handle = self.state.lock_sessions().get(&id).cloned();
                        match handle {
                            Some(cancel) => {
                                cancel.cancel();
                                let body = Json::obj(vec![
                                    ("id", Json::from_usize(id as usize)),
                                    ("cancelled", Json::Bool(true)),
                                ])
                                .to_string();
                                self.respond(token, 200, "application/json", &body, reuse);
                            }
                            None => self.respond_problem(
                                token,
                                Problem::new(
                                    404,
                                    "unknown-session",
                                    "Not Found",
                                    format!("session {id} is unknown or already finished"),
                                ),
                                reuse,
                            ),
                        }
                    }
                }
            }
            (m, p) => {
                if let Some(allow) = allowed_methods(p) {
                    // Satellite fix: methods are case-sensitive (RFC
                    // 9110 §9.1) — `get` is a 405 with `Allow`, never
                    // a silent alias of `GET`.
                    self.respond_problem(
                        token,
                        Problem::new(
                            405,
                            "method-not-allowed",
                            "Method Not Allowed",
                            format!("method {m:?} is not allowed for {p} (methods are case-sensitive)"),
                        )
                        .allow(allow),
                        reuse,
                    );
                } else {
                    self.respond_problem(
                        token,
                        Problem::new(404, "not-found", "Not Found", format!("no route for {p}")),
                        reuse,
                    );
                }
            }
        }
    }

    /// Queue one inline response and account the keep-alive budget.
    fn respond(&mut self, token: u64, status: u16, ctype: &str, body: &str, reuse: bool) {
        let bytes = response_bytes(status, ctype, "", body, reuse);
        self.queue_inline(token, bytes, reuse);
    }

    fn respond_problem(&mut self, token: u64, p: Problem, reuse: bool) {
        let bytes = p.response(reuse);
        self.queue_inline(token, bytes, reuse);
    }

    /// A head-level protocol error: problem body, then drain + close
    /// (the connection's framing cannot be trusted afterwards).
    fn problem_close(&mut self, token: u64, p: Problem) {
        let bytes = p.response(false);
        {
            let Some(c) = self.conns.get_mut(&token) else { return };
            c.wbuf.extend_from_slice(&bytes);
            c.state = ConnState::Drain;
        }
        self.flush(token);
    }

    fn queue_inline(&mut self, token: u64, bytes: Vec<u8>, reuse: bool) {
        {
            let Some(c) = self.conns.get_mut(&token) else { return };
            c.wbuf.extend_from_slice(&bytes);
            c.served += 1;
            if reuse {
                c.state = ConnState::Head;
                c.last_read = Instant::now();
            } else {
                c.state = ConnState::Drain;
            }
        }
        self.flush(token);
    }

    /// Write as much of `wbuf` as the socket accepts; close when a
    /// draining connection finishes, re-arm `EV_WRITE` otherwise.
    fn flush(&mut self, token: u64) {
        let mut gone = false;
        {
            let Some(c) = self.conns.get_mut(&token) else { return };
            if let Some(stream) = c.stream.as_mut() {
                let mut progressed = false;
                while c.woff < c.wbuf.len() {
                    match stream.write(&c.wbuf[c.woff..]) {
                        Ok(0) => {
                            gone = true;
                            break;
                        }
                        Ok(n) => {
                            c.woff += n;
                            progressed = true;
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                        Err(_) => {
                            gone = true;
                            break;
                        }
                    }
                }
                if progressed {
                    c.last_write_progress = Instant::now();
                }
                if c.woff >= c.wbuf.len() {
                    c.wbuf.clear();
                    c.woff = 0;
                }
            }
        }
        if gone {
            self.hang_up(token);
            return;
        }
        let close_now = match self.conns.get(&token) {
            Some(c) => matches!(c.state, ConnState::Drain) && c.wbuf.is_empty() && !c.busy,
            None => false,
        };
        if close_now {
            self.close_conn(token);
            return;
        }
        self.update_interest(token);
    }

    fn update_interest(&mut self, token: u64) {
        let target = {
            let Some(c) = self.conns.get_mut(&token) else { return };
            if c.stream.is_none() {
                return;
            }
            let want = if c.wbuf.len() > c.woff {
                EV_READ | EV_WRITE
            } else {
                EV_READ
            };
            if want == c.interest {
                return;
            }
            c.interest = want;
            (c.fd, want)
        };
        let _ = self.poller.modify(target.0, token, target.1);
    }

    /// The client vanished (EOF, reset, flood, or budget kill): close
    /// the socket, cancel any live session, and — if a worker still
    /// owns the token — keep a `gone` tombstone until its `Msg::End`.
    fn hang_up(&mut self, token: u64) {
        let (fd, had_stream, busy, sid) = {
            let Some(c) = self.conns.get_mut(&token) else { return };
            let had = c.stream.take().is_some();
            c.gone = true;
            c.wbuf.clear();
            c.woff = 0;
            (c.fd, had, c.busy, c.session.take())
        };
        if had_stream {
            let _ = self.poller.deregister(fd, token);
        }
        if let Some(sid) = sid {
            cancel_session(&self.state, sid);
        }
        if !busy {
            self.conns.remove(&token);
        }
    }

    /// Orderly close of an idle/drained connection.
    fn close_conn(&mut self, token: u64) {
        if let Some(c) = self.conns.remove(&token) {
            if let Some(sid) = c.session {
                cancel_session(&self.state, sid);
            }
            if c.stream.is_some() {
                let _ = self.poller.deregister(c.fd, token);
            }
        }
    }

    fn apply_msg(&mut self, msg: Msg) {
        match msg {
            Msg::Started { conn, session } => match self.conns.get_mut(&conn) {
                Some(c) if !c.gone => c.session = Some(session),
                // The client vanished before the submit landed:
                // cancel right away so the router stops decoding.
                _ => cancel_session(&self.state, session),
            },
            Msg::Data { conn, bytes } => {
                let queued = match self.conns.get_mut(&conn) {
                    Some(c) if !c.gone && c.stream.is_some() => {
                        c.wbuf.extend_from_slice(&bytes);
                        true
                    }
                    _ => false,
                };
                if queued {
                    self.flush(conn);
                }
            }
            Msg::End { conn, reuse } => {
                let action = match self.conns.get_mut(&conn) {
                    None => EndAction::Nothing,
                    Some(c) => {
                        c.busy = false;
                        c.session = None;
                        c.served += 1;
                        if c.gone {
                            EndAction::Remove
                        } else if reuse {
                            c.state = ConnState::Head;
                            c.last_read = Instant::now();
                            EndAction::Continue
                        } else {
                            c.state = ConnState::Drain;
                            EndAction::Flush
                        }
                    }
                };
                match action {
                    EndAction::Nothing => {}
                    EndAction::Remove => {
                        self.conns.remove(&conn);
                    }
                    EndAction::Continue => {
                        self.flush(conn);
                        // A pipelined next request may already be
                        // buffered.
                        self.advance(conn);
                    }
                    EndAction::Flush => self.flush(conn),
                }
            }
        }
    }

    /// Periodic policy sweep: idle timeouts, write budgets, write
    /// stalls. Runs every poll tick (~[`POLL_TICK`]).
    fn sweep(&mut self) {
        let now = Instant::now();
        let mut kills: Vec<u64> = Vec::new();
        let mut timeouts: Vec<(u64, bool)> = Vec::new();
        for (&t, c) in self.conns.iter() {
            if c.stream.is_none() {
                continue;
            }
            let buffered = c.wbuf.len() - c.woff;
            if buffered > 0
                && (buffered > self.cfg.write_budget
                    || now.duration_since(c.last_write_progress) > self.cfg.write_stall)
            {
                // Slow-client policy: a stalled reader loses its
                // connection and its session, not our memory.
                kills.push(t);
                continue;
            }
            match &c.state {
                ConnState::Head | ConnState::Body { .. } => {
                    if now.duration_since(c.last_read) > self.cfg.idle_timeout {
                        let mid_request =
                            !c.rbuf.is_empty() || matches!(c.state, ConnState::Body { .. });
                        timeouts.push((t, mid_request));
                    }
                }
                _ => {}
            }
        }
        for t in kills {
            self.hang_up(t);
        }
        for (t, mid_request) in timeouts {
            if mid_request {
                self.problem_close(
                    t,
                    Problem::new(
                        408,
                        "request-timeout",
                        "Request Timeout",
                        "client sent a partial request and went idle",
                    ),
                );
            } else {
                // Idle keep-alive connection: close silently.
                self.close_conn(t);
            }
        }
    }

    /// Loop exit: cancel every live session, drop every socket, and
    /// disconnect the worker pool (workers exit once their session
    /// terminates and the work channel is empty).
    fn teardown(&mut self) {
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            if let Some(c) = self.conns.remove(&t) {
                if let Some(sid) = c.session {
                    cancel_session(&self.state, sid);
                }
                if c.stream.is_some() {
                    let _ = self.poller.deregister(c.fd, t);
                }
            }
        }
        self.work_tx.take();
    }
}

/// The [`ServeStats`] snapshot as a flat JSON object
/// (`GET /metrics?format=json`).
fn stats_to_json(s: &ServeStats) -> Json {
    Json::obj(vec![
        ("requests", Json::from_usize(s.requests)),
        ("batches", Json::from_usize(s.batches)),
        ("generated_tokens", Json::from_usize(s.generated_tokens)),
        ("rejected", Json::from_usize(s.rejected)),
        ("evicted", Json::from_usize(s.evicted)),
        ("deadline_evicted", Json::from_usize(s.deadline_evicted)),
        ("cancelled", Json::from_usize(s.cancelled)),
        ("dropped_clients", Json::from_usize(s.dropped_clients)),
        ("ttft_ms_total", Json::num(s.ttft_ms_total)),
        ("ttft_samples", Json::from_usize(s.ttft_samples)),
        ("prefix_hits", Json::from_usize(s.prefix_hits)),
        ("prefix_misses", Json::from_usize(s.prefix_misses)),
        ("cow_splits", Json::from_usize(s.cow_splits)),
        ("page_evictions", Json::from_usize(s.page_evictions)),
        ("kv_pages", Json::from_usize(s.kv_pages)),
        ("kv_pages_peak", Json::from_usize(s.kv_pages_peak)),
        ("spec_rounds", Json::from_usize(s.spec_rounds)),
        ("spec_drafted", Json::from_usize(s.spec_drafted)),
        ("spec_accepted", Json::from_usize(s.spec_accepted)),
        ("spec_rollbacks", Json::from_usize(s.spec_rollbacks)),
        ("wall_secs", Json::num(s.wall_secs)),
    ])
}

// ---------------------------------------------------------------------
// Worker pool
// ---------------------------------------------------------------------

/// Worker-side handle for one connection: every byte and lifecycle
/// event goes through the loop's message channel + waker.
struct Outbox<'a> {
    conn: u64,
    tx: &'a Sender<Msg>,
    waker: &'a Waker,
}

impl Outbox<'_> {
    fn started(&self, session: u64) {
        if self
            .tx
            .send(Msg::Started {
                conn: self.conn,
                session,
            })
            .is_ok()
        {
            self.waker.wake();
        }
    }

    fn data(&self, bytes: Vec<u8>) {
        if self
            .tx
            .send(Msg::Data {
                conn: self.conn,
                bytes,
            })
            .is_ok()
        {
            self.waker.wake();
        }
    }

    fn end(&self, reuse: bool) {
        if self
            .tx
            .send(Msg::End {
                conn: self.conn,
                reuse,
            })
            .is_ok()
        {
            self.waker.wake();
        }
    }
}

/// Worker thread: pull one [`Work`] at a time (the `Mutex<Receiver>`
/// hand-off is released while the request runs) until the loop drops
/// the sender.
fn worker_loop(
    rx: &Arc<Mutex<Receiver<Work>>>,
    tx: &Sender<Msg>,
    waker: &Waker,
    state: &Arc<HttpState>,
) {
    loop {
        let work = {
            let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
            guard.recv()
        };
        let Ok(work) = work else { return };
        run_generate(work, tx, waker, state);
    }
}

/// Parsed `POST /v1/generate` body.
struct GenerateBody {
    req: Request,
    stream: bool,
}

/// Extract the request with the lazy path scanner — one validating
/// skip-scan, then per-field raw-slice reads; no value tree.
fn parse_generate(body: &str) -> Result<GenerateBody, Problem> {
    fn bad(detail: String) -> Problem {
        Problem::new(400, "invalid-request", "Bad Request", detail)
    }
    let lazy = LazyJson::parse(body).map_err(|e| bad(format!("bad json: {e}")))?;
    let prompt_raw = lazy
        .path(&["prompt"])
        .ok_or_else(|| bad("missing 'prompt'".into()).field("prompt"))?;
    let prompt64 = prompt_raw
        .int_array()
        .map_err(|_| bad("'prompt' must be an array of integer token ids".into()).field("prompt"))?;
    let mut prompt = Vec::with_capacity(prompt64.len());
    for t in prompt64 {
        let tok = i32::try_from(t)
            .map_err(|_| bad(format!("prompt token {t} is out of i32 range")).field("prompt"))?;
        prompt.push(tok);
    }
    let max_new = match lazy.path(&["max_new"]) {
        None => 16,
        Some(raw) if raw.is_null() => 16,
        Some(raw) => raw
            .as_usize()
            .ok_or_else(|| bad("'max_new' must be a non-negative integer".into()).field("max_new"))?,
    };
    let stream = match lazy.path(&["stream"]) {
        None => false,
        Some(raw) if raw.is_null() => false,
        Some(raw) => raw
            .as_bool()
            .ok_or_else(|| bad("'stream' must be a boolean".into()).field("stream"))?,
    };
    let deadline = match lazy.path(&["deadline_ms"]) {
        None => None,
        Some(raw) if raw.is_null() => None,
        Some(raw) => {
            let ms = raw
                .as_f64()
                .filter(|ms| *ms >= 0.0)
                .ok_or_else(|| {
                    bad("'deadline_ms' must be a non-negative number".into()).field("deadline_ms")
                })?;
            if ms == 0.0 {
                // Same convention as `--deadline-ms 0` and
                // `SchedulerConfig::deadline`: zero disables the
                // deadline (the expire-immediately form exists only
                // on the in-process `Request::deadline` API).
                None
            } else {
                // try_from: a finite-but-huge value must be a 400,
                // not a panic in a worker thread.
                let d = Duration::try_from_secs_f64(ms / 1e3)
                    .map_err(|_| bad("'deadline_ms' out of range".into()).field("deadline_ms"))?;
                Some(d)
            }
        }
    };
    Ok(GenerateBody {
        req: Request {
            prompt,
            max_new,
            deadline,
        },
        stream,
    })
}

/// One `POST /v1/generate`, end to end, on a worker thread.
fn run_generate(work: Work, tx: &Sender<Msg>, waker: &Waker, state: &Arc<HttpState>) {
    let out = Outbox {
        conn: work.conn,
        tx,
        waker,
    };
    let parsed = match parse_generate(&work.body) {
        Ok(p) => p,
        Err(p) => {
            out.data(p.response(work.reuse));
            out.end(work.reuse);
            return;
        }
    };
    // Submit while holding the server lock only for the enqueue
    // itself; the stream is consumed lock-free.
    let session = match state.lock_server().as_ref() {
        Some(server) => server.submit(parsed.req),
        None => {
            let p = Problem::new(503, "shutting-down", "Service Unavailable", "server is shutting down");
            out.data(p.response(false));
            out.end(false);
            return;
        }
    };
    let id = session.id();
    state.lock_sessions().insert(id, session.cancel_handle());
    // Shutdown race: if the cancel sweep ran between our submit and
    // this registration, the registry lock we just went through makes
    // the `running` store visible — self-cancel so no session can
    // outlive shutdown uncancelled.
    if !state.running.load(Ordering::Acquire) {
        session.cancel();
    }
    out.started(id);
    if parsed.stream {
        stream_session(&out, id, &session, state, work.reuse);
    } else {
        let r = session.collect();
        if r.rejected {
            // Satellite fix: 429s carry `Retry-After` derived from
            // the submit-gate depth.
            let retry = retry_after_hint(state);
            let p = Problem::new(
                429,
                "queue-full",
                "Too Many Requests",
                format!("admission queue is full; retry in ~{retry}s"),
            )
            .retry_after(retry)
            .with("id", Json::from_usize(id as usize));
            out.data(p.response(work.reuse));
            out.end(work.reuse);
        } else {
            let body = Json::obj(vec![
                ("id", Json::from_usize(id as usize)),
                ("tokens", Json::arr(r.tokens.iter().map(|&t| Json::num(t)))),
                ("queue_ms", Json::num(r.queue_ms)),
                ("latency_ms", Json::num(r.latency_ms)),
                ("ttft_ms", Json::num(r.ttft_ms)),
                ("rejected", Json::Bool(r.rejected)),
                ("evicted", Json::Bool(r.evicted)),
                ("cancelled", Json::Bool(r.cancelled)),
                ("incomplete", Json::Bool(r.incomplete)),
            ])
            .to_string();
            if r.incomplete {
                // The router died mid-session; the tokens are
                // truncated. Close the connection after.
                out.data(response_bytes(500, "application/json", "", &body, false));
                out.end(false);
            } else {
                out.data(response_bytes(200, "application/json", "", &body, work.reuse));
                out.end(work.reuse);
            }
        }
    }
    state.lock_sessions().remove(&id);
}

/// SSE-style chunked token streaming: one `data: {...}\n\n` frame per
/// event, opening with `{"id": n}` so the client can `DELETE` the
/// session mid-stream.
fn stream_session(
    out: &Outbox<'_>,
    id: u64,
    session: &super::serve::Session,
    state: &Arc<HttpState>,
    reuse: bool,
) {
    // Gate rejections are synchronous in `Server::submit`, so an
    // upfront `Rejected` is already in the channel: answer a plain
    // 429 + `Retry-After` instead of opening an SSE stream.
    let mut first = session.try_recv();
    if matches!(first, Some(Event::Rejected)) {
        let retry = retry_after_hint(state);
        let p = Problem::new(
            429,
            "queue-full",
            "Too Many Requests",
            format!("admission queue is full; retry in ~{retry}s"),
        )
        .retry_after(retry)
        .with("id", Json::from_usize(id as usize));
        out.data(p.response(reuse));
        out.end(reuse);
        return;
    }
    let header = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nTransfer-Encoding: chunked\r\nCache-Control: no-store\r\nConnection: {}\r\n\r\n",
        if reuse { "keep-alive" } else { "close" }
    );
    out.data(header.into_bytes());
    out.data(frame_bytes(&Json::obj(vec![(
        "id",
        Json::from_usize(id as usize),
    )])));
    let mut saw_terminal = false;
    loop {
        let ev = match first.take() {
            Some(ev) => Some(ev),
            None => session.recv(),
        };
        let Some(ev) = ev else { break };
        let (frame, terminal) = match ev {
            Event::Token(t) => (Json::obj(vec![("token", Json::num(t))]), false),
            Event::Done(s) => (Json::obj(vec![("done", stats_json(&s))]), true),
            Event::Evicted(s) => (Json::obj(vec![("evicted", stats_json(&s))]), true),
            Event::Rejected => {
                // Late scheduler-level rejection: the stream is
                // already open, so the retry hint rides in the frame.
                let retry = retry_after_hint(state);
                (
                    Json::obj(vec![
                        ("rejected", Json::Bool(true)),
                        ("retry_after_secs", Json::from_usize(retry as usize)),
                    ]),
                    true,
                )
            }
        };
        out.data(frame_bytes(&frame));
        if terminal {
            saw_terminal = true;
            break;
        }
    }
    if !saw_terminal {
        // The event stream closed with no terminal: the router died
        // mid-session. Tell the client explicitly — a truncated token
        // stream must not read as a completed one.
        out.data(frame_bytes(&Json::obj(vec![("aborted", Json::Bool(true))])));
    }
    // Terminal chunk.
    out.data(b"0\r\n\r\n".to_vec());
    // A healthy terminal keeps the connection; an aborted stream
    // closes it (the client cannot trust our framing after that).
    out.end(reuse && saw_terminal);
}

fn stats_json(s: &SessionStats) -> Json {
    Json::obj(vec![
        ("tokens", Json::from_usize(s.tokens)),
        ("queue_ms", Json::num(s.queue_ms)),
        ("latency_ms", Json::num(s.latency_ms)),
        ("ttft_ms", Json::num(s.ttft_ms)),
        ("cancelled", Json::Bool(s.cancelled)),
    ])
}

/// One SSE frame as one HTTP chunk.
fn frame_bytes(payload: &Json) -> Vec<u8> {
    let data = format!("data: {payload}\n\n");
    format!("{:x}\r\n{data}\r\n", data.len()).into_bytes()
}

// ---------------------------------------------------------------------
// Loopback client (benches / integration tests / examples)
// ---------------------------------------------------------------------

/// Minimal blocking HTTP client for the loopback surface above — just
/// enough protocol for the benches and integration tests to drive
/// `slab serve --http` over a real socket without external crates.
/// One-shot helpers ([`get`]/[`post`]/[`delete`]) send
/// `Connection: close`; [`HttpConn`] keeps one connection alive
/// across requests (and can pipeline them).
pub mod client {
    use super::super::serve::Response;
    use crate::util::json::Json;
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::time::Duration;

    /// A completed (non-streaming) HTTP exchange.
    pub struct HttpReply {
        pub status: u16,
        pub body: String,
        /// Response headers, in wire order, names lower-cased.
        pub headers: Vec<(String, String)>,
    }

    impl HttpReply {
        /// Case-insensitive header lookup.
        pub fn header(&self, name: &str) -> Option<&str> {
            self.headers
                .iter()
                .find(|(n, _)| n.eq_ignore_ascii_case(name))
                .map(|(_, v)| v.as_str())
        }
    }

    /// Status line + parsed headers of one response.
    struct ReplyHead {
        status: u16,
        chunked: bool,
        content_length: Option<usize>,
        headers: Vec<(String, String)>,
    }

    fn connect(addr: SocketAddr) -> std::io::Result<TcpStream> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(stream)
    }

    fn read_head(reader: &mut BufReader<TcpStream>) -> std::io::Result<ReplyHead> {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed before a status line",
            ));
        }
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        let mut chunked = false;
        let mut content_length = None;
        let mut headers = Vec::new();
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h)? == 0 {
                break;
            }
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((name, value)) = h.split_once(':') {
                let name = name.trim().to_ascii_lowercase();
                let value = value.trim();
                if name == "transfer-encoding" && value.eq_ignore_ascii_case("chunked") {
                    chunked = true;
                }
                if name == "content-length" {
                    content_length = value.parse().ok();
                }
                headers.push((name, value.to_string()));
            }
        }
        Ok(ReplyHead {
            status,
            chunked,
            content_length,
            headers,
        })
    }

    /// Read one full response (headers + de-chunked or sized body).
    /// Framing-aware, so it works on keep-alive connections.
    fn read_reply(reader: &mut BufReader<TcpStream>) -> std::io::Result<HttpReply> {
        let head = read_head(reader)?;
        let body = if head.chunked {
            let mut out = String::new();
            while let Some(chunk) = read_chunk(reader)? {
                out.push_str(&chunk);
            }
            out
        } else if let Some(n) = head.content_length {
            let mut buf = vec![0u8; n];
            reader.read_exact(&mut buf)?;
            String::from_utf8_lossy(&buf).into_owned()
        } else {
            // No framing: read to EOF (close-delimited body).
            let mut out = String::new();
            reader.read_to_string(&mut out)?;
            out
        };
        Ok(HttpReply {
            status: head.status,
            body,
            headers: head.headers,
        })
    }

    /// One chunk of a chunked response body; `None` at the terminal
    /// zero-length chunk. A malformed or missing size line (server
    /// died mid-stream, truncated read) is an **error**, never
    /// mistaken for the clean terminal chunk.
    fn read_chunk(reader: &mut BufReader<TcpStream>) -> std::io::Result<Option<String>> {
        let mut size_line = String::new();
        reader.read_line(&mut size_line)?;
        let trimmed = size_line.trim();
        let size = usize::from_str_radix(trimmed, 16).map_err(|_| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad chunk size line {trimmed:?} (stream truncated?)"),
            )
        })?;
        if size == 0 {
            // Consume the trailing CRLF after the terminal chunk so a
            // keep-alive connection is left correctly framed.
            let mut crlf = String::new();
            let _ = reader.read_line(&mut crlf);
            return Ok(None);
        }
        let mut payload = vec![0u8; size];
        reader.read_exact(&mut payload)?;
        let mut crlf = [0u8; 2];
        reader.read_exact(&mut crlf)?;
        Ok(Some(String::from_utf8_lossy(&payload).into_owned()))
    }

    /// Serialize one request (line + headers + body) — the single
    /// place the client-side wire framing lives.
    fn write_request(
        stream: &mut TcpStream,
        method: &str,
        path: &str,
        body: &str,
        close: bool,
    ) -> std::io::Result<()> {
        let conn = if close { "close" } else { "keep-alive" };
        write!(
            stream,
            "{method} {path} HTTP/1.1\r\nHost: slab\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {conn}\r\n\r\n{body}",
            body.len()
        )?;
        stream.flush()
    }

    /// Send `method path` with an optional JSON body on a fresh
    /// one-shot (`Connection: close`) connection; return the
    /// fully-read reply.
    pub fn request(
        addr: SocketAddr,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> std::io::Result<HttpReply> {
        let mut stream = connect(addr)?;
        write_request(&mut stream, method, path, body.unwrap_or(""), true)?;
        let mut reader = BufReader::new(stream);
        read_reply(&mut reader)
    }

    pub fn get(addr: SocketAddr, path: &str) -> std::io::Result<HttpReply> {
        request(addr, "GET", path, None)
    }

    pub fn post(addr: SocketAddr, path: &str, body: &str) -> std::io::Result<HttpReply> {
        request(addr, "POST", path, Some(body))
    }

    pub fn delete(addr: SocketAddr, path: &str) -> std::io::Result<HttpReply> {
        request(addr, "DELETE", path, None)
    }

    /// A keep-alive client connection: issue many requests over one
    /// socket, or pipeline them ([`send`](HttpConn::send) several,
    /// then [`read_reply`](HttpConn::read_reply) each in order).
    pub struct HttpConn {
        writer: TcpStream,
        reader: BufReader<TcpStream>,
    }

    impl HttpConn {
        pub fn connect(addr: SocketAddr) -> std::io::Result<HttpConn> {
            let stream = connect(addr)?;
            let writer = stream.try_clone()?;
            Ok(HttpConn {
                writer,
                reader: BufReader::new(stream),
            })
        }

        /// Fire a request without waiting for the reply (pipelining).
        pub fn send(&mut self, method: &str, path: &str, body: Option<&str>) -> std::io::Result<()> {
            write_request(&mut self.writer, method, path, body.unwrap_or(""), false)
        }

        /// Read the next in-order reply off the connection.
        pub fn read_reply(&mut self) -> std::io::Result<HttpReply> {
            read_reply(&mut self.reader)
        }

        /// Blocking request/reply round trip on this connection.
        pub fn request(
            &mut self,
            method: &str,
            path: &str,
            body: Option<&str>,
        ) -> std::io::Result<HttpReply> {
            self.send(method, path, body)?;
            self.read_reply()
        }
    }

    /// An open SSE token stream (a `POST /v1/generate` with
    /// `"stream": true`): read frames one at a time, cancel from
    /// another connection, keep reading — exactly what an interactive
    /// client does.
    pub struct SseStream {
        reader: BufReader<TcpStream>,
        pub status: u16,
        /// Response headers (lower-cased names).
        pub headers: Vec<(String, String)>,
        chunked: bool,
        content_length: Option<usize>,
    }

    impl SseStream {
        pub fn open(addr: SocketAddr, body: &str) -> std::io::Result<SseStream> {
            let mut stream = connect(addr)?;
            write_request(&mut stream, "POST", "/v1/generate", body, true)?;
            let mut reader = BufReader::new(stream);
            let head = read_head(&mut reader)?;
            Ok(SseStream {
                reader,
                status: head.status,
                headers: head.headers,
                chunked: head.chunked,
                content_length: head.content_length,
            })
        }

        /// Case-insensitive header lookup.
        pub fn header(&self, name: &str) -> Option<&str> {
            self.headers
                .iter()
                .find(|(n, _)| n.eq_ignore_ascii_case(name))
                .map(|(_, v)| v.as_str())
        }

        /// Next `data:` frame parsed as JSON; `None` once the stream
        /// is over. Errors if the reply was not a stream (e.g. a 429
        /// problem body — use [`read_body`](SseStream::read_body)).
        pub fn next_frame(&mut self) -> std::io::Result<Option<Json>> {
            if !self.chunked {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("reply {} is not a stream", self.status),
                ));
            }
            let Some(chunk) = read_chunk(&mut self.reader)? else {
                return Ok(None);
            };
            let payload = chunk
                .trim_start_matches("data: ")
                .trim_end_matches('\n')
                .to_string();
            let v = Json::parse(&payload).map_err(|e| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad sse frame {payload:?}: {e}"),
                )
            })?;
            Ok(Some(v))
        }

        /// The plain (non-chunked) body of a rejected open — a 429
        /// problem body, for instance.
        pub fn read_body(&mut self) -> std::io::Result<String> {
            let n = self.content_length.unwrap_or(0);
            let mut buf = vec![0u8; n];
            self.reader.read_exact(&mut buf)?;
            Ok(String::from_utf8_lossy(&buf).into_owned())
        }
    }

    /// Parse a non-streaming `POST /v1/generate` reply body into the
    /// blocking [`Response`] shape (token-identity checks in tests).
    pub fn parse_generate_reply(body: &str) -> Option<(u64, Response)> {
        let v = Json::parse(body).ok()?;
        let id = v.get("id").as_i64()? as u64;
        let tokens = v
            .get("tokens")
            .as_arr()?
            .iter()
            .map(|t| t.as_i64().map(|x| x as i32))
            .collect::<Option<Vec<i32>>>()?;
        Some((
            id,
            Response {
                tokens,
                queue_ms: v.get("queue_ms").as_f64().unwrap_or(0.0),
                latency_ms: v.get("latency_ms").as_f64().unwrap_or(0.0),
                ttft_ms: v.get("ttft_ms").as_f64().unwrap_or(0.0),
                rejected: v.get("rejected").as_bool().unwrap_or(false),
                evicted: v.get("evicted").as_bool().unwrap_or(false),
                cancelled: v.get("cancelled").as_bool().unwrap_or(false),
                incomplete: v.get("incomplete").as_bool().unwrap_or(false),
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    //! Loopback unit tests: every route over a real socket, native
    //! engine, no artifacts — they run on every `cargo test`. The
    //! wire-contract corpus (raw-socket malformed requests, slow
    //! clients, the 256-stream soak) lives in
    //! `tests/http_integration.rs`.

    use super::client;
    use super::*;
    use crate::coordinator::serve::test_support::eos_free_params;
    use crate::coordinator::serve::{Backend, SchedulerConfig, ServerConfig};
    use crate::model::{Params, SlabModel};
    use crate::runtime::ModelCfg;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg::llama("tiny-http", 32, 8, 1, 2, 16, 12, 4)
    }

    fn spin_with(cfg: &ModelCfg, seed: u64, scfg: ServerConfig, hcfg: HttpConfig) -> HttpServer {
        let model = SlabModel::from_dense(&Params::init(cfg, seed), 1);
        let server = Server::start_with(Backend::NativeBatched(Box::new(model)), scfg);
        HttpServer::bind_with("127.0.0.1:0", server, hcfg).expect("bind loopback")
    }

    fn spin(cfg: &ModelCfg, seed: u64, scfg: ServerConfig) -> HttpServer {
        spin_with(cfg, seed, scfg, HttpConfig::default())
    }

    #[test]
    fn healthz_metrics_and_unknown_routes() {
        let http = spin(&tiny_cfg(), 81, ServerConfig::default());
        let addr = http.addr();
        let ok = client::get(addr, "/healthz").expect("healthz");
        assert_eq!(ok.status, 200);
        assert!(ok.body.contains("\"status\":\"ok\""), "{}", ok.body);
        let metrics = client::get(addr, "/metrics").expect("metrics");
        assert_eq!(metrics.status, 200);
        assert!(metrics.body.contains("requests"), "{}", metrics.body);
        assert!(metrics.body.contains("mean_ttft_ms"), "{}", metrics.body);
        assert!(metrics.body.contains("prefix_hit_rate"), "{}", metrics.body);
        assert!(metrics.body.contains("kv_pages"), "{}", metrics.body);
        assert!(metrics.body.contains("spec_acceptance_rate"), "{}", metrics.body);
        let missing = client::get(addr, "/nope").expect("404");
        assert_eq!(missing.status, 404);
        assert!(missing.body.contains("urn:slab:problem:not-found"), "{}", missing.body);
        let wrong_method = client::get(addr, "/v1/generate").expect("405");
        assert_eq!(wrong_method.status, 405);
        let bad_delete = client::delete(addr, "/v1/sessions/not-a-number").expect("400");
        assert_eq!(bad_delete.status, 400);
        let unknown_session = client::delete(addr, "/v1/sessions/999").expect("404");
        assert_eq!(unknown_session.status, 404);
        http.shutdown().expect("shutdown");
    }

    #[test]
    fn query_strings_allow_headers_and_problem_bodies() {
        let http = spin(&tiny_cfg(), 85, ServerConfig::default());
        let addr = http.addr();
        // Satellite fix: the query string is stripped before routing.
        let probed = client::get(addr, "/healthz?probe=1").expect("healthz with query");
        assert_eq!(probed.status, 200, "{}", probed.body);
        let json_metrics = client::get(addr, "/metrics?format=json").expect("metrics json");
        assert_eq!(json_metrics.status, 200);
        let v = Json::parse(&json_metrics.body).expect("metrics body is json");
        assert!(v.get("requests").as_usize().is_some(), "{}", json_metrics.body);
        assert!(v.get("generated_tokens").as_usize().is_some());
        // 405s carry Allow (RFC 9110 §10.2.2) and a problem body.
        let wrong = client::get(addr, "/v1/generate").expect("405");
        assert_eq!(wrong.status, 405);
        assert_eq!(wrong.header("allow"), Some("POST"));
        assert_eq!(wrong.header("content-type"), Some("application/problem+json"));
        assert!(
            wrong.body.contains("urn:slab:problem:method-not-allowed"),
            "{}",
            wrong.body
        );
        // 400s carry field-level context.
        let bad = client::post(addr, "/v1/generate", r#"{"prompt": "text"}"#).expect("400");
        assert_eq!(bad.status, 400);
        assert!(bad.body.contains("\"field\":\"prompt\""), "{}", bad.body);
        assert!(
            bad.body.contains("urn:slab:problem:invalid-request"),
            "{}",
            bad.body
        );
        http.shutdown().expect("shutdown");
    }

    #[test]
    fn generate_rejects_malformed_bodies() {
        let http = spin(&tiny_cfg(), 82, ServerConfig::default());
        let addr = http.addr();
        for bad in [
            "not json at all",
            "{}",                         // missing prompt
            r#"{"prompt": "text"}"#,      // non-array prompt
            r#"{"prompt": [1.5]}"#,       // non-integer token
            r#"{"prompt": [5000000000]}"#, // out of i32 range
            r#"{"prompt": [5], "max_new": -2}"#,
            r#"{"prompt": [5], "stream": "yes"}"#,
            r#"{"prompt": [5], "deadline_ms": -1}"#,
            // Finite but not representable as a Duration: must be a
            // 400, not a panic in a worker thread.
            r#"{"prompt": [5], "deadline_ms": 1e300}"#,
        ] {
            let reply = client::post(addr, "/v1/generate", bad).expect("reply");
            assert_eq!(reply.status, 400, "body {bad:?} → {}", reply.body);
            assert!(
                reply.body.contains("urn:slab:problem:"),
                "body {bad:?} → {}",
                reply.body
            );
        }
        // The server is still healthy afterwards.
        let ok = client::post(addr, "/v1/generate", r#"{"prompt": [5, 6], "max_new": 3}"#)
            .expect("good request");
        assert_eq!(ok.status, 200);
        let stats = http.shutdown().expect("shutdown");
        assert_eq!(stats.requests, 1, "malformed bodies never reach the engine");
    }

    #[test]
    fn streamed_tokens_equal_blocking_generate() {
        let cfg = tiny_cfg();
        let http = spin(&cfg, 83, ServerConfig::default());
        let addr = http.addr();
        let body = r#"{"prompt": [5, 6, 7], "max_new": 6}"#;
        let blocking = client::post(addr, "/v1/generate", body).expect("blocking");
        assert_eq!(blocking.status, 200);
        let (_, reply) = client::parse_generate_reply(&blocking.body).expect("parse");
        assert!(!reply.rejected);

        let stream_body = r#"{"prompt": [5, 6, 7], "max_new": 6, "stream": true}"#;
        let mut sse = client::SseStream::open(addr, stream_body).expect("open stream");
        assert_eq!(sse.status, 200);
        let first = sse.next_frame().expect("frame").expect("id frame");
        assert!(first.get("id").as_i64().is_some(), "{first:?}");
        let mut streamed = Vec::new();
        let mut saw_done = false;
        while let Some(frame) = sse.next_frame().expect("frame") {
            if let Some(tok) = frame.get("token").as_i64() {
                streamed.push(tok as i32);
            } else if !frame.get("done").is_null() {
                assert_eq!(
                    frame.get("done").get("tokens").as_usize(),
                    Some(streamed.len())
                );
                saw_done = true;
            } else {
                panic!("unexpected frame {frame:?}");
            }
        }
        assert!(saw_done, "stream must end with a done frame");
        assert_eq!(streamed, reply.tokens, "streamed vs blocking tokens");
        http.shutdown().expect("shutdown");
    }

    #[test]
    fn delete_cancels_a_live_stream() {
        // Long-budget session on a deliberately slow config (dim 64,
        // ~1k ticks to finish): read two tokens, DELETE the session,
        // and the stream must terminate early with cancelled=true.
        let cfg = ModelCfg::llama("slow-http", 32, 64, 2, 2, 128, 1024, 4);
        let params = eos_free_params(&cfg, 84);
        let model = SlabModel::from_dense(&params, 1);
        let server = Server::start_with(
            Backend::NativeBatched(Box::new(model)),
            ServerConfig {
                sched: SchedulerConfig {
                    max_batch: 1,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let http = HttpServer::bind("127.0.0.1:0", server).expect("bind");
        let addr = http.addr();
        let budget = cfg.max_seq - cfg.prompt_len;
        let body = format!(r#"{{"prompt": [5, 6], "max_new": {budget}, "stream": true}}"#);
        let mut sse = client::SseStream::open(addr, &body).expect("open");
        let id = sse
            .next_frame()
            .expect("frame")
            .expect("id frame")
            .get("id")
            .as_i64()
            .expect("id") as u64;
        let mut tokens = 0usize;
        while tokens < 2 {
            let frame = sse.next_frame().expect("frame").expect("open stream");
            if frame.get("token").as_i64().is_some() {
                tokens += 1;
            } else {
                panic!("terminal before two tokens: {frame:?}");
            }
        }
        let cancel = client::delete(addr, &format!("/v1/sessions/{id}")).expect("cancel");
        assert_eq!(cancel.status, 200);
        let mut cancelled_seen = false;
        while let Some(frame) = sse.next_frame().expect("frame") {
            if frame.get("token").as_i64().is_some() {
                tokens += 1;
            } else if !frame.get("done").is_null() {
                assert_eq!(frame.get("done").get("cancelled").as_bool(), Some(true));
                cancelled_seen = true;
            }
        }
        assert!(cancelled_seen, "terminal frame carries cancelled=true");
        assert!(
            tokens < budget,
            "cancel must stop the stream early ({tokens} of {budget})"
        );
        let stats = http.shutdown().expect("shutdown");
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.requests, 1, "the cancelled session still counts");
    }

    #[test]
    fn keep_alive_reuses_and_budgets_connections() {
        let http = spin(&tiny_cfg(), 86, ServerConfig::default());
        let addr = http.addr();
        let mut conn = client::HttpConn::connect(addr).expect("connect");
        for _ in 0..3 {
            let r = conn.request("GET", "/healthz", None).expect("keep-alive request");
            assert_eq!(r.status, 200);
            assert_eq!(r.header("connection"), Some("keep-alive"));
        }
        // Pipelining: two requests written before either reply is
        // read, answered in order on the same connection.
        conn.send("GET", "/healthz", None).expect("send 1");
        conn.send("POST", "/v1/generate", Some(r#"{"prompt": [5], "max_new": 2}"#))
            .expect("send 2");
        let r1 = conn.read_reply().expect("pipelined 1");
        let r2 = conn.read_reply().expect("pipelined 2");
        assert_eq!(r1.status, 200);
        assert!(r1.body.contains("\"status\":\"ok\""), "{}", r1.body);
        assert_eq!(r2.status, 200);
        assert!(client::parse_generate_reply(&r2.body).is_some(), "{}", r2.body);
        http.shutdown().expect("shutdown");

        // A request budget of 2: the second response announces
        // `Connection: close` and the socket really closes.
        let http = spin_with(
            &tiny_cfg(),
            87,
            ServerConfig::default(),
            HttpConfig {
                keep_alive_requests: 2,
                ..HttpConfig::default()
            },
        );
        let addr = http.addr();
        let mut conn = client::HttpConn::connect(addr).expect("connect");
        let r1 = conn.request("GET", "/healthz", None).expect("first");
        assert_eq!(r1.header("connection"), Some("keep-alive"));
        let r2 = conn.request("GET", "/healthz", None).expect("second");
        assert_eq!(r2.header("connection"), Some("close"));
        assert!(
            conn.request("GET", "/healthz", None).is_err(),
            "budget-exhausted connection must be closed"
        );
        http.shutdown().expect("shutdown");
    }

    #[test]
    fn connection_limit_answers_503_with_retry_after() {
        let http = spin_with(
            &tiny_cfg(),
            88,
            ServerConfig::default(),
            HttpConfig {
                max_conns: 1,
                ..HttpConfig::default()
            },
        );
        let addr = http.addr();
        // Occupy the single slot with a keep-alive connection; the
        // completed request proves the loop registered it.
        let mut held = client::HttpConn::connect(addr).expect("connect");
        let ok = held.request("GET", "/healthz", None).expect("held conn request");
        assert_eq!(ok.status, 200);
        let refused = client::get(addr, "/healthz").expect("over-limit reply");
        assert_eq!(refused.status, 503);
        assert!(refused.header("retry-after").is_some(), "503 must carry Retry-After");
        assert!(
            refused.body.contains("urn:slab:problem:overloaded"),
            "{}",
            refused.body
        );
        drop(held);
        http.shutdown().expect("shutdown");
    }

    #[test]
    fn poll_fallback_backend_serves_requests() {
        let http = spin_with(
            &tiny_cfg(),
            89,
            ServerConfig::default(),
            HttpConfig {
                force_poll: true,
                ..HttpConfig::default()
            },
        );
        let addr = http.addr();
        let ok = client::post(addr, "/v1/generate", r#"{"prompt": [5, 6], "max_new": 3}"#)
            .expect("generate");
        assert_eq!(ok.status, 200, "{}", ok.body);
        let (_, r) = client::parse_generate_reply(&ok.body).expect("parse");
        assert!(!r.rejected && !r.tokens.is_empty());
        let stats = http.shutdown().expect("shutdown");
        assert_eq!(stats.requests, 1);
    }
}
